"""Reproduce the paper's core comparison on one dataset (fast slice of
benchmarks/run.py, which sweeps all eight datasets and both depths).

HashNet vs {Equivalent-NN, Random-Edge-Removal, Low-Rank, Dark-Knowledge}
at compression 1/8 and 1/64 on the BASIC analogue — the paper's Table 1/2
columns.  Expected ordering (paper §6): at 1/64 HashNet >> everything;
at 1/8 HashNet ~ NN > RER > LRD.

    PYTHONPATH=src python examples/paper_mnist.py [--epochs 20] [--n 4000]
"""
import argparse

from repro.data import mnist_synthetic as D
from repro.paper import mlp, train as T

parser = argparse.ArgumentParser()
parser.add_argument("--epochs", type=int, default=15)
parser.add_argument("--n", type=int, default=3000)
parser.add_argument("--dataset", default="basic", choices=D.DATASETS)
args = parser.parse_args()

x, y = D.load(args.dataset, "train", n=args.n, seed=0)
xt, yt = D.load(args.dataset, "test", n=2000, seed=1)
cfg = T.TrainConfig(epochs=args.epochs, distill_temp=2.0, distill_alpha=0.7)
dims = (784, 500, 10)          # paper uses 1000 hidden units; 500 here

# compression-1 teacher for the DK variants
tspec = mlp.MLPSpec(dims, method="dense", dropout=0.3, input_dropout=0.1)
tparams, _ = T.fit(tspec, x, y, cfg=cfg)
teacher_err = T.evaluate(tspec, tparams, xt, yt)
print(f"teacher (compression 1): {teacher_err*100:.2f}%\n")

print(f"{'method':12s} {'1/8':>8s} {'1/64':>8s}")
for method in ("hashed", "hashed_dk", "nn", "dk", "rer", "lrd"):
    errs = []
    for c in (1 / 8, 1 / 64):
        r = T.run_method(method, dims, c, x, y, xt, yt, cfg,
                         teacher=(tspec, tparams))
        errs.append(r["test_err"])
    print(f"{method:12s} {errs[0]*100:7.2f}% {errs[1]*100:7.2f}%")
print("\npaper claim to check: the hashed rows degrade far less from "
      "1/8 -> 1/64 than every baseline.")
