"""Serve a small model with batched requests through the continuous-
batching engine: mixed prompt lengths, interleaved admissions, per-slot
cache positions, greedy + sampled generation.

    PYTHONPATH=src python examples/serve_lm.py
    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-4b --hashed
"""
import argparse
import time

import jax
import numpy as np

import repro.configs as C
from repro.configs.reduced import reduced
from repro.models import build
from repro.serving.engine import Engine, Request

parser = argparse.ArgumentParser()
parser.add_argument("--arch", default="qwen3-1.7b")
parser.add_argument("--hashed", action="store_true")
parser.add_argument("--requests", type=int, default=10)
parser.add_argument("--slots", type=int, default=4)
args = parser.parse_args()

cfg = reduced(C.get(args.arch))
if args.hashed:
    cfg = cfg.hashed_variant(1 / 8)
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
engine = Engine(model, params, slots=args.slots, max_len=128, eos_id=-1)
t0 = time.time()
for uid in range(args.requests):
    plen = int(rng.integers(3, 20))
    engine.submit(Request(
        uid=uid,
        prompt=rng.integers(2, cfg.vocab_size, plen).astype(np.int32),
        max_new_tokens=int(rng.integers(4, 12)),
        temperature=0.0 if uid % 2 == 0 else 0.8))
done = engine.run()
dt = time.time() - t0
total = sum(len(r.tokens) for r in done)
for r in sorted(done, key=lambda r: r.uid):
    print(f"req {r.uid:2d} ({len(r.prompt):2d}-token prompt) "
          f"-> {r.tokens}")
print(f"\n{len(done)} requests, {total} tokens, {dt:.1f}s "
      f"({total/dt:.1f} tok/s) on {args.slots} slots "
      f"[{cfg.name}]")
assert len(done) == args.requests
