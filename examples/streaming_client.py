"""Streaming client: consume incremental `RequestOutput` deltas from
`Engine.submit` handles.

Two consumption styles over one engine:

1. **Blocking iteration** — ``for delta in handle:`` drives engine
   ticks on demand until the request finishes (simplest for one
   request at a time).
2. **Poll-style multiplexing** — one ``eng.step()`` loop, draining
   every live handle's buffered deltas per tick (how a server
   multiplexes many concurrent streams).

Each request carries its own SamplingParams (greedy, seeded top-p,
stop-sequence) and the seeded requests are reproducible token-for-token
across reruns — the per-request counter-based PRNG streams survive
preemption and prefix caching bitwise.

With ``--trace-out FILE`` every request's lifecycle (queued wait,
prefill chunks, decode ticks) is recorded and exported as Chrome
trace-event JSON — open it at https://ui.perfetto.dev.

With ``--spec-draft RATIO`` (e.g. ``1/8``) the engine decodes
self-speculatively: a compressed draft derived off the same weights
proposes ``--spec-k`` tokens per tick and the full model verifies them
in one dispatch — the streams below are bitwise identical either way,
and the exit line reports the measured accept rate.

    PYTHONPATH=src python examples/streaming_client.py \
        [--trace-out stream_trace.json] [--spec-draft 1/8 --spec-k 4]
"""
import argparse

import jax
import numpy as np

import repro.configs as C
from repro.configs.reduced import reduced
from repro.models import build
from repro.obs import Tracer
from repro.serving import Engine, Request, SamplingParams

parser = argparse.ArgumentParser()
parser.add_argument("--arch", default="qwen3-1.7b")
parser.add_argument("--hashed", action="store_true")
parser.add_argument("--trace-out", default=None, metavar="FILE",
                    help="export per-request spans as Chrome "
                         "trace-event JSON (open in Perfetto)")
parser.add_argument("--spec-draft", default=None, metavar="POLICY",
                    help="self-speculative decoding: draft policy JSON "
                         "or ratio ('1/8') derived off the served "
                         "weights — output stays bitwise identical")
parser.add_argument("--spec-k", type=int, default=4,
                    help="draft proposal depth (with --spec-draft)")
args = parser.parse_args()

cfg = reduced(C.get(args.arch))
if args.hashed:
    cfg = cfg.hashed_variant(1 / 8)
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

draft = None
if args.spec_draft:
    from repro.serving.draft import build_draft
    _, dmodel, dparams = build_draft(cfg, params, args.spec_draft)
    draft = (dmodel, dparams)

tracer = Tracer(enabled=bool(args.trace_out))
eng = Engine(model, params, max_concurrency=2, max_len=128, eos_id=-1,
             prefix_cache=True, prefill_chunk=16, tracer=tracer,
             draft=draft, spec_k=args.spec_k)

# -- style 1: blocking iteration over one handle ---------------------------
prompt = rng.integers(2, cfg.vocab_size, 12).astype(np.int32)
handle = eng.submit(Request(
    uid=0, prompt=prompt,
    sampling=SamplingParams(temperature=0.8, top_p=0.9, seed=42,
                            max_tokens=8, logprobs=2)))
assert handle, "rejected?"
print("== blocking iteration (seeded top-p, top-2 logprobs) ==")
for delta in handle:
    pairs = "" if not delta.new_topk else \
        "  top2=" + str([[(t, round(lp, 2)) for t, lp in step]
                         for step in delta.new_topk])
    print(f"  += {delta.new_token_ids}{pairs}"
          + (f"  [{delta.finish_reason}]" if delta.done else ""))
print(f"  total logprob {handle.req.cumulative_logprob:.3f}")

# -- style 2: poll-style multiplexing --------------------------------------
# learn greedy's opening tokens so a stop-sequence provably triggers
probe = eng.submit(Request(uid=99, prompt=prompt.copy(),
                           sampling=SamplingParams(max_tokens=2)))
list(probe)                      # drive to completion
stop_seq = tuple(probe.req.tokens)

print("== multiplexed streams (greedy / seeded / stop-sequence) ==")
specs = [
    ("greedy", SamplingParams(max_tokens=6)),
    ("seeded", SamplingParams(temperature=1.0, top_k=50, seed=7,
                              max_tokens=6)),
    # greedy rerun with its own opening as the stop: ends early, "stop"
    ("stop", SamplingParams(max_tokens=6, stop=(stop_seq,))),
]
handles = []
for uid, (tag, sp) in enumerate(specs, start=1):
    h = eng.submit(Request(uid=uid, prompt=prompt.copy(), sampling=sp))
    assert h
    handles.append((tag, h))
while eng.pending():
    eng.step()
    for tag, h in handles:
        for d in h.drain():
            print(f"  {tag:6s} += {d.new_token_ids}"
                  + (f"  [{d.finish_reason}]" if d.done else ""))
stats = eng.stats()
print("finish reasons:", stats["finish_reasons"])
if "spec" in stats:
    sp = stats["spec"]
    print(f"spec decode: accept_rate={sp['accept_rate']:.3f} "
          f"mean_accept_len={sp['mean_accept_len']:.2f} (k={sp['k']})")
if args.trace_out:
    tracer.export(args.trace_out)
    print(f"trace -> {args.trace_out} (open at https://ui.perfetto.dev)")
