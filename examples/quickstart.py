"""Quickstart: the paper's technique in two minutes.

1. HashedNets MLP (paper-faithful): an 8x-compressed net matches the
   equivalent-size dense baseline on a synthetic MNIST analogue.
2. The same technique as a first-class config flag on a modern LLM
   architecture (qwen3 family, reduced size): param count drops ~8x,
   one train step runs, loss is finite.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.configs.reduced import reduced
from repro.data import mnist_synthetic as D
from repro.models import build
from repro.paper import mlp, train as ptrain

print("=== 1. HashedNets MLP (Chen et al. 2015) ===")
x, y = D.load("basic", "train", n=2000, seed=0)
xt, yt = D.load("basic", "test", n=1000, seed=1)
cfg = ptrain.TrainConfig(epochs=10)
dims = (784, 300, 10)

full = ptrain.run_method("nn", dims, 1.0, x, y, xt, yt, cfg)
print(f"dense   1/1 : err {full['test_err']*100:5.2f}%  "
      f"params {full['free_params']:,}")
for method in ("hashed", "nn", "rer", "lrd"):
    r = ptrain.run_method(method, dims, 1 / 8, x, y, xt, yt, cfg)
    print(f"{method:7s} 1/8 : err {r['test_err']*100:5.2f}%  "
          f"params {r['free_params']:,}")

print("\n=== 2. Hashed LLM (same technique, modern arch) ===")
dense_cfg = reduced(C.get("qwen3-1.7b"))
hashed_cfg = dense_cfg.hashed_variant(compression=1 / 8)
for cfg_i in (dense_cfg, hashed_cfg):
    m = build(cfg_i)
    params = m.init(jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree.leaves(params))
    tok = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                             cfg_i.vocab_size)
    loss, _ = jax.jit(m.train_loss)(params, {"tokens": tok, "targets": tok})
    print(f"{cfg_i.name:28s} params {n:10,}  loss {float(loss):.3f}")
print("\nhashed variant stores ~8x fewer projection parameters; "
      "same architecture, same code path.")
