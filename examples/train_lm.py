"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the full production stack — mesh, sharded state, data pipeline,
prefetcher, checkpointing, preemption guard — on a CPU-sized mesh.  The
same runner drives the 512-chip dry-run configs.

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --small    # CI-sized
    PYTHONPATH=src python examples/train_lm.py --hashed   # paper technique on
"""
import argparse

import repro.configs as C
from repro.configs.base import ArchConfig, register
from repro.launch import mesh as mesh_lib
from repro.launch.train import run

parser = argparse.ArgumentParser()
parser.add_argument("--small", action="store_true")
parser.add_argument("--hashed", action="store_true")
parser.add_argument("--steps", type=int, default=None)
parser.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = parser.parse_args()

# ~100M params: emb 2*32k*512=33M + 10 layers * ~6.8M = 68M  -> 101M
cfg = ArchConfig(
    name="lm-100m", family="dense", arch_kind="decoder",
    num_layers=10, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
    d_ff=2048, vocab_size=32000, rope_theta=10000.0,
    activation="swiglu", remat=False, dtype="float32",
)
if args.small:
    cfg = cfg.with_(num_layers=2, d_model=128, d_ff=512, vocab_size=2048,
                    name="lm-small")
if args.hashed:
    cfg = cfg.hashed_variant(1 / 8)

steps = args.steps or (40 if args.small else 300)
mesh = mesh_lib.single_device_mesh()
out = run(cfg, mesh, steps=steps, batch=4, seq=256,
          ckpt_dir=args.ckpt_dir, ckpt_every=100, lr=1e-3, log_every=10)
print(f"\ntrained {cfg.name}: loss {out['losses'][0]:.3f} -> "
      f"{out['losses'][-1]:.3f} over {out['final_step']} steps")
assert out["losses"][-1] < out["losses"][0], "loss must decrease"
