"""Differential serving fuzz: the prefix-sharing paged engine must be
observationally identical to the engine it replaced.

Harness 1 (differential): random workloads — prompt lengths including
shared / divergent / duplicated prefixes, priorities, max_new_tokens,
pool sizes down to oversubscription, chunked and monolithic prefill —
run through the engine with ``prefix_cache`` on vs off vs
``generate_batch``.  Greedy outputs must be token-identical in all
three, and ``leak_check`` (including refcounts) must pass after every
run with zero pages left beyond what the prefix tree retains.

Harness 1b (seeded sampling): the same differential property for
temperature > 0 — random per-request SamplingParams (temperature,
top-k, top-p, min-p, explicit and engine-drawn seeds) must be
token-identical across prefix on/off, every chunk size, pools down to
oversubscription (preemption-recompute), AND a fully-provisioned
``generate_batch`` — the counter-based (seed, position) PRNG streams
make sampled decode exactly as replayable as greedy.

Harness 1c (speculative decoding): self-speculative decode — a
compressed draft rung proposing k tokens per tick, verified by the
base model in one dispatch — must be bitwise token-identical to the
non-speculative engine under the SAME adversarial axes: mixed
greedy/sampled rows, prefix cache on/off, every chunk size, pools
down to oversubscription (preemption + draft-row rollback), and both
draft rungs (1/8, 1/16).  Both KV pools must audit leak-free after.

Harness 2 (stateful): a hypothesis ``RuleBasedStateMachine`` (falling
back to the conftest stub's deterministic random-walk mode when the real
package is absent) over raw ``PageAllocator`` + ``PagedKVCache``
refcount ops: alloc / share / COW / release / publish / pressure
sequences never double-free, never write to a page with refcount > 1,
and ``leak_check`` holds at every step.

Example counts scale with ``FUZZ_EXAMPLES`` / ``FUZZ_EXAMPLES_SLOW``
(CI runs the fast tier bounded, the slow tier with the full sweep).
"""
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from hypothesis.stateful import (RuleBasedStateMachine, invariant, rule)

import jax  # noqa: E402

from repro.configs.base import ArchConfig
from repro.models import build
from repro.serving.api import SamplingParams
from repro.serving.engine import Engine, Request, generate_batch
from repro.serving.paged_cache import PagedKVCache
from repro.serving.scheduler import SchedulerConfig

FAST_EXAMPLES = int(os.environ.get("FUZZ_EXAMPLES", "4"))
SLOW_EXAMPLES = int(os.environ.get("FUZZ_EXAMPLES_SLOW", "20"))

TINY = ArchConfig(
    name="tiny-fuzz", family="dense", arch_kind="decoder",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, remat=False, dtype="float32")

PAGE = 8
MAX_LEN = 64


@pytest.fixture(scope="module")
def tiny():
    m = build(TINY)
    return m, m.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tiny_drafts(tiny):
    """Two draft rungs off the same served weights (shallow + deep)."""
    from repro.serving.draft import build_draft
    _, params = tiny
    return {r: build_draft(TINY, params, r)[1:] for r in ("1/8", "1/16")}


# ---------------------------------------------------------------------------
# differential fuzz: prefix on == prefix off == generate_batch
# ---------------------------------------------------------------------------

def _workload(rng):
    """Prompts with shared system prefixes, exact duplicates, and
    divergent tails; per-request priorities; one max_new (so
    generate_batch stays comparable)."""
    n_req = int(rng.integers(2, 7))
    sys_len = int(rng.integers(0, 22))
    sys_p = rng.integers(2, TINY.vocab_size, size=sys_len).astype(np.int32)
    max_new = int(rng.integers(1, 7))
    prompts, prios = [], []
    for _ in range(n_req):
        r = rng.random()
        if prompts and r < 0.15:        # exact duplicate: boundary reuse
            prompts.append(prompts[int(rng.integers(len(prompts)))].copy())
        elif sys_len and r < 0.75:      # shared prefix, divergent tail
            tail = rng.integers(2, TINY.vocab_size,
                                size=int(rng.integers(1, 9))).astype(
                                    np.int32)
            prompts.append(np.concatenate([sys_p, tail]))
        else:                           # unrelated prompt
            prompts.append(rng.integers(
                2, TINY.vocab_size,
                size=int(rng.integers(1, 25))).astype(np.int32))
        prios.append(int(rng.integers(0, 3)))
    return prompts, prios, max_new


def _run(m, params, prompts, prios, max_new, *, prefix, chunk, num_pages,
         deadline=None, sampling=None, draft=None, spec_k=3,
         spec_adaptive=False, batched=True):
    eng = Engine(m, params, max_concurrency=3, max_len=MAX_LEN, eos_id=-1,
                 page_size=PAGE, num_pages=num_pages, prefix_cache=prefix,
                 prefill_chunk=chunk, draft=draft, spec_k=spec_k,
                 spec_adaptive=spec_adaptive, batched_prefill=batched,
                 scheduler=SchedulerConfig(policy="priority", max_queue=64,
                                           deadline_s=deadline))
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new,
                    sampling=sampling[i] if sampling else None,
                    priority=prios[i]) for i, p in enumerate(prompts)]
    accepted = {r.uid for r in reqs if eng.submit(r)}
    done = eng.run()
    # no leaked pages or refcounts: everything still held is exactly
    # what the prefix tree retains for future hits
    eng.kv.leak_check()
    retained = eng.kv.prefix.num_pages if eng.kv.prefix is not None else 0
    assert eng.kv.alloc.num_used == retained
    assert all(r is None for r in eng.rows) and not eng._prefilling
    if eng.spec is not None:        # draft pool: private, fully drained
        eng.spec.leak_check()
        assert eng.spec.kv.alloc.num_used == 0
    return ({r.uid: list(r.tokens) for r in done}, accepted,
            {r.uid: r.status for r in reqs}, eng)


def _check_one(tiny, seed):
    m, params = tiny
    rng = np.random.default_rng(seed)
    prompts, prios, max_new = _workload(rng)
    # pools from comfortable down to oversubscribed (3 rows want up to
    # ~15 pages); every prompt still individually fits (fits_ever)
    num_pages = int(rng.integers(8, 26))
    chunk = [None, 1, 3, PAGE][int(rng.integers(4))]

    on, acc_on, _, eng = _run(m, params, prompts, prios, max_new,
                              prefix=True, chunk=chunk,
                              num_pages=num_pages)
    off, acc_off, _, _ = _run(m, params, prompts, prios, max_new,
                              prefix=False, chunk=None,
                              num_pages=num_pages)
    # sequential (batched_prefill=False) arm: the batched ragged
    # dispatch (the default above) must be bitwise inert
    seq, acc_seq, _, _ = _run(m, params, prompts, prios, max_new,
                              prefix=True, chunk=chunk,
                              num_pages=num_pages, batched=False)
    assert acc_on == acc_off == acc_seq == set(range(len(prompts)))
    assert on == off, (on, off, chunk, num_pages)
    assert on == seq, (on, seq, chunk, num_pages)
    batch = generate_batch(m, params, prompts, max_new_tokens=max_new,
                           max_len=MAX_LEN, slots=3, eos_id=-1,
                           page_size=PAGE, num_pages=num_pages)
    assert batch == [on[uid] for uid in sorted(on)]
    return eng


@settings(max_examples=FAST_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_fuzz_prefix_on_off_batch_token_identical(tiny, seed):
    _check_one(tiny, seed)


@pytest.mark.slow
@settings(max_examples=SLOW_EXAMPLES, deadline=None)
@given(seed=st.integers(10 ** 6, 2 * 10 ** 6))
def test_fuzz_full_sweep(tiny, seed):
    """Full sweep: same property, fresh seed range, every chunk size,
    and the batched-prefill on/off axis against the same workload."""
    m, params = tiny
    rng = np.random.default_rng(seed)
    prompts, prios, max_new = _workload(rng)
    num_pages = int(rng.integers(8, 26))
    outs = []
    for prefix, chunk, batched in [
            (False, None, True), (True, None, True), (True, 1, True),
            (True, 3, True), (True, PAGE, True), (True, 3 * PAGE, True),
            (False, None, False), (True, 3, False),
            (True, 3 * PAGE, False)]:
        toks, acc, _, _ = _run(m, params, prompts, prios, max_new,
                               prefix=prefix, chunk=chunk,
                               num_pages=num_pages, batched=batched)
        outs.append(toks)
        assert acc == set(range(len(prompts)))
    assert all(o == outs[0] for o in outs[1:])


@settings(max_examples=max(FAST_EXAMPLES // 2, 2), deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_fuzz_deadlines_terminal_and_leak_free(tiny, seed):
    """With queue deadlines expiry is wall-clock (not comparable token
    for token) — but every request must still reach a terminal state
    and the pool must stay leak-free."""
    m, params = tiny
    rng = np.random.default_rng(seed)
    prompts, prios, max_new = _workload(rng)
    _, accepted, status, eng = _run(
        m, params, prompts, prios, max_new, prefix=True,
        chunk=[None, 3][int(rng.integers(2))],
        num_pages=int(rng.integers(8, 26)), deadline=0.0)
    for uid, stat in status.items():
        assert stat in ("done", "expired", "rejected"), (uid, stat)
    assert eng.stats()["done"] + eng.stats()["failed"] == len(prompts)


def test_fuzz_preemption_mid_chunked_prefill(tiny):
    """A pool sized so the youngest row — a long prompt mid-chunked-
    prefill — gets preempted: tokens still match the fully-provisioned
    run, the preemption counter sees the queued victim (it is neither
    done nor failed nor in a row when stats are read mid-run), the
    landed chunks are published so the resume hits the prefix tree, and
    nothing leaks."""
    m, params = tiny
    rng = np.random.default_rng(11)
    short = [rng.integers(2, TINY.vocab_size, size=6).astype(np.int32)
             for _ in range(2)]
    long_p = rng.integers(2, TINY.vocab_size, size=40).astype(np.int32)
    prompts = short + [long_p]          # long admitted last => youngest
    prios = [0] * len(prompts)

    # max_new keeps the shorts decoding (and growing pages) for the
    # whole 10-chunk prefill of the long prompt; 9 usable pages let the
    # long admit early, then run dry on the shorts' growth => the
    # youngest row (the long, mid-prefill) is preempted
    full, _, _, _ = _run(m, params, prompts, prios, 16, prefix=True,
                         chunk=4, num_pages=None)
    tight, _, _, eng = _run(m, params, prompts, prios, 16, prefix=True,
                            chunk=4, num_pages=10)
    assert tight == full
    # forced preemption mid-batched-prefill must also match the
    # sequential (batched off) tight-pool run bitwise
    tight_seq, _, _, _ = _run(m, params, prompts, prios, 16, prefix=True,
                              chunk=4, num_pages=10, batched=False)
    assert tight_seq == tight
    stats = eng.stats()
    assert stats["preemptions"] == eng._n_preempt > 0, \
        "pool sizing did not force a preemption"
    assert stats["requeued"] >= stats["preemptions"]
    # the long prompt shares nothing with the shorts, so any prefix hit
    # can only come from its own chunks published at preemption
    assert stats["hit_tokens"] > 0, \
        "mid-prefill preemption did not publish landed pages"
    assert stats["prefill_chunks"] > len(long_p) // 4


# ---------------------------------------------------------------------------
# seeded sampling: temperature > 0 is exactly as replayable as greedy
# ---------------------------------------------------------------------------

def _sampling_params(rng, max_new):
    """Random per-request SamplingParams: mixed greedy/sampled rows,
    truncation knobs, penalties, and both explicit and engine-drawn
    (seed=None) seeds."""
    t = [0.0, 0.7, 1.3][int(rng.integers(3))]
    return SamplingParams(
        temperature=t,
        top_k=[0, 5, 40][int(rng.integers(3))],
        top_p=[1.0, 0.9][int(rng.integers(2))],
        min_p=[0.0, 0.05][int(rng.integers(2))],
        repetition_penalty=[1.0, 1.2][int(rng.integers(2))],
        presence_penalty=[0.0, 0.3][int(rng.integers(2))],
        seed=None if rng.random() < 0.25 else int(rng.integers(10 ** 6)),
        max_tokens=max_new)


@settings(max_examples=FAST_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_fuzz_seeded_sampling_token_identical(tiny, seed):
    """Sampled decode (mixed greedy/temperature/top-k/top-p/min-p/
    penalty rows, explicit + engine-drawn seeds) is token-identical
    across prefix-cache on/off, chunked prefill sizes, oversubscribed
    pools (preemption-recompute), and a fully-provisioned
    generate_batch."""
    m, params = tiny
    rng = np.random.default_rng(seed)
    prompts, prios, max_new = _workload(rng)
    sps = [_sampling_params(rng, max_new) for _ in prompts]
    num_pages = int(rng.integers(8, 26))
    chunk = [None, 1, 3, PAGE][int(rng.integers(4))]

    on, acc_on, _, eng = _run(m, params, prompts, prios, max_new,
                              prefix=True, chunk=chunk,
                              num_pages=num_pages, sampling=sps)
    # the off arm also runs batched_prefill=False: one comparison pins
    # the prefix AND batched-ragged-prefill axes under sampled decode
    off, acc_off, _, _ = _run(m, params, prompts, prios, max_new,
                              prefix=False, chunk=None,
                              num_pages=num_pages, sampling=sps,
                              batched=False)
    assert acc_on == acc_off == set(range(len(prompts)))
    assert on == off, (on, off, chunk, num_pages)
    # fully-provisioned batch (no preemption possible): same tokens —
    # preempt-and-recompute replays the identical PRNG stream
    batch = generate_batch(m, params, prompts, max_new_tokens=max_new,
                           max_len=MAX_LEN, slots=3, eos_id=-1,
                           page_size=PAGE, sampling=sps)
    assert batch == [on[uid] for uid in sorted(on)]


@pytest.mark.slow
@settings(max_examples=SLOW_EXAMPLES, deadline=None)
@given(seed=st.integers(2 * 10 ** 6, 3 * 10 ** 6))
def test_fuzz_seeded_sampling_full_sweep(tiny, seed):
    """Slow tier: the sampled workload across every chunk size and the
    prefix on/off axis, one workload per example."""
    m, params = tiny
    rng = np.random.default_rng(seed)
    prompts, prios, max_new = _workload(rng)
    sps = [_sampling_params(rng, max_new) for _ in prompts]
    num_pages = int(rng.integers(8, 26))
    outs = []
    for prefix, chunk in [(False, None), (True, None), (True, 1),
                          (True, 3), (True, PAGE), (True, 3 * PAGE)]:
        toks, acc, _, _ = _run(m, params, prompts, prios, max_new,
                               prefix=prefix, chunk=chunk,
                               num_pages=num_pages, sampling=sps)
        outs.append(toks)
        assert acc == set(range(len(prompts)))
    assert all(o == outs[0] for o in outs[1:])


def test_fuzz_seeded_sampling_preemption_mid_prefill(tiny):
    """The PR-4 mid-chunked-prefill preemption scenario with sampled
    rows: the tight pool must reproduce the fully-provisioned sampled
    tokens, preemptions and all."""
    m, params = tiny
    rng = np.random.default_rng(11)
    short = [rng.integers(2, TINY.vocab_size, size=6).astype(np.int32)
             for _ in range(2)]
    long_p = rng.integers(2, TINY.vocab_size, size=40).astype(np.int32)
    prompts = short + [long_p]
    prios = [0] * len(prompts)
    sps = [SamplingParams(temperature=1.1, top_p=0.9, seed=50 + i,
                          max_tokens=16) for i in range(len(prompts))]

    full, _, _, _ = _run(m, params, prompts, prios, 16, prefix=True,
                         chunk=4, num_pages=None, sampling=sps)
    tight, _, _, eng = _run(m, params, prompts, prios, 16, prefix=True,
                            chunk=4, num_pages=10, sampling=sps)
    assert tight == full
    assert eng.stats()["preemptions"] > 0, \
        "pool sizing did not force a preemption"


# ---------------------------------------------------------------------------
# speculative decoding: spec on == spec off, bitwise
# ---------------------------------------------------------------------------

@settings(max_examples=FAST_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_fuzz_spec_decode_token_identical(tiny, tiny_drafts, seed):
    """Self-speculative decode is an implementation detail: the same
    workload (mixed greedy/sampled rows, explicit + engine-drawn seeds)
    through a speculative engine — random draft rung, proposal depth,
    chunk size, prefix on/off, pools down to oversubscription — emits
    bitwise the tokens of the non-speculative engine."""
    m, params = tiny
    rng = np.random.default_rng(seed)
    prompts, prios, max_new = _workload(rng)
    sps = [_sampling_params(rng, max_new) for _ in prompts]
    num_pages = int(rng.integers(8, 26))
    chunk = [None, 1, 3, PAGE][int(rng.integers(4))]
    prefix = bool(rng.integers(2))
    draft = tiny_drafts[("1/8", "1/16")[int(rng.integers(2))]]
    k = int(rng.integers(2, 5))

    base, acc_b, _, _ = _run(m, params, prompts, prios, max_new,
                             prefix=prefix, chunk=chunk,
                             num_pages=num_pages, sampling=sps)
    spec, acc_s, _, eng = _run(m, params, prompts, prios, max_new,
                               prefix=prefix, chunk=chunk,
                               num_pages=num_pages, sampling=sps,
                               draft=draft, spec_k=k)
    assert acc_b == acc_s == set(range(len(prompts)))
    assert spec == base, (chunk, num_pages, prefix, k)
    st_ = eng.stats()["spec"]
    assert st_["verify_dispatches"] > 0
    assert 0.0 <= st_["accept_rate"] <= 1.0


@pytest.mark.slow
@settings(max_examples=max(SLOW_EXAMPLES // 5, 2), deadline=None)
@given(seed=st.integers(3 * 10 ** 6, 4 * 10 ** 6))
def test_fuzz_spec_decode_full_sweep(tiny, tiny_drafts, seed):
    """Slow tier: one workload, the spec-off baseline, then a draft
    rung across the prefix/chunk axes must reproduce it.  Every spec
    engine jit-compiles its own propose/verify dispatches, so examples
    and arms are budgeted tighter than the other sweeps."""
    m, params = tiny
    rng = np.random.default_rng(seed)
    prompts, prios, max_new = _workload(rng)
    sps = [_sampling_params(rng, max_new) for _ in prompts]
    num_pages = int(rng.integers(8, 26))
    rung = ("1/8", "1/16")[seed % 2]
    k = int(rng.integers(2, 5))
    base, acc, _, _ = _run(m, params, prompts, prios, max_new,
                           prefix=True, chunk=None, num_pages=num_pages,
                           sampling=sps)
    assert acc == set(range(len(prompts)))
    for prefix, chunk in [(False, None), (True, 3), (True, PAGE)]:
        toks, acc, _, _ = _run(m, params, prompts, prios, max_new,
                               prefix=prefix, chunk=chunk,
                               num_pages=num_pages, sampling=sps,
                               draft=tiny_drafts[rung], spec_k=k)
        assert acc == set(range(len(prompts)))
        assert toks == base, (rung, prefix, chunk, num_pages)


@settings(max_examples=FAST_EXAMPLES, deadline=None)
@given(seed=st.integers(10 ** 6, 2 * 10 ** 6))
def test_fuzz_spec_adaptive_k_token_identical(tiny, tiny_drafts, seed):
    """The adaptive proposal-depth controller is a pure scheduling
    knob: with ``spec_adaptive`` the EWMA walks k inside [1, k_max]
    between ticks, yet every emitted token must stay bitwise the
    non-speculative engine's — acceptance is an equality check against
    the base sampler's own draws at whatever depth was proposed."""
    m, params = tiny
    rng = np.random.default_rng(seed)
    prompts, prios, max_new = _workload(rng)
    sps = [_sampling_params(rng, max_new) for _ in prompts]
    num_pages = int(rng.integers(8, 26))
    chunk = [None, 1, 3, PAGE][int(rng.integers(4))]
    prefix = bool(rng.integers(2))
    draft = tiny_drafts[("1/8", "1/16")[int(rng.integers(2))]]
    k_max = int(rng.integers(2, 5))

    base, acc_b, _, _ = _run(m, params, prompts, prios, max_new,
                             prefix=prefix, chunk=chunk,
                             num_pages=num_pages, sampling=sps)
    spec, acc_s, _, eng = _run(m, params, prompts, prios, max_new,
                               prefix=prefix, chunk=chunk,
                               num_pages=num_pages, sampling=sps,
                               draft=draft, spec_k=k_max,
                               spec_adaptive=True)
    assert acc_b == acc_s == set(range(len(prompts)))
    assert spec == base, (chunk, num_pages, prefix, k_max)
    st_ = eng.stats()["spec"]
    assert st_["adaptive"] and st_["k_max"] == k_max
    assert 1 <= eng.spec.k <= k_max
    assert 0.0 <= st_["accept_ewma"] <= 1.0


def test_fuzz_spec_decode_preemption_mid_prefill(tiny, tiny_drafts):
    """The mid-chunked-prefill preemption scenario with speculation on:
    draft rows roll back with their base rows, recompute replays both
    PRNG streams, and the tight pool reproduces the fully-provisioned
    non-speculative tokens."""
    m, params = tiny
    rng = np.random.default_rng(11)
    short = [rng.integers(2, TINY.vocab_size, size=6).astype(np.int32)
             for _ in range(2)]
    long_p = rng.integers(2, TINY.vocab_size, size=40).astype(np.int32)
    prompts = short + [long_p]
    prios = [0] * len(prompts)
    # longer decodes than the non-spec variant of this test: speculation
    # finishes rows in fewer ticks, so sustained growth (max_new=24) is
    # what actually exhausts an 11-page pool mid-decode
    sps = [SamplingParams(temperature=1.1, top_p=0.9, seed=50 + i,
                          max_tokens=24) for i in range(len(prompts))]

    full, _, _, _ = _run(m, params, prompts, prios, 24, prefix=True,
                         chunk=4, num_pages=None, sampling=sps)
    tight, _, _, eng = _run(m, params, prompts, prios, 24, prefix=True,
                            chunk=4, num_pages=11, sampling=sps,
                            draft=tiny_drafts["1/8"], spec_k=3)
    assert tight == full
    assert eng.stats()["preemptions"] > 0, \
        "pool sizing did not force a preemption"
    # rejected proposals were actually rolled back along the way
    assert eng.metrics.snapshot()["spec.rollback_tokens"] > 0


# ---------------------------------------------------------------------------
# stateful refcount machine: alloc / share / COW / release / publish
# ---------------------------------------------------------------------------

class PagedRefcountMachine(RuleBasedStateMachine):
    """Random walks over the raw cache bookkeeping.  The engine is not
    involved: rules poke admit/share, decode growth (with the COW
    guard), publishing rows to the prefix tree, releases, and allocator
    pressure (LRU reclaim) directly, asserting the write-privacy
    invariant and full refcount accounting after every step."""

    PS, ROWS, MAXP, PAGES = 4, 4, 5, 18

    def __init__(self):
        super().__init__()
        self.kv = PagedKVCache(self.PAGES, self.PS, self.ROWS, self.MAXP,
                               prefix_cache=True)
        self.toks = {}

    def _publish(self, row):
        n = int(self.kv.lengths[row])
        self.kv.index_row(row, np.asarray(self.toks[row][:n], np.int32), n)

    @rule(row=st.integers(0, 3), tlen=st.integers(1, 18),
          pat=st.integers(0, 2), stride=st.integers(1, 2))
    def admit(self, row, tlen, pat, stride):
        if row in self.kv.row_pages:
            return
        # tiny alphabet + patterned ids: prefix collisions are the norm
        ids = [(pat + i * stride) % 4 for i in range(tlen)]
        if self.kv.admit_row(row, tlen, token_ids=np.asarray(ids,
                                                             np.int32)):
            # the engine gathers and unpins in the same tick; mirror it
            self.kv.drop_tail_ref(row)
            self.toks[row] = ids

    @rule(row=st.integers(0, 3), tok=st.integers(0, 3))
    def decode_grow(self, row, tok):
        if row not in self.kv.row_pages:
            return
        status = self.kv.ensure_decode_room(row)
        assert status in ("ok", "oom", "full")
        if status != "ok":
            return
        # THE invariant: the slot about to be written is private —
        # ensure_decode_room must have COW'd any shared target
        j = int(self.kv.lengths[row]) // self.PS
        page = self.kv.row_pages[row][j]
        assert self.kv.alloc.refcount(page) == 1, \
            f"write target page {page} has refcount > 1"
        self.kv.pending_copies.clear()      # host-only: copies are virtual
        self.kv.advance(row)
        self.toks[row].append(tok)

    @rule(row=st.integers(0, 3))
    def publish(self, row):
        if row in self.kv.row_pages:
            self._publish(row)

    @rule(row=st.integers(0, 3), pub=st.booleans())
    def release(self, row, pub):
        if row not in self.kv.row_pages:
            return
        if pub:                         # finish/preempt publish-then-free
            self._publish(row)
        self.kv.release_row(row)
        del self.toks[row]

    @rule(need=st.integers(1, 6))
    def pressure(self, need):
        """Allocator pressure: reclaim LRU tree pages; whatever is
        granted is handed straight back."""
        got = self.kv._alloc_or_evict(need)
        if got is not None:
            self.kv.alloc.free(got)

    @invariant()
    def no_leaks(self):
        self.kv.leak_check()


TestPagedRefcountMachine = PagedRefcountMachine.TestCase
try:  # real hypothesis: bound the search; the stub ignores the attribute
    TestPagedRefcountMachine.settings = settings(max_examples=15,
                                                 deadline=None)
except Exception:  # pragma: no cover
    pass


def test_allocator_refcount_misuse_raises():
    """Double-free / foreign-free / unallocated-incref all raise."""
    from repro.serving.paged_cache import PageAllocator
    alloc = PageAllocator(6)
    (page,) = alloc.alloc(1)
    alloc.incref(page)
    assert alloc.refcount(page) == 2
    assert not alloc.decref(page)
    assert alloc.decref(page)           # freed on the last holder
    with pytest.raises(ValueError):
        alloc.decref(page)              # double free
    with pytest.raises(ValueError):
        alloc.incref(page)              # incref on a free page
    with pytest.raises(ValueError):
        alloc.free([0])                 # trash page was never allocated
