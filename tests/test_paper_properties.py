"""Property-based tests (hypothesis) for the paper's mathematical claims.

- Eq. 4 == Eq. 5: weight sharing == feature hashing (exact, any shape).
- Eq. 1: hashed inner products are unbiased (statistical, over seeds).
- Eq. 12: autodiff dw == the paper's explicit scatter-sum formula.
- Uniformity: bucket occupancy is approximately uniform.
- Spec invariants: real_param_count ~= compression * virtual_size.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import HashedSpec, feature_hash, hashed, hashing, init

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def specs(draw, max_dim=96):
    rows = draw(st.integers(4, max_dim))
    cols = draw(st.integers(4, max_dim))
    comp = draw(st.sampled_from([1.0, 0.5, 0.25, 0.125, 1 / 16]))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    return HashedSpec((rows, cols), comp, mode="element", seed=seed)


@pytest.mark.slow
@given(spec=specs(), batch=st.integers(1, 5))
@settings(**SETTINGS)
def test_eq4_equals_eq5(spec, batch):
    """z = x @ V  ==  w^T phi_i(x) for every output i (paper §4.3)."""
    key = jax.random.PRNGKey(spec.seed % 1000)
    w = init(key, spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, spec.rows))
    z_ws = hashed.matmul(x, w, spec, path="materialize")       # Eq. 4
    z_fh = feature_hash.matmul_via_feature_hashing(x, w, spec)  # Eq. 5
    np.testing.assert_allclose(np.asarray(z_ws), np.asarray(z_fh),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
@given(spec=specs())
@settings(**SETTINGS)
def test_eq12_gradient(spec):
    """jax.grad dw == paper Eq. 12 explicit scatter-sum."""
    key = jax.random.PRNGKey(3)
    w = init(key, spec)
    x = jax.random.normal(jax.random.PRNGKey(4), (3, spec.rows))
    g = jax.random.normal(jax.random.PRNGKey(5), (3, spec.cols))

    def loss(w):
        return jnp.sum(hashed.matmul(x, w, spec, path="materialize") * g)

    dw_auto = jax.grad(loss)(w)
    # Eq. 12: dw_k = sum_{i,j: h(i,j)=k} xi(i,j) * (x^T g)[i, j]
    gv = x.T @ g
    i = jnp.arange(spec.rows)[:, None]
    j = jnp.arange(spec.cols)[None, :]
    idx, sgn = hashed.element_indices(spec, i, j)
    dw_explicit = jnp.zeros((spec.num_buckets,)).at[idx.ravel()].add(
        (gv * sgn).ravel())
    np.testing.assert_allclose(np.asarray(dw_auto),
                               np.asarray(dw_explicit), rtol=1e-4,
                               atol=1e-4)


def test_eq1_unbiased_inner_product():
    """E_phi[phi(x)^T phi(x')] == x^T x' over random hash seeds (Eq. 1)."""
    rng = np.random.default_rng(0)
    d, k = 64, 16
    x = rng.standard_normal(d).astype(np.float32)
    xp = rng.standard_normal(d).astype(np.float32)
    true = float(x @ xp)
    vals = []
    for seed in range(400):
        idx, sgn = feature_hash.index_map(d, k, seed)
        phi_x = np.zeros(k, np.float32)
        phi_xp = np.zeros(k, np.float32)
        np.add.at(phi_x, np.asarray(idx), np.asarray(sgn) * x)
        np.add.at(phi_xp, np.asarray(idx), np.asarray(sgn) * xp)
        vals.append(float(phi_x @ phi_xp))
    est = np.mean(vals)
    se = np.std(vals) / np.sqrt(len(vals))
    assert abs(est - true) < 4 * se + 1e-3, (est, true, se)


def test_bucket_uniformity():
    """h is approximately uniform: chi-square over buckets within 5x the
    99.9% quantile for a few (shape, seed) combos."""
    for seed in (0, 7, 12345):
        spec = HashedSpec((256, 256), 0.125, mode="element", seed=seed)
        i = jnp.arange(256)[:, None]
        j = jnp.arange(256)[None, :]
        idx, _ = hashed.element_indices(spec, i, j)
        counts = np.bincount(np.asarray(idx).ravel(),
                             minlength=spec.num_buckets)
        expected = 256 * 256 / spec.num_buckets
        chi2 = float(((counts - expected) ** 2 / expected).sum())
        # dof ~ num_buckets; loose bound (5x) to keep the test stable
        assert chi2 < 5 * spec.num_buckets, (seed, chi2, spec.num_buckets)


def test_sign_hash_balanced():
    i = jnp.arange(512)[:, None]
    j = jnp.arange(512)[None, :]
    sgn = hashing.sign_hash(i, j, 9)
    frac = float(jnp.mean((sgn > 0).astype(jnp.float32)))
    assert 0.49 < frac < 0.51, frac


@given(spec=specs())
@settings(**SETTINGS)
def test_param_budget(spec):
    got = spec.real_param_count()
    want = spec.compression * spec.virtual_size
    assert got <= max(want * 1.05, spec.n_panels), (got, want)
    assert got >= want * 0.5


@given(st.integers(0, 2**31 - 1), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_derive_seed_deterministic_and_mixing(a, b):
    s1 = hashing.derive_seed(a, b)
    s2 = hashing.derive_seed(a, b)
    assert s1 == s2
    assert 0 <= s1 < 2 ** 32
    if a != b:
        assert hashing.derive_seed(a, b) != hashing.derive_seed(b, a) or a == b


def test_grad_compression_sketch_unbiased():
    """Hashed-space gradient sketch: EF residual decays the error; the
    sketch roundtrip is unbiased over seeds."""
    from repro.train import grad_compress as gc
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal(512).astype(np.float32))
    approx = []
    for seed in range(200):
        spec = gc.SketchSpec(512, 64, seed)
        G = gc.sketch_compress(g, spec)
        approx.append(np.asarray(gc.sketch_decompress(G, spec, g.shape)))
    est = np.mean(approx, axis=0)
    err = np.abs(est - np.asarray(g)).mean()
    assert err < 0.35, err  # collisions add variance, not bias


def test_grad_compression_error_feedback_converges():
    """With error feedback, the ACCUMULATED compressed updates track the
    accumulated true gradient (the sketched-SGD guarantee)."""
    from repro.train import grad_compress as gc
    rng = np.random.default_rng(2)
    residual = jnp.zeros((256,), jnp.float32)
    total_true = np.zeros(256)
    total_sent = np.zeros(256)
    for step in range(50):
        g = jnp.asarray(rng.standard_normal(256).astype(np.float32))
        sent, residual = gc.sketch_roundtrip(g, residual, 0.25, seed=11)
        total_true += np.asarray(g)
        total_sent += np.asarray(sent)
    # residual bounds the gap
    gap = np.abs(total_true - total_sent).max()
    res = float(jnp.abs(residual).max())
    assert gap <= res + 1e-3, (gap, res)


def test_int8_roundtrip_error_feedback():
    from repro.train import grad_compress as gc
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
    r = jnp.zeros_like(g)
    approx, r2 = gc.int8_roundtrip(g, r)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.abs(approx - g).max()) <= scale * 0.5 + 1e-6
    np.testing.assert_allclose(np.asarray(approx + r2), np.asarray(g),
                               rtol=1e-5, atol=1e-5)
