"""Tensor-parallel serving tier (host-simulated mesh).

Run with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; with
one device the whole module skips (CI gives this tier its own job).

What sharded serving must preserve — bitwise:

- **kernel parity**: attention is per-head independent, so a head
  shard of the paged decode kernel / ragged flash-prefill kernel over
  a KV-head-sharded page pool, concatenated across shards, equals the
  single-device output exactly (ref and pallas-interpret impls; both
  manual slicing and a real ``shard_map``),
- **engine identity**: ``Engine(mesh=...)`` is observationally
  identical to the single-device engine across the repo's existing
  differential-fuzz axes — prefix cache on/off, chunk sizes, batched
  prefill on/off, pools down to oversubscription (preemption), seeded
  sampling, both attention impls,
- **placement**: hashed banks shard over "model", dense weights
  replicate (the o-projection consumes an exact all-gather, never a
  psum), the page pool shards on the KV-head axis; head counts not
  divisible by tp degrade to full replication and still match,
- **guards**: mesh requires the paged backend and excludes the
  speculative draft; ``engine.shard.*`` metrics exist only on mesh
  engines.
"""
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

if jax.device_count() < 2:
    pytest.skip(
        "needs a multi-device mesh: run with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8",
        allow_module_level=True)

from jax.experimental.shard_map import shard_map  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs.base import ArchConfig  # noqa: E402
from repro.launch.mesh import make_serving_mesh  # noqa: E402
from repro.models import build  # noqa: E402
from repro.serving.api import SamplingParams  # noqa: E402
from repro.serving.engine import Engine, Request  # noqa: E402
from repro.serving.scheduler import SchedulerConfig  # noqa: E402

SHARD_EXAMPLES = int(os.environ.get("SHARD_EXAMPLES", "3"))

TINY = ArchConfig(
    name="tiny-shard", family="dense", arch_kind="decoder",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, remat=False, dtype="float32")

PAGE = 8
MAX_LEN = 64


@pytest.fixture(scope="module")
def tiny():
    m = build(TINY)
    return m, m.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tiny_hashed():
    cfg = TINY.hashed_variant(0.25)
    m = build(cfg)
    return m, m.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# kernel parity: head shards concatenate to the full output, bitwise
# ---------------------------------------------------------------------------

def _rand_paged(rng, *, b=3, hq=4, hkv=2, d=16, ps=8, npages=13, maxp=4):
    q = rng.standard_normal((b, hq, d)).astype(np.float32)
    pk = rng.standard_normal((npages, ps, hkv, d)).astype(np.float32)
    pv = rng.standard_normal((npages, ps, hkv, d)).astype(np.float32)
    # distinct physical pages per row; page 0 stays the trash page
    pages = rng.permutation(np.arange(1, npages))[:b * maxp]
    table = pages.reshape(b, maxp).astype(np.int32)
    lengths = rng.integers(1, ps * maxp + 1, size=b).astype(np.int32)
    return q, pk, pv, table, lengths


def _decode_fn(impl):
    if impl == "ref":
        from repro.kernels.ref import paged_attention_ref
        return paged_attention_ref
    from repro.kernels.paged_attention import paged_decode_attention
    return paged_decode_attention


def _prefill_fn(impl):
    if impl == "ref":
        from repro.kernels.ref import paged_prefill_ref
        return paged_prefill_ref
    from repro.kernels.flash_prefill import paged_prefill_attention
    return paged_prefill_attention


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_paged_decode_manual_head_slices_concat(impl):
    """GQA head shard by hand: q heads [2s:2s+2] with kv head [s]
    produce exactly the matching slice of the full output."""
    fn = _decode_fn(impl)
    rng = np.random.default_rng(0)
    q, pk, pv, table, lengths = _rand_paged(rng)
    full = np.asarray(fn(q, pk, pv, table, lengths, 0))
    parts = [np.asarray(fn(q[:, 2 * s:2 * s + 2],
                           pk[:, :, s:s + 1], pv[:, :, s:s + 1],
                           table, lengths, 0)) for s in range(2)]
    np.testing.assert_array_equal(np.concatenate(parts, axis=1), full)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_paged_decode_shard_map_parity(impl):
    fn = _decode_fn(impl)
    rng = np.random.default_rng(1)
    q, pk, pv, table, lengths = _rand_paged(rng)
    full = np.asarray(fn(q, pk, pv, table, lengths, 0))
    mesh = make_serving_mesh(2)
    sharded = shard_map(
        lambda q_, k_, v_, t_, l_, w_: fn(q_, k_, v_, t_, l_, w_),
        mesh=mesh,
        in_specs=(P(None, "model", None), P(None, None, "model", None),
                  P(None, None, "model", None), P(None, None), P(None),
                  P()),
        out_specs=P(None, "model", None), check_rep=False)
    got = np.asarray(sharded(q, pk, pv, table, lengths, jnp.int32(0)))
    np.testing.assert_array_equal(got, full)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_paged_prefill_shard_map_parity(impl):
    fn = _prefill_fn(impl)
    rng = np.random.default_rng(2)
    b, s, hq, hkv, d, ps, maxp = 3, 8, 4, 2, 16, 8, 4
    q = rng.standard_normal((b, s, hq, d)).astype(np.float32)
    pk = rng.standard_normal((13, ps, hkv, d)).astype(np.float32)
    pv = rng.standard_normal((13, ps, hkv, d)).astype(np.float32)
    table = rng.permutation(np.arange(1, 13))[:b * maxp] \
        .reshape(b, maxp).astype(np.int32)
    starts = rng.integers(0, ps, size=b).astype(np.int32)
    counts = rng.integers(1, s + 1, size=b).astype(np.int32)
    full = np.asarray(fn(q, pk, pv, table, starts, counts, 0))
    mesh = make_serving_mesh(2)
    sharded = shard_map(
        lambda q_, k_, v_, t_, s_, c_, w_: fn(q_, k_, v_, t_, s_, c_, w_),
        mesh=mesh,
        in_specs=(P(None, None, "model", None),
                  P(None, None, "model", None),
                  P(None, None, "model", None),
                  P(None, None), P(None), P(None), P()),
        out_specs=P(None, None, "model", None), check_rep=False)
    got = np.asarray(sharded(q, pk, pv, table, starts, counts,
                             jnp.int32(0)))
    if impl == "ref":
        np.testing.assert_array_equal(got, full)
    else:
        # interpret mode lowers the kernel body through XLA:CPU, whose
        # within-head reduction strategy can depend on the head extent
        # (n_kv=1 per shard vs 2 unsharded) — 1-ulp drift, not a
        # sharding error.  On TPU the per-head blocks are independent.
        np.testing.assert_allclose(got, full, rtol=3e-7, atol=3e-7)


# ---------------------------------------------------------------------------
# engine differential fuzz: mesh on == mesh off, bitwise
# ---------------------------------------------------------------------------

def _workload(rng, vocab):
    n_req = int(rng.integers(2, 6))
    sys_len = int(rng.integers(0, 22))
    sys_p = rng.integers(2, vocab, size=sys_len).astype(np.int32)
    max_new = int(rng.integers(1, 7))
    prompts, prios = [], []
    for _ in range(n_req):
        r = rng.random()
        if prompts and r < 0.15:
            prompts.append(prompts[int(rng.integers(len(prompts)))].copy())
        elif sys_len and r < 0.75:
            tail = rng.integers(2, vocab, size=int(
                rng.integers(1, 9))).astype(np.int32)
            prompts.append(np.concatenate([sys_p, tail]))
        else:
            prompts.append(rng.integers(
                2, vocab, size=int(rng.integers(1, 25))).astype(np.int32))
        prios.append(int(rng.integers(0, 3)))
    return prompts, prios, max_new


def _sampling_params(rng, max_new):
    t = [0.0, 0.7, 1.3][int(rng.integers(3))]
    return SamplingParams(
        temperature=t,
        top_k=[0, 5, 40][int(rng.integers(3))],
        top_p=[1.0, 0.9][int(rng.integers(2))],
        seed=int(rng.integers(10 ** 6)),
        max_tokens=max_new)


def _run(m, params, prompts, prios, max_new, *, mesh, prefix, chunk,
         num_pages, sampling=None, batched=True, impl="ref"):
    eng = Engine(m, params, max_concurrency=3, max_len=MAX_LEN,
                 eos_id=-1, page_size=PAGE, num_pages=num_pages,
                 prefix_cache=prefix, prefill_chunk=chunk,
                 batched_prefill=batched, attn_impl=impl, mesh=mesh,
                 scheduler=SchedulerConfig(policy="priority",
                                           max_queue=64))
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new,
                    sampling=sampling[i] if sampling else None,
                    priority=prios[i]) for i, p in enumerate(prompts)]
    accepted = {r.uid for r in reqs if eng.submit(r)}
    eng.run()
    eng.kv.leak_check()
    assert accepted == set(range(len(prompts)))
    return {r.uid: list(r.tokens) for r in reqs}, eng


@settings(max_examples=SHARD_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_fuzz_sharded_token_identical(tiny, seed):
    """The existing differential-fuzz axes (prefix on/off, chunk sizes,
    batched prefill, pools to oversubscription, seeded sampling) with
    the mesh as one more arm: tp=2 and tp=4 (non-divisible kv heads ->
    degrades to replication) both reproduce the single-device tokens."""
    m, params = tiny
    rng = np.random.default_rng(seed)
    prompts, prios, max_new = _workload(rng, TINY.vocab_size)
    sps = [_sampling_params(rng, max_new) for _ in prompts] \
        if rng.random() < 0.5 else None
    num_pages = int(rng.integers(10, 26))
    chunk = [None, 3, PAGE][int(rng.integers(3))]
    prefix = bool(rng.integers(2))
    batched = bool(rng.integers(2))
    kw = dict(prefix=prefix, chunk=chunk, num_pages=num_pages,
              sampling=sps, batched=batched)
    base, _ = _run(m, params, prompts, prios, max_new, mesh=None, **kw)
    for tp in (2, 4):
        got, _ = _run(m, params, prompts, prios, max_new,
                      mesh=make_serving_mesh(tp), **kw)
        assert got == base, (tp, chunk, num_pages, prefix, batched)


def test_sharded_pallas_impl_token_identical(tiny):
    """The pallas (interpret-mode) kernels inside shard_map reproduce
    the single-device pallas tokens."""
    m, params = tiny
    rng = np.random.default_rng(5)
    prompts, prios, max_new = _workload(rng, TINY.vocab_size)
    kw = dict(prefix=True, chunk=PAGE, num_pages=None, impl="pallas")
    base, _ = _run(m, params, prompts, prios, max_new, mesh=None, **kw)
    got, _ = _run(m, params, prompts, prios, max_new,
                  mesh=make_serving_mesh(2), **kw)
    assert got == base


def test_sharded_hashed_banks_token_identical(tiny_hashed):
    """Hashed banks shard over "model" (materialize is a pure gather —
    exact); the compressed config matches bitwise too."""
    m, params = tiny_hashed
    rng = np.random.default_rng(6)
    prompts, prios, max_new = _workload(rng, TINY.vocab_size)
    kw = dict(prefix=True, chunk=None, num_pages=None)
    base, _ = _run(m, params, prompts, prios, max_new, mesh=None, **kw)
    got, _ = _run(m, params, prompts, prios, max_new,
                  mesh=make_serving_mesh(2), **kw)
    assert got == base


def test_sharded_preemption_token_identical(tiny):
    """Oversubscribed pool forces preemption + recompute through the
    sharded gather/copy paths: tokens still match the single-device
    tight pool AND the fully-provisioned run."""
    m, params = tiny
    rng = np.random.default_rng(11)
    short = [rng.integers(2, TINY.vocab_size, size=6).astype(np.int32)
             for _ in range(2)]
    long_p = rng.integers(2, TINY.vocab_size, size=40).astype(np.int32)
    prompts = short + [long_p]
    prios = [0] * len(prompts)
    full, _ = _run(m, params, prompts, prios, 16, mesh=None,
                   prefix=True, chunk=4, num_pages=None)
    tight, _ = _run(m, params, prompts, prios, 16, mesh=None,
                    prefix=True, chunk=4, num_pages=10)
    tight_mesh, eng = _run(m, params, prompts, prios, 16,
                           mesh=make_serving_mesh(2),
                           prefix=True, chunk=4, num_pages=10)
    assert tight == full and tight_mesh == tight
    assert eng.stats()["preemptions"] > 0, \
        "pool sizing did not force a preemption"


# ---------------------------------------------------------------------------
# placement, metrics, guards
# ---------------------------------------------------------------------------

def _flat_axes(spec):
    out = []
    for ax in tuple(spec):
        if isinstance(ax, (tuple, list)):
            out.extend(ax)
        elif ax is not None:
            out.append(ax)
    return out


def test_sharded_placement_and_metrics(tiny_hashed):
    """Pool shards on the KV-head axis, banks shard over "model",
    dense weights replicate; engine.shard.* gauges/counters exist and
    count dispatches — and only on mesh engines."""
    m, params = tiny_hashed
    mesh = make_serving_mesh(2)
    eng = Engine(m, params, max_concurrency=2, max_len=MAX_LEN,
                 eos_id=-1, page_size=PAGE, mesh=mesh)
    # page pool: axis 3 of (nl, P, ps, Hkv, hd) on "model"
    for leaf in (eng.pages["k"], eng.pages["v"]):
        s = tuple(leaf.sharding.spec)
        assert len(s) > 3 and s[3] == "model", s
    # params: banks sharded, everything else replicated
    bank_axes, dense_axes = [], []
    specs = m.pspecs()

    def collect(spec, p):
        axes = _flat_axes(p.sharding.spec)
        is_bank = any(isinstance(ax, (tuple, list)) and "tp" in ax
                      for ax in spec)
        (bank_axes if is_bank else dense_axes).append(axes)
        return p

    jax.tree.map(collect, specs, eng.params,
                 is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert bank_axes, "hashed config produced no bank leaves"
    assert all(axes == ["model"] for axes in bank_axes), bank_axes
    assert all(axes == [] for axes in dense_axes), \
        "a dense weight was sharded (o-proj psum would break identity)"

    snap = eng.metrics.snapshot()
    assert snap["engine.shard.devices"] == 2
    assert snap["engine.shard.tp"] == 2
    eng.submit(Request(uid=0, prompt=np.arange(12, dtype=np.int32) + 2,
                       max_new_tokens=4))
    eng.run()
    snap = eng.metrics.snapshot()
    assert snap["engine.shard.decode_dispatches"] > 0
    assert snap["engine.shard.prefill_dispatches"] > 0

    plain = Engine(m, params, max_concurrency=2, max_len=MAX_LEN,
                   eos_id=-1, page_size=PAGE)
    assert not any(k.startswith("engine.shard.")
                   for k in plain.metrics.snapshot())


def test_mesh_guards(tiny):
    m, params = tiny
    mesh = make_serving_mesh(2)
    with pytest.raises(ValueError, match="paged"):
        Engine(m, params, max_concurrency=2, max_len=MAX_LEN,
               eos_id=-1, paged=False, mesh=mesh)
    from repro.serving.draft import build_draft
    _, dm, dp = build_draft(TINY, params, "1/8")
    with pytest.raises(ValueError, match="speculative"):
        Engine(m, params, max_concurrency=2, max_len=MAX_LEN,
               eos_id=-1, page_size=PAGE, draft=(dm, dp), mesh=mesh)


def test_make_serving_mesh_rejects_oversized():
    with pytest.raises(ValueError, match="devices"):
        make_serving_mesh(jax.device_count() + 1)
