"""Multi-model engine: N registry models on one scheduler + one page
pool must be observationally identical to N dedicated engines.

- fuzz-pinned identity: a two-tenant engine (dense + hashed configs,
  quota on one tenant, mixed greedy/seeded-sampled rows, bursty
  submission order) emits bitwise the tokens of two dedicated
  single-model engines given the same requests,
- page quotas bound a tenant's distinct-page footprint at every tick
  while the workload still completes,
- tenant lanes: a hot tenant's backlog never head-of-line-blocks the
  other tenant's admission (both make progress inside the burst),
- per-tenant scheduler counters (``sched.tenant.<model>.*``) balance,
  cancel_queued stamps the "cancelled" terminal, and queue-deadline
  expiry on the shared pool leaves both KV caches leak-free,
- Scheduler model-filter primitives (``drain`` / ``expire`` /
  ``pop_admissible`` / ``depth_by_model``) respect tenant boundaries.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ArchConfig
from repro.models import build
from repro.serving.api import SamplingParams
from repro.serving.engine import Engine, Request
from repro.serving.multi_model import MultiModelEngine
from repro.serving.scheduler import Scheduler, SchedulerConfig

TINY = ArchConfig(
    name="tiny-mm", family="dense", arch_kind="decoder",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, remat=False, dtype="float32")

PAGE = 8
MAX_LEN = 64
FAST_EXAMPLES = int(__import__("os").environ.get("FUZZ_EXAMPLES", "4"))


@pytest.fixture(scope="module")
def packs():
    """(model, params) per tenant: dense + hashed variants."""
    out = {}
    for tag, cfg in (("dense", TINY),
                     ("hashed", TINY.hashed_variant(0.25))):
        m = build(cfg)
        out[tag] = (m, m.init(jax.random.PRNGKey(0)))
    return out


def _mm(packs, *, quota=None, slots=2, deadline=None, prefix=False,
        max_queue=64):
    mm = MultiModelEngine(
        page_size=PAGE,
        scheduler=SchedulerConfig(max_queue=max_queue,
                                  deadline_s=deadline))
    for tag, (m, p) in packs.items():
        mm.add_model(tag, m, p, slots=slots, max_len=MAX_LEN,
                     eos_id=-1, seed=0, prefix_cache=prefix,
                     page_quota=quota if tag == "hashed" else None)
    return mm


def _workload(rng, n):
    """(model, prompt, SamplingParams) triples, mixed greedy/sampled."""
    work = []
    for i in range(n):
        prompt = rng.integers(2, TINY.vocab_size,
                              size=int(rng.integers(3, 16))).astype(
                                  np.int32)
        if rng.random() < 0.4:
            sp = SamplingParams(max_tokens=int(rng.integers(2, 7)))
        else:
            sp = SamplingParams(temperature=0.8, top_p=0.9,
                                seed=500 + i,
                                max_tokens=int(rng.integers(2, 7)))
        work.append((("dense", "hashed")[int(rng.integers(2))],
                     prompt, sp))
    return work


# ---------------------------------------------------------------------------
# identity: shared pool + shared scheduler is bitwise inert
# ---------------------------------------------------------------------------

@settings(max_examples=FAST_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_fuzz_two_tenants_token_identical_to_dedicated(packs, seed):
    rng = np.random.default_rng(seed)
    work = _workload(rng, int(rng.integers(4, 10)))
    quota = int(rng.integers(10, 20))

    mm = _mm(packs, quota=quota)
    for uid, (tag, prompt, sp) in enumerate(work):
        assert mm.submit(Request(uid=uid, prompt=prompt.copy(),
                                 sampling=sp), model=tag)
    done = mm.run()
    got = {r.uid: list(r.tokens) for r in done}

    want = {}
    for tenant in ("dense", "hashed"):
        m, p = packs[tenant]
        eng = Engine(m, p, slots=2, max_len=MAX_LEN, eos_id=-1,
                     page_size=PAGE, seed=0,
                     scheduler=SchedulerConfig(max_queue=64))
        for uid, (tag, prompt, sp) in enumerate(work):
            if tag == tenant:
                eng.submit(Request(uid=uid, prompt=prompt.copy(),
                                   sampling=sp))
        for r in eng.run():
            want[r.uid] = list(r.tokens)
    assert got == want
    for tag in ("dense", "hashed"):
        mm[tag].kv.leak_check()


# ---------------------------------------------------------------------------
# quotas
# ---------------------------------------------------------------------------

def test_page_quota_bounds_footprint_every_tick(packs):
    quota = 8                        # 2 slots x 4 pages max each
    mm = _mm(packs, quota=quota)
    rng = np.random.default_rng(1)
    for uid in range(8):
        mm.submit(Request(
            uid=uid,
            prompt=rng.integers(2, TINY.vocab_size, size=12).astype(
                np.int32),
            max_new_tokens=10), model="hashed")
    ticks = 0
    while mm.pending() and ticks < 500:
        mm.step()
        held = mm["hashed"].kv.pages_held()
        assert held <= quota, (held, quota)
        ticks += 1
    done = mm["hashed"]._done
    assert sorted(r.uid for r in done) == list(range(8))
    assert all(len(r.tokens) == 10 for r in done)


def test_quota_rejects_never_fitting_request(packs):
    mm = _mm(packs, quota=2)         # 2 pages can never hold 30 tokens
    ok = mm.submit(Request(
        uid=0, prompt=np.arange(2, 26, dtype=np.int32),
        max_new_tokens=6), model="hashed")
    assert not ok
    assert mm.submit(Request(
        uid=1, prompt=np.arange(2, 26, dtype=np.int32),
        max_new_tokens=6), model="dense")


# ---------------------------------------------------------------------------
# fairness under a bursty two-tenant arrival
# ---------------------------------------------------------------------------

def test_bursty_tenants_no_head_of_line_blocking(packs):
    """A 12-deep dense backlog arriving first must not delay hashed
    admission: tenant lanes are scanned independently, and each
    tenant's rows only compete for their own engine's slots."""
    mm = _mm(packs, slots=2, max_queue=64)
    rng = np.random.default_rng(2)

    def burst(tag, uids):
        for uid in uids:
            mm.submit(Request(
                uid=uid,
                prompt=rng.integers(2, TINY.vocab_size, size=8).astype(
                    np.int32),
                max_new_tokens=8), model=tag)

    burst("dense", range(12))            # hot tenant first...
    burst("hashed", range(100, 104))     # ...then the light one
    mm.step()
    snap = mm.metrics.snapshot()
    # the very first tick admits from BOTH lanes despite the dense
    # backlog being strictly ahead in arrival order
    assert snap["sched.tenant.dense.admitted"] >= 1
    assert snap["sched.tenant.hashed.admitted"] >= 1
    mm.run()
    snap = mm.metrics.snapshot()
    for tag, n in (("dense", 12), ("hashed", 4)):
        assert snap[f"sched.tenant.{tag}.submitted"] == n
        assert snap[f"sched.tenant.{tag}.admitted"] == n
        assert snap[f"model.{tag}.engine.done"] == n


# ---------------------------------------------------------------------------
# lifecycle: cancel, deadline expiry, counters
# ---------------------------------------------------------------------------

def test_cancel_queued_stamps_cancelled_terminal(packs):
    mm = _mm(packs, slots=1, max_queue=64)
    for uid in range(6):
        mm.submit(Request(uid=uid,
                          prompt=np.arange(2, 10, dtype=np.int32),
                          max_new_tokens=4),
                  model=("dense", "hashed")[uid % 2])
    mm.step()                            # admit one row per tenant
    cancelled = mm.cancel_queued()
    assert cancelled and all(r.status == "cancelled" and
                             r.finish_reason == "cancelled"
                             for r in cancelled)
    mm.run()                             # in-flight rows finish
    snap = mm.metrics.snapshot()
    n_cancelled = sum(snap.get(f"model.{t}.engine.cancelled", 0)
                      for t in ("dense", "hashed"))
    assert n_cancelled == len(cancelled)
    done = [r for t in ("dense", "hashed") for r in mm[t]._done]
    assert len(done) + len(cancelled) == 6
    assert all(len(r.tokens) == 4 for r in done)


def test_shared_pool_deadline_expiry_leak_free(packs):
    mm = _mm(packs, slots=1, deadline=0.0)
    for uid in range(8):
        mm.submit(Request(uid=uid,
                          prompt=np.arange(2, 12, dtype=np.int32),
                          max_new_tokens=6),
                  model=("dense", "hashed")[uid % 2])
    mm.run()
    snap = mm.metrics.snapshot()
    expired = sum(snap.get(f"sched.tenant.{t}.expired", 0)
                  for t in ("dense", "hashed"))
    assert expired > 0
    for tag in ("dense", "hashed"):
        mm[tag].kv.leak_check()
    assert mm._alloc.num_used == 0       # nothing retained, no prefix


# ---------------------------------------------------------------------------
# scheduler tenant primitives
# ---------------------------------------------------------------------------

def _req(uid, model=None, prio=0):
    return Request(uid=uid, prompt=np.arange(2, 6, dtype=np.int32),
                   max_new_tokens=2, priority=prio, model=model)


def test_scheduler_model_filters():
    s = Scheduler(SchedulerConfig(policy="priority", max_queue=64,
                                  deadline_s=1.0))
    for uid, (m, p) in enumerate([("a", 0), ("b", 0), ("a", 1),
                                  (None, 0), ("b", 1)]):
        assert s.submit(_req(uid, m, p), now=0.0)
    assert len(s) == 5
    assert s.depth_by_model() == {"a": 2, "b": 2, "": 1}

    # pop_admissible(model=...) only serves that tenant's lanes,
    # priority order within the tenant
    r = s.pop_admissible(lambda _: True, model="a")
    assert (r.uid, r.model) == (0, "a")
    r = s.pop_admissible(lambda _: True, model="b")
    assert (r.uid, r.model) == (1, "b")
    assert len(s) == 3

    # expire(model=...) touches only that tenant's queued requests
    dead = s.expire(now=5.0, model="b")
    assert [d.uid for d in dead] == [4]
    d = s.depth_by_model()
    assert (d.get("a"), d.get("b", 0), d.get("")) == (1, 0, 1)

    # drain(model=None) empties everything left
    rest = s.drain()
    assert sorted(r.uid for r in rest) == [2, 3]
    assert len(s) == 0


def test_scheduler_drain_single_tenant():
    s = Scheduler(SchedulerConfig(max_queue=64))
    for uid, m in enumerate(["a", "b", "a"]):
        s.submit(_req(uid, m), now=0.0)
    got = s.drain(model="a")
    assert sorted(r.uid for r in got) == [0, 2]
    d = s.depth_by_model()
    assert (d.get("a", 0), d.get("b")) == (0, 1)
