"""Property tests for the continuous-batching serving stack.

Host-side invariants (no model, pure bookkeeping — hypothesis drives
random admit/retire/preempt sequences):
- the page allocator never double-assigns and never leaks,
- PagedKVCache row bookkeeping conserves pages across admit / grow /
  release,
- the scheduler preserves FIFO order within a priority class, bounds
  its queue (backpressure), and expires past-deadline requests.

Engine-level invariants (tiny decoder, real jitted prefill/decode):
- requests admit AND retire mid-flight (a short request completes while
  a long one is still decoding — the acceptance criterion),
- every admitted request retires with exactly max_new_tokens or an EOS,
- preemption under an oversubscribed page pool reproduces the
  fully-provisioned greedy output token-for-token,
- greedy decode through an ``.hnart`` cold start (Engine.from_artifact)
  is token-identical to the in-memory engine (determinism regression).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

SETTINGS = dict(max_examples=25, deadline=None)

from repro.serving.paged_cache import PageAllocator, PagedKVCache
from repro.serving.scheduler import Scheduler, SchedulerConfig


class _Req:
    """Stand-in request for scheduler-only tests."""

    def __init__(self, uid, priority=0):
        self.uid = uid
        self.priority = priority
        self.submit_time = None


# ---------------------------------------------------------------------------
# page allocator
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(num_pages=st.integers(2, 24), seed=st.integers(0, 10 ** 6))
def test_allocator_never_double_assigns_or_leaks(num_pages, seed):
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(num_pages)
    held = []
    for _ in range(40):
        if held and rng.random() < 0.4:
            i = int(rng.integers(len(held)))
            alloc.free(held.pop(i))
        else:
            n = int(rng.integers(0, num_pages))
            free_before = alloc.num_free
            got = alloc.alloc(n)
            if n > free_before:
                assert got is None, "granted more pages than were free"
                continue
            assert got is not None and len(got) == n
            held.append(got)
        flat = [p for g in held for p in g]
        assert len(flat) == len(set(flat)), "double-assigned page"
        assert 0 not in flat, "trash page handed out"
        assert alloc.num_free + alloc.num_used == num_pages - 1
        assert alloc.num_used == len(flat)
    for g in held:
        alloc.free(g)
    assert alloc.num_free == num_pages - 1


def test_allocator_rejects_bad_free():
    alloc = PageAllocator(4)
    got = alloc.alloc(1)
    alloc.free(got)
    with pytest.raises(ValueError):
        alloc.free(got)          # double free
    with pytest.raises(ValueError):
        alloc.free([0])          # trash page was never allocated


@settings(**SETTINGS)
@given(num_pages=st.integers(4, 32), page_size=st.sampled_from([4, 8, 16]),
       rows=st.integers(1, 4), seed=st.integers(0, 10 ** 6))
def test_paged_cache_random_admit_grow_release(num_pages, page_size, rows,
                                               seed):
    """No page leak across random admit / decode-grow / release."""
    maxp = 4
    kv = PagedKVCache(num_pages, page_size, rows, maxp)
    rng = np.random.default_rng(seed)
    for _ in range(60):
        op = rng.random()
        bound = [r for r in range(rows) if r in kv.row_pages]
        free_rows = [r for r in range(rows) if r not in kv.row_pages]
        if op < 0.4 and free_rows:
            tokens = int(rng.integers(1, maxp * page_size))
            if kv.pages_for(tokens) <= kv.alloc.num_free:
                assert kv.admit_row(free_rows[0], tokens)
                r = free_rows[0]
                n = kv.pages_for(tokens)
                assert list(kv.table[r, :n]) == kv.row_pages[r]
                assert (kv.table[r, n:] == 0).all()
            else:
                assert not kv.admit_row(free_rows[0], tokens)
        elif op < 0.7 and bound:
            r = bound[int(rng.integers(len(bound)))]
            st_ = kv.ensure_decode_room(r)
            if st_ == "ok":
                kv.advance(r)
        elif bound:
            kv.release_row(bound[int(rng.integers(len(bound)))])
        kv.leak_check()
    for r in list(kv.row_pages):
        kv.release_row(r)
    kv.leak_check()
    assert kv.alloc.num_free == kv.usable_pages


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(n=st.integers(1, 30), classes=st.integers(1, 3),
       seed=st.integers(0, 10 ** 6))
def test_scheduler_fifo_within_priority_class(n, classes, seed):
    """Service order within a class equals submission order, under a
    random admissibility gate (pages free / busy rows)."""
    rng = np.random.default_rng(seed)
    sched = Scheduler(SchedulerConfig(policy="priority", max_queue=n))
    reqs = [_Req(uid, priority=int(rng.integers(classes)))
            for uid in range(n)]
    for r in reqs:
        assert sched.submit(r, now=float(r.uid))
    served = []
    stall = 0
    while len(sched) and stall < 200:
        admissible = rng.random() < 0.7
        got = sched.pop_admissible(lambda r: admissible)
        if got is None:
            stall += 1
            continue
        served.append(got)
    assert len(served) == n
    for c in range(classes):
        uids = [r.uid for r in served if r.priority == c]
        assert uids == sorted(uids), f"class {c} out of FIFO order"


def test_scheduler_backpressure_bounded_queue():
    sched = Scheduler(SchedulerConfig(max_queue=2))
    assert sched.submit(_Req(0), now=0.0)
    assert sched.submit(_Req(1), now=0.0)
    assert not sched.submit(_Req(2), now=0.0)      # full: refused
    sched.pop_admissible(lambda r: True)
    assert sched.submit(_Req(3), now=1.0)          # drained: accepted


def test_scheduler_deadline_expiry():
    sched = Scheduler(SchedulerConfig(deadline_s=1.0))
    a, b = _Req(0), _Req(1)
    sched.submit(a, now=0.0)
    sched.submit(b, now=5.0)
    dead = sched.expire(now=5.5)
    assert [r.uid for r in dead] == [0] and len(sched) == 1


def test_scheduler_deadline_spares_preempted_requests():
    """The deadline bounds queue wait BEFORE first admission; a
    preempted (already-admitted, tokens served) request must not be
    expired on requeue."""
    sched = Scheduler(SchedulerConfig(deadline_s=1.0))
    r = _Req(0)
    sched.submit(r, now=0.0)
    got = sched.pop_admissible(lambda q: True)
    got.first_admit_time = 0.1                    # engine admitted it
    sched.requeue(got)                            # preempted much later
    assert sched.expire(now=10.0) == []
    assert sched.pop_admissible(lambda q: True) is r


def test_scheduler_requeue_restores_head():
    sched = Scheduler(SchedulerConfig())
    a, b = _Req(0), _Req(1)
    sched.submit(a, now=0.0)
    sched.submit(b, now=0.0)
    got = sched.pop_admissible(lambda r: True)
    assert got is a
    sched.requeue(got)                             # preempted
    assert sched.pop_admissible(lambda r: True) is a


def test_scheduler_priority_classes_served_in_order():
    sched = Scheduler(SchedulerConfig(policy="priority"))
    lo, hi = _Req(0, priority=5), _Req(1, priority=0)
    sched.submit(lo, now=0.0)
    sched.submit(hi, now=0.1)
    assert sched.pop_admissible(lambda r: True) is hi


# ---------------------------------------------------------------------------
# engine: continuous batching over a real (tiny) decoder
# ---------------------------------------------------------------------------

import jax  # noqa: E402

from repro.configs.base import ArchConfig  # noqa: E402
from repro.models import build  # noqa: E402
from repro.serving.engine import Engine, Request  # noqa: E402

TINY = ArchConfig(
    name="tiny-serve", family="dense", arch_kind="decoder",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, remat=False, dtype="float32")


@pytest.fixture(scope="module")
def tiny():
    m = build(TINY)
    return m, m.init(jax.random.PRNGKey(0))


def _prompt(rng, lo=2, hi=12):
    return rng.integers(2, TINY.vocab_size,
                        size=int(rng.integers(lo, hi))).astype(np.int32)


def test_engine_admits_and_retires_mid_flight(tiny):
    """The acceptance criterion: a short request completes while a long
    one is still decoding, and a late submit is admitted mid-flight."""
    m, params = tiny
    eng = Engine(m, params, max_concurrency=2, max_len=64, eos_id=-1,
                 page_size=8)
    rng = np.random.default_rng(0)
    long_req = Request(uid=0, prompt=_prompt(rng), max_new_tokens=24)
    short_req = Request(uid=1, prompt=_prompt(rng), max_new_tokens=3)
    assert eng.submit(long_req) and eng.submit(short_req)
    while not short_req.done:
        eng.step()
    assert long_req.status == "running" and not long_req.done, \
        "short request should retire while the long one decodes"
    late = Request(uid=2, prompt=_prompt(rng), max_new_tokens=2)
    assert eng.submit(late)
    while not late.done:
        eng.step()
    assert not long_req.done, "late arrival admitted + retired mid-flight"
    eng.run()
    assert long_req.done and len(long_req.tokens) == 24
    eng.kv.leak_check()
    assert eng.kv.alloc.num_used == 0


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n_req=st.integers(1, 5),
       eos=st.integers(-1, 255))
def test_every_admitted_request_retires_exactly(tiny, seed, n_req, eos):
    """Random admit/retire traffic: every accepted request finishes with
    exactly max_new_tokens, or earlier on EOS; no page leaks; row slots
    all free at drain."""
    m, params = tiny
    eng = Engine(m, params, max_concurrency=2, max_len=64, eos_id=eos,
                 page_size=4, num_pages=17)
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=i, prompt=_prompt(rng),
                    max_new_tokens=int(rng.integers(1, 10)))
            for i in range(n_req)]
    accepted = [r for r in reqs if eng.submit(r)]
    done = eng.run()
    assert {r.uid for r in done} == {r.uid for r in accepted}
    for r in done:
        assert r.done and r.status == "done"
        if len(r.tokens) < r.max_new_tokens:
            assert r.tokens[-1] == eos, (r.tokens, eos)
        else:
            assert len(r.tokens) == r.max_new_tokens, \
                "generated past max_new_tokens"
    eng.kv.leak_check()
    assert eng.kv.alloc.num_used == 0
    assert all(r is None for r in eng.rows)


def test_preemption_reproduces_greedy_tokens(tiny):
    """Oversubscribed page pool: preempt-and-recompute must reproduce
    the fully-provisioned greedy output token-for-token."""
    m, params = tiny
    rng = np.random.default_rng(3)
    prompts = [_prompt(rng, 8, 12) for _ in range(2)]

    def run(**kw):
        eng = Engine(m, params, max_concurrency=2, max_len=64, eos_id=-1,
                     **kw)
        for i, p in enumerate(prompts):
            assert eng.submit(Request(uid=i, prompt=p, max_new_tokens=12))
        done = eng.run()
        return [r.tokens for r in sorted(done, key=lambda r: r.uid)], eng

    full, _ = run(page_size=4)
    tight, eng = run(page_size=4, num_pages=8)   # 7 usable: forces oom
    assert sum(r.preemptions for r in eng._done) > 0, \
        "pool sizing did not force a preemption"
    assert tight == full
    eng.kv.leak_check()


def test_submit_rejects_never_fitting_request(tiny):
    """A request whose working set can never fit is refused at submit
    (otherwise it wedges the FIFO head forever)."""
    m, params = tiny
    eng = Engine(m, params, max_concurrency=1, max_len=32, eos_id=-1,
                 page_size=4, num_pages=4)        # 3 usable pages
    big = Request(uid=0, prompt=np.arange(10, dtype=np.int32) + 2,
                  max_new_tokens=20)              # needs 8 pages
    assert not eng.submit(big)
    assert big.status == "rejected" and eng.failed == [big]
    ok = Request(uid=1, prompt=np.arange(4, dtype=np.int32) + 2,
                 max_new_tokens=4)
    assert eng.submit(ok)
    done = eng.run()
    assert [r.uid for r in done] == [1]


def test_determinism_artifact_vs_in_memory_engine(tiny, tmp_path):
    """Greedy decode through an ``.hnart`` cold start is token-identical
    to the in-memory engine under the continuous-batching scheduler."""
    from repro import artifact

    cfg = TINY.hashed_variant(0.25)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(1))
    path = str(tmp_path / "tiny.hnart")
    artifact.export_model(path, cfg, params)

    rng = np.random.default_rng(7)
    prompts = [_prompt(rng) for _ in range(4)]

    def drive(eng):
        for i, p in enumerate(prompts):
            assert eng.submit(Request(uid=i, prompt=p, max_new_tokens=6))
        done = eng.run()
        return [r.tokens for r in sorted(done, key=lambda r: r.uid)]

    live = drive(Engine(m, params, max_concurrency=2, max_len=64,
                        eos_id=-1, page_size=8))
    cold = drive(Engine.from_artifact(path, slots=2, max_len=64,
                                      eos_id=-1, page_size=8))
    assert cold == live
