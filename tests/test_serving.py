"""Property tests for the continuous-batching serving stack.

Host-side invariants (no model, pure bookkeeping — hypothesis drives
random admit/retire/preempt sequences):
- the page allocator never double-assigns and never leaks,
- PagedKVCache row bookkeeping conserves pages across admit / grow /
  release,
- the scheduler preserves FIFO order within a priority class, bounds
  its queue (backpressure), and expires past-deadline requests.

Engine-level invariants (tiny decoder, real jitted prefill/decode):
- requests admit AND retire mid-flight (a short request completes while
  a long one is still decoding — the acceptance criterion),
- every admitted request retires with exactly max_new_tokens or an EOS,
- preemption under an oversubscribed page pool reproduces the
  fully-provisioned greedy output token-for-token,
- greedy decode through an ``.hnart`` cold start (Engine.from_artifact)
  is token-identical to the in-memory engine (determinism regression).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

SETTINGS = dict(max_examples=25, deadline=None)

from repro.serving.paged_cache import PageAllocator, PagedKVCache
from repro.serving.scheduler import Scheduler, SchedulerConfig


class _Req:
    """Stand-in request for scheduler-only tests."""

    def __init__(self, uid, priority=0):
        self.uid = uid
        self.priority = priority
        self.submit_mono = None


# ---------------------------------------------------------------------------
# page allocator
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(num_pages=st.integers(2, 24), seed=st.integers(0, 10 ** 6))
def test_allocator_never_double_assigns_or_leaks(num_pages, seed):
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(num_pages)
    held = []
    for _ in range(40):
        if held and rng.random() < 0.4:
            i = int(rng.integers(len(held)))
            alloc.free(held.pop(i))
        else:
            n = int(rng.integers(0, num_pages))
            free_before = alloc.num_free
            got = alloc.alloc(n)
            if n > free_before:
                assert got is None, "granted more pages than were free"
                continue
            assert got is not None and len(got) == n
            held.append(got)
        flat = [p for g in held for p in g]
        assert len(flat) == len(set(flat)), "double-assigned page"
        assert 0 not in flat, "trash page handed out"
        assert alloc.num_free + alloc.num_used == num_pages - 1
        assert alloc.num_used == len(flat)
    for g in held:
        alloc.free(g)
    assert alloc.num_free == num_pages - 1


def test_allocator_rejects_bad_free():
    alloc = PageAllocator(4)
    got = alloc.alloc(1)
    alloc.free(got)
    with pytest.raises(ValueError):
        alloc.free(got)          # double free
    with pytest.raises(ValueError):
        alloc.free([0])          # trash page was never allocated


@settings(**SETTINGS)
@given(num_pages=st.integers(4, 32), page_size=st.sampled_from([4, 8, 16]),
       rows=st.integers(1, 4), seed=st.integers(0, 10 ** 6))
def test_paged_cache_random_admit_grow_release(num_pages, page_size, rows,
                                               seed):
    """No page leak across random admit / decode-grow / release."""
    maxp = 4
    kv = PagedKVCache(num_pages, page_size, rows, maxp)
    rng = np.random.default_rng(seed)
    for _ in range(60):
        op = rng.random()
        bound = [r for r in range(rows) if r in kv.row_pages]
        free_rows = [r for r in range(rows) if r not in kv.row_pages]
        if op < 0.4 and free_rows:
            tokens = int(rng.integers(1, maxp * page_size))
            if kv.pages_for(tokens) <= kv.alloc.num_free:
                assert kv.admit_row(free_rows[0], tokens)
                r = free_rows[0]
                n = kv.pages_for(tokens)
                assert list(kv.table[r, :n]) == kv.row_pages[r]
                assert (kv.table[r, n:] == 0).all()
            else:
                assert not kv.admit_row(free_rows[0], tokens)
        elif op < 0.7 and bound:
            r = bound[int(rng.integers(len(bound)))]
            st_ = kv.ensure_decode_room(r)
            if st_ == "ok":
                kv.advance(r)
        elif bound:
            kv.release_row(bound[int(rng.integers(len(bound)))])
        kv.leak_check()
    for r in list(kv.row_pages):
        kv.release_row(r)
    kv.leak_check()
    assert kv.alloc.num_free == kv.usable_pages


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(n=st.integers(1, 30), classes=st.integers(1, 3),
       seed=st.integers(0, 10 ** 6))
def test_scheduler_fifo_within_priority_class(n, classes, seed):
    """Service order within a class equals submission order, under a
    random admissibility gate (pages free / busy rows)."""
    rng = np.random.default_rng(seed)
    sched = Scheduler(SchedulerConfig(policy="priority", max_queue=n))
    reqs = [_Req(uid, priority=int(rng.integers(classes)))
            for uid in range(n)]
    for r in reqs:
        assert sched.submit(r, now=float(r.uid))
    served = []
    stall = 0
    while len(sched) and stall < 200:
        admissible = rng.random() < 0.7
        got = sched.pop_admissible(lambda r: admissible)
        if got is None:
            stall += 1
            continue
        served.append(got)
    assert len(served) == n
    for c in range(classes):
        uids = [r.uid for r in served if r.priority == c]
        assert uids == sorted(uids), f"class {c} out of FIFO order"


def test_scheduler_backpressure_bounded_queue():
    sched = Scheduler(SchedulerConfig(max_queue=2))
    assert sched.submit(_Req(0), now=0.0)
    assert sched.submit(_Req(1), now=0.0)
    assert not sched.submit(_Req(2), now=0.0)      # full: refused
    sched.pop_admissible(lambda r: True)
    assert sched.submit(_Req(3), now=1.0)          # drained: accepted


def test_scheduler_deadline_expiry():
    sched = Scheduler(SchedulerConfig(deadline_s=1.0))
    a, b = _Req(0), _Req(1)
    sched.submit(a, now=0.0)
    sched.submit(b, now=5.0)
    dead = sched.expire(now=5.5)
    assert [r.uid for r in dead] == [0] and len(sched) == 1


def test_scheduler_deadline_spares_preempted_requests():
    """The deadline bounds queue wait BEFORE first admission; a
    preempted (already-admitted, tokens served) request must not be
    expired on requeue."""
    sched = Scheduler(SchedulerConfig(deadline_s=1.0))
    r = _Req(0)
    sched.submit(r, now=0.0)
    got = sched.pop_admissible(lambda q: True)
    got.first_admit_mono = 0.1                    # engine admitted it
    sched.requeue(got)                            # preempted much later
    assert sched.expire(now=10.0) == []
    assert sched.pop_admissible(lambda q: True) is r


def test_scheduler_unpop_keeps_admitted_monotone():
    """unpop() returns a popped-but-unplaceable head to the queue; the
    ``admitted`` counter stays monotone (a ``diff_snapshots`` window
    containing an unpop must never report negative admissions — the
    regression this pins), ``unpopped`` records the bounce, and
    ``snapshot`` derives the net."""
    sched = Scheduler(SchedulerConfig())
    a, b = _Req(0), _Req(1)
    sched.submit(a, now=0.0)
    sched.submit(b, now=0.0)
    got = sched.pop_admissible(lambda r: True)
    assert got is a
    before = sched.counters["admitted"]
    sched.unpop(got)
    assert sched.counters["admitted"] == before, \
        "admitted decremented on unpop"
    assert sched.counters["unpopped"] == 1
    snap = sched.snapshot()
    assert snap["admitted_net"] == snap["admitted"] - snap["unpopped"] == 0
    # arrival order restored: a pops again before b
    assert sched.pop_admissible(lambda r: True) is a
    assert sched.snapshot()["admitted_net"] == 1


@settings(**SETTINGS)
@given(n=st.integers(1, 12), seed=st.integers(0, 10 ** 6))
def test_scheduler_counters_monotone_under_random_unpops(n, seed):
    """Every counter is non-decreasing across a random pop/unpop/
    requeue sequence, and admitted_net == pops that stuck."""
    rng = np.random.default_rng(seed)
    sched = Scheduler(SchedulerConfig(max_queue=n))
    for uid in range(n):
        assert sched.submit(_Req(uid), now=float(uid))
    prev = sched.snapshot()
    stuck = 0
    for _ in range(60):
        got = sched.pop_admissible(lambda r: True)
        if got is None:
            break
        roll = rng.random()
        if roll < 0.3:
            sched.unpop(got)
        elif roll < 0.5:
            got.first_admit_mono = 0.0
            sched.requeue(got)
            stuck += 1
        else:
            stuck += 1
        snap = sched.snapshot()
        for k in prev:
            if k not in ("queue_depth", "admitted_net"):
                assert snap[k] >= prev[k], f"counter {k} went backwards"
        prev = snap
    assert prev["admitted_net"] == stuck


def test_scheduler_requeue_restores_head():
    sched = Scheduler(SchedulerConfig())
    a, b = _Req(0), _Req(1)
    sched.submit(a, now=0.0)
    sched.submit(b, now=0.0)
    got = sched.pop_admissible(lambda r: True)
    assert got is a
    sched.requeue(got)                             # preempted
    assert sched.pop_admissible(lambda r: True) is a


def test_scheduler_priority_classes_served_in_order():
    sched = Scheduler(SchedulerConfig(policy="priority"))
    lo, hi = _Req(0, priority=5), _Req(1, priority=0)
    sched.submit(lo, now=0.0)
    sched.submit(hi, now=0.1)
    assert sched.pop_admissible(lambda r: True) is hi


# ---------------------------------------------------------------------------
# engine: continuous batching over a real (tiny) decoder
# ---------------------------------------------------------------------------

import jax  # noqa: E402

from repro.configs.base import ArchConfig  # noqa: E402
from repro.models import build  # noqa: E402
from repro.serving.engine import Engine, Request  # noqa: E402

TINY = ArchConfig(
    name="tiny-serve", family="dense", arch_kind="decoder",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, remat=False, dtype="float32")


@pytest.fixture(scope="module")
def tiny():
    m = build(TINY)
    return m, m.init(jax.random.PRNGKey(0))


def _prompt(rng, lo=2, hi=12):
    return rng.integers(2, TINY.vocab_size,
                        size=int(rng.integers(lo, hi))).astype(np.int32)


def test_engine_admits_and_retires_mid_flight(tiny):
    """The acceptance criterion: a short request completes while a long
    one is still decoding, and a late submit is admitted mid-flight."""
    m, params = tiny
    eng = Engine(m, params, max_concurrency=2, max_len=64, eos_id=-1,
                 page_size=8)
    rng = np.random.default_rng(0)
    long_req = Request(uid=0, prompt=_prompt(rng), max_new_tokens=24)
    short_req = Request(uid=1, prompt=_prompt(rng), max_new_tokens=3)
    assert eng.submit(long_req) and eng.submit(short_req)
    while not short_req.done:
        eng.step()
    assert long_req.status == "running" and not long_req.done, \
        "short request should retire while the long one decodes"
    late = Request(uid=2, prompt=_prompt(rng), max_new_tokens=2)
    assert eng.submit(late)
    while not late.done:
        eng.step()
    assert not long_req.done, "late arrival admitted + retired mid-flight"
    eng.run()
    assert long_req.done and len(long_req.tokens) == 24
    eng.kv.leak_check()
    assert eng.kv.alloc.num_used == 0


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n_req=st.integers(1, 5),
       eos=st.integers(-1, 255))
def test_every_admitted_request_retires_exactly(tiny, seed, n_req, eos):
    """Random admit/retire traffic: every accepted request finishes with
    exactly max_new_tokens, or earlier on EOS; no page leaks; row slots
    all free at drain."""
    m, params = tiny
    eng = Engine(m, params, max_concurrency=2, max_len=64, eos_id=eos,
                 page_size=4, num_pages=17)
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=i, prompt=_prompt(rng),
                    max_new_tokens=int(rng.integers(1, 10)))
            for i in range(n_req)]
    accepted = [r for r in reqs if eng.submit(r)]
    done = eng.run()
    assert {r.uid for r in done} == {r.uid for r in accepted}
    for r in done:
        assert r.done and r.status == "done"
        if len(r.tokens) < r.max_new_tokens:
            assert r.tokens[-1] == eos, (r.tokens, eos)
        else:
            assert len(r.tokens) == r.max_new_tokens, \
                "generated past max_new_tokens"
    eng.kv.leak_check()
    assert eng.kv.alloc.num_used == 0
    assert all(r is None for r in eng.rows)


def test_preemption_reproduces_greedy_tokens(tiny):
    """Oversubscribed page pool: preempt-and-recompute must reproduce
    the fully-provisioned greedy output token-for-token."""
    m, params = tiny
    rng = np.random.default_rng(3)
    prompts = [_prompt(rng, 8, 12) for _ in range(2)]

    def run(**kw):
        eng = Engine(m, params, max_concurrency=2, max_len=64, eos_id=-1,
                     **kw)
        for i, p in enumerate(prompts):
            assert eng.submit(Request(uid=i, prompt=p, max_new_tokens=12))
        done = eng.run()
        return [r.tokens for r in sorted(done, key=lambda r: r.uid)], eng

    full, _ = run(page_size=4)
    tight, eng = run(page_size=4, num_pages=8)   # 7 usable: forces oom
    assert sum(r.preemptions for r in eng._done) > 0, \
        "pool sizing did not force a preemption"
    assert tight == full
    eng.kv.leak_check()


def test_submit_rejects_never_fitting_request(tiny):
    """A request whose working set can never fit is refused at submit
    (otherwise it wedges the FIFO head forever)."""
    m, params = tiny
    eng = Engine(m, params, max_concurrency=1, max_len=32, eos_id=-1,
                 page_size=4, num_pages=4)        # 3 usable pages
    big = Request(uid=0, prompt=np.arange(10, dtype=np.int32) + 2,
                  max_new_tokens=20)              # needs 8 pages
    assert not eng.submit(big)
    assert big.status == "rejected" and eng.failed == [big]
    ok = Request(uid=1, prompt=np.arange(4, dtype=np.int32) + 2,
                 max_new_tokens=4)
    assert eng.submit(ok)
    done = eng.run()
    assert [r.uid for r in done] == [1]


def test_wall_clock_steps_do_not_skew_durations(tiny, monkeypatch):
    """NTP-style wall-clock steps (time.time jumping BACKWARDS between
    reads) must not skew queue-wait/TTFT/latency: every duration comes
    off the monotonic clock.  The old wall-clock arithmetic clamped the
    negative deltas to 0 — hiding the skew instead of being immune."""
    from repro.serving import engine as engine_mod
    m, params = tiny
    wall = {"t": 10_000.0}

    def stepping_wall():
        wall["t"] -= 97.0            # a hard backwards step every read
        return wall["t"]

    monkeypatch.setattr(engine_mod, "_now_wall", stepping_wall)
    eng = Engine(m, params, max_concurrency=2, max_len=64, eos_id=-1,
                 page_size=8)
    rng = np.random.default_rng(5)
    reqs = [Request(uid=i, prompt=_prompt(rng), max_new_tokens=4)
            for i in range(3)]
    for r in reqs:
        assert eng.submit(r)
    done = eng.run()
    assert len(done) == 3
    for r in done:
        # monotonic marks stay ordered however the wall clock thrashes
        assert (r.submit_mono <= r.first_admit_mono
                <= r.first_token_mono <= r.finish_mono)
        # wall timestamps still populated — they are display-only
        assert r.submit_time is not None and r.finish_time is not None
    stats = eng.stats()
    assert stats["latency_p50_s"] >= 0.0
    assert stats["ttft_p50_s"] >= 0.0 and stats["ttft_mean_s"] >= 0.0
    snap = eng.metrics.snapshot()
    for h in ("engine.ttft_s", "engine.queue_wait_s"):
        assert snap[h]["count"] == 3 and snap[h]["min"] >= 0.0, h


def test_deadline_expiry_immune_to_wall_clock_steps(tiny, monkeypatch):
    """Queue-deadline expiry keys off the monotonic clock: a wall-clock
    step can neither spuriously expire a fresh request nor immortalize
    an overdue one.  The mono clock is driven directly; the wall clock
    is pinned to nonsense to prove it is irrelevant."""
    from repro.serving import engine as engine_mod
    mono = {"t": 0.0}
    monkeypatch.setattr(engine_mod, "_now_mono", lambda: mono["t"])
    monkeypatch.setattr(engine_mod, "_now_wall", lambda: -1e9)
    m, params = tiny
    eng = Engine(m, params, max_concurrency=1, max_len=64, eos_id=-1,
                 page_size=8,
                 scheduler=SchedulerConfig(deadline_s=5.0, max_queue=8))
    rng = np.random.default_rng(7)
    a = Request(uid=0, prompt=_prompt(rng), max_new_tokens=8)
    b = Request(uid=1, prompt=_prompt(rng), max_new_tokens=2)
    assert eng.submit(a)                     # submit_mono = 0.0
    mono["t"] = 1.0
    assert eng.submit(b)                     # queued behind a
    mono["t"] = 2.0
    eng.step()                               # b waited 1s < 5s: kept
    assert b.status == "queued"
    mono["t"] = 7.0
    eng.step()                               # b waited 6s > 5s: expired
    assert b.status == "expired" and b.finish_reason == "deadline"
    eng.run()
    assert a.done and len(a.tokens) == 8     # a unaffected throughout


def test_expired_request_stamps_finish_clocks_and_terminal_delta(
        tiny, monkeypatch):
    """The deadline-expiry path finishes a request like any other
    terminal path: ``finish_mono``/``finish_time`` are stamped at the
    expiring tick (latency math and streaming clients read them) and
    the handle drains a terminal ``deadline`` delta.  Regression: the
    expire path used to leave both clocks ``None``."""
    from repro.serving import engine as engine_mod
    mono = {"t": 0.0}
    monkeypatch.setattr(engine_mod, "_now_mono", lambda: mono["t"])
    monkeypatch.setattr(engine_mod, "_now_wall", lambda: 1234.5)
    m, params = tiny
    eng = Engine(m, params, max_concurrency=1, max_len=64, eos_id=-1,
                 page_size=8,
                 scheduler=SchedulerConfig(deadline_s=5.0, max_queue=8))
    rng = np.random.default_rng(11)
    a = Request(uid=0, prompt=_prompt(rng), max_new_tokens=6)
    b = Request(uid=1, prompt=_prompt(rng), max_new_tokens=2)
    ha = eng.submit(a)
    hb = eng.submit(b)
    assert ha and hb
    eng.step()                       # a admitted: holds the only row
    mono["t"] = 7.0                  # b's queue wait exceeds 5s
    eng.run()
    assert b.status == "expired" and b.finish_reason == "deadline"
    assert b.finish_mono == 7.0, "finish_mono not stamped on expiry"
    assert b.finish_time == 1234.5, "finish_time not stamped on expiry"
    deltas = list(hb)
    assert deltas and deltas[-1].done \
        and deltas[-1].finish_reason == "deadline"
    assert a.done and len(a.tokens) == 6


# ---------------------------------------------------------------------------
# prefix index (radix tree over KV pages)
# ---------------------------------------------------------------------------

from repro.serving.paged_cache import PageAllocator as _PA  # noqa: E402
from repro.serving.paged_cache import PrefixIndex  # noqa: E402


@settings(**SETTINGS)
@given(seed=st.integers(0, 10 ** 6), ps=st.sampled_from([2, 4]),
       n_seq=st.integers(1, 6))
def test_prefix_index_match_is_longest_indexed_prefix(seed, ps, n_seq):
    """After inserting sequences over a tiny alphabet, match() returns
    exactly the pages of the longest indexed full-page prefix, and the
    tree's refcount claims always verify against the allocator."""
    rng = np.random.default_rng(seed)
    alloc = _PA(64)
    idx = PrefixIndex(ps, alloc)
    indexed = {}                         # chunk-path tuple -> page
    for _ in range(n_seq):
        toks = rng.integers(0, 3, size=int(rng.integers(1, 5 * ps)))
        pages = alloc.alloc(-(-len(toks) // ps))
        idx.insert(toks, pages, len(toks))
        for j in range(len(toks) // ps):
            key = tuple(toks[:(j + 1) * ps])
            indexed.setdefault(key, pages[j])
        alloc.free(pages)                # tree refs keep indexed pages
    probe = rng.integers(0, 3, size=int(rng.integers(1, 6 * ps)))
    fulls, tail = idx.match(probe)
    assert len(fulls) <= len(probe) // ps
    for j, page in enumerate(fulls):
        assert indexed[tuple(probe[:(j + 1) * ps])] == page
    if len(fulls) < len(probe) // ps:          # stopped: next page unindexed
        assert tuple(probe[:(len(fulls) + 1) * ps]) not in indexed
    if tail is not None:
        page, use = tail
        assert 0 < use < ps and alloc.refcount(page) >= 1
    # every tree page is allocator-held exactly once by the tree
    for p in idx.pages():
        assert alloc.refcount(p) == 1


def test_prefix_index_evicts_lru_only_unreferenced():
    """Eviction reclaims least-recently-used tree-only pages leaf-first;
    pages a row still maps (refcount > 1) are never touched."""
    alloc = _PA(8)
    idx = PrefixIndex(2, alloc)
    old = alloc.alloc(2)
    idx.insert(np.array([0, 1, 0, 1]), old, 4)
    alloc.free(old)                      # tree-only now (LRU)
    new = alloc.alloc(2)
    idx.insert(np.array([2, 3, 2, 3]), new, 4)   # fresher, row-held
    assert idx.evictable() == 2          # only the tree-only pages
    assert idx.evict(1) == 1             # drops old's LEAF, parent stays
    fulls, _ = idx.match(np.array([2, 3, 2, 3]))
    assert fulls == new, "eviction touched a row-held entry"
    fulls, _ = idx.match(np.array([0, 1, 0, 1]))
    assert fulls == old[:1], "leaf-first LRU should keep the parent"
    assert idx.evict(5) == 1             # parent became a leaf: reclaimed
    fulls, _ = idx.match(np.array([0, 1, 0, 1]))
    assert fulls == []
    alloc.free(new)                      # row drops; tree still holds
    assert alloc.num_used == idx.num_pages == 2


@settings(**SETTINGS)
@given(num_pages=st.integers(6, 24), seed=st.integers(0, 10 ** 6))
def test_paged_cache_prefix_admit_share_release(num_pages, seed):
    """Random admit-with-sharing / publish / release sequences conserve
    refcounts (leak_check) and shared mappings never exceed what the
    tree indexed."""
    ps, rows, maxp = 4, 3, 4
    kv = PagedKVCache(num_pages, ps, rows, maxp, prefix_cache=True)
    rng = np.random.default_rng(seed)
    toks = {}
    for _ in range(60):
        op = rng.random()
        bound = sorted(kv.row_pages)
        free_rows = [r for r in range(rows) if r not in kv.row_pages]
        if op < 0.45 and free_rows:
            n = int(rng.integers(1, maxp * ps))
            ids = rng.integers(0, 2, size=n)         # collision-heavy
            if kv.admit_row(free_rows[0], n, token_ids=ids):
                r = free_rows[0]
                kv.drop_tail_ref(r)
                toks[r] = list(ids)
                assert kv.row_meta[r].hit_tokens <= max(n - 1, 0)
        elif op < 0.7 and bound:
            r = bound[int(rng.integers(len(bound)))]
            if kv.ensure_decode_room(r) == "ok":
                kv.pending_copies.clear()
                kv.advance(r)
                toks[r].append(int(rng.integers(0, 2)))
        elif op < 0.85 and bound:
            r = bound[int(rng.integers(len(bound)))]
            n = int(kv.lengths[r])
            kv.index_row(r, np.asarray(toks[r][:n]), n)
        elif bound:
            r = bound[int(rng.integers(len(bound)))]
            kv.release_row(r)
            del toks[r]
        kv.leak_check()
    for r in list(kv.row_pages):
        kv.release_row(r)
    kv.leak_check()
    assert kv.alloc.num_used == kv.prefix.num_pages


def test_engine_prefix_cache_survives_request_lifetime(tiny):
    """Sequential identical prompts: the first request's pages outlive
    it in the tree, so the second admission maps them by reference and
    still decodes token-identically."""
    m, params = tiny
    rng = np.random.default_rng(5)
    prompt = _prompt(rng, 18, 19)

    def run(prefix):
        eng = Engine(m, params, max_concurrency=1, max_len=64, eos_id=-1,
                     page_size=8, prefix_cache=prefix)
        outs = []
        for uid in range(2):             # strictly sequential lifetimes
            req = Request(uid=uid, prompt=prompt.copy(), max_new_tokens=5)
            assert eng.submit(req)
            eng.run()
            outs.append(req.tokens)
        eng.kv.leak_check()
        return outs, eng

    base, _ = run(False)
    got, eng = run(True)
    assert got == base
    stats = eng.stats()
    assert stats["hit_tokens"] > 0, "second admission missed the tree"
    assert stats["pages_shared"] >= 2
    # drained engine holds exactly the tree's retained pages
    assert eng.kv.alloc.num_used == eng.kv.prefix.num_pages > 0


def _run_sequential_pair(m, params, prompt, *, prefix, rows):
    eng = Engine(m, params, max_concurrency=rows, max_len=64, eos_id=-1,
                 page_size=8, prefix_cache=prefix)
    outs = []
    for uid in range(2):
        req = Request(uid=uid, prompt=prompt.copy(), max_new_tokens=4)
        assert eng.submit(req)
        eng.run()
        outs.append(req.tokens)
        assert req.status == "done", req.status
    eng.kv.leak_check()
    return outs, eng


def test_engine_prefix_hit_near_max_len_slide_back(tiny):
    """A prefix hit that leaves the resume chunk pressed against the
    cache edge forces the slid-back bucket (start < pos, fixed 8-grid
    shape): output must still match the prefix-off engine."""
    m, params = tiny
    rng = np.random.default_rng(13)
    prompt = _prompt(rng, 60, 61)       # 60 + 4 new tokens == max_len
    # rows=2 leaves spare pool pages, so the partial-tail pin survives
    # admission: hit 59 -> resume c=1 at pos 59, room 5 -> no menu
    # bucket fits -> the window must slide back
    base, _ = _run_sequential_pair(m, params, prompt, prefix=False,
                                   rows=2)
    got, eng = _run_sequential_pair(m, params, prompt, prefix=True,
                                    rows=2)
    assert got == base
    assert eng.stats()["hit_tokens"] >= 59


def test_engine_admission_survives_tail_pin_on_drained_pool(tiny):
    """Livelock regression: with the whole pool retained by the tree
    for this very prompt, the partial-tail pin would hold the last
    reclaimable page hostage — admission must drop the pin (trading the
    tail reuse) rather than fail forever."""
    m, params = tiny
    rng = np.random.default_rng(13)
    prompt = _prompt(rng, 60, 61)       # needs all 8 pages of the pool
    base, _ = _run_sequential_pair(m, params, prompt, prefix=False,
                                   rows=1)
    got, eng = _run_sequential_pair(m, params, prompt, prefix=True,
                                    rows=1)
    assert got == base
    stats = eng.stats()
    assert stats["hit_tokens"] >= 56    # full pages still shared
    assert stats["evictions"] > 0       # the unpinned tail was reclaimed


def test_engine_chunked_prefill_token_identical(tiny):
    """Chunked prefill (including chunk=1) reproduces monolithic greedy
    output and interleaves with decode (tick accounting shows overlap)."""
    m, params = tiny
    rng = np.random.default_rng(9)
    prompts = [_prompt(rng, 20, 40), _prompt(rng, 3, 8)]

    def run(chunk):
        eng = Engine(m, params, max_concurrency=2, max_len=64, eos_id=-1,
                     page_size=8, prefill_chunk=chunk)
        for uid, p in enumerate(prompts):
            assert eng.submit(Request(uid=uid, prompt=p.copy(),
                                      max_new_tokens=8))
        done = eng.run()
        eng.kv.leak_check()
        return ([r.tokens for r in sorted(done, key=lambda r: r.uid)],
                eng.stats())

    base, _ = run(None)
    for chunk in (1, 7, 8):
        got, stats = run(chunk)
        assert got == base, f"chunk={chunk}"
        assert stats["prefill_chunks"] >= sum(
            -(-len(p) // chunk) for p in prompts)
    _, stats = run(4)
    assert stats["interleaved_ticks"] > 0, \
        "long chunked prefill never overlapped a decode tick"


def test_determinism_artifact_vs_in_memory_engine(tiny, tmp_path):
    """Greedy decode through an ``.hnart`` cold start is token-identical
    to the in-memory engine under the continuous-batching scheduler."""
    from repro import artifact

    cfg = TINY.hashed_variant(0.25)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(1))
    path = str(tmp_path / "tiny.hnart")
    artifact.export_model(path, cfg, params)

    rng = np.random.default_rng(7)
    prompts = [_prompt(rng) for _ in range(4)]

    def drive(eng):
        for i, p in enumerate(prompts):
            assert eng.submit(Request(uid=i, prompt=p, max_new_tokens=6))
        done = eng.run()
        return [r.tokens for r in sorted(done, key=lambda r: r.uid)]

    live = drive(Engine(m, params, max_concurrency=2, max_len=64,
                        eos_id=-1, page_size=8))
    cold = drive(Engine.from_artifact(path, slots=2, max_len=64,
                                      eos_id=-1, page_size=8))
    assert cold == live
