"""CI gate for the multi-pod dry-run: two representative cells must
lower + compile on the production meshes (subprocess: the 512-device
XLA flag must be set before jax initializes)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, timeout=480, env=env, cwd=REPO)


# one light train cell + one light decode cell; the full 66-cell sweep is
# the out-of-band gate (runs/dryrun_final).  Multi-pod train compiles of
# larger archs exceed this container's 35 GB RAM when run under pytest.
@pytest.mark.parametrize("args", [
    ["--arch", "granite-moe-1b-a400m", "--shape", "train_4k"],
    ["--arch", "qwen3-1.7b", "--shape", "decode_32k", "--multi-pod"],
])
def test_dryrun_cell_compiles(args):
    proc = _run(args)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "compiled OK" in proc.stdout
    assert "roofline fraction" in proc.stdout
