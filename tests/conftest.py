import sys

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Bound pytest's resident memory: compiled-executable caches from one
    test module (e.g. 27 arch smokes) otherwise stack under later modules'
    subprocess compiles on this 35 GB container."""
    yield
    jax.clear_caches()


# ---------------------------------------------------------------------------
# hypothesis shim
# ---------------------------------------------------------------------------
# The container has no `hypothesis` wheel (offline image).  The property
# tests only use a small API surface — @given / @settings / strategies.
# {integers, sampled_from, composite} — so when the real package is absent
# we install a minimal deterministic stand-in: each @given test runs
# `max_examples` examples drawn from a per-test seeded PRNG.  With the real
# package installed this shim is inert.
#
# The shim also covers `hypothesis.stateful` (RuleBasedStateMachine /
# rule / precondition / invariant): the stand-in's TestCase runs a fixed
# number of deterministic episodes, each a random walk over the rules
# whose preconditions hold, drawing rule arguments from the same seeded
# strategies and checking every @invariant after every step — the
# deterministic mode the serving refcount state machine falls back to.

def _install_hypothesis_stub():
    import functools
    import random
    import types
    import zlib

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def example(self, rng):
            return self._draw(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[rng.randrange(len(opts))])

    def booleans():
        return _Strategy(lambda rng: bool(rng.randrange(2)))

    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.example(rng)
                                           for s in strategies))

    def composite(fn):
        @functools.wraps(fn)
        def builder(*args, **kwargs):
            def draw_fn(rng):
                return fn(lambda s: s.example(rng), *args, **kwargs)
            return _Strategy(draw_fn)
        return builder

    def settings(**kw):
        def deco(fn):
            fn._stub_settings = dict(kw)
            return fn
        return deco

    def given(*gargs, **gkwargs):
        def deco(fn):
            import inspect
            params = list(inspect.signature(fn).parameters.values())
            # real hypothesis binds positional strategies to the RIGHTMOST
            # parameters (leading params stay fixtures); mirror that.
            n_pos = len(gargs)
            pos_names = [p.name for p in params[len(params) - n_pos:]] \
                if n_pos else []
            remaining = [p for p in params[:len(params) - n_pos]
                         if p.name not in gkwargs]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # read settings at call time: @settings may sit above OR
                # below @given (both valid with real hypothesis)
                n = getattr(wrapper, "_stub_settings", {}).get(
                    "max_examples", 25)
                rng = random.Random(
                    zlib.crc32(fn.__name__.encode()) & 0x7FFFFFFF)
                for _ in range(n):
                    ex_kwargs = {nm: s.example(rng)
                                 for nm, s in zip(pos_names, gargs)}
                    ex_kwargs.update({k: s.example(rng)
                                      for k, s in gkwargs.items()})
                    fn(*args, **kwargs, **ex_kwargs)

            # pytest collects by signature: expose only the parameters NOT
            # supplied by strategies so the rest aren't mistaken for
            # fixtures.
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature(remaining)
            return wrapper
        return deco

    # -- stateful: deterministic random-walk stand-in ----------------------

    def rule(**strategies):
        def deco(fn):
            fn._stub_rule = strategies
            return fn
        return deco

    def initialize(**strategies):
        def deco(fn):
            fn._stub_rule = strategies
            fn._stub_initialize = True
            return fn
        return deco

    def precondition(pred):
        def deco(fn):
            fn._stub_precond = pred
            return fn
        return deco

    def invariant():
        def deco(fn):
            fn._stub_invariant = True
            return fn
        return deco

    def _make_test_case(machine_cls):
        import unittest

        class Case(unittest.TestCase):
            settings = None

            def test_state_machine(self):
                names = sorted(n for n in dir(machine_cls)
                               if getattr(getattr(machine_cls, n),
                                          "_stub_rule", None) is not None)
                inits = [n for n in names
                         if getattr(getattr(machine_cls, n),
                                    "_stub_initialize", False)]
                steps = [n for n in names if n not in inits]
                invs = [n for n in dir(machine_cls)
                        if getattr(getattr(machine_cls, n),
                                   "_stub_invariant", False)]
                rng = random.Random(
                    zlib.crc32(machine_cls.__name__.encode()) & 0x7FFFFFFF)
                for _ in range(12):                     # episodes
                    m = machine_cls()
                    try:
                        def fire(name):
                            fn = getattr(m, name)
                            pred = getattr(fn, "_stub_precond", None)
                            if pred is not None and not pred(m):
                                return
                            fn(**{k: s.example(rng) for k, s in
                                  fn._stub_rule.items()})
                            for inv in invs:
                                getattr(m, inv)()

                        for name in inits:
                            fire(name)
                        for _ in range(60):             # steps per episode
                            fire(steps[rng.randrange(len(steps))])
                    finally:
                        m.teardown()

        Case.__name__ = machine_cls.__name__ + "TestCase"
        Case.__qualname__ = Case.__name__
        return Case

    class _MachineMeta(type):
        @property
        def TestCase(cls):
            return _make_test_case(cls)

    class RuleBasedStateMachine(metaclass=_MachineMeta):
        def __init__(self):
            pass

        def teardown(self):
            pass

    stateful = types.ModuleType("hypothesis.stateful")
    stateful.RuleBasedStateMachine = RuleBasedStateMachine
    stateful.rule = rule
    stateful.initialize = initialize
    stateful.precondition = precondition
    stateful.invariant = invariant

    strat = types.ModuleType("hypothesis.strategies")
    strat.integers = integers
    strat.sampled_from = sampled_from
    strat.booleans = booleans
    strat.floats = floats
    strat.tuples = tuples
    strat.composite = composite

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strat
    hyp.stateful = stateful
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None)
    hyp.__stub__ = True

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
    sys.modules["hypothesis.stateful"] = stateful


try:  # pragma: no cover - depends on environment
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _install_hypothesis_stub()
