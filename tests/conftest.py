import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Bound pytest's resident memory: compiled-executable caches from one
    test module (e.g. 27 arch smokes) otherwise stack under later modules'
    subprocess compiles on this 35 GB container."""
    yield
    jax.clear_caches()
