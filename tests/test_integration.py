"""Integration tests: training loop, checkpoint/restart/remesh,
preemption recovery, serving engine, data pipeline determinism."""
import json
import os
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import ArchConfig
from repro.configs.reduced import reduced
from repro.data import lm_stream, mnist_synthetic, pipeline
from repro.launch import mesh as mesh_lib
from repro.launch.train import run
from repro.models import build
from repro.serving.engine import generate_batch
from repro.train import checkpoint as ckpt_lib
from repro.train import fault_tolerance as ft

TINY = ArchConfig(
    name="tiny-lm", family="dense", arch_kind="decoder",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, remat=False, dtype="float32")


@pytest.mark.slow
def test_train_loss_decreases(tmp_path):
    mesh = mesh_lib.single_device_mesh()
    out = run(TINY, mesh, steps=120, batch=16, seq=32, lr=3e-3,
              ckpt_dir=str(tmp_path / "ck"), ckpt_every=1000, log_every=0)
    first = np.mean(out["losses"][:10])
    last = np.mean(out["losses"][-10:])
    assert last < first - 0.1, (first, last)


@pytest.mark.slow
def test_hashed_train_loss_decreases():
    mesh = mesh_lib.single_device_mesh()
    cfg = TINY.hashed_variant(0.25).with_(hash_panel_cols=0)
    out = run(cfg, mesh, steps=120, batch=16, seq=32, lr=3e-3,
              ckpt_dir=None, log_every=0)
    first = np.mean(out["losses"][:10])
    last = np.mean(out["losses"][-10:])
    assert last < first - 0.1, (first, last)


def test_checkpoint_roundtrip_and_restart(tmp_path):
    ck = str(tmp_path / "ck")
    mesh = mesh_lib.single_device_mesh()
    out1 = run(TINY, mesh, steps=8, batch=4, seq=16, ckpt_dir=ck,
               ckpt_every=4, log_every=0)
    assert ckpt_lib.latest_step(ck) == 8
    # restart: resumes from step 8, trains to 12
    out2 = run(TINY, mesh, steps=12, batch=4, seq=16, ckpt_dir=ck,
               ckpt_every=100, log_every=0)
    assert out2["final_step"] == 12
    assert len(out2["losses"]) == 4          # only steps 8..11 run


def test_checkpoint_atomicity_and_gc(tmp_path):
    ck = str(tmp_path / "ck")
    state = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((3, 3))},
             "step": jnp.asarray(5)}
    for s in (1, 2, 3, 4):
        ckpt_lib.save(state, ck, s, keep=2)
    assert ckpt_lib.available_steps(ck) == [3, 4]
    # a partial (uncommitted) dir must be ignored
    os.makedirs(os.path.join(ck, "step_00000009"))
    assert ckpt_lib.latest_step(ck) == 4
    got = ckpt_lib.restore(ck, jax.tree.map(jnp.zeros_like, state))
    np.testing.assert_allclose(np.asarray(got["a"]), np.arange(8.0))


def test_checkpoint_elastic_remesh(tmp_path):
    """Save under one mesh layout, restore under another (pod loss)."""
    ck = str(tmp_path / "ck")
    from jax.sharding import NamedSharding, PartitionSpec as P
    state = {"w": jnp.arange(64.0).reshape(8, 8)}
    ckpt_lib.save(state, ck, 1)
    mesh2 = mesh_lib.make_mesh((1, 1), ("data", "model"))
    restored = ckpt_lib.restore(
        ck, jax.eval_shape(lambda: state), mesh=mesh2,
        pspecs={"w": P("data", "model")})
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.arange(64.0).reshape(8, 8))
    assert isinstance(restored["w"].sharding, NamedSharding)


@pytest.mark.slow
def test_preemption_guard_emergency_checkpoint(tmp_path):
    """SIGTERM mid-run -> clean exit with a committed checkpoint."""
    ck = str(tmp_path / "ck")
    mesh = mesh_lib.single_device_mesh()

    killer = threading.Timer(3.0, lambda: os.kill(os.getpid(),
                                                  signal.SIGTERM))
    killer.start()
    out = run(TINY, mesh, steps=10000, batch=8, seq=32,
              ckpt_dir=ck, ckpt_every=10 ** 9, log_every=0)
    killer.cancel()
    assert out["final_step"] < 10000            # stopped early
    assert ckpt_lib.latest_step(ck) == out["final_step"]


def test_run_with_restarts():
    calls = []

    def flaky(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise RuntimeError("node lost")
        return 42

    assert ft.run_with_restarts(flaky, max_restarts=3) == 42
    assert calls == [0, 1, 2]


def test_heartbeat_watchdog(tmp_path):
    hb1 = ft.Heartbeat(str(tmp_path / "h1.json"), host_id=0)
    hb2 = ft.Heartbeat(str(tmp_path / "h2.json"), host_id=1)
    hb1.beat(5)
    time.sleep(0.05)
    stale = ft.watchdog([hb1, hb2], max_age_s=10.0)
    assert stale == [1]                          # hb2 never beat
    stale = ft.watchdog([hb1, hb2], max_age_s=0.01)
    assert 0 in stale                            # hb1 now stale too


def test_step_timer_straggler():
    t = ft.StepTimer(straggler_factor=2.0, warmup=0)
    for _ in range(6):
        t.start()
        time.sleep(0.01)
        t.stop()
    t.start()
    time.sleep(0.08)
    out = t.stop()
    assert out["straggler"], out


def test_lm_stream_deterministic_and_host_sharded():
    a1 = next(lm_stream.batches(1, 4, 16, 100, host_id=0, num_hosts=2))
    a2 = next(lm_stream.batches(1, 4, 16, 100, host_id=0, num_hosts=2))
    b1 = next(lm_stream.batches(1, 4, 16, 100, host_id=1, num_hosts=2))
    np.testing.assert_array_equal(a1["tokens"], a2["tokens"])
    assert not np.array_equal(a1["tokens"], b1["tokens"])
    # targets are next-token shifted
    np.testing.assert_array_equal(a1["tokens"][:, 1:], a1["targets"][:, :-1])


def test_lm_stream_learnable():
    """The markov stream has far less entropy than uniform."""
    seqs = lm_stream.markov_sequences(0, 64, 128, vocab=64)
    # bigram conditional entropy estimate
    from collections import Counter, defaultdict
    ctx = defaultdict(Counter)
    for row in seqs:
        for t in range(2, len(row)):
            ctx[(row[t - 2], row[t - 1])][row[t]] += 1
    ents = []
    for c, cnt in ctx.items():
        tot = sum(cnt.values())
        if tot >= 5:
            p = np.array(list(cnt.values())) / tot
            ents.append(-(p * np.log(p)).sum())
    assert np.mean(ents) < 0.7 * np.log(64)


def test_synthetic_datasets_shapes_and_determinism():
    for ds in mnist_synthetic.DATASETS:
        x, y = mnist_synthetic.load(ds, "train", n=64, seed=0)
        x2, y2 = mnist_synthetic.load(ds, "train", n=64, seed=0)
        np.testing.assert_array_equal(x, x2)
        assert x.shape == (64, 784) and x.dtype == np.float32
        assert x.min() >= 0.0 and x.max() <= 1.0
        assert y.min() >= 0 and y.max() < mnist_synthetic.num_classes(ds)
        # both classes/labels present
        assert len(np.unique(y)) >= 2


def test_prefetcher_orders_and_propagates_errors():
    it = iter(range(10))
    pf = pipeline.Prefetcher(it, place=lambda x: x * 2)
    assert [next(pf) for _ in range(10)] == [0, 2, 4, 6, 8, 10, 12, 14,
                                             16, 18]

    def bad():
        yield 1
        raise ValueError("boom")

    pf2 = pipeline.Prefetcher(bad(), place=lambda x: x)
    assert next(pf2) == 1
    with pytest.raises(ValueError):
        next(pf2)
        next(pf2)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3-1.7b", "zamba2-2.7b", "rwkv6-7b"])
def test_serving_engine_matches_sequential(arch):
    cfg = reduced(C.get(arch)).with_(dtype="float32")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompts = [np.arange(4) + 3, np.arange(7) + 1, np.arange(5) + 9]
    outs = generate_batch(m, params, prompts, max_new_tokens=4,
                          max_len=48, slots=2, eos_id=-1)

    def single(prompt, n=4):
        batch = {"tokens": jnp.asarray(prompt[None]),
                 "cache": m.init_cache(1, 48)}
        logits, cache = m.prefill(params, batch)
        toks = [int(jnp.argmax(logits[0, -1]))]
        for _ in range(n - 1):
            logits, cache = m.decode_step(
                params, jnp.asarray([[toks[-1]]], jnp.int32), cache)
            toks.append(int(jnp.argmax(logits[0, -1])))
        return toks

    for p, got in zip(prompts, outs):
        assert single(np.asarray(p, np.int32)) == got


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["hashed_space", "int8"])
def test_train_with_grad_compression_converges(kind):
    """Compressed-gradient training (error feedback) still reduces loss —
    the cross-pod exchange path exercised end to end."""
    mesh = mesh_lib.single_device_mesh()
    out = run(TINY, mesh, steps=120, batch=16, seq=32, lr=3e-3,
              log_every=0, grad_compressor=kind)
    first = np.mean(out["losses"][:10])
    last = np.mean(out["losses"][-10:])
    assert last < first - 0.1, (kind, first, last)
