"""Observability layer: metrics registry, span tracer, and the
engine's instrumentation contract.

Host-side (no model):
- histogram bucket-edge semantics (Prometheus ``le``: a value exactly
  on an edge lands in the bucket that edge closes), percentile
  clamping, snapshot/diff arithmetic,
- `MetricView` compat surface (legacy ``stats["x"] += 1`` call sites
  publish into the registry),
- disabled-tracer no-op guarantee; Chrome trace-event JSON round-trip.

Engine-level (tiny decoder, real jitted prefill/decode):
- span nesting stays balanced under preemption-recompute (every
  ``queued``/``request`` B has its E, preempted requests re-open
  ``queued``),
- tracing is bitwise inert: the same seeded workload emits identical
  tokens with the tracer off vs on (prefix cache + chunked prefill +
  an oversubscribed pool — the busiest instrumented paths),
- ``shutdown()`` leak audit: clean engines report zero anomalies,
  corrupted bookkeeping increments ``kv.leak_anomalies`` instead of
  raising — including rows in the speculative draft pool.
"""
import json

import numpy as np
import pytest

from repro.obs.metrics import (Counter, Histogram, MetricsRegistry,
                               diff_snapshots)
from repro.obs.trace import ENGINE_PID, REQUEST_PID, Tracer


# ---------------------------------------------------------------------------
# histogram bucket semantics
# ---------------------------------------------------------------------------

def test_histogram_bucket_edges_le_semantics():
    h = Histogram("h", edges=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0):        # 1.0 exactly on edge -> bucket le=1.0
        h.observe(v)
    h.observe(1.5)              # (1, 2]
    h.observe(2.0)              # exactly on edge -> le=2.0 bucket
    h.observe(3.0)              # (2, 4]
    h.observe(9.0)              # overflow
    assert h.counts == [2, 2, 1, 1]
    assert h.count == 6
    assert h.total == pytest.approx(0.5 + 1.0 + 1.5 + 2.0 + 3.0 + 9.0)
    snap = h.snapshot()
    assert snap["min"] == 0.5 and snap["max"] == 9.0
    # cumulative le-buckets cover exactly the populated edges
    assert snap["buckets"] == [[1.0, 2], [2.0, 4], [4.0, 5], ["+Inf", 6]]


def test_histogram_percentiles_clamped_to_observed_range():
    h = Histogram("h", edges=(1.0, 10.0, 100.0))
    for v in (2.0, 3.0, 4.0):
        h.observe(v)
    # rank bucket is (1, 10]; upper edge 10 clamps to observed max 4
    assert h.percentile(50) == 4.0
    h.observe(500.0)                    # overflow bucket: p100 = vmax
    assert h.percentile(100) == 500.0
    assert h.mean == pytest.approx((2 + 3 + 4 + 500) / 4)
    empty = Histogram("e")
    assert empty.percentile(50) == 0.0
    assert empty.snapshot()["count"] == 0


def test_histogram_rejects_bad_edges():
    with pytest.raises(ValueError):
        Histogram("h", edges=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("h", edges=(1.0, 1.0))


# ---------------------------------------------------------------------------
# registry + dict-view compat
# ---------------------------------------------------------------------------

def test_metric_view_publishes_into_registry():
    r = MetricsRegistry()
    view = r.group("kv", keys=("pages_fresh",))
    view["pages_fresh"] += 3          # legacy += call site
    view["cow_copies"] += 1           # unknown key registers on touch
    assert r.counter("kv.pages_fresh").value == 3
    assert r.counter("kv.cow_copies").value == 1
    assert dict(view) == {"pages_fresh": 3, "cow_copies": 1}
    with pytest.raises(TypeError):
        del view["pages_fresh"]


def test_registry_name_kind_conflicts_raise():
    r = MetricsRegistry()
    r.counter("x")
    with pytest.raises(TypeError):
        r.gauge("x")
    assert isinstance(r.counter("x"), Counter)   # get-or-create idempotent


def test_snapshot_diff_and_render(tmp_path):
    r = MetricsRegistry()
    r.counter("a.n").inc(5)
    r.gauge("a.g").set(7)
    h = r.histogram("a.h", edges=(1.0, 2.0))
    h.observe(0.5)
    base = r.snapshot()
    r.counter("a.n").inc(2)
    h.observe(1.5)
    d = diff_snapshots(r.snapshot(), base)
    assert d["a.n"] == 2
    assert d["a.h"]["count"] == 1
    assert d["a.h"]["mean"] == pytest.approx(1.5)
    out = tmp_path / "metrics.json"
    r.export(str(out))
    loaded = json.loads(out.read_text())["metrics"]
    assert loaded["a.n"] == 7 and loaded["a.g"] == 7
    txt = r.render()
    assert "a.n" in txt and "hist" in txt and "gauge" in txt


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    assert tr.now() == 0.0
    tr.track(0, 0, "x")
    tr.begin(0, 0, "a")
    tr.complete(0, 0, "b", 0.0)
    tr.instant(0, 0, "c")
    tr.end(0, 0, "a")
    assert tr.events == [] and tr._tracks == {}


def test_trace_export_roundtrip(tmp_path):
    tr = Tracer()
    tr.track(REQUEST_PID, 3, "req 3")
    tr.begin(REQUEST_PID, 3, "request", prompt_len=4)
    t0 = tr.now()
    tr.complete(ENGINE_PID, 0, "tick", t0, decoded=2)
    tr.instant(REQUEST_PID, 3, "first_token")
    tr.end(REQUEST_PID, 3, "request")
    out = tmp_path / "trace.json"
    tr.export(str(out))
    doc = json.loads(out.read_text())
    ev = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    names = {e["args"]["name"] for e in ev if e["ph"] == "M"}
    assert {"engine", "requests", "req 3"} <= names
    x = [e for e in ev if e["ph"] == "X"][0]
    assert x["dur"] >= 0 and x["args"] == {"decoded": 2}
    assert [e["ph"] for e in ev if e["ph"] in "BE"] == ["B", "E"]
    ts = [e["ts"] for e in ev if e["ph"] != "M"]
    assert ts == sorted(ts)


# ---------------------------------------------------------------------------
# engine instrumentation (tiny decoder)
# ---------------------------------------------------------------------------

import jax  # noqa: E402

from repro.configs.base import ArchConfig  # noqa: E402
from repro.models import build  # noqa: E402
from repro.serving.engine import Engine, Request  # noqa: E402
from repro.serving.scheduler import SchedulerConfig  # noqa: E402

TINY = ArchConfig(
    name="tiny-obs", family="dense", arch_kind="decoder",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, remat=False, dtype="float32")


@pytest.fixture(scope="module")
def tiny():
    m = build(TINY)
    return m, m.init(jax.random.PRNGKey(0))


def _run(model, params, *, tracer=None, num_pages=None, seed=0,
         n_req=6, max_new=6, prefix_cache=False, prefill_chunk=None,
         debug_leak_check=False, draft=None):
    eng = Engine(model, params, max_concurrency=2, max_len=64,
                 eos_id=-1, page_size=8, num_pages=num_pages,
                 tracer=tracer, prefix_cache=prefix_cache,
                 prefill_chunk=prefill_chunk,
                 debug_leak_check=debug_leak_check, draft=draft,
                 scheduler=SchedulerConfig(max_queue=n_req + 1))
    rng = np.random.default_rng(seed)
    shared = rng.integers(2, TINY.vocab_size, size=11).astype(np.int32)
    for uid in range(n_req):
        tail = rng.integers(2, TINY.vocab_size,
                            size=int(rng.integers(2, 9))).astype(np.int32)
        eng.submit(Request(uid=uid, prompt=np.concatenate([shared, tail]),
                           max_new_tokens=max_new))
    done = eng.run()
    return eng, {r.uid: list(r.tokens) for r in done}


def test_spans_balance_under_preemption(tiny):
    """An oversubscribed pool forces preemption-recompute; every span
    stays balanced and preempted requests re-open ``queued``."""
    model, params = tiny
    tr = Tracer()
    # 6 usable pages for 2 rows x (up to 27 feed tokens / 8 per page):
    # both rows active oversubscribe the pool
    eng, toks = _run(model, params, tracer=tr, num_pages=7,
                     n_req=5, max_new=8)
    assert eng.stats()["preemptions"] > 0
    assert eng._n_preempt == eng.stats()["preemptions"]
    per_track = {}
    for e in tr.events:
        if e["ph"] in "BE" and e["pid"] == REQUEST_PID:
            d = per_track.setdefault((e["tid"], e["name"]), [0, 0])
            d[0 if e["ph"] == "B" else 1] += 1
    for (tid, name), (b, end) in per_track.items():
        assert b == end, f"unbalanced {name} span on request {tid}"
    # at least one preempted request waited in queue more than once
    assert any(name == "queued" and b >= 2
               for (tid, name), (b, _) in per_track.items())
    preempts = [e for e in tr.events if e.get("name") == "preempt"]
    assert len(preempts) == eng.stats()["preemptions"]
    # engine-track ticks recorded as X slices
    assert any(e["ph"] == "X" and e["pid"] == ENGINE_PID
               and e["name"] == "tick" for e in tr.events)


@pytest.mark.parametrize("seed", [0, 3])
def test_tracing_is_bitwise_inert(tiny, seed):
    """Same seeded workload, tracer off vs on: identical tokens, even
    through prefix-cache hits, chunked prefill, and preemption."""
    model, params = tiny
    kw = dict(num_pages=12, seed=seed, n_req=6, max_new=6,
              prefix_cache=True, prefill_chunk=8)
    _, toks_off = _run(model, params, tracer=Tracer(enabled=False), **kw)
    eng, toks_on = _run(model, params, tracer=Tracer(enabled=True), **kw)
    assert toks_on == toks_off
    assert eng.tracer.events       # the traced arm actually recorded


def test_engine_metrics_registry_names(tiny):
    model, params = tiny
    eng, toks = _run(model, params, n_req=4, max_new=4)
    snap = eng.metrics.snapshot()
    for name in ("engine.ticks", "engine.tokens", "engine.done",
                 "sched.submitted", "sched.queue_depth",
                 "kv.pages_in_use", "kv.pages_fresh",
                 "sampler.dispatches.decode"):
        assert name in snap, name
    assert snap["engine.done"] == 4
    assert snap["engine.tokens"] == sum(len(t) for t in toks.values())
    assert snap["engine.ttft_s"]["count"] == 4
    assert snap["engine.queue_wait_s"]["count"] == 4
    # stats() is a thin view over the same registry
    s = eng.stats()
    assert s["ticks"] == snap["engine.ticks"]
    assert s["submitted"] == snap["sched.submitted"]
    assert s["sampler_dispatches"]["decode"] \
        == snap["sampler.dispatches.decode"]


def test_leak_check_clean_and_corrupted(tiny):
    model, params = tiny
    eng, _ = _run(model, params, n_req=3, max_new=4,
                  debug_leak_check=True)
    eng.shutdown()
    assert eng.last_leak_error is None
    assert eng.metrics.snapshot()["kv.leak_anomalies"] == 0
    # corrupt the bookkeeping: a page allocated but held by no row
    eng.kv.alloc.alloc(1)
    eng.shutdown()
    assert eng.last_leak_error is not None
    assert eng.metrics.snapshot()["kv.leak_anomalies"] == 1


def test_leak_audit_covers_draft_kv_rows(tiny):
    """The shutdown audit extends to the speculative draft pool: a
    clean spec engine reports zero anomalies, and a corrupted DRAFT
    row (base pool untouched) still lands in ``kv.leak_anomalies`` /
    ``last_leak_error``."""
    model, params = tiny
    from repro.serving.draft import build_draft
    _, dm, dp = build_draft(TINY, params, "1/8")
    eng, _ = _run(model, params, n_req=3, max_new=4,
                  debug_leak_check=True, draft=(dm, dp))
    eng.shutdown()
    assert eng.last_leak_error is None
    assert eng.metrics.snapshot()["kv.leak_anomalies"] == 0
    # corrupt only the draft pool's bookkeeping
    eng.spec.kv.alloc.alloc(1)
    eng.kv.leak_check()                 # base pool audits clean...
    eng.shutdown()                      # ...the draft audit catches it
    assert eng.last_leak_error is not None
    assert eng.metrics.snapshot()["kv.leak_anomalies"] == 1
