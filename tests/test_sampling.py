"""Sampling & streaming request API: SamplingParams validation and
compat lowering, the fused batched sampler's contracts (greedy ==
argmax, penalties, counter-based PRNG streams), stop sequences and
finish reasons, logprob reporting, RequestHandle streaming, and the
one-dispatch-per-decode-tick invariant.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import build
from repro.serving import (Engine, Request, SamplingParams,
                           SchedulerConfig, generate_batch)
from repro.serving import sampling as S

TINY = ArchConfig(
    name="tiny-sampling", family="dense", arch_kind="decoder",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, remat=False, dtype="float32")


@pytest.fixture(scope="module")
def tiny():
    m = build(TINY)
    return m, m.init(jax.random.PRNGKey(0))


def _prompt(rng, lo=3, hi=12):
    return rng.integers(2, TINY.vocab_size,
                        size=int(rng.integers(lo, hi))).astype(np.int32)


# ---------------------------------------------------------------------------
# SamplingParams validation + compat lowering
# ---------------------------------------------------------------------------

def test_sampling_params_validation():
    for bad in (dict(temperature=-0.1), dict(top_k=-1), dict(top_p=0.0),
                dict(top_p=1.5), dict(min_p=-0.1), dict(min_p=1.1),
                dict(repetition_penalty=0.0), dict(max_tokens=0),
                dict(logprobs=-1), dict(seed="x"), dict(stop=((),))):
        with pytest.raises(ValueError):
            SamplingParams(**bad)
    sp = SamplingParams(temperature=0.5, top_k=10, stop=[3, 4])
    assert sp.stop == ((3, 4),)           # single sequence wrapped
    sp = SamplingParams(stop=[[1, 2], (5,)])
    assert sp.stop == ((1, 2), (5,))
    assert SamplingParams().greedy and not SamplingParams(
        temperature=0.1).greedy


def test_legacy_request_lowers_into_sampling_params():
    r = Request(uid=0, prompt=np.asarray([2, 3], np.int32),
                max_new_tokens=5, temperature=0.7)
    assert r.sampling == SamplingParams(temperature=0.7, max_tokens=5)
    # explicit sampling wins and back-fills the legacy mirrors
    sp = SamplingParams(temperature=1.2, max_tokens=9, top_p=0.8)
    r = Request(uid=1, prompt=np.asarray([2], np.int32),
                max_new_tokens=3, temperature=0.0, sampling=sp)
    assert r.max_new_tokens == 9 and r.temperature == 1.2


def test_compat_legacy_request_token_identical_to_explicit_params(tiny):
    """The compat shim regression: legacy Request(temperature=0) and an
    explicit default SamplingParams produce identical greedy tokens."""
    m, params = tiny
    rng = np.random.default_rng(0)
    prompts = [_prompt(rng) for _ in range(3)]

    def run(make):
        eng = Engine(m, params, max_concurrency=2, max_len=64, eos_id=-1,
                     page_size=8)
        for i, p in enumerate(prompts):
            assert eng.submit(make(i, p))
        done = eng.run()
        return [r.tokens for r in sorted(done, key=lambda r: r.uid)]

    legacy = run(lambda i, p: Request(uid=i, prompt=p.copy(),
                                      max_new_tokens=6))
    explicit = run(lambda i, p: Request(
        uid=i, prompt=p.copy(),
        sampling=SamplingParams(max_tokens=6)))
    assert legacy == explicit


# ---------------------------------------------------------------------------
# fused sampler unit contracts
# ---------------------------------------------------------------------------

def _state_arrays(st, sl=slice(None)):
    return {k: jnp.asarray(v) for k, v in st.batch(sl).items()}


def test_penalties_reference_and_default_noop():
    rng = np.random.default_rng(1)
    x = rng.standard_normal((2, 31)).astype(np.float32)
    seen = rng.random((2, 31)) < 0.3
    out_seen = seen & (rng.random((2, 31)) < 0.5)
    rp = np.asarray([1.7, 1.0], np.float32)
    pp = np.asarray([0.6, 0.0], np.float32)
    got = np.asarray(S.apply_penalties(
        jnp.asarray(x), jnp.asarray(seen), jnp.asarray(out_seen),
        jnp.asarray(rp), jnp.asarray(pp)))
    want = x.copy()
    pen = np.where(x > 0, x / rp[:, None], x * rp[:, None])
    want = np.where(seen, pen, want) - pp[:, None] * out_seen
    np.testing.assert_allclose(got, want, rtol=1e-6)
    # row 1 has defaults: bitwise untouched (greedy-compat invariant)
    np.testing.assert_array_equal(got[1], x[1])


def test_counter_prng_deterministic_and_position_keyed():
    """Same (seed, pos) => same draw; advancing pos changes it; the
    call is pure (no hidden stream state)."""
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.standard_normal((1, 128)) * 2, jnp.float32)
    st = S.SamplerState(1, 128)
    req = Request(uid=0, prompt=np.asarray([5, 6], np.int32),
                  sampling=SamplingParams(temperature=1.0, seed=11,
                                          max_tokens=8))
    req.seed_used = 11
    st.bind(0, req)
    a = int(S.sample_tokens(logits, _state_arrays(st))["token"][0])
    b = int(S.sample_tokens(logits, _state_arrays(st))["token"][0])
    assert a == b
    toks = set()
    for pos in range(12):
        st.pos[0] = pos
        toks.add(int(S.sample_tokens(logits, _state_arrays(st))
                     ["token"][0]))
    assert len(toks) > 1, "position never changed the draw"


def test_greedy_rows_are_argmax_and_mix_with_sampled():
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    st = S.SamplerState(4, 64)
    for row in (1, 3):
        req = Request(uid=row, prompt=np.asarray([1], np.int32),
                      sampling=SamplingParams(temperature=1.5, top_k=8,
                                              seed=row, max_tokens=4))
        req.seed_used = row
        st.bind(row, req)
    out = S.sample_tokens(logits, _state_arrays(st))
    am = np.asarray(jnp.argmax(logits, -1))
    got = np.asarray(out["token"])
    assert got[0] == am[0] and got[2] == am[2]   # cleared rows: greedy


def test_greedy_specialization_bitwise_matches_full_pipeline():
    """with_sampling=False (the all-greedy dispatch) must return the
    same tokens/logprobs as the full pipeline for greedy rows."""
    rng = np.random.default_rng(15)
    logits = jnp.asarray(rng.standard_normal((3, 70)), jnp.float32)
    st = S.SamplerState(3, 70)              # cleared rows: all greedy
    arrays = {k: jnp.asarray(v) for k, v in st.batch().items()}
    full = S.sample_tokens(logits, arrays, logprob_k=2,
                           with_sampling=True)
    fast = S.sample_tokens(logits, arrays, logprob_k=2,
                           with_sampling=False)
    for key in full:
        np.testing.assert_array_equal(np.asarray(full[key]),
                                      np.asarray(fast[key]), err_msg=key)


def test_truncationless_dispatch_bitwise_matches_full():
    """with_truncation=False (temperature-only batches) must match the
    full pipeline when every row's truncation knobs are disabled."""
    rng = np.random.default_rng(16)
    logits = jnp.asarray(rng.standard_normal((2, 90)) * 2, jnp.float32)
    st = S.SamplerState(2, 90)
    for row in range(2):
        req = Request(uid=row, prompt=np.asarray([3], np.int32),
                      sampling=SamplingParams(temperature=1.1, seed=row,
                                              max_tokens=4))
        req.seed_used = row
        st.bind(row, req)
    assert not st.uses_truncation.any() and st.is_sampled.all()
    arrays = {k: jnp.asarray(v) for k, v in st.batch().items()}
    full = S.sample_tokens(logits, arrays, with_truncation=True)
    fast = S.sample_tokens(logits, arrays, with_truncation=False)
    for key in full:
        np.testing.assert_array_equal(np.asarray(full[key]),
                                      np.asarray(fast[key]), err_msg=key)


def test_maskless_dispatch_bitwise_matches_masked():
    """The engine omits the (B, V) penalty masks when no bound row uses
    penalties — that specialization must be bitwise identical to the
    full pipeline (defaults are exact no-ops)."""
    rng = np.random.default_rng(14)
    logits = jnp.asarray(rng.standard_normal((3, 80)) * 2, jnp.float32)
    st = S.SamplerState(3, 80)
    for row in range(3):
        req = Request(uid=row, prompt=np.asarray([4, 5], np.int32),
                      sampling=SamplingParams(temperature=1.0, top_p=0.9,
                                              seed=row, max_tokens=4))
        req.seed_used = row
        st.bind(row, req)
    assert not st.uses_penalties.any()
    with_masks = {k: jnp.asarray(v) for k, v in st.batch().items()}
    without = {k: jnp.asarray(v) for k, v in
               st.batch(with_masks=False).items()}
    a = S.sample_tokens(logits, with_masks, logprob_k=3)
    b = S.sample_tokens(logits, without, logprob_k=3)
    for key in a:
        np.testing.assert_array_equal(np.asarray(a[key]),
                                      np.asarray(b[key]), err_msg=key)


def test_logprobs_are_log_softmax_of_penalized_logits():
    rng = np.random.default_rng(4)
    logits = jnp.asarray(rng.standard_normal((2, 50)) * 3, jnp.float32)
    st = S.SamplerState(2, 50)
    out = S.sample_tokens(logits, _state_arrays(st), logprob_k=5)
    lp = np.asarray(jax.nn.log_softmax(logits, axis=-1))
    tok = np.asarray(out["token"])
    np.testing.assert_allclose(np.asarray(out["logprob"]),
                               lp[np.arange(2), tok], rtol=1e-6)
    # top-k report: descending, and the greedy token leads it
    tlp = np.asarray(out["topk_logprobs"])
    tid = np.asarray(out["topk_ids"])
    assert (np.diff(tlp, axis=1) <= 0).all()
    np.testing.assert_array_equal(tid[:, 0], tok)


# ---------------------------------------------------------------------------
# engine: stop sequences, finish reasons, logprobs, streaming
# ---------------------------------------------------------------------------

def _run_one(tiny, req, **eng_kw):
    m, params = tiny
    eng = Engine(m, params, max_concurrency=2, max_len=64, eos_id=-1,
                 page_size=8, **eng_kw)
    h = eng.submit(req)
    assert h
    eng.run()
    return eng, h


def test_stop_sequence_finishes_with_reason_stop(tiny):
    rng = np.random.default_rng(5)
    prompt = _prompt(rng)
    # learn greedy's first two tokens, then rerun with them as stop
    _, h = _run_one(tiny, Request(uid=0, prompt=prompt.copy(),
                                  max_new_tokens=8))
    ref_toks = list(h.req.tokens)
    assert h.req.finish_reason == "length"
    req = Request(uid=1, prompt=prompt.copy(),
                  sampling=SamplingParams(max_tokens=8,
                                          stop=(tuple(ref_toks[:2]),)))
    eng, h2 = _run_one(tiny, req)
    assert req.tokens == ref_toks[:2]        # stop tokens stay in output
    assert req.finish_reason == "stop" and req.done
    assert eng.stats()["finish_reasons"]["stop"] == 1


def test_max_len_truncation_reports_length(tiny):
    """The max_len force-retire backstop reports finish_reason
    "length" + truncated.  Unreachable through submit (fits_ever bounds
    prompt+max_tokens by max_len), so the budget is widened after
    acceptance to simulate the inconsistency the backstop guards."""
    import dataclasses as dc
    rng = np.random.default_rng(6)
    req = Request(uid=0, prompt=_prompt(rng, 10, 11),
                  sampling=SamplingParams(max_tokens=20))
    m, params = tiny
    eng = Engine(m, params, max_concurrency=1, max_len=32, eos_id=-1,
                 page_size=8)
    assert eng.submit(req)
    req.sampling = dc.replace(req.sampling, max_tokens=1000)
    req.max_new_tokens = 1000
    eng.run()
    assert req.truncated and req.finish_reason == "length"
    assert len(req.tokens) < 1000


def test_deadline_expiry_reports_deadline(tiny):
    m, params = tiny
    rng = np.random.default_rng(7)
    eng = Engine(m, params, max_concurrency=1, max_len=64, eos_id=-1,
                 page_size=8,
                 scheduler=SchedulerConfig(deadline_s=0.05))
    first = Request(uid=0, prompt=_prompt(rng), max_new_tokens=4)
    starved = Request(uid=1, prompt=_prompt(rng), max_new_tokens=4)
    h0 = eng.submit(first)
    eng.step()                   # admit first: exempt from the deadline
    h1 = eng.submit(starved)     # queued behind the only row
    assert h0 and h1
    import time
    time.sleep(0.06)             # let the queue wait exceed deadline_s
    eng.run()
    assert first.done and starved.status == "expired"
    assert starved.finish_reason == "deadline"
    assert eng.stats()["finish_reasons"]["deadline"] == 1
    # the starved handle terminates its stream with the deadline marker
    deltas = list(h1)
    assert deltas and deltas[-1].done \
        and deltas[-1].finish_reason == "deadline"


def test_request_logprobs_accumulate_and_cap(tiny):
    m, params = tiny
    rng = np.random.default_rng(8)
    req = Request(uid=0, prompt=_prompt(rng),
                  sampling=SamplingParams(max_tokens=5, logprobs=3))
    eng, _ = _run_one(tiny, req)
    assert len(req.token_logprobs) == len(req.tokens) == 5
    assert all(lp <= 0 for lp in req.token_logprobs)
    assert req.cumulative_logprob == pytest.approx(
        sum(req.token_logprobs))
    assert len(req.topk_logprobs) == 5
    assert all(len(step) == 3 for step in req.topk_logprobs)
    # greedy: the chosen token tops every report
    for tok, step in zip(req.tokens, req.topk_logprobs):
        assert step[0][0] == tok
    with pytest.raises(ValueError):
        eng.submit(Request(uid=1, prompt=_prompt(rng),
                           sampling=SamplingParams(logprobs=99)))


def test_streaming_handle_iterates_deltas(tiny):
    m, params = tiny
    rng = np.random.default_rng(9)
    req = Request(uid=0, prompt=_prompt(rng),
                  sampling=SamplingParams(temperature=0.9, seed=3,
                                          max_tokens=6))
    eng = Engine(m, params, max_concurrency=1, max_len=64, eos_id=-1,
                 page_size=8)
    h = eng.submit(req)
    assert h and bool(h)
    deltas = list(h)                       # drives the engine itself
    streamed = [t for d in deltas for t in d.new_token_ids]
    assert streamed == req.tokens and len(req.tokens) == 6
    assert deltas[-1].done and deltas[-1].finish_reason == "length"
    assert deltas[-1].num_generated == 6
    assert deltas[-1].cumulative_logprob == pytest.approx(
        req.cumulative_logprob)
    assert [d for d in deltas[:-1] if d.finish_reason] == []
    assert list(h) == []                   # exhausted stream stays empty
    # rejected submit: falsy handle, empty stream
    bad = Request(uid=1, prompt=np.arange(40, dtype=np.int32) + 2,
                  sampling=SamplingParams(max_tokens=1000))
    hb = eng.submit(bad)
    assert not hb and list(hb) == [] and bad.status == "rejected"


def test_one_fused_dispatch_per_decode_tick_mixed_params(tiny):
    """However many distinct SamplingParams share the batch, decode
    runs EXACTLY one sampler dispatch per decoding tick."""
    m, params = tiny
    rng = np.random.default_rng(10)
    eng = Engine(m, params, max_concurrency=4, max_len=64, eos_id=-1,
                 page_size=8)
    mixes = [SamplingParams(max_tokens=6),
             SamplingParams(temperature=0.8, top_p=0.9, seed=1,
                            max_tokens=6),
             SamplingParams(temperature=1.3, top_k=11, min_p=0.05,
                            seed=2, max_tokens=6),
             SamplingParams(temperature=1.0, repetition_penalty=1.3,
                            presence_penalty=0.4, seed=3, max_tokens=6)]
    for i, sp in enumerate(mixes):
        assert eng.submit(Request(uid=i, prompt=_prompt(rng),
                                  sampling=sp))
    eng.run()
    st = eng.stats()
    assert st["done"] == 4
    decode_ticks = st["decode_ticks"] + st["interleaved_ticks"]
    assert st["sampler_dispatches"]["decode"] == decode_ticks > 0
    assert st["sampler_dispatches"]["prefill"] == 4


def test_seeded_generation_reproduces_across_engines(tiny):
    """Same seeds => identical tokens on a fresh engine; different seed
    => different tokens (overwhelmingly)."""
    m, params = tiny
    rng = np.random.default_rng(11)
    prompts = [_prompt(rng, 8, 12) for _ in range(3)]
    sp = [SamplingParams(temperature=1.2, top_p=0.95, seed=100 + i,
                         max_tokens=10) for i in range(3)]
    a = generate_batch(m, params, prompts, max_len=64, slots=2,
                       eos_id=-1, page_size=8, sampling=sp)
    b = generate_batch(m, params, prompts, max_len=64, slots=2,
                       eos_id=-1, page_size=8, sampling=sp)
    assert a == b
    sp2 = [SamplingParams(temperature=1.2, top_p=0.95, seed=900 + i,
                          max_tokens=10) for i in range(3)]
    c = generate_batch(m, params, prompts, max_len=64, slots=2,
                       eos_id=-1, page_size=8, sampling=sp2)
    assert c != a


def test_unseeded_sampling_reproducible_via_engine_seed(tiny):
    """seed=None draws from the engine's seeded stream: same engine
    seed + submit order reproduce; different engine seed diverges."""
    m, params = tiny
    rng = np.random.default_rng(12)
    prompts = [_prompt(rng, 8, 12) for _ in range(2)]

    def run(engine_seed):
        sp = [SamplingParams(temperature=1.1, max_tokens=8)
              for _ in prompts]
        return generate_batch(m, params, prompts, max_len=64, slots=2,
                              eos_id=-1, page_size=8, sampling=sp,
                              seed=engine_seed)

    assert run(0) == run(0)
    assert run(0) != run(1)


def test_from_artifact_engine_serves_sampling_api(tiny, tmp_path):
    """Cold start from .hnart: the sampling surface passes through and
    seeded decode is token-identical to the in-memory engine."""
    from repro import artifact

    cfg = TINY.hashed_variant(0.25)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(1))
    path = str(tmp_path / "tiny.hnart")
    artifact.export_model(path, cfg, params)
    rng = np.random.default_rng(13)
    prompts = [_prompt(rng) for _ in range(3)]
    sp = [SamplingParams(temperature=0.9, top_k=20, seed=i,
                         max_tokens=5, logprobs=2) for i in range(3)]

    def drive(eng):
        reqs = [Request(uid=i, prompt=p.copy(), sampling=sp[i])
                for i, p in enumerate(prompts)]
        for r in reqs:
            assert eng.submit(r)
        eng.run()
        return [(r.tokens, r.finish_reason) for r in reqs]

    live = drive(Engine(m, params, max_concurrency=2, max_len=64,
                        eos_id=-1, page_size=8, max_logprobs=4))
    cold = drive(Engine.from_artifact(path, slots=2, max_len=64,
                                      eos_id=-1, page_size=8,
                                      max_logprobs=4))
    assert cold == live
