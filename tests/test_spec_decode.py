"""Self-speculative decoding: the draft ladder's derivation invariants
and the policy round-trip through artifacts.

- a draft `CompressionPolicy` survives the ``.hnart`` header and the
  registry metadata channel byte-for-byte (``policy_to_dict`` /
  ``policy_from_dict`` stay exact inverses through both),
- hash seeds are ratio-independent: every rung of the ladder
  re-addresses the same per-slot hash streams as the served banks
  (this is what makes a policy rung a *free* draft model),
- the equal-ratio rung aliases every param leaf by reference — the
  zero-copy degenerate draft,
- ``Engine.from_artifact(..., draft_policy=...)`` cold-starts a
  speculative engine off one mmap whose output is bitwise the
  non-speculative engine's,
- spec.* metrics land in the engine's MAIN registry while the draft
  pool keeps its accounting private (no aliasing of kv.* counters),
- the regression gate's fresh-only-key semantics: sections the
  baseline predates WARN at ``--level invariants``, never fail.

Distribution-identity under preemption / prefix cache / chunked
prefill is fuzz-pinned in tests/test_serving_fuzz.py.
"""
import importlib.util
import pathlib

import jax
import numpy as np
import pytest

from repro import artifact
from repro.artifact import format as afmt
from repro.artifact import registry as areg
from repro.configs.base import ArchConfig
from repro.models import build
from repro.policy import rules as POL
from repro.serving import draft as draft_lib
from repro.serving.engine import Engine, Request
from repro.serving.api import SamplingParams

TINY = ArchConfig(
    name="tiny-spec", family="dense", arch_kind="decoder",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, remat=False, dtype="float32")


# ---------------------------------------------------------------------------
# policy round-trip: .hnart header + registry metadata
# ---------------------------------------------------------------------------

def test_draft_policy_roundtrips_header_and_registry(tmp_path):
    pol = POL.CompressionPolicy(
        rules=(POL.PolicyRule(match="layers.attn.*", compression=0.25),),
        compression=0.125)
    cfg = TINY.policy_variant(pol)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "draft.hnart")
    artifact.export_model(path, cfg, params)

    # channel 1: the artifact header carries the full policy
    cfg2, _, _ = artifact.load_model(path)
    assert cfg2 == cfg
    assert POL.effective(cfg2) == pol
    header = afmt.read_header(path)
    assert POL.policy_from_dict(header["config"]["hash_policy"],
                                strict=False) == pol

    # channel 2: registry metadata names the draft rung for cold starts
    root = str(tmp_path / "reg")
    areg.register(root, "toy", path,
                  metadata={"draft_policy": POL.policy_to_dict(pol)})
    e = areg.resolve(root, "toy")
    assert POL.policy_from_dict(e["metadata"]["draft_policy"]) == pol


# ---------------------------------------------------------------------------
# ladder invariants: shared seeds, zero-copy top rung
# ---------------------------------------------------------------------------

def test_draft_banks_reuse_base_seeds_across_ratios():
    """Seeds key on the slot, never the ratio: every rung of the ladder
    hashes into the same per-slot streams as the served banks."""
    from repro.models.transformer import bank_spec_map
    base = TINY.hashed_variant(0.25)
    base_specs = bank_spec_map(base)
    assert any(s is not None for s in base_specs.values())
    for ratio in (0.25, 0.125, 1 / 16):
        pol = draft_lib.resolve_draft_policy(ratio, base)
        dspecs = bank_spec_map(base.policy_variant(pol).with_(
            name="tiny-spec-draft"))
        assert set(dspecs) == set(base_specs)
        for path, bs in base_specs.items():
            ds = dspecs[path]
            if bs is None:
                assert ds is None, path
                continue
            assert ds.seed == bs.seed, (path, ratio)
            assert ds.virtual_shape == bs.virtual_shape, (path, ratio)
            assert ds.mode == bs.mode and ds.exec_path == bs.exec_path


def test_equal_ratio_draft_aliases_every_leaf():
    """The degenerate top rung: draft spec == base spec on every slot,
    so derive_draft_params aliases the whole tree by reference."""
    base = TINY.hashed_variant(0.125)
    m = build(base)
    params = m.init(jax.random.PRNGKey(0))
    dcfg, dmodel, dparams = draft_lib.build_draft(base, params, 0.125)
    lb = jax.tree_util.tree_leaves(params)
    ld = jax.tree_util.tree_leaves(dparams)
    assert len(lb) == len(ld)
    assert all(a is b for a, b in zip(ld, lb))


def test_deeper_rung_shrinks_banks_but_aliases_dense():
    base = TINY.hashed_variant(0.25)
    m = build(base)
    params = m.init(jax.random.PRNGKey(0))
    _, _, dparams = draft_lib.build_draft(base, params, 1 / 16)
    n_alias = n_shrunk = 0
    flat_b = jax.tree_util.tree_leaves_with_path(params)
    flat_d = jax.tree_util.tree_leaves_with_path(dparams)
    for (pb, b), (pd, d) in zip(flat_b, flat_d):
        assert pb == pd
        if d is b:
            n_alias += 1
        else:
            assert d.size < b.size, pb
            n_shrunk += 1
    assert n_alias > 0 and n_shrunk > 0


# ---------------------------------------------------------------------------
# cold start: one mmap feeds both models
# ---------------------------------------------------------------------------

def test_from_artifact_draft_policy_bitwise_identical(tmp_path):
    cfg = TINY.hashed_variant(0.125)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "m.hnart")
    artifact.export_model(path, cfg, params)
    root = str(tmp_path / "reg")
    areg.register(root, "toy", path)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 7)]
    sps = [None,
           SamplingParams(temperature=0.9, top_p=0.9, seed=7,
                          max_tokens=6),
           None]

    def run(**extra):
        eng = Engine.from_artifact("toy", registry_root=root, slots=2,
                                   max_len=64, eos_id=-1, page_size=8,
                                   **extra)
        for uid, (p, sp) in enumerate(zip(prompts, sps)):
            assert eng.submit(Request(uid=uid, prompt=p.copy(),
                                      max_new_tokens=6, sampling=sp))
        done = eng.run()
        return {r.uid: list(r.tokens) for r in done}, eng

    base, _ = run()
    spec, eng = run(draft_policy="1/16", spec_k=3)
    assert spec == base
    st = eng.stats()["spec"]
    assert st["verify_dispatches"] > 0 and st["k"] == 3
    eng.spec.leak_check()
    assert eng.spec.kv.alloc.num_used == 0


# ---------------------------------------------------------------------------
# observability placement
# ---------------------------------------------------------------------------

def test_spec_metrics_in_main_registry_draft_pool_private():
    cfg = TINY.hashed_variant(0.25)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    _, dm, dp = draft_lib.build_draft(cfg, params, 1 / 8)
    eng = Engine(m, params, max_concurrency=2, max_len=64, eos_id=-1,
                 page_size=8, draft=(dm, dp), spec_k=3)
    rng = np.random.default_rng(1)
    for uid in range(3):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(2, cfg.vocab_size, 6)
                           .astype(np.int32),
                           max_new_tokens=5))
    eng.run()
    snap = eng.metrics.snapshot()
    for name in ("spec.ticks", "spec.proposed", "spec.accepted_drafts",
                 "spec.rollback_tokens", "spec.draft_dispatches",
                 "spec.verify_dispatches", "spec.accept_len"):
        assert name in snap, name
    assert snap["spec.ticks"] > 0
    assert snap["spec.accept_len"]["count"] > 0
    # the draft pool's page accounting must NOT alias the base kv.*
    # metrics: its cache publishes into a private registry
    assert eng.spec._kv_metrics is not eng.metrics
    assert "kv.pages_fresh" in eng.spec._kv_metrics.snapshot()
    st = eng.stats()["spec"]
    assert st["ticks"] == snap["spec.ticks"]
    assert 0.0 <= st["accept_rate"] <= 1.0


# ---------------------------------------------------------------------------
# regression-gate semantics for freshly grown bench sections
# ---------------------------------------------------------------------------

def _load_check_regression():
    p = pathlib.Path(__file__).resolve().parents[1] / "benchmarks" \
        / "check_regression.py"
    spec = importlib.util.spec_from_file_location("_check_regression", p)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_regression_gate_fresh_only_sections_warn_not_fail():
    """A new bench section (e.g. spec_decode landing in this PR) must
    never block at --level invariants: fresh-only keys WARN.  A key
    *missing* from fresh results stays a hard failure."""
    cr = _load_check_regression()
    base = {"mixed_sampling": {"tokens_match": True, "tok_s": 1.0}}
    fresh = {"mixed_sampling": {"tokens_match": True, "tok_s": 9.0},
             "spec_decode": {"tokens_match": True, "accept_rate": 0.9,
                             "speedup": 1.3}}
    probs = list(cr.compare(base, fresh, level="invariants",
                            tight_tol=0.05, perf_tol=0.75))
    assert probs and all(sev == "warn" for sev, _ in probs)
    assert any("spec_decode" in msg for _, msg in probs)
    # shrinking the bench is a regression, not a warning
    probs = list(cr.compare(fresh, base, level="invariants",
                            tight_tol=0.05, perf_tol=0.75))
    assert any(sev == "fail" and "missing key" in msg
               for sev, msg in probs)
    # spec correctness/accounting keys gate once baselined
    assert cr.classify(("spec_decode", "tokens_match")) == cr.EXACT
    assert cr.classify(("spec_decode", "spec_k")) == cr.EXACT
    assert cr.classify(("spec_decode", "accept_rate")) == cr.TIGHT
    assert cr.classify(("spec_decode", "speedup")) == cr.PERF
