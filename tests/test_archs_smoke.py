"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, shape + finiteness assertions; prefill/decode cache paths; hashed
variants (the paper technique) on every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs import reduced
from repro.models import build

B, S = 2, 16
KEY = jax.random.PRNGKey(0)


def _batch(cfg, key=None, seq=S):
    key = key or jax.random.PRNGKey(7)
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(k1, (B, seq), 0, cfg.vocab_size),
        "targets": jax.random.randint(k2, (B, seq), 0, cfg.vocab_size),
    }
    if cfg.arch_kind == "encdec":
        batch["frames"] = jax.random.normal(
            k3, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.num_image_tokens:
        batch["image_embeds"] = jax.random.normal(
            k3, (B, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("name", C.ASSIGNED)
def test_train_step_smoke(name):
    cfg = reduced(C.get(name))
    m = build(cfg)
    params = m.init(KEY)
    batch = _batch(cfg)
    loss, metrics = jax.jit(m.train_loss)(params, batch)
    assert np.isfinite(float(loss)), (name, float(loss))
    assert float(loss) > 0.0
    assert np.isfinite(float(metrics["accuracy"]))
    # one SGD step must change the loss (gradients flow everywhere relevant)
    grads = jax.grad(lambda p: m.train_loss(p, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0.0


@pytest.mark.parametrize("name", C.ASSIGNED)
def test_prefill_decode_smoke(name):
    cfg = reduced(C.get(name))
    m = build(cfg)
    params = m.init(KEY)
    max_len = 32
    batch = _batch(cfg, seq=8)
    batch["cache"] = m.init_cache(B, max_len)
    logits, cache = jax.jit(m.prefill)(params, batch)
    assert logits.shape[:2] == (B, 1)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1).astype(jnp.int32)
    for _ in range(2):
        logits, cache = jax.jit(m.decode_step)(params, tok[:, None], cache)
        assert logits.shape[:2] == (B, 1)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1).astype(jnp.int32)
    assert int(cache["index"]) == 8 + 2 + cfg.num_image_tokens


@pytest.mark.parametrize("name", ["llama3-405b", "granite-moe-1b-a400m",
                                  "zamba2-2.7b", "rwkv6-7b",
                                  "whisper-medium"])
@pytest.mark.slow
def test_hashed_variant_smoke(name):
    """The paper technique as a first-class config flag on every family."""
    cfg = reduced(C.get(name)).with_(
        hashed=True, compression=0.25, hash_mode="element",
        hash_panel_cols=0, hash_path="auto")
    dense = reduced(C.get(name))
    m = build(cfg)
    md = build(dense)
    params = m.init(KEY)
    pdense = md.init(KEY)

    def proj_count(p):
        # compression applies to projection weights; embeddings/head are
        # governed by hash_embeddings (off here)
        return sum(x.size for k, x in
                   jax.tree_util.tree_leaves_with_path(p)
                   if "embed" not in str(k) and "lm_head" not in str(k))

    n_hashed, n_dense = proj_count(params), proj_count(pdense)
    assert n_hashed < 0.45 * n_dense, (n_hashed, n_dense)
    batch = _batch(cfg)
    loss, _ = jax.jit(m.train_loss)(params, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0.0
    grads = jax.grad(lambda p: m.train_loss(p, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0.0


def test_decode_matches_full_forward_decoder():
    """Teacher-forced decode must reproduce the training forward exactly
    (GQA + RoPE + sliding-window cache correctness end-to-end).
    fp32 so the comparison is numerically meaningful."""
    cfg = reduced(C.get("gemma3-4b")).with_(dtype="float32")
    m = build(cfg)
    params = m.init(KEY)
    seq = 12
    batch = _batch(cfg, seq=seq)
    # full forward logits via train path
    x = batch["tokens"]
    batch_pf = dict(batch)
    batch_pf["tokens"] = x[:, :1]
    batch_pf["cache"] = m.init_cache(B, seq + 2)
    logits, cache = m.prefill(params, batch_pf)
    outs = [logits]
    for t in range(1, seq):
        logits, cache = m.decode_step(params, x[:, t:t + 1], cache)
        outs.append(logits)
    dec_logits = jnp.concatenate(outs, axis=1)

    # training-path logits (same tokens, no cache)
    from repro.models.transformer import softmax_xent  # noqa
    # reuse train_loss internals by re-running prefill with full tokens:
    batch_full = dict(batch)
    batch_full["cache"] = m.init_cache(B, seq + 2)
    last, _ = m.prefill(params, batch_full)
    np.testing.assert_allclose(
        np.asarray(last[:, 0], np.float32),
        np.asarray(dec_logits[:, -1], np.float32), rtol=2e-4, atol=2e-4)


def test_pspecs_match_params():
    for name in ["llama3-405b", "granite-moe-1b-a400m", "zamba2-2.7b",
                 "rwkv6-7b", "whisper-medium"]:
        cfg = reduced(C.get(name))
        m = build(cfg)
        params = jax.eval_shape(m.init, KEY)
        specs = m.pspecs()
        jax.tree.map(lambda p, s: None, params, specs,
                     is_leaf=lambda x: hasattr(x, "shape"))  # same structure
        pl = jax.tree.structure(params)
        from jax.sharding import PartitionSpec as P
        sl = jax.tree.structure(specs,
                                is_leaf=lambda x: isinstance(x, P))
        assert pl == sl, (name, pl, sl)
