"""HTTP serving front-end: the asyncio layer must be an observation-
preserving wrapper around the tick-driven engine.

- completions through the server — streaming SSE and plain JSON,
  interleaved — are bitwise token-identical to submitting the same
  requests to an identically-seeded ``Engine`` directly,
- protocol errors map deterministically: unknown model -> 404, bad
  payload / never-fits prompt -> 400, wrong method -> 405, full
  queue -> 429 (with Retry-After), queue-deadline expiry -> 504,
- graceful drain: ``begin_drain`` stops admission (503 on /healthz and
  new submissions), cancels queued requests, finishes in-flight rows,
  and the driver exits; ``http.*`` metrics land in the engine registry.

Stdlib-asyncio only (the CI image has no HTTP client/server deps);
each test drives its own event loop via ``asyncio.run``.
"""
import asyncio
import json

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import build
from repro.serving.api import SamplingParams
from repro.serving.engine import Engine, Request
from repro.serving.http import HTTPFrontend
from repro.serving.http import client as http_client
from repro.serving.scheduler import SchedulerConfig

TINY = ArchConfig(
    name="tiny-http", family="dense", arch_kind="decoder",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, remat=False, dtype="float32")

PAGE = 8
MAX_LEN = 64


@pytest.fixture(scope="module")
def tiny():
    m = build(TINY)
    return m, m.init(jax.random.PRNGKey(0))


def _engine(tiny, **kw):
    m, params = tiny
    kw.setdefault("max_concurrency", 2)
    kw.setdefault("scheduler", SchedulerConfig(max_queue=32))
    return Engine(m, params, max_len=MAX_LEN, eos_id=-1,
                  page_size=PAGE, **kw)


def _workload(n, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        prompt = [int(x) for x in rng.integers(
            2, TINY.vocab_size, size=int(rng.integers(3, 14)))]
        if i % 3 == 0:
            sp = dict(temperature=0.0)
        else:
            sp = dict(temperature=0.8, top_p=0.9, seed=100 + i)
        out.append((prompt, dict(sp, max_tokens=int(rng.integers(2, 7)))))
    return out


def test_http_token_identical_to_direct_engine(tiny):
    """Streaming and JSON completions through the server reproduce a
    direct Engine run bitwise — greedy and seeded-sampled rows."""
    work = _workload(6)

    ref = _engine(tiny)
    for uid, (prompt, kw) in enumerate(work):
        ref.submit(Request(
            uid=uid, prompt=np.array(prompt, dtype=np.int32),
            sampling=SamplingParams(
                temperature=kw["temperature"], top_p=kw.get("top_p", 1.0),
                seed=kw.get("seed"), max_tokens=kw["max_tokens"])))
    want = {r.uid: list(r.tokens) for r in ref.run()}

    async def go():
        fe = HTTPFrontend(_engine(tiny), port=0, default_model="tiny")
        await fe.start()
        tasks = []
        for uid, (prompt, kw) in enumerate(work):
            payload = dict(model="tiny", prompt=prompt, **kw)
            if uid % 2:                      # interleave SSE + JSON
                tasks.append(http_client.collect_stream(
                    fe.host, fe.port, payload))
            else:
                tasks.append(http_client.request(
                    fe.host, fe.port, "POST", "/v1/completions", payload))
        results = await asyncio.gather(*tasks)
        got = {}
        for uid, r in enumerate(results):
            if uid % 2:
                assert r["finish_reason"] == "length"
                got[uid] = r["tokens"]
            else:
                status, body = r
                assert status == 200
                got[uid] = body["choices"][0]["token_ids"]
                assert body["usage"]["completion_tokens"] == len(got[uid])
        await fe.aclose()
        return got

    assert asyncio.run(go()) == want


def test_http_error_codes(tiny):
    async def go():
        fe = HTTPFrontend(_engine(tiny), port=0, default_model="tiny")
        await fe.start()
        h, p = fe.host, fe.port
        out = {}
        out["models"] = await http_client.request(h, p, "GET", "/v1/models")
        out["404"] = await http_client.request(
            h, p, "POST", "/v1/completions",
            dict(model="nope", prompt=[2, 3], max_tokens=2))
        out["400_prompt"] = await http_client.request(
            h, p, "POST", "/v1/completions",
            dict(model="tiny", prompt="not token ids", max_tokens=2))
        out["400_fits"] = await http_client.request(
            h, p, "POST", "/v1/completions",
            dict(model="tiny", prompt=[2] * (MAX_LEN + 8), max_tokens=2))
        out["405"] = await http_client.request(h, p, "GET",
                                               "/v1/completions")
        out["health"] = await http_client.request(h, p, "GET", "/healthz")
        out["metrics"] = await http_client.request(h, p, "GET", "/metrics")
        out["lost"] = await http_client.request(h, p, "GET", "/nowhere")
        await fe.aclose()
        return out

    out = asyncio.run(go())
    assert out["models"][0] == 200
    assert [m["id"] for m in out["models"][1]["data"]] == ["tiny"]
    assert out["404"][0] == 404
    assert out["400_prompt"][0] == 400
    assert out["400_fits"][0] == 400
    assert out["405"][0] == 405
    assert out["health"][0] == 200
    assert out["metrics"][0] == 200 and "http.requests" in out["metrics"][1]
    assert out["lost"][0] == 404


def test_http_backpressure_429(tiny):
    """A full bounded queue refuses with 429 + Retry-After instead of
    queueing unboundedly; accepted requests still finish."""
    async def go():
        fe = HTTPFrontend(
            _engine(tiny, max_concurrency=1,
                    scheduler=SchedulerConfig(max_queue=1)),
            port=0, default_model="tiny")
        await fe.start()
        payload = dict(model="tiny", prompt=[2, 3, 4, 5, 6],
                       max_tokens=12, temperature=0.0)
        tasks = [http_client.request(fe.host, fe.port, "POST",
                                     "/v1/completions", payload)
                 for _ in range(8)]
        results = await asyncio.gather(*tasks)
        snap = fe.metrics.snapshot()
        await fe.aclose()
        return results, snap

    results, snap = asyncio.run(go())
    codes = sorted(s for s, _ in results)
    assert 429 in codes, codes
    ok = [b for s, b in results if s == 200]
    assert ok and all(len(b["choices"][0]["token_ids"]) == 12 for b in ok)
    assert snap["http.responses.429"] == codes.count(429)


def test_http_deadline_504(tiny):
    """Queue-deadline expiry surfaces as 504 on both response paths.

    deadline_s=0 expires anything that spends a tick queued; with one
    slot most of the burst must queue.  Rather than race the admit
    path, assert the mapping on whichever requests expired."""
    async def go2():
        fe = HTTPFrontend(
            _engine(tiny, max_concurrency=1,
                    scheduler=SchedulerConfig(max_queue=16,
                                              deadline_s=0.0)),
            port=0, default_model="tiny")
        await fe.start()
        payload = dict(model="tiny", prompt=[2, 3, 4], max_tokens=6,
                       temperature=0.0)
        tasks = [http_client.request(fe.host, fe.port, "POST",
                                     "/v1/completions", payload)
                 for _ in range(4)]
        stream_task = asyncio.create_task(_stream_status(
            fe.host, fe.port, payload))
        results = await asyncio.gather(*tasks)
        s_status = await stream_task
        await fe.aclose()
        return [s for s, _ in results] + [s_status]

    codes = asyncio.run(go2())
    assert 504 in codes, codes
    assert all(c in (200, 504) for c in codes), codes


async def _stream_status(host, port, payload):
    try:
        await http_client.collect_stream(host, port, payload)
        return 200
    except http_client.HTTPStreamError as e:
        return e.status


def test_http_graceful_drain(tiny):
    """begin_drain: health flips to 503, queued requests come back
    cancelled (503), in-flight rows run to completion, driver exits."""
    async def go():
        fe = HTTPFrontend(
            _engine(tiny, max_concurrency=1,
                    scheduler=SchedulerConfig(max_queue=16)),
            port=0, default_model="tiny")
        await fe.start()
        payload = dict(model="tiny", prompt=[2, 3, 4, 5], max_tokens=16,
                       temperature=0.0)
        tasks = [asyncio.create_task(
            http_client.request(fe.host, fe.port, "POST",
                                "/v1/completions", payload))
            for _ in range(3)]
        # let the first request reach a decode row before draining
        for _ in range(200):
            await asyncio.sleep(0.005)
            if fe.metrics.snapshot().get("engine.admitted", 0) >= 1:
                break
        fe.begin_drain()
        health = await http_client.request(fe.host, fe.port, "GET",
                                           "/healthz")
        late = await http_client.request(
            fe.host, fe.port, "POST", "/v1/completions", payload)
        results = await asyncio.gather(*tasks)
        await asyncio.wait_for(fe.wait_drained(), 60)
        snap = fe.metrics.snapshot()
        await fe.aclose()
        return health, late, results, snap

    health, late, results, snap = asyncio.run(go())
    assert health[0] == 503
    assert late[0] == 503
    codes = sorted(s for s, _ in results)
    assert codes[-1] == 503 or codes[0] == 200, codes
    # whatever was admitted before the drain finished fully
    done_tokens = [b["choices"][0]["token_ids"]
                   for s, b in results if s == 200]
    assert all(len(t) == 16 for t in done_tokens)
    # queued-at-drain requests were cancelled, not dropped
    assert snap.get("engine.cancelled", 0) == codes.count(503)


def test_http_request_counters(tiny):
    """http.* metrics live in the engine's registry: request count,
    per-status responses, stream count."""
    async def go():
        fe = HTTPFrontend(_engine(tiny), port=0, default_model="tiny")
        await fe.start()
        payload = dict(model="tiny", prompt=[2, 3, 4], max_tokens=3,
                       temperature=0.0)
        await http_client.request(fe.host, fe.port, "POST",
                                  "/v1/completions", payload)
        await http_client.collect_stream(fe.host, fe.port, payload)
        await http_client.request(fe.host, fe.port, "GET", "/v1/models")
        snap = fe.metrics.snapshot()
        await fe.aclose()
        return snap

    snap = asyncio.run(go())
    assert snap["http.requests"] == 3
    assert snap["http.streams"] == 1
    assert snap["http.responses.200"] == 3
    assert snap["engine.done"] == 2


def test_http_json_body_shape(tiny):
    """The JSON completion follows the OpenAI-style envelope."""
    async def go():
        fe = HTTPFrontend(_engine(tiny), port=0, default_model="tiny")
        await fe.start()
        status, body = await http_client.request(
            fe.host, fe.port, "POST", "/v1/completions",
            dict(model="tiny", prompt=[5, 6, 7], max_tokens=4,
                 temperature=0.0))
        await fe.aclose()
        return status, body

    status, body = asyncio.run(go())
    assert status == 200
    assert body["object"] == "text_completion"
    assert body["model"] == "tiny"
    assert body["id"].startswith("cmpl-")
    ch = body["choices"][0]
    assert ch["finish_reason"] == "length"
    assert len(ch["token_ids"]) == 4
    assert body["usage"] == {"prompt_tokens": 3, "completion_tokens": 4,
                             "total_tokens": 7}
