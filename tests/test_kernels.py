"""Pallas kernel sweep: every kernel vs the pure-jnp ref.py oracle,
across shapes, modes, dtypes, and compression factors (interpret mode).
Covers the hashed decompress-GEMM kernels and the paged-gather decode
attention kernel behind the continuous-batching engine."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HashedSpec, init
from repro.kernels import ops, ref
from repro.kernels import hashed_matmul as hk
from repro.kernels.paged_attention import paged_decode_attention

ELEMENT_CASES = [
    # (rows, cols, compression, panel_cols, block)
    (128, 128, 0.5, 0, (32, 128, 128)),
    (256, 384, 0.125, 0, (64, 128, 128)),
    (256, 384, 0.125, 128, (64, 128, 128)),
    (512, 256, 1.0 / 64, 256, (128, 128, 128)),
    (384, 512, 0.25, 256, (16, 128, 256)),
]

BLOCK_CASES = [
    # (rows, cols, compression, block_shape)
    (256, 512, 0.125, (128, 128)),
    (384, 256, 0.25, (128, 64)),
    (256, 384, 0.3, (64, 128)),
    (512, 512, 1.0 / 16, (128, 128)),
]


def _mk(rows, seed, batch=(64,), dtype=jnp.float32):
    return jax.random.normal(
        jax.random.PRNGKey(seed), batch + (rows,)).astype(dtype)


@pytest.mark.parametrize("rows,cols,c,panel,block", ELEMENT_CASES)
def test_element_fwd(rows, cols, c, panel, block):
    spec = HashedSpec((rows, cols), c, mode="element", seed=rows + cols,
                      panel_cols=panel)
    w = init(jax.random.PRNGKey(0), spec)
    x = _mk(rows, 1)
    got = ops.hashed_matmul(x, w, spec, block=block)
    want = ref.hashed_matmul_ref(x, w, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("rows,cols,c,panel,block", ELEMENT_CASES[:3])
def test_element_grads(rows, cols, c, panel, block):
    spec = HashedSpec((rows, cols), c, mode="element", seed=3,
                      panel_cols=panel)
    w = init(jax.random.PRNGKey(0), spec)
    x = _mk(rows, 2, batch=(3, 40))

    gk = jax.grad(lambda x, w: (ops.hashed_matmul(x, w, spec, block=block)
                                ** 2).sum(), argnums=(0, 1))(x, w)
    gr = jax.grad(lambda x, w: (ref.hashed_matmul_ref(x, w, spec)
                                ** 2).sum(), argnums=(0, 1))(x, w)
    for a, b in zip(gk, gr):
        scale = max(1.0, float(np.abs(np.asarray(b)).max()))
        np.testing.assert_allclose(np.asarray(a) / scale,
                                   np.asarray(b) / scale,
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("rows,cols,c,bs", BLOCK_CASES)
def test_block_fwd(rows, cols, c, bs):
    spec = HashedSpec((rows, cols), c, mode="block", seed=rows ^ cols,
                      block_shape=bs)
    w = init(jax.random.PRNGKey(0), spec)
    x = _mk(rows, 1, batch=(2, 37))
    got = ops.hashed_matmul(x, w, spec)
    want = ref.hashed_matmul_ref(x, w, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("rows,cols,c,bs", BLOCK_CASES)
def test_block_grads(rows, cols, c, bs):
    spec = HashedSpec((rows, cols), c, mode="block", seed=17, block_shape=bs)
    w = init(jax.random.PRNGKey(0), spec)
    x = _mk(rows, 2, batch=(53,))
    gk = jax.grad(lambda x, w: (ops.hashed_matmul(x, w, spec) ** 2).sum(),
                  argnums=(0, 1))(x, w)
    gr = jax.grad(lambda x, w: (ref.hashed_matmul_ref(x, w, spec) ** 2).sum(),
                  argnums=(0, 1))(x, w)
    for a, b in zip(gk, gr):
        scale = max(1.0, float(np.abs(np.asarray(b)).max()))
        np.testing.assert_allclose(np.asarray(a) / scale,
                                   np.asarray(b) / scale,
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mode,dtype", itertools.product(
    ["element", "block"], [jnp.float32, jnp.bfloat16]))
def test_dtypes(mode, dtype):
    if mode == "element":
        spec = HashedSpec((256, 256), 0.125, mode=mode, seed=5,
                          panel_cols=128)
    else:
        spec = HashedSpec((256, 256), 0.125, mode=mode, seed=5,
                          block_shape=(128, 128))
    w = init(jax.random.PRNGKey(0), spec, dtype=dtype)
    x = _mk(256, 1, dtype=dtype)
    got = np.asarray(ops.hashed_matmul(x, w, spec), np.float32)
    want = np.asarray(ref.hashed_matmul_ref(x, w, spec), np.float32)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)
    assert got.dtype == np.float32  # cast for compare; kernel out == in dtype
    assert ops.hashed_matmul(x, w, spec).dtype == dtype


def test_row_padding():
    """Row counts that don't divide the block are padded then sliced."""
    spec = HashedSpec((128, 256), 0.25, mode="element", seed=1)
    w = init(jax.random.PRNGKey(0), spec)
    for m in (1, 7, 100, 129):
        x = _mk(128, m, batch=(m,))
        got = ops.hashed_matmul(x, w, spec)
        want = ref.hashed_matmul_ref(x, w, spec)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_transpose_kernels_direct():
    """dx kernels (transpose-forward) vs oracle, both modes."""
    spec_e = HashedSpec((256, 384), 0.125, mode="element", seed=11,
                        panel_cols=128)
    w = init(jax.random.PRNGKey(0), spec_e)
    g = _mk(384, 4, batch=(128,))
    got = hk.element_matmul(g, w, spec_e, block=(128, 128, 128),
                            transpose=True, interpret=True)
    want = ref.hashed_matmul_t_ref(g, w, spec_e)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)

    spec_b = HashedSpec((256, 384), 0.125, mode="block", seed=11,
                        block_shape=(128, 128))
    wb = init(jax.random.PRNGKey(0), spec_b)
    got = hk.block_matmul(g, wb, spec_b, bm=128, transpose=True,
                          interpret=True)
    want = ref.hashed_matmul_t_ref(g, wb, spec_b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_dw_kernels_direct():
    x = _mk(256, 5, batch=(128,))
    g = _mk(384, 6, batch=(128,))
    spec_e = HashedSpec((256, 384), 0.125, mode="element", seed=23,
                        panel_cols=128)
    got = hk.element_dw(x, g, spec_e, block=(128, 128, 128), interpret=True)
    want = ref.hashed_dw_ref(x, g, spec_e)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)

    spec_b = HashedSpec((256, 384), 0.125, mode="block", seed=23,
                        block_shape=(128, 128))
    got = hk.block_dw(x, g, spec_b, bm=128, interpret=True)
    want = ref.hashed_dw_ref(x, g, spec_b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# paged-gather decode attention (serving hot path)
# ---------------------------------------------------------------------------

def _tol(dtype):
    """Shared parity tolerances: tight fp32, loose bf16 (same ladder as
    the GEMM dtype sweep above)."""
    return (2e-5, 2e-4) if dtype == jnp.float32 else (3e-2, 3e-1)


def _mk_paged(seed, *, b, ps, maxp, n_kv, g, d, dtype=jnp.float32,
              lengths=None):
    """Random page pools + per-row page tables with DISTINCT live pages
    (the allocator invariant) + ragged lengths."""
    rng = np.random.default_rng(seed)
    num_pages = 1 + b * maxp                       # page 0 = trash
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    pk = jax.random.normal(ks[0], (num_pages, ps, n_kv, d)).astype(dtype)
    pv = jax.random.normal(ks[1], (num_pages, ps, n_kv, d)).astype(dtype)
    q = jax.random.normal(ks[2], (b, n_kv * g, d)).astype(dtype)
    if lengths is None:
        lengths = rng.integers(1, maxp * ps + 1, size=b)
    lengths = np.asarray(lengths, np.int32)
    table = np.zeros((b, maxp), np.int32)
    pool = list(range(1, num_pages))
    rng.shuffle(pool)
    for i in range(b):
        n = -(-int(lengths[i]) // ps)
        for j in range(n):
            table[i, j] = pool.pop()
    return q, pk, pv, jnp.asarray(table), jnp.asarray(lengths)


@pytest.mark.parametrize("ps,maxp,g,dtype", [
    (4, 3, 1, jnp.float32),
    (8, 4, 2, jnp.float32),
    (16, 2, 4, jnp.float32),
    (8, 3, 2, jnp.bfloat16),
    (16, 4, 1, jnp.bfloat16),
])
def test_paged_attention_kernel_vs_ref(ps, maxp, g, dtype):
    """Kernel (online softmax page walk) vs gather-then-attend oracle,
    across page sizes, ragged lengths, GQA groups, and dtypes."""
    q, pk, pv, table, lengths = _mk_paged(
        ps * maxp + g, b=3, ps=ps, maxp=maxp, n_kv=2, g=g, d=16,
        dtype=dtype)
    got = paged_decode_attention(q, pk, pv, table, lengths,
                                 interpret=True)
    want = ref.paged_attention_ref(q, pk, pv, table, lengths)
    assert got.dtype == q.dtype
    rtol, atol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=atol)


@pytest.mark.parametrize("window", [1, 3, 8])
def test_paged_attention_sliding_window(window):
    """Windowed masking parity (the gemma local-attention layers)."""
    q, pk, pv, table, lengths = _mk_paged(
        11 + window, b=2, ps=4, maxp=4, n_kv=2, g=2, d=8)
    got = paged_decode_attention(q, pk, pv, table, lengths,
                                 jnp.int32(window), interpret=True)
    want = ref.paged_attention_ref(q, pk, pv, table, lengths, window)
    rtol, atol = _tol(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=rtol, atol=atol)


def test_paged_attention_matches_dense_attend():
    """Stronger oracle: scatter a dense KV cache into pages and compare
    both paged paths against the engine's dense attention (attend with
    per-row kv_valid), which the serving parity tests trust."""
    from repro.nn import attention as ATT
    b, ps, maxp, n_kv, g, d = 2, 8, 3, 2, 2, 16
    q, pk, pv, table, lengths = _mk_paged(
        5, b=b, ps=ps, maxp=maxp, n_kv=n_kv, g=g, d=d)
    t = maxp * ps
    # gather the paged layout back to (B, T, n_kv, d) dense
    kd = jnp.take(pk, table, axis=0).reshape(b, t, n_kv, d)
    vd = jnp.take(pv, table, axis=0).reshape(b, t, n_kv, d)
    plan = ATT.AttentionPlan(d_model=n_kv * g * d, num_heads=n_kv * g,
                             num_kv_heads=n_kv, head_dim=d,
                             dtype=jnp.float32)
    q_pos = (lengths - 1)[:, None]                 # (B, 1)
    kv_valid = jnp.arange(t)[None, :] < lengths[:, None]
    want = ATT.attend(plan, q[:, None], kd, vd, q_pos, jnp.arange(t),
                      kv_valid)[:, 0]              # (B, Hq*D)
    for impl, out in [
        ("ref", ref.paged_attention_ref(q, pk, pv, table, lengths)),
        ("pallas", paged_decode_attention(q, pk, pv, table, lengths,
                                          interpret=True)),
    ]:
        np.testing.assert_allclose(
            np.asarray(out).reshape(b, -1), np.asarray(want),
            rtol=2e-5, atol=2e-4, err_msg=impl)


def test_paged_attention_idle_rows_finite():
    """length == 0 rows (idle decode slots, whole table on the trash
    page) must produce finite output — no 0/0 softmax."""
    q, pk, pv, table, lengths = _mk_paged(
        9, b=3, ps=4, maxp=2, n_kv=2, g=1, d=8, lengths=[5, 0, 3])
    table = table.at[1, :].set(0)
    for out in (ref.paged_attention_ref(q, pk, pv, table, lengths),
                paged_decode_attention(q, pk, pv, table, lengths,
                                       interpret=True)):
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_array_equal(np.asarray(out)[1], 0.0)


def test_kernel_matches_core_paths():
    """pallas == scan == materialize through the core dispatcher."""
    from repro.core import matmul
    spec = HashedSpec((256, 256), 0.25, mode="element", seed=31,
                      panel_cols=128)
    w = init(jax.random.PRNGKey(0), spec)
    x = _mk(256, 7, batch=(32,))
    y_pal = matmul(x, w, spec, path="pallas")
    y_scan = matmul(x, w, spec, path="scan")
    y_mat = matmul(x, w, spec, path="materialize")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_mat),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_mat),
                               rtol=2e-5, atol=2e-5)
