"""Pallas kernel sweep: every kernel vs the pure-jnp ref.py oracle,
across shapes, modes, dtypes, and compression factors (interpret mode).
Covers the hashed decompress-GEMM kernels and the paged-gather decode
attention kernel behind the continuous-batching engine."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HashedSpec, init
from repro.kernels import ops, ref
from repro.kernels import hashed_matmul as hk
from repro.kernels.paged_attention import paged_decode_attention

ELEMENT_CASES = [
    # (rows, cols, compression, panel_cols, block)
    (128, 128, 0.5, 0, (32, 128, 128)),
    (256, 384, 0.125, 0, (64, 128, 128)),
    (256, 384, 0.125, 128, (64, 128, 128)),
    (512, 256, 1.0 / 64, 256, (128, 128, 128)),
    (384, 512, 0.25, 256, (16, 128, 256)),
]

BLOCK_CASES = [
    # (rows, cols, compression, block_shape)
    (256, 512, 0.125, (128, 128)),
    (384, 256, 0.25, (128, 64)),
    (256, 384, 0.3, (64, 128)),
    (512, 512, 1.0 / 16, (128, 128)),
]


def _mk(rows, seed, batch=(64,), dtype=jnp.float32):
    return jax.random.normal(
        jax.random.PRNGKey(seed), batch + (rows,)).astype(dtype)


@pytest.mark.parametrize("rows,cols,c,panel,block", ELEMENT_CASES)
def test_element_fwd(rows, cols, c, panel, block):
    spec = HashedSpec((rows, cols), c, mode="element", seed=rows + cols,
                      panel_cols=panel)
    w = init(jax.random.PRNGKey(0), spec)
    x = _mk(rows, 1)
    got = ops.hashed_matmul(x, w, spec, block=block)
    want = ref.hashed_matmul_ref(x, w, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("rows,cols,c,panel,block", ELEMENT_CASES[:3])
def test_element_grads(rows, cols, c, panel, block):
    spec = HashedSpec((rows, cols), c, mode="element", seed=3,
                      panel_cols=panel)
    w = init(jax.random.PRNGKey(0), spec)
    x = _mk(rows, 2, batch=(3, 40))

    gk = jax.grad(lambda x, w: (ops.hashed_matmul(x, w, spec, block=block)
                                ** 2).sum(), argnums=(0, 1))(x, w)
    gr = jax.grad(lambda x, w: (ref.hashed_matmul_ref(x, w, spec)
                                ** 2).sum(), argnums=(0, 1))(x, w)
    for a, b in zip(gk, gr):
        scale = max(1.0, float(np.abs(np.asarray(b)).max()))
        np.testing.assert_allclose(np.asarray(a) / scale,
                                   np.asarray(b) / scale,
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("rows,cols,c,bs", BLOCK_CASES)
def test_block_fwd(rows, cols, c, bs):
    spec = HashedSpec((rows, cols), c, mode="block", seed=rows ^ cols,
                      block_shape=bs)
    w = init(jax.random.PRNGKey(0), spec)
    x = _mk(rows, 1, batch=(2, 37))
    got = ops.hashed_matmul(x, w, spec)
    want = ref.hashed_matmul_ref(x, w, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("rows,cols,c,bs", BLOCK_CASES)
def test_block_grads(rows, cols, c, bs):
    spec = HashedSpec((rows, cols), c, mode="block", seed=17, block_shape=bs)
    w = init(jax.random.PRNGKey(0), spec)
    x = _mk(rows, 2, batch=(53,))
    gk = jax.grad(lambda x, w: (ops.hashed_matmul(x, w, spec) ** 2).sum(),
                  argnums=(0, 1))(x, w)
    gr = jax.grad(lambda x, w: (ref.hashed_matmul_ref(x, w, spec) ** 2).sum(),
                  argnums=(0, 1))(x, w)
    for a, b in zip(gk, gr):
        scale = max(1.0, float(np.abs(np.asarray(b)).max()))
        np.testing.assert_allclose(np.asarray(a) / scale,
                                   np.asarray(b) / scale,
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mode,dtype", itertools.product(
    ["element", "block"], [jnp.float32, jnp.bfloat16]))
def test_dtypes(mode, dtype):
    if mode == "element":
        spec = HashedSpec((256, 256), 0.125, mode=mode, seed=5,
                          panel_cols=128)
    else:
        spec = HashedSpec((256, 256), 0.125, mode=mode, seed=5,
                          block_shape=(128, 128))
    w = init(jax.random.PRNGKey(0), spec, dtype=dtype)
    x = _mk(256, 1, dtype=dtype)
    got = np.asarray(ops.hashed_matmul(x, w, spec), np.float32)
    want = np.asarray(ref.hashed_matmul_ref(x, w, spec), np.float32)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)
    assert got.dtype == np.float32  # cast for compare; kernel out == in dtype
    assert ops.hashed_matmul(x, w, spec).dtype == dtype


def test_row_padding():
    """Row counts that don't divide the block are padded then sliced."""
    spec = HashedSpec((128, 256), 0.25, mode="element", seed=1)
    w = init(jax.random.PRNGKey(0), spec)
    for m in (1, 7, 100, 129):
        x = _mk(128, m, batch=(m,))
        got = ops.hashed_matmul(x, w, spec)
        want = ref.hashed_matmul_ref(x, w, spec)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_transpose_kernels_direct():
    """dx kernels (transpose-forward) vs oracle, both modes."""
    spec_e = HashedSpec((256, 384), 0.125, mode="element", seed=11,
                        panel_cols=128)
    w = init(jax.random.PRNGKey(0), spec_e)
    g = _mk(384, 4, batch=(128,))
    got = hk.element_matmul(g, w, spec_e, block=(128, 128, 128),
                            transpose=True, interpret=True)
    want = ref.hashed_matmul_t_ref(g, w, spec_e)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)

    spec_b = HashedSpec((256, 384), 0.125, mode="block", seed=11,
                        block_shape=(128, 128))
    wb = init(jax.random.PRNGKey(0), spec_b)
    got = hk.block_matmul(g, wb, spec_b, bm=128, transpose=True,
                          interpret=True)
    want = ref.hashed_matmul_t_ref(g, wb, spec_b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_dw_kernels_direct():
    x = _mk(256, 5, batch=(128,))
    g = _mk(384, 6, batch=(128,))
    spec_e = HashedSpec((256, 384), 0.125, mode="element", seed=23,
                        panel_cols=128)
    got = hk.element_dw(x, g, spec_e, block=(128, 128, 128), interpret=True)
    want = ref.hashed_dw_ref(x, g, spec_e)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)

    spec_b = HashedSpec((256, 384), 0.125, mode="block", seed=23,
                        block_shape=(128, 128))
    got = hk.block_dw(x, g, spec_b, bm=128, interpret=True)
    want = ref.hashed_dw_ref(x, g, spec_b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# paged-gather decode attention (serving hot path)
# ---------------------------------------------------------------------------

def _tol(dtype):
    """Shared parity tolerances: tight fp32, loose bf16 (same ladder as
    the GEMM dtype sweep above)."""
    return (2e-5, 2e-4) if dtype == jnp.float32 else (3e-2, 3e-1)


def _mk_paged(seed, *, b, ps, maxp, n_kv, g, d, dtype=jnp.float32,
              lengths=None):
    """Random page pools + per-row page tables with DISTINCT live pages
    (the allocator invariant) + ragged lengths."""
    rng = np.random.default_rng(seed)
    num_pages = 1 + b * maxp                       # page 0 = trash
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    pk = jax.random.normal(ks[0], (num_pages, ps, n_kv, d)).astype(dtype)
    pv = jax.random.normal(ks[1], (num_pages, ps, n_kv, d)).astype(dtype)
    q = jax.random.normal(ks[2], (b, n_kv * g, d)).astype(dtype)
    if lengths is None:
        lengths = rng.integers(1, maxp * ps + 1, size=b)
    lengths = np.asarray(lengths, np.int32)
    table = np.zeros((b, maxp), np.int32)
    pool = list(range(1, num_pages))
    rng.shuffle(pool)
    for i in range(b):
        n = -(-int(lengths[i]) // ps)
        for j in range(n):
            table[i, j] = pool.pop()
    return q, pk, pv, jnp.asarray(table), jnp.asarray(lengths)


@pytest.mark.parametrize("ps,maxp,g,dtype", [
    (4, 3, 1, jnp.float32),
    (8, 4, 2, jnp.float32),
    (16, 2, 4, jnp.float32),
    (8, 3, 2, jnp.bfloat16),
    (16, 4, 1, jnp.bfloat16),
])
def test_paged_attention_kernel_vs_ref(ps, maxp, g, dtype):
    """Kernel (online softmax page walk) vs gather-then-attend oracle,
    across page sizes, ragged lengths, GQA groups, and dtypes."""
    q, pk, pv, table, lengths = _mk_paged(
        ps * maxp + g, b=3, ps=ps, maxp=maxp, n_kv=2, g=g, d=16,
        dtype=dtype)
    got = paged_decode_attention(q, pk, pv, table, lengths,
                                 interpret=True)
    want = ref.paged_attention_ref(q, pk, pv, table, lengths)
    assert got.dtype == q.dtype
    rtol, atol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=atol)


@pytest.mark.parametrize("window", [1, 3, 8])
def test_paged_attention_sliding_window(window):
    """Windowed masking parity (the gemma local-attention layers)."""
    q, pk, pv, table, lengths = _mk_paged(
        11 + window, b=2, ps=4, maxp=4, n_kv=2, g=2, d=8)
    got = paged_decode_attention(q, pk, pv, table, lengths,
                                 jnp.int32(window), interpret=True)
    want = ref.paged_attention_ref(q, pk, pv, table, lengths, window)
    rtol, atol = _tol(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=rtol, atol=atol)


def test_paged_attention_matches_dense_attend():
    """Stronger oracle: scatter a dense KV cache into pages and compare
    both paged paths against the engine's dense attention (attend with
    per-row kv_valid), which the serving parity tests trust."""
    from repro.nn import attention as ATT
    b, ps, maxp, n_kv, g, d = 2, 8, 3, 2, 2, 16
    q, pk, pv, table, lengths = _mk_paged(
        5, b=b, ps=ps, maxp=maxp, n_kv=n_kv, g=g, d=d)
    t = maxp * ps
    # gather the paged layout back to (B, T, n_kv, d) dense
    kd = jnp.take(pk, table, axis=0).reshape(b, t, n_kv, d)
    vd = jnp.take(pv, table, axis=0).reshape(b, t, n_kv, d)
    plan = ATT.AttentionPlan(d_model=n_kv * g * d, num_heads=n_kv * g,
                             num_kv_heads=n_kv, head_dim=d,
                             dtype=jnp.float32)
    q_pos = (lengths - 1)[:, None]                 # (B, 1)
    kv_valid = jnp.arange(t)[None, :] < lengths[:, None]
    want = ATT.attend(plan, q[:, None], kd, vd, q_pos, jnp.arange(t),
                      kv_valid)[:, 0]              # (B, Hq*D)
    for impl, out in [
        ("ref", ref.paged_attention_ref(q, pk, pv, table, lengths)),
        ("pallas", paged_decode_attention(q, pk, pv, table, lengths,
                                          interpret=True)),
    ]:
        np.testing.assert_allclose(
            np.asarray(out).reshape(b, -1), np.asarray(want),
            rtol=2e-5, atol=2e-4, err_msg=impl)


def test_paged_attention_idle_rows_finite():
    """length == 0 rows (idle decode slots, whole table on the trash
    page) must produce finite output — no 0/0 softmax."""
    q, pk, pv, table, lengths = _mk_paged(
        9, b=3, ps=4, maxp=2, n_kv=2, g=1, d=8, lengths=[5, 0, 3])
    table = table.at[1, :].set(0)
    for out in (ref.paged_attention_ref(q, pk, pv, table, lengths),
                paged_decode_attention(q, pk, pv, table, lengths,
                                       interpret=True)):
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_array_equal(np.asarray(out)[1], 0.0)


def _mk_shared_paged(seed, *, b, ps, maxp, n_kv, g, d, shared_pages,
                     dtype=jnp.float32):
    """Like _mk_paged but every row's table ALIASES the same leading
    ``shared_pages`` physical pages (prefix sharing), with private pages
    after; lengths all extend past the shared region."""
    rng = np.random.default_rng(seed)
    num_pages = 1 + shared_pages + b * (maxp - shared_pages)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    pk = jax.random.normal(ks[0], (num_pages, ps, n_kv, d)).astype(dtype)
    pv = jax.random.normal(ks[1], (num_pages, ps, n_kv, d)).astype(dtype)
    q = jax.random.normal(ks[2], (b, n_kv * g, d)).astype(dtype)
    lengths = rng.integers(shared_pages * ps + 1, maxp * ps + 1, size=b)
    lengths = np.asarray(lengths, np.int32)
    table = np.zeros((b, maxp), np.int32)
    pool = list(range(1 + shared_pages, num_pages))
    rng.shuffle(pool)
    for i in range(b):
        table[i, :shared_pages] = np.arange(1, 1 + shared_pages)
        n = -(-int(lengths[i]) // ps)
        for j in range(shared_pages, n):
            table[i, j] = pool.pop()
    return q, pk, pv, jnp.asarray(table), jnp.asarray(lengths)


@pytest.mark.parametrize("shared,window", [(1, 0), (2, 0), (3, 0), (2, 5)])
def test_paged_attention_shared_prefix_tables(shared, window):
    """Prefix sharing aliases one physical page into many rows' tables;
    kernel and plain oracle must read through the aliases bitwise as if
    each row owned private copies (the shared-page-aware oracle)."""
    q, pk, pv, table, lengths = _mk_shared_paged(
        23 + shared, b=3, ps=4, maxp=4, n_kv=2, g=2, d=16,
        shared_pages=shared)
    want = ref.paged_attention_shared_ref(q, pk, pv, table, lengths,
                                          window)
    got_ref = ref.paged_attention_ref(q, pk, pv, table, lengths, window)
    np.testing.assert_array_equal(np.asarray(got_ref), np.asarray(want))
    got_pal = paged_decode_attention(q, pk, pv, table, lengths,
                                     jnp.int32(window), interpret=True)
    rtol, atol = _tol(jnp.float32)
    np.testing.assert_allclose(np.asarray(got_pal), np.asarray(want),
                               rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# chunked prefill parity (serving engine prefill path)
# ---------------------------------------------------------------------------

from repro.configs.base import ArchConfig  # noqa: E402
from repro.models import build as build_model  # noqa: E402
from repro.serving.engine import Engine, _copy_pages  # noqa: E402

_PS = 8
_CHUNK_MAX_LEN = 64


def _chunk_cfg(dtype, n_kv, window=0, global_every=0):
    return ArchConfig(
        name=f"tiny-chunk-{dtype}-{n_kv}-{window}", family="dense",
        arch_kind="decoder", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=n_kv, head_dim=16, d_ff=128, vocab_size=128,
        remat=False, dtype=dtype, sliding_window=window,
        global_every=global_every)


def _chunk_shape(pos, c, chunk):
    """The engine's own chunk-shape ladder (invoked unbound on a stub
    so the test exercises exactly the shipped compile shapes — a
    hand-copied ladder here could silently drift)."""
    import types
    stub = types.SimpleNamespace(max_len=_CHUNK_MAX_LEN,
                                 prefill_chunk=chunk,
                                 BUCKET=Engine.BUCKET,
                                 _SUB_BUCKETS=Engine._SUB_BUCKETS)
    return Engine._chunk_shape(stub, pos, c)


def _prefill_in_chunks(model, params, feed, chunk):
    """Replicate the engine's chunked prefill: bucketed pad-and-mask
    chunks against a carried scratch cache, each chunk's pages landed
    through the engine's masked page-write."""
    prefill = jax.jit(model.prefill)
    maxp = _CHUNK_MAX_LEN // _PS
    pages = model.init_paged_cache(1 + maxp, _PS)
    table = np.arange(1, 1 + maxp, dtype=np.int32)     # row owns 1..maxp
    cache = model.init_cache(1, _CHUNK_MAX_LEN)
    pos, t = 0, len(feed)
    logits = None
    while pos < t:
        c = min(chunk, t - pos)
        start, bucket, real = _chunk_shape(pos, c, chunk)
        if start != pos:                 # slid-back window
            cache = dict(cache, index=jnp.asarray(start, jnp.int32))
        prompt = np.pad(feed[start:start + real], (0, bucket - real))
        logits, cache = prefill(params, {
            "tokens": jnp.asarray(prompt[None, :]), "cache": cache,
            "length": jnp.asarray(real, jnp.int32)})
        lo, hi = pos // _PS, -(-(pos + c) // _PS)
        wpids = np.zeros((maxp,), np.int32)
        wpids[lo:hi] = table[lo:hi]
        pages = _copy_pages(pages, cache["k"], cache["v"],
                            jnp.asarray(wpids))
        pos += c
    return logits, cache, pages


@pytest.mark.parametrize("dtype,n_kv,window,ge", [
    (jnp.float32, 2, 0, 0),
    (jnp.float32, 1, 0, 0),
    (jnp.bfloat16, 2, 0, 0),
    (jnp.bfloat16, 1, 0, 0),
    (jnp.float32, 2, 6, 2),          # sliding-window + global mix
])
def test_chunked_prefill_bitwise_parity(dtype, n_kv, window, ge):
    """A prompt prefilled in chunks of {1, ps-1, ps, 3*ps} produces
    bitwise-identical KV pages and logits to monolithic prefill — every
    query attends over the same full-width cache buffer either way, so
    chunking (and therefore prefix reuse, which serves previously
    chunk-computed pages) cannot perturb greedy decoding."""
    name = "float32" if dtype == jnp.float32 else "bfloat16"
    model = build_model(_chunk_cfg(name, n_kv, window, ge))
    params = model.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(7)
    t = 3 * _PS + 3                                     # partial tail page
    feed = rng.integers(2, 128, size=t).astype(np.int32)

    logits_m, cache_m, pages_m = _prefill_in_chunks(model, params, feed, t)
    for chunk in (1, _PS - 1, _PS, 3 * _PS):
        logits_c, cache_c, pages_c = _prefill_in_chunks(
            model, params, feed, chunk)
        np.testing.assert_array_equal(
            np.asarray(logits_m, np.float32),
            np.asarray(logits_c, np.float32), err_msg=f"chunk={chunk}")
        for key in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(cache_m[key], np.float32)[:, :, :t],
                np.asarray(cache_c[key], np.float32)[:, :, :t],
                err_msg=f"cache {key} chunk={chunk}")
            # pages compare on every readable position: the row's table
            # in order, flattened back to sequence layout, up to the
            # feed.  Offsets past the feed are pad garbage that lengths
            # masking keeps unreadable (and differ by bucket pattern).
            pm = np.asarray(pages_m[key], np.float32)
            pc = np.asarray(pages_c[key], np.float32)
            nl, _, ps, hkv, hd = pm.shape
            np.testing.assert_array_equal(
                pm[:, 1:].reshape(nl, -1, hkv, hd)[:, :t],
                pc[:, 1:].reshape(nl, -1, hkv, hd)[:, :t],
                err_msg=f"pages {key} chunk={chunk}")


def test_kernel_matches_core_paths():
    """pallas == scan == materialize through the core dispatcher."""
    from repro.core import matmul
    spec = HashedSpec((256, 256), 0.25, mode="element", seed=31,
                      panel_cols=128)
    w = init(jax.random.PRNGKey(0), spec)
    x = _mk(256, 7, batch=(32,))
    y_pal = matmul(x, w, spec, path="pallas")
    y_scan = matmul(x, w, spec, path="scan")
    y_mat = matmul(x, w, spec, path="materialize")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_mat),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_mat),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# batched ragged flash-prefill (serving batched prefill path)
# ---------------------------------------------------------------------------

from repro.kernels.flash_prefill import paged_prefill_attention  # noqa: E402


def _mk_ragged_prefill(seed, *, ps, maxp, n_kv, g, d, starts, counts,
                       shared_pages=0, dtype=jnp.float32):
    """Random pools + tables + a ragged (starts, counts) chunk layout.

    Row b's chunk queries sit at positions [starts[b], starts[b]+counts[b]);
    its full history [0, starts[b]+counts[b]) — old prefix AND the fresh
    chunk's K/V — is already in the pool (the engine scatters the chunk
    before attending).  With ``shared_pages`` the leading pages ALIAS one
    physical page set across rows (prefix sharing / mid-COW layout)."""
    starts = np.asarray(starts, np.int32)
    counts = np.asarray(counts, np.int32)
    b = len(starts)
    s_blk = int(counts.max()) if counts.size else 1
    rng = np.random.default_rng(seed)
    num_pages = 1 + shared_pages + b * (maxp - shared_pages)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    pk = jax.random.normal(ks[0], (num_pages, ps, n_kv, d)).astype(dtype)
    pv = jax.random.normal(ks[1], (num_pages, ps, n_kv, d)).astype(dtype)
    q = jax.random.normal(ks[2], (b, s_blk, n_kv * g, d)).astype(dtype)
    table = np.zeros((b, maxp), np.int32)
    pool = list(range(1 + shared_pages, num_pages))
    rng.shuffle(pool)
    for i in range(b):
        table[i, :shared_pages] = np.arange(1, 1 + shared_pages)
        for j in range(shared_pages, maxp):
            table[i, j] = pool.pop()
    return (q, pk, pv, jnp.asarray(table), jnp.asarray(starts),
            jnp.asarray(counts))


def _dense_prefill_oracle(q, pk, pv, table, starts, counts, window=0):
    """Row-by-row dense oracle through ATT.attend (the path the serving
    parity tests trust): gather each row's pages to a dense cache and
    attend its real chunk queries with causal + length masking."""
    from repro.nn import attention as ATT
    b, s_blk, hq, d = q.shape
    _, ps, n_kv, _ = pk.shape
    t = table.shape[1] * ps
    plan = ATT.AttentionPlan(d_model=hq * d, num_heads=hq,
                             num_kv_heads=n_kv, head_dim=d,
                             dtype=q.dtype,
                             sliding_window=int(window))
    outs = np.zeros((b, s_blk, hq, d), np.float32)
    kv_pos = jnp.arange(t)
    for i in range(b):
        n = int(counts[i])
        if n == 0:
            continue
        kd = jnp.take(pk, table[i], axis=0).reshape(1, t, n_kv, d)
        vd = jnp.take(pv, table[i], axis=0).reshape(1, t, n_kv, d)
        q_pos = int(starts[i]) + jnp.arange(n)
        kv_valid = kv_pos < int(starts[i]) + n
        o = ATT.attend(plan, q[i:i + 1, :n], kd, vd, q_pos, kv_pos,
                       kv_valid)
        outs[i, :n] = np.asarray(o, np.float32).reshape(n, hq, d)
    return outs


_PS_RAGGED = 8
_RAGGED_CASES = [
    # counts sweep: 1, ps-1, ps, 3*ps, ragged mixes; starts exercise
    # page-offset rags (mid-page, boundary, zero)
    ([0, 0, 0], [1, _PS_RAGGED - 1, _PS_RAGGED]),
    ([0], [3 * _PS_RAGGED]),
    ([5, 8, 0, 13], [7, 9, 24, 1]),
    ([3, 17, 10], [1, 6, 22]),
]


@pytest.mark.parametrize("starts,counts", _RAGGED_CASES)
@pytest.mark.parametrize("g,dtype", [(1, jnp.float32), (2, jnp.float32),
                                     (4, jnp.bfloat16)])
def test_flash_prefill_kernel_vs_ref_vs_dense(starts, counts, g, dtype):
    """Ragged chunk layouts: Pallas kernel vs paged_prefill_ref (must be
    close) and ref vs the dense attend oracle, across GQA groups and
    dtypes.  Pad slots must come back zero."""
    q, pk, pv, table, st_, cn = _mk_ragged_prefill(
        11 + g + len(counts), ps=_PS_RAGGED, maxp=4, n_kv=2, g=g, d=16,
        starts=starts, counts=counts, dtype=dtype)
    want = ref.paged_prefill_ref(q, pk, pv, table, st_, cn)
    got = paged_prefill_attention(q, pk, pv, table, st_, cn,
                                  interpret=True)
    rtol, atol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=atol)
    dense = _dense_prefill_oracle(q, pk, pv, table, st_, cn)
    b, s_blk, hq, d = q.shape
    wantf = np.asarray(want, np.float32)
    for i in range(b):
        n = int(cn[i])
        np.testing.assert_allclose(wantf[i, :n], dense[i, :n],
                                   rtol=rtol, atol=atol,
                                   err_msg=f"row {i} vs dense")
        np.testing.assert_array_equal(wantf[i, n:], 0.0,
                                      err_msg=f"row {i} pad slots")


@pytest.mark.parametrize("window", [1, 3, 8])
def test_flash_prefill_sliding_window(window):
    """Windowed masking parity on ragged chunks (gemma local layers)."""
    q, pk, pv, table, st_, cn = _mk_ragged_prefill(
        31 + window, ps=4, maxp=4, n_kv=2, g=2, d=8,
        starts=[0, 6, 9], counts=[5, 2, 7])
    want = ref.paged_prefill_ref(q, pk, pv, table, st_, cn, window)
    got = paged_prefill_attention(q, pk, pv, table, st_, cn,
                                  jnp.int32(window), interpret=True)
    rtol, atol = _tol(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=rtol, atol=atol)
    dense = _dense_prefill_oracle(q, pk, pv, table, st_, cn, window)
    for i in range(len(cn)):
        n = int(cn[i])
        np.testing.assert_allclose(np.asarray(want, np.float32)[i, :n],
                                   dense[i, :n], rtol=rtol, atol=atol)


def test_flash_prefill_shared_prefix_mid_cow():
    """Rows whose leading pages alias the same physical pages (prefix
    sharing; the engine resolves the boundary page via COW before the
    dispatch): reads through the aliases must match a dense gather of
    each row's table, and empty (count==0) padding rows stay zero."""
    q, pk, pv, table, st_, cn = _mk_ragged_prefill(
        43, ps=4, maxp=4, n_kv=2, g=2, d=16,
        starts=[8, 8, 11, 0], counts=[5, 3, 2, 0], shared_pages=2)
    want = ref.paged_prefill_ref(q, pk, pv, table, st_, cn)
    got = paged_prefill_attention(q, pk, pv, table, st_, cn,
                                  interpret=True)
    rtol, atol = _tol(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=rtol, atol=atol)
    dense = _dense_prefill_oracle(q, pk, pv, table, st_, cn)
    wantf = np.asarray(want, np.float32)
    for i in range(len(cn)):
        n = int(cn[i])
        np.testing.assert_allclose(wantf[i, :n], dense[i, :n],
                                   rtol=rtol, atol=atol)
    np.testing.assert_array_equal(wantf[3], 0.0)
    assert np.isfinite(np.asarray(got, np.float32)).all()


def test_chunk_shape_slide_back_stays_in_bounds():
    """The 8-grid slide-back must honor start + bucket <= max_len for
    prefix-hit offsets landing within 8 tokens of max_len (the regime
    where a naive pos + c - b rewind could overrun)."""
    for pos in range(_CHUNK_MAX_LEN - 8, _CHUNK_MAX_LEN):
        for c in range(1, _CHUNK_MAX_LEN - pos + 1):
            start, bucket, real = _chunk_shape(pos, c, chunk=None)
            assert start + bucket <= _CHUNK_MAX_LEN, (pos, c, start, bucket)
            assert start <= pos and start + real == pos + c, (pos, c)


# ---------------------------------------------------------------------------
# sampling filters: radix-select top-k kernel + top-p / min-p vs oracles
# ---------------------------------------------------------------------------

from repro.kernels.topk import NEG as TOPK_NEG  # noqa: E402
from repro.kernels.topk import topk_mask  # noqa: E402
from repro.serving.sampling import minp_mask, topp_mask  # noqa: E402


def _mask_of(x):
    return np.asarray(jnp.asarray(x, jnp.float32)) <= TOPK_NEG / 2


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("k", [1, 8, None, 0])   # None => k = V (disabled)
def test_topk_kernel_parity_uniform_k(dtype, k):
    """Pallas radix-select (interpret) AND the lax fallback vs the numpy
    sort oracle: identical surviving values, identical masks — ties at
    the threshold all survive in every implementation."""
    b, v = 4, 203                          # v % 128 != 0: pad path
    x = jnp.asarray(np.random.default_rng(k or 77).standard_normal(
        (b, v)), dtype)
    kk = np.full((b,), v if k is None else k, np.int32)
    want = ref.topk_mask_ref(x, kk, fill=TOPK_NEG)
    got_pallas = topk_mask(x, kk, use_pallas=True, interpret=True)
    got_lax = topk_mask(x, kk, use_pallas=False)
    for name, got in (("pallas", got_pallas), ("lax", got_lax)):
        np.testing.assert_array_equal(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            err_msg=f"{name} k={k} {dtype}")
    if k and k < v:
        # at least k survivors; bf16 rounding may tie at the threshold,
        # and ties all survive, so the mask can be slightly smaller
        assert _mask_of(got_pallas).sum(axis=1).max() <= v - k


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_kernel_parity_ragged_per_row_k(dtype):
    """Mixed per-row k in ONE dispatch (the fused-sampler contract):
    k=1, small, V, disabled(0), and mid — all against the oracle."""
    rng = np.random.default_rng(3)
    for v in (64, 129, 500):
        x = jnp.asarray(rng.standard_normal((5, v)) * 4, dtype)
        kk = np.asarray([1, 8, v, 0, max(v // 3, 1)], np.int32)
        want = ref.topk_mask_ref(x, kk, fill=TOPK_NEG)
        got = topk_mask(x, kk, use_pallas=True, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            err_msg=f"v={v}")
        np.testing.assert_array_equal(
            np.asarray(topk_mask(x, kk, use_pallas=False), np.float32),
            np.asarray(want, np.float32), err_msg=f"lax v={v}")


def test_topk_kernel_signed_zero_threshold_parity():
    """A +-0.0 threshold: float compares treat -0.0 == +0.0 but their
    bit patterns differ — the radix kernel canonicalizes zeros so its
    mask matches the float-comparing oracle and fallback exactly."""
    row = np.asarray([1.0, 0.0, -0.0, -1.0], np.float32)
    x = jnp.asarray(np.stack([row, -row]))
    kk = np.asarray([2, 2], np.int32)       # threshold lands on +-0.0
    want = ref.topk_mask_ref(x, kk, fill=TOPK_NEG)
    got_p = topk_mask(x, kk, use_pallas=True, interpret=True)
    got_l = topk_mask(x, kk, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_l), np.asarray(want))
    # both zeros tie at the threshold: all three survive in row 0
    assert (~_mask_of(got_p)[0]).sum() == 3


def test_topk_kernel_exact_with_ties():
    """Duplicated values straddling the threshold: value-threshold
    semantics keep ALL ties, in kernel, fallback, and oracle alike."""
    row = np.asarray([3.0, 3.0, 3.0, 1.0, 1.0, -2.0, 0.5, 3.0],
                     np.float32)
    x = jnp.asarray(np.stack([row, row]))
    kk = np.asarray([2, 5], np.int32)     # k=2 cuts inside the 3.0 run
    want = ref.topk_mask_ref(x, kk, fill=TOPK_NEG)
    got = topk_mask(x, kk, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert (~_mask_of(got)[0]).sum() == 4    # all four 3.0s survive
    np.testing.assert_array_equal(
        np.asarray(topk_mask(x, kk, use_pallas=False)), np.asarray(want))


@pytest.mark.parametrize("p", [0.1, 0.9, 1.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topp_mask_parity(p, dtype):
    """Nucleus filter vs the numpy descending-walk oracle, including
    per-row mixed p in one call."""
    rng = np.random.default_rng(int(p * 10))
    z = jnp.asarray(rng.standard_normal((4, 157)) * 3, dtype)
    pa = np.full((4,), p, np.float32)
    got = topp_mask(jnp.asarray(z, jnp.float32), jnp.asarray(pa))
    want = ref.topp_mask_ref(jnp.asarray(z, jnp.float32), pa)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    if p == 1.0:
        assert not _mask_of(got).any()       # disabled: nothing filtered
    # ragged per-row p
    pm = np.asarray([p, 1.0, 0.5, 0.05], np.float32)
    got = topp_mask(jnp.asarray(z, jnp.float32), jnp.asarray(pm))
    want = ref.topp_mask_ref(jnp.asarray(z, jnp.float32), pm)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_topp_always_keeps_top1_and_minp_parity():
    """p -> 0 still keeps the argmax (prefix-mass rule), and the min-p
    filter matches its oracle across mixed rows."""
    rng = np.random.default_rng(9)
    z = jnp.asarray(rng.standard_normal((3, 97)) * 5, jnp.float32)
    got = topp_mask(z, jnp.asarray(np.full((3,), 1e-6, np.float32)))
    kept = ~_mask_of(got)
    assert (kept.sum(axis=1) >= 1).all()
    am = np.asarray(jnp.argmax(z, -1))
    assert all(kept[i, am[i]] for i in range(3))
    mp = np.asarray([0.0, 0.2, 1.0], np.float32)
    np.testing.assert_array_equal(
        np.asarray(minp_mask(z, jnp.asarray(mp))),
        np.asarray(ref.minp_mask_ref(z, mp)))
