"""Compressed artifact subsystem tests: format round-trip fidelity,
quantization tolerance, registry integrity, report accounting, the
checkpoint export hook, and the serving-engine fixes that ride this PR
(per-request prefill temperature, bucketed static-shape prefill)."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.configs as C
from repro import artifact
from repro.artifact import format as afmt
from repro.artifact import quant as aquant
from repro.artifact import registry as areg
from repro.artifact import report as areport
from repro.configs.reduced import reduced
from repro.core import HashedSpec, hashed, init, spec_from_dict, spec_to_dict
from repro.models import build
from repro.serving.engine import Engine, Request, generate_batch

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# HashedSpec <-> dict (backs the artifact header)
# ---------------------------------------------------------------------------

@st.composite
def any_specs(draw):
    mode = draw(st.sampled_from(["element", "block"]))
    if mode == "element":
        rows = draw(st.integers(4, 256))
        cols = draw(st.integers(4, 256))
        panel = draw(st.sampled_from([0, 16, 64]))
        block = (128, 128)
    else:
        bm = draw(st.sampled_from([8, 16, 32]))
        bn = draw(st.sampled_from([8, 16, 32]))
        rows = bm * draw(st.integers(1, 4))
        cols = bn * draw(st.integers(1, 4))
        panel = 0
        block = (bm, bn)
    return HashedSpec(
        virtual_shape=(rows, cols),
        compression=draw(st.sampled_from([1.0, 0.5, 0.25, 0.125, 1 / 16])),
        mode=mode,
        seed=draw(st.integers(0, 2 ** 31 - 1)),
        panel_cols=panel,
        block_shape=block,
        use_sign=draw(st.sampled_from([True, False])),
    )


@given(spec=any_specs())
@settings(**SETTINGS)
def test_spec_dict_roundtrip(spec):
    d = spec_to_dict(spec)
    json.loads(json.dumps(d))               # JSON-safe
    back = spec_from_dict(d)
    assert back == spec
    # derived sizes survive (what the report relies on)
    assert back.real_param_shape() == spec.real_param_shape()
    assert back.virtual_size == spec.virtual_size


def test_spec_dict_defaults_forward_compat():
    d = {"virtual_shape": [8, 8], "compression": 0.5, "mode": "element",
         "seed": 3}
    s = spec_from_dict(d)
    assert s.panel_cols == 0 and s.use_sign


def test_spec_dict_roundtrip_non_default_sign_and_panels():
    """Non-default use_sign/panel_cols must survive the JSON round-trip
    exactly (a dropped sign flag would silently flip weight sharing)."""
    spec = HashedSpec((96, 160), 0.25, mode="element", seed=77,
                      panel_cols=32, use_sign=False)
    d = spec_to_dict(spec)
    assert d["use_sign"] is False and d["panel_cols"] == 32
    back = spec_from_dict(json.loads(json.dumps(d)))
    assert back == spec
    assert back.use_sign is False and back.panel_cols == 32
    assert back.n_panels == spec.n_panels == 5
    assert back.num_buckets == spec.num_buckets
    # and the sign flag actually changes materialization
    signed = dataclasses.replace(spec, use_sign=True)
    w = init(jax.random.PRNGKey(0), spec)
    assert not np.array_equal(np.asarray(hashed.materialize(w, spec)),
                              np.asarray(hashed.materialize(w, signed)))


# ---------------------------------------------------------------------------
# ragged block grids
# ---------------------------------------------------------------------------

def test_materialize_rows_block_ragged_cols():
    """cols not a multiple of block_cols: the ceil tile grid is sliced back."""
    spec = HashedSpec((32, 40), 0.5, mode="block", seed=5,
                      block_shape=(16, 16))
    w = init(jax.random.PRNGKey(0), spec)
    v = hashed.materialize(w, spec)
    assert v.shape == (32, 40)
    row_ids = jnp.asarray([0, 7, 31, 15])
    got = hashed.materialize_rows(w, spec, row_ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(v)[np.asarray(row_ids)],
                               rtol=1e-6, atol=1e-6)
    # batched row_ids shape
    got2 = hashed.materialize_rows(w, spec, row_ids.reshape(2, 2))
    assert got2.shape == (2, 2, 40)


def test_materialize_rows_block_ragged_rows():
    """rows not a multiple of block_rows: the last tile-row is partial;
    row gathers near and past the boundary must match materialize()."""
    spec = HashedSpec((40, 32), 0.5, mode="block", seed=11,
                      block_shape=(16, 16))
    w = init(jax.random.PRNGKey(3), spec)
    v = hashed.materialize(w, spec)
    assert v.shape == (40, 32)
    # last full-tile row, first ragged-tile row, final row
    row_ids = jnp.asarray([0, 15, 16, 31, 32, 39])
    got = hashed.materialize_rows(w, spec, row_ids)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(v)[np.asarray(row_ids)],
                               rtol=1e-6, atol=1e-6)
    # ragged rows AND cols together, batched id shape
    spec2 = HashedSpec((40, 24), 0.5, mode="block", seed=12,
                       block_shape=(16, 16))
    w2 = init(jax.random.PRNGKey(4), spec2)
    v2 = hashed.materialize(w2, spec2)
    ids = jnp.asarray([[3, 39], [17, 20]])
    got2 = hashed.materialize_rows(w2, spec2, ids)
    assert got2.shape == (2, 2, 24)
    np.testing.assert_allclose(
        np.asarray(got2),
        np.asarray(v2)[np.asarray(ids)], rtol=1e-6, atol=1e-6)


def test_matmul_scan_block_ragged_rows_and_cols():
    spec = HashedSpec((40, 48), 0.5, mode="block", seed=9,
                      block_shape=(16, 16))
    w = init(jax.random.PRNGKey(1), spec)
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 40))
    want = x @ hashed.materialize(w, spec)
    got = hashed.matmul_scan(x, w, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_dw_ref_block_ragged_matches_autodiff():
    from repro.kernels import ref
    spec = HashedSpec((40, 48), 0.5, mode="block", seed=3,
                      block_shape=(16, 16))
    w = init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 40))
    g = jax.random.normal(jax.random.PRNGKey(2), (3, 48))
    dw_auto = jax.grad(
        lambda w: jnp.sum(x @ hashed.materialize(w, spec) * g))(w)
    dw_ref = ref.hashed_dw_ref(x, g, spec)
    np.testing.assert_allclose(np.asarray(dw_ref), np.asarray(dw_auto),
                               rtol=1e-4, atol=1e-4)


def test_pallas_path_rejects_ragged_block():
    from repro.kernels import ops
    spec = HashedSpec((32, 40), 0.5, mode="block", seed=5,
                      block_shape=(16, 16))
    w = init(jax.random.PRNGKey(0), spec)
    x = jnp.ones((4, 32))
    with pytest.raises(ValueError, match="divide"):
        ops.hashed_matmul(x, w, spec)


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["int8", "fp8"])
def test_quant_roundtrip_error_bound(scheme):
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((37, 53)) * 3).astype(np.float32)
    z = aquant.quantize(x, scheme, group=64)
    back = aquant.dequantize(z)
    assert back.shape == x.shape and back.dtype == x.dtype
    bound = aquant.max_abs_error(scheme, z.scales)
    assert float(np.abs(back - x).max()) <= bound + 1e-7


def test_quant_preserves_zeros_and_bf16():
    import ml_dtypes
    x = np.zeros((8, 8), ml_dtypes.bfloat16)
    z = aquant.quantize(x, "int8", group=16)
    back = aquant.dequantize(z)
    assert str(back.dtype) == "bfloat16"
    assert float(np.abs(np.asarray(back, np.float32)).max()) == 0.0


# ---------------------------------------------------------------------------
# format round-trip
# ---------------------------------------------------------------------------

def test_unflatten_mixed_dict_list():
    tree = {"a": [{"w": np.arange(3)}, {"w": np.arange(2)}],
            "b": {"c": np.ones(1)}}
    entries = afmt.flatten_with_paths(tree)
    back = afmt.unflatten_from_paths(entries)
    assert isinstance(back["a"], list) and len(back["a"]) == 2
    np.testing.assert_array_equal(back["a"][1]["w"], np.arange(2))
    np.testing.assert_array_equal(back["b"]["c"], np.ones(1))


def _mlp_roundtrip(tmp_path, quant):
    """Paper-faithful hashmlp: export_tree + bank specs, logits fidelity."""
    from repro.paper import mlp
    spec = mlp.MLPSpec((784, 300, 10), method="hashed", compression=1 / 8)
    params = mlp.init(spec, jax.random.PRNGKey(0))
    bank_specs = {(l, "w"): spec.hashed_spec(l)
                  for l in range(spec.n_layers)}
    path = str(tmp_path / f"mlp_{quant}.hnart")
    artifact.export_tree(path, params, bank_specs=bank_specs, quant=quant)
    _, loaded = artifact.load(path)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 784))
    want = np.asarray(mlp.apply(spec, params, x))
    got = np.asarray(mlp.apply(spec, loaded, x))
    return want, got, path


def test_mlp_artifact_roundtrip_exact(tmp_path):
    want, got, path = _mlp_roundtrip(tmp_path, "none")
    np.testing.assert_array_equal(want, got)     # fp32: bit-exact
    rows = areport.artifact_rows(afmt.read_header(path))
    banks = [r for r in rows if r["kind"] == "bank"]
    assert banks and all(abs(r["param_ratio"] - 1 / 8) < 0.01
                         for r in banks)


def test_mlp_artifact_roundtrip_int8(tmp_path):
    want, got, _ = _mlp_roundtrip(tmp_path, "int8")
    # documented int8 bound: per-element error <= absmax(group)/254;
    # through 2 layers of a 300-wide net the logit drift stays small
    assert float(np.abs(want - got).max()) < 0.15 * float(
        np.abs(want).max() + 1.0)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "rwkv6-7b"])
def test_transformer_artifact_logits_exact(arch, tmp_path):
    cfg = reduced(C.get(arch)).with_(dtype="float32").hashed_variant(0.125)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "m.hnart")
    artifact.export_model(path, cfg, params)
    cfg2, m2, p2 = artifact.load_model(path)
    assert cfg2 == cfg
    batch = {"tokens": jnp.asarray([[5, 9, 2, 7]]),
             "cache": m.init_cache(1, 32)}
    l1, _ = m.prefill(params, batch)
    batch2 = {"tokens": jnp.asarray([[5, 9, 2, 7]]),
              "cache": m2.init_cache(1, 32)}
    l2, _ = m2.prefill(p2, batch2)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_transformer_artifact_int8_tolerance(tmp_path):
    cfg = reduced(C.get("qwen3-1.7b")).with_(
        dtype="float32").hashed_variant(0.125)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "m8.hnart")
    artifact.export_model(path, cfg, params, quant="int8")
    _, m2, p2 = artifact.load_model(path)
    batch = {"tokens": jnp.asarray([[5, 9, 2, 7]]),
             "cache": m.init_cache(1, 32)}
    l1, _ = m.prefill(params, batch)
    l2, _ = m2.prefill(p2, {"tokens": jnp.asarray([[5, 9, 2, 7]]),
                            "cache": m2.init_cache(1, 32)})
    # int8 per-group quantization: logits agree to a few percent of scale
    denom = float(np.abs(np.asarray(l1)).max()) + 1e-6
    assert float(np.abs(np.asarray(l1) - np.asarray(l2)).max()) / denom < 0.1


def test_artifact_disk_size_tracks_compression(tmp_path):
    """fp32 banks: bank bytes on disk == real_param_count * 4 exactly;
    total file within alignment+header slack of the sum of sections."""
    cfg = reduced(C.get("qwen3-1.7b")).with_(
        dtype="float32").hashed_variant(0.125)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "m.hnart")
    header = artifact.export_model(path, cfg, params)
    for e in header["leaves"]:
        if e["kind"] == "bank":
            spec = spec_from_dict(e["spec"])
            assert e["nbytes"] == \
                spec.real_param_count() * e["stack"] * 4
    total_sections = sum(e["nbytes"] for e in header["leaves"])
    size = os.path.getsize(path)
    slack = header["data_start"] + 64 * (len(header["leaves"]) + 1)
    assert total_sections <= size <= total_sections + slack


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_versions_and_integrity(tmp_path):
    art = str(tmp_path / "a.hnart")
    artifact.export_tree(art, {"w": np.arange(100, dtype=np.float32)})
    root = str(tmp_path / "reg")
    v1 = areg.register(root, "toy", art, metadata={"step": 1})
    v2 = areg.register(root, "toy", art, metadata={"step": 2})
    assert (v1, v2) == (1, 2)
    e = areg.resolve(root, "toy")
    assert e["version"] == 2 and e["metadata"]["step"] == 2
    e1 = areg.resolve(root, "toy@1")
    assert e1["version"] == 1
    assert os.path.exists(e["path"])
    # corruption must fail the cold start
    with open(e["path"], "r+b") as f:
        f.seek(-1, os.SEEK_END)
        f.write(b"\xFF")
    with pytest.raises(ValueError, match="integrity"):
        areg.resolve(root, "toy")
    # unknown model
    with pytest.raises(KeyError):
        areg.resolve(root, "nope")


def test_registry_lock_timeout_survives_wall_clock_step(tmp_path,
                                                        monkeypatch):
    """Lock acquisition times out on the MONOTONIC clock: a wall clock
    stepping backwards (NTP) while another process holds the lock used
    to extend the wait unboundedly (regression for the wall-deadline
    ``_Lock.__enter__``)."""
    root = str(tmp_path / "reg")
    os.makedirs(root)
    lock = areg._Lock(root, timeout_s=1.0)
    os.mkdir(lock.path)              # another process holds the lock

    fake_mono = [100.0]

    def monotonic():
        fake_mono[0] += 0.1
        return fake_mono[0]

    wall = [1e9]

    def wall_time():
        wall[0] -= 3600.0            # NTP steps backwards at every look
        return wall[0]

    monkeypatch.setattr(areg.time, "monotonic", monotonic)
    monkeypatch.setattr(areg.time, "time", wall_time)
    monkeypatch.setattr(areg.time, "sleep", lambda s: None)
    with pytest.raises(TimeoutError):
        lock.__enter__()
    assert fake_mono[0] - 100.0 < 5.0, \
        "lock wait must be bounded in monotonic time"
    os.rmdir(lock.path)
    # with the lock free, acquisition succeeds despite the wall chaos
    with lock:
        assert os.path.isdir(lock.path)
    assert not os.path.isdir(lock.path)


def test_registry_version_zero_is_an_error(tmp_path):
    art = str(tmp_path / "a.hnart")
    artifact.export_tree(art, {"w": np.arange(8, dtype=np.float32)})
    root = str(tmp_path / "reg")
    areg.register(root, "toy", art)
    with pytest.raises(KeyError):
        areg.resolve(root, "toy@0")
    with pytest.raises(KeyError):
        areg.resolve(root, "toy", version=0)


def test_bank_spec_map_covers_hashed_embeddings_all_kinds():
    from repro.models.transformer import bank_spec_map
    for arch in ("qwen3-1.7b", "rwkv6-7b", "zamba2-2.7b"):
        cfg = reduced(C.get(arch)).hashed_variant(0.125).with_(
            hash_embeddings=True)
        m = bank_spec_map(cfg)
        assert ("embed", "emb") in m, arch
        assert m[("embed", "emb")].virtual_shape == \
            (cfg.padded_vocab, cfg.d_model)


def test_engine_from_artifact_serves(tmp_path):
    cfg = reduced(C.get("qwen3-1.7b")).with_(dtype="float32")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "m.hnart")
    artifact.export_model(path, cfg, params)
    root = str(tmp_path / "reg")
    areg.register(root, "qwen-toy", path)

    eng = Engine.from_artifact("qwen-toy", registry_root=root,
                               slots=2, max_len=64, eos_id=-1)
    eng.submit(Request(uid=0, prompt=np.arange(5, dtype=np.int32) + 2,
                       max_new_tokens=3))
    done = eng.run()
    assert len(done) == 1 and len(done[0].tokens) == 3
    # identical to serving the live params
    want = generate_batch(m, params, [np.arange(5, dtype=np.int32) + 2],
                          max_new_tokens=3, max_len=64, slots=2, eos_id=-1)
    assert done[0].tokens == want[0]


# ---------------------------------------------------------------------------
# checkpoint export hook
# ---------------------------------------------------------------------------

def test_checkpoint_on_save_exports_artifact(tmp_path):
    from repro.train import checkpoint as ckpt_lib
    cfg = reduced(C.get("qwen3-1.7b")).with_(dtype="float32")
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    state = {"params": params, "step": jnp.asarray(7)}
    adir = str(tmp_path / "artifacts")
    root = str(tmp_path / "reg")
    os.makedirs(adir)
    hook = ckpt_lib.artifact_exporter(cfg, adir, registry_root=root,
                                      model_name="qwen-ckpt")
    ckpt_lib.save(state, str(tmp_path / "ck"), 7, on_save=hook)
    apath = os.path.join(adir, "model_00000007.hnart")
    assert os.path.exists(apath)
    header = afmt.read_header(apath)
    assert header["meta"]["step"] == 7
    e = areg.resolve(root, "qwen-ckpt")
    assert e["metadata"]["step"] == 7
    # the artifact holds ONLY params (no optimizer state)
    paths = {tuple(x["path"])[:1] for x in header["leaves"]}
    assert ("step",) not in paths


# ---------------------------------------------------------------------------
# serving engine fixes
# ---------------------------------------------------------------------------

def _tiny_model():
    cfg = reduced(C.get("qwen3-1.7b")).with_(dtype="float32")
    m = build(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def test_prefill_samples_with_request_temperature():
    """Admitting into slot i>0 must use THAT request's temperature, not
    slot 0's (the seed bug: temps[0]).  Under the fused sampler the
    prefill dispatch samples the sliced row state — the slice must
    carry the admitted request's temperature."""
    _, m, params = _tiny_model()
    seen = []

    class Spy(Engine):
        def _run_sampler(self, logits, sl, kind):
            if kind == "prefill":
                seen.append(
                    [float(t) for t in
                     self._sampler_state.batch(sl)["temperature"]])
            return super()._run_sampler(logits, sl, kind)

    eng = Spy(m, params, slots=2, max_len=64, eos_id=-1)
    eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32) + 3,
                       max_new_tokens=2, temperature=0.0))
    eng.submit(Request(uid=1, prompt=np.arange(5, dtype=np.int32) + 1,
                       max_new_tokens=2, temperature=7.5))
    eng.run()
    assert [0.0] in seen and [7.5] in seen, seen


def test_prefill_bucketing_single_compile_and_exact():
    """Distinct prompt lengths in one 64-bucket share ONE prefill trace,
    and pad-and-mask generation matches exact-length sequential decode."""
    _, m, params = _tiny_model()
    traces = [0]
    orig = m.prefill
    orig_paged = m.prefill_paged

    def counting(p, b):
        traces[0] += 1
        return orig(p, b)

    def counting_paged(*a, **kw):
        # batched ragged prefill is the default engine path; either
        # entry point tracing more than once breaks the bucket pin
        traces[0] += 1
        return orig_paged(*a, **kw)

    m2 = m._replace(prefill=counting, prefill_paged=counting_paged)
    prompts = [np.arange(n, dtype=np.int32) + 1 for n in (4, 7, 23, 12)]
    outs = generate_batch(m2, params, prompts, max_new_tokens=4,
                          max_len=96, slots=2, eos_id=-1)
    assert traces[0] == 1, f"{traces[0]} prefill traces for one bucket"

    def single(prompt, n=4):
        batch = {"tokens": jnp.asarray(prompt[None]),
                 "cache": m.init_cache(1, 96)}
        logits, cache = m.prefill(params, batch)
        toks = [int(jnp.argmax(logits[0, -1]))]
        for _ in range(n - 1):
            logits, cache = m.decode_step(
                params, jnp.asarray([[toks[-1]]], jnp.int32), cache)
            toks.append(int(jnp.argmax(logits[0, -1])))
        return toks

    for pr, got in zip(prompts, outs):
        assert single(pr) == got


def test_prefill_bucket_clamped_to_max_len():
    """max_len below the 64-bucket: padding must clamp to the cache size
    (the unclamped bucket over-ran the KV dynamic_update_slice)."""
    _, m, params = _tiny_model()
    prompts = [np.arange(9, dtype=np.int32) + 1]
    outs = generate_batch(m, params, prompts, max_new_tokens=3,
                          max_len=48, slots=1, eos_id=-1)
    assert len(outs[0]) == 3
