"""Compression-policy subsystem tests: flat-knob compat lowering must be
byte-identical (the API redesign cannot move any weights), the equal-memory
budget solver must land on target for every registered config, per-slot
rules must steer mode/ratio/path/quant, and policies must survive JSON /
config / artifact round-trips."""
import dataclasses
import json
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro import artifact, policy as POL
from repro.artifact import format as afmt
from repro.artifact import report as areport
from repro.configs.reduced import reduced
from repro.core import HashedSpec
from repro.core.hashing import derive_seed
from repro.models import build
from repro.models.transformer import bank_spec_map, hash_slots, \
    slot_assignments

ALL_ARCHS = C.names()


def _legacy_spec(cfg, seed_key, vshape):
    """The pre-policy _hspec formula, verbatim — the compat contract."""
    seed = derive_seed(0xC0FFEE, zlib.crc32(seed_key.encode()) & 0x7FFFFFFF)
    return HashedSpec(
        virtual_shape=tuple(vshape),
        compression=cfg.compression,
        mode=cfg.hash_mode,
        seed=seed,
        panel_cols=(cfg.hash_panel_cols if cfg.hash_mode == "element"
                    else 0),
        block_shape=tuple(cfg.hash_block),
    )


# known seed keys per slot path (a representative per arch kind) — pins
# the seed derivation so a refactor can't silently re-key the hashes
SEED_KEYS = {
    "qwen3-1.7b": {
        ("layers", "attn", "q", "w"): "attn.q",
        ("layers", "ffn", "out", "w"): "ffn.out",
        ("embed", "emb"): "embed",
    },
    "llama3-405b": {                     # untied: has an lm_head slot
        ("lm_head", "w"): "lm_head",
    },
    "granite-moe-1b-a400m": {
        ("layers", "moe", "in"): "moe.in",
        ("layers", "moe", "out"): "moe.out",
    },
    "rwkv6-7b": {
        ("layers", "tm", "r", "w"): "rwkv.r",
        ("layers", "cm", "k", "w"): "cmix.k",
    },
    "zamba2-2.7b": {
        ("mamba_groups", "mamba", "in_proj", "w"): "mamba.in",
        ("shared", "attn", "q", "w"): "attn.q",
        ("shared", "ffn", "in", "w"): "ffn.in",
    },
    "whisper-medium": {
        ("encoder", "attn", "q", "w"): "enc.q",
        ("decoder", "self", "k", "w"): "dec.k",
        ("decoder", "cross", "v", "w"): "xattn.v",
        ("encoder", "ffn", "in", "w"): "ffn.in",
        ("decoder", "ffn", "in", "w"): "ffn.in",   # historically shared
    },
}


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize("mode", ["element", "block"])
def test_flat_knobs_lower_byte_identical(arch, mode):
    """Legacy flat-knob configs resolved through the policy layer produce
    byte-identical HashedSpecs (same seeds, shapes, bucket counts)."""
    cfg = C.get(arch).hashed_variant(0.125, mode=mode).with_(
        hash_embeddings=True)
    slots = {s.path: s for s in hash_slots(cfg)}
    specs = bank_spec_map(cfg)
    assert specs, arch
    for path, spec in specs.items():
        want = _legacy_spec(cfg, "<seed via slot>",
                            slots[path].virtual_shape)
        want = dataclasses.replace(want, seed=slots[path].seed)
        assert spec == want, path
        # byte-identical serialization (what lands in artifact headers)
        assert json.dumps(spec.to_dict()) == json.dumps(want.to_dict())
    for path, key in SEED_KEYS.get(arch, {}).items():
        assert path in slots, (arch, path)
        assert slots[path].seed == derive_seed(
            0xC0FFEE, zlib.crc32(key.encode()) & 0x7FFFFFFF), (arch, path)


def test_flat_vs_explicit_single_rule_policy_identical_params():
    """An explicit single-rule policy equals the flat knobs: same specs,
    bit-identical params from the same key."""
    base = reduced(C.get("qwen3-1.7b")).with_(dtype="float32")
    flat = base.hashed_variant(0.25)
    pol = POL.CompressionPolicy(rules=(POL.PolicyRule(
        match="*", compression=0.25, mode="element",
        panel_cols=flat.hash_panel_cols, block_shape=flat.hash_block,
        path=flat.hash_path),))
    viapolicy = base.with_(hashed=True, hash_policy=pol)
    assert bank_spec_map(flat) == bank_spec_map(viapolicy)
    p1 = build(flat).init(jax.random.PRNGKey(0))
    p2 = build(viapolicy).init(jax.random.PRNGKey(0))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), p1, p2)


# ---------------------------------------------------------------------------
# budget solver
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_budget_solver_within_one_percent(arch):
    """Acceptance: total real params within 1% of budget * total virtual
    on every registered config."""
    budget = 1 / 8
    cfg = C.get(arch).policy_variant(POL.CompressionPolicy(budget=budget))
    specs = bank_spec_map(cfg)
    total_virtual = sum(s.virtual_size for s in specs.values())
    total_real = sum(s.real_param_count() for s in specs.values())
    target = budget * total_virtual
    assert abs(total_real - target) <= 0.01 * target, \
        (arch, total_real, target)


def test_budget_with_pinned_rule_reallocates():
    """Pinning attention at 1/4 under a 1/8 total budget must push the
    free slots below 1/8 so the total still lands on target."""
    budget = 1 / 8
    pol = POL.CompressionPolicy(budget=budget, rules=(
        POL.PolicyRule(match="layers.attn.*", compression=1 / 4),))
    cfg = C.get("qwen3-1.7b").policy_variant(pol)
    asg = slot_assignments(cfg)
    attn = [a for p, a in asg.items() if p[:2] == ("layers", "attn")]
    ffn = [a for p, a in asg.items() if p[:2] == ("layers", "ffn")]
    assert all(a.spec.compression == 1 / 4 for a in attn)
    assert all(a.spec.compression < budget for a in ffn)
    specs = bank_spec_map(cfg)
    total_virtual = sum(s.virtual_size for s in specs.values())
    total_real = sum(s.real_param_count() for s in specs.values())
    target = budget * total_virtual
    assert abs(total_real - target) <= 0.01 * target


def test_budget_floor_and_cap_waterfill():
    slots = (
        POL.Slot(path=("a", "w"), virtual_shape=(1000, 100), seed=1),
        POL.Slot(path=("b", "w"), virtual_shape=(1000, 100), seed=2),
        POL.Slot(path=("c", "w"), virtual_shape=(1000, 100), seed=3),
    )
    pol = POL.CompressionPolicy(budget=0.1, rules=(
        POL.PolicyRule(match="a", floor=0.2),      # forced above target
        POL.PolicyRule(match="b", cap=0.05),       # forced below target
    ))
    asg = POL.resolve(pol, slots)
    ca = asg[("a", "w")].spec.compression
    cb = asg[("b", "w")].spec.compression
    cc = asg[("c", "w")].spec.compression
    assert ca == pytest.approx(0.2)
    assert cb == pytest.approx(0.05)
    # c absorbs the remainder: 0.3*V total = 0.2*V + 0.05*V + cc*V
    assert cc == pytest.approx(0.05)
    # solver-level exactness (before bucket rounding)
    assert ca + cb + cc == pytest.approx(3 * 0.1)


def test_budget_solver_saturates_when_infeasible():
    assign = POL.solve(10.0, [("a", 1000, 0.5, 1.0)])
    assert assign["a"] == pytest.approx(0.5)  # floor binds; no crash


def test_budget_solver_mixed_floor_cap_exact():
    """One slot capped below and one floored above the naive common
    ratio: a feasible exact allocation exists and must be found (naive
    simultaneous clamping overshot by 10% here)."""
    assign = POL.solve(100.0, [("a", 100, 0.0, 0.4),
                               ("b", 100, 0.7, 1.0)])
    assert assign["b"] == pytest.approx(0.7)
    assert assign["a"] == pytest.approx(0.3)
    assert 100 * assign["a"] + 100 * assign["b"] == pytest.approx(100.0)


# ---------------------------------------------------------------------------
# rule matching / per-slot overrides
# ---------------------------------------------------------------------------

def test_rules_steer_mode_path_and_exclusion():
    pol = POL.CompressionPolicy(
        compression=1 / 8, mode="element", panel_cols=0, path="scan",
        rules=(
            POL.PolicyRule(match="layers.attn.*", mode="block",
                           block_shape=(16, 16), compression=1 / 4,
                           path="materialize"),
            POL.PolicyRule(match="*.ffn.out", compression=1 / 2),
            POL.PolicyRule(match="embed.*", hashed=True),
            POL.PolicyRule(match="lm_head", hashed=False),
        ))
    cfg = reduced(C.get("qwen3-1.7b")).with_(hashed=True, hash_policy=pol)
    asg = slot_assignments(cfg)
    q = asg[("layers", "attn", "q", "w")].spec
    assert (q.mode, q.block_shape, q.compression, q.exec_path) == \
        ("block", (16, 16), 1 / 4, "materialize")
    assert q.panel_cols == 0  # block mode never stratifies panels
    out = asg[("layers", "ffn", "out", "w")].spec
    assert (out.mode, out.compression, out.exec_path) == \
        ("element", 1 / 2, "scan")
    # rule turned the embedding ON without the hash_embeddings knob
    assert asg[("embed", "emb")].spec is not None
    assert asg[("embed", "emb")].spec.virtual_shape == \
        (cfg.padded_vocab, cfg.d_model)
    # ... and lm_head OFF explicitly (untied arch: qwen3 ties, so check
    # the rule against llama3's untied head)
    asg_l = slot_assignments(C.get("llama3-405b").with_(
        hashed=True, hash_embeddings=True, hash_policy=pol))
    assert asg_l[("lm_head", "w")].spec is None
    # the model actually builds and runs under the mixed policy
    m = build(cfg.with_(dtype="float32"))
    params = m.init(jax.random.PRNGKey(0))
    assert "emb" in params["embed"] and \
        params["embed"]["emb"].ndim == 1  # element-mode bank, not a table
    batch = {"tokens": jnp.asarray([[1, 2, 3, 4]]),
             "targets": jnp.asarray([[2, 3, 4, 5]])}
    loss, _ = jax.jit(m.train_loss)(params, batch)
    assert np.isfinite(float(loss))


def test_first_matching_rule_wins():
    pol = POL.CompressionPolicy(rules=(
        POL.PolicyRule(match="layers.attn.q", compression=1 / 2),
        POL.PolicyRule(match="layers.attn.*", compression=1 / 16),
    ))
    cfg = C.get("qwen3-1.7b").policy_variant(pol)
    asg = slot_assignments(cfg)
    assert asg[("layers", "attn", "q", "w")].spec.compression == 1 / 2
    assert asg[("layers", "attn", "k", "w")].spec.compression == 1 / 16
    assert asg[("layers", "attn", "q", "w")].rule == "layers.attn.q"


def test_policy_validation_rejects_garbage():
    with pytest.raises(ValueError, match="mode"):
        POL.CompressionPolicy(rules=(
            POL.PolicyRule(match="*", mode="banana"),)).validate()
    with pytest.raises(ValueError, match="floor"):
        POL.CompressionPolicy(rules=(
            POL.PolicyRule(match="*", floor=0.5, cap=0.1),)).validate()
    with pytest.raises(ValueError, match="unknown rule keys"):
        POL.rule_from_dict({"match": "*", "compresion": 0.5})
    with pytest.raises(ValueError, match="budget"):
        POL.CompressionPolicy(budget=3.0).validate()


# ---------------------------------------------------------------------------
# serialization round-trips
# ---------------------------------------------------------------------------

def _mixed_policy():
    return POL.CompressionPolicy(
        budget=1 / 8, compression=1 / 8, mode="element", panel_cols=64,
        block_shape=(32, 32), path="scan",
        rules=(
            POL.PolicyRule(match="layers.attn.*", mode="block",
                           block_shape=(16, 16), floor=1 / 32),
            POL.PolicyRule(match="*.ffn.out", compression=1 / 2,
                           quant="int8", path="materialize"),
            POL.PolicyRule(match="embed.*", hashed=False),
        ))


def test_policy_json_roundtrip(tmp_path):
    pol = _mixed_policy()
    d = POL.policy_to_dict(pol)
    json.loads(json.dumps(d))                      # JSON-safe
    assert POL.policy_from_dict(d) == pol
    f = str(tmp_path / "pol.json")
    POL.dump(pol, f)
    assert POL.load(f) == pol
    # user-facing "default" sub-object layout
    assert POL.policy_from_dict(
        {"budget": 0.125, "default": {"mode": "block"}}).mode == "block"


def test_policy_from_newer_writer_readable_non_strict():
    """Artifact read path: unknown policy/rule keys from a future writer
    are dropped, not fatal (same contract as config_from_dict); the
    strict user-file path still rejects them as typos."""
    d = POL.policy_to_dict(_mixed_policy())
    d["dither"] = True
    d["rules"][0]["sparsity"] = 0.5
    with pytest.raises(ValueError):
        POL.policy_from_dict(d)
    pol = POL.policy_from_dict(d, strict=False)
    assert pol == _mixed_policy()
    cfg_d = afmt.config_to_dict(
        reduced(C.get("qwen3-1.7b")).policy_variant(_mixed_policy()))
    cfg_d["hash_policy"]["rules"][0]["sparsity"] = 0.5
    assert afmt.config_from_dict(cfg_d).hash_policy == _mixed_policy()


def test_config_dict_roundtrip_carries_policy():
    cfg = reduced(C.get("qwen3-1.7b")).policy_variant(_mixed_policy())
    d = afmt.config_to_dict(cfg)
    json.loads(json.dumps(d))
    assert afmt.config_from_dict(d) == cfg


def test_artifact_roundtrip_policy_config_and_logits(tmp_path):
    pol = POL.CompressionPolicy(
        compression=1 / 8, panel_cols=0,
        rules=(POL.PolicyRule(match="layers.attn.*", compression=1 / 4),
               POL.PolicyRule(match="layers.ffn.*", mode="block",
                              block_shape=(16, 16), compression=1 / 2)))
    cfg = reduced(C.get("qwen3-1.7b")).with_(
        dtype="float32").policy_variant(pol)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "pol.hnart")
    artifact.export_model(path, cfg, params)
    cfg2, m2, p2 = artifact.load_model(path)
    assert cfg2 == cfg and cfg2.hash_policy == pol
    batch = {"tokens": jnp.asarray([[5, 9, 2, 7]]),
             "cache": m.init_cache(1, 32)}
    l1, _ = m.prefill(params, batch)
    l2, _ = m2.prefill(p2, {"tokens": jnp.asarray([[5, 9, 2, 7]]),
                            "cache": m2.init_cache(1, 32)})
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_per_slot_quant_override_in_artifact(tmp_path):
    pol = POL.CompressionPolicy(
        compression=1 / 4, panel_cols=0,
        rules=(POL.PolicyRule(match="layers.ffn.*", quant="int8"),))
    cfg = reduced(C.get("qwen3-1.7b")).with_(
        dtype="float32").policy_variant(pol)
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "q.hnart")
    header = artifact.export_model(path, cfg, params,
                                   quant_min_size=0)
    quantized = {tuple(e["path"]) for e in header["leaves"] if e["quant"]}
    assert any(p[:2] == ("layers", "ffn") for p in quantized)
    assert not any(p[:2] == ("layers", "attn") for p in quantized)
    # still loads and serves logits (int8 error is bounded, just finite)
    _, m2, p2 = artifact.load_model(path)
    l2, _ = m2.prefill(p2, {"tokens": jnp.asarray([[5, 9, 2, 7]]),
                            "cache": m2.init_cache(1, 32)})
    assert np.isfinite(np.asarray(l2, np.float32)).all()


def test_report_groups_by_rule(tmp_path):
    pol = POL.CompressionPolicy(
        compression=1 / 8, panel_cols=0,
        rules=(POL.PolicyRule(match="layers.attn.*", compression=1 / 4),))
    cfg = reduced(C.get("qwen3-1.7b")).with_(
        dtype="float32").policy_variant(pol)
    m = build(cfg)
    path = str(tmp_path / "r.hnart")
    artifact.export_model(path, cfg, m.init(jax.random.PRNGKey(0)))
    header = afmt.read_header(path)
    rows = areport.rows_by_rule(header)
    by_name = {r["name"]: r for r in rows}
    assert "layers.attn.*" in by_name and "(defaults)" in by_name
    assert by_name["layers.attn.*"]["param_ratio"] == pytest.approx(
        1 / 4, rel=0.05)
    assert by_name["(defaults)"]["param_ratio"] == pytest.approx(
        1 / 8, rel=0.05)
    txt = areport.report(path)
    assert "by policy rule" in txt and "layers.attn.*" in txt


# ---------------------------------------------------------------------------
# satellites: variant naming, CLI ratios, mesh-derived bank sharding
# ---------------------------------------------------------------------------

def test_hashed_variant_exact_tags_and_get_roundtrip():
    base = C.get("qwen3-1.7b")
    assert base.hashed_variant(0.125).name.endswith("-hashed8")
    assert base.hashed_variant(1 / 16).name.endswith("-hashed16")
    # 0.3 is NOT "hashed3" (that would claim 1/3)
    assert base.hashed_variant(0.3).name.endswith("-hashedc0.3")
    for c in (0.125, 1 / 16, 0.3, 0.25):
        v = base.hashed_variant(c)
        got = C.get(v.name)
        assert got == v, c
    rv = reduced(base).hashed_variant(0.3)
    assert C.get(rv.name) == rv


def test_parse_ratio():
    assert POL.parse_ratio("1/8") == pytest.approx(0.125)
    assert POL.parse_ratio("0.25") == 0.25


def test_bank_pspec_derives_from_active_mesh():
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as shd
    from repro.nn import layers as L
    spec = HashedSpec((60, 60), 0.5, mode="element", seed=1, panel_cols=0)
    n0 = spec.real_param_shape()[0]
    assert n0 % 256 != 0
    # no mesh: production 256-grid fallback -> replicated
    assert L.bank_pspec(spec) == P(None)
    # tiny CI mesh: 1x1 grid divides everything -> sharded spec
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with shd.use_mesh(mesh):
        assert L.bank_pspec(spec) == P((L.FSDP, L.TP))
