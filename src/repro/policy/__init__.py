"""Compression policy subsystem: per-slot hashing rules + equal-memory
budget solving (the API the paper's per-layer/equal-storage experiments
need; see repro.policy.rules for the model).

    from repro import policy
    pol = policy.CompressionPolicy(
        budget=1 / 8,
        rules=(policy.PolicyRule(match="layers.attn.*",
                                 compression=1 / 4),
               policy.PolicyRule(match="embed.*", hashed=False)))
    cfg = C.get("qwen3-1.7b").policy_variant(pol)
"""
from repro.policy.budget import solve  # noqa: F401
from repro.policy.rules import (  # noqa: F401
    CompressionPolicy,
    PolicyRule,
    Slot,
    SlotAssignment,
    dump,
    effective,
    from_flat,
    load,
    parse_ratio,
    policy_from_dict,
    policy_to_dict,
    resolve,
    rule_from_dict,
    slot_path,
)
