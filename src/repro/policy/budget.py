"""Equal-memory budget solver.

The paper's headline comparisons hold *total storage* fixed while varying
how the budget is spent (§5: "with the same number of real parameters").
Given a target total real-parameter count and the free slots' virtual
sizes, every free slot gets ``c_i = clip(c*, floor_i, cap_i)`` for one
common waterlevel ``c*``.  Unbounded slots therefore share one ratio —
proportional-to-size allocation of real parameters — while bounded slots
saturate at their floor/cap and the others absorb the difference.

``total(c*) = sum(v_i * clip(c*, lo_i, hi_i))`` is continuous and
nondecreasing in ``c*``, so the exact waterlevel is a 1-D root found by
bisection; whenever a feasible allocation exists it is hit exactly (up
to float precision, then HashedSpec bucket rounding).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

# (key, virtual_size, floor, cap) per free slot
FreeSlot = Tuple[object, int, float, float]


def solve(target_real: float, free: Sequence[FreeSlot], *,
          fixed_real: float = 0.0) -> Dict[object, float]:
    """Allocate per-slot compression ratios.

    target_real: desired total real params across ALL hashed slots.
    free:        slots the solver controls (pinned slots are accounted in
                 ``fixed_real`` and excluded).
    fixed_real:  real params already committed by pinned rules.

    Returns {key: compression}.  If floors force the total above target
    (or caps below), the result saturates at the bounds — the closest
    achievable allocation.
    """
    pool: List[FreeSlot] = [(k, int(v), float(lo), float(hi))
                            for k, v, lo, hi in free]
    if not pool:
        return {}
    remaining = max(float(target_real) - float(fixed_real), 0.0)

    def total(level: float) -> float:
        return sum(v * min(max(level, lo), hi) for _, v, lo, hi in pool)

    lo_level, hi_level = 0.0, max(hi for _, _, _, hi in pool)
    if total(lo_level) >= remaining:      # floors already overshoot
        level = lo_level
    elif total(hi_level) <= remaining:    # caps can't reach the target
        level = hi_level
    else:
        for _ in range(100):              # monotone bisection: exact c*
            mid = 0.5 * (lo_level + hi_level)
            if total(mid) < remaining:
                lo_level = mid
            else:
                hi_level = mid
        level = 0.5 * (lo_level + hi_level)
    return {k: min(max(level, lo), hi) for k, _, lo, hi in pool}
