"""Declarative compression policies: per-slot hashing rules.

The paper's experiments (§5, §6) vary compression *per layer* and compare
networks at *equal storage*; related work goes further (Functional Hashing
configures hashing per layer, Structured Multi-Hashing allocates one
parameter budget across the whole model).  This module is that API: a
:class:`CompressionPolicy` is an ordered list of :class:`PolicyRule`\\ s
matched against *slot paths* — the dotted param-leaf paths of
``models.transformer.bank_spec_map`` with the trailing ``w`` leaf dropped,
e.g. ``layers.attn.q``, ``layers.moe.in``, ``embed.emb``, ``lm_head`` —
plus policy-wide defaults and an optional equal-memory *budget* solved by
:mod:`repro.policy.budget`.

Matching is first-rule-wins ``fnmatch`` globbing (``layers.attn.*``,
``*.ffn.out``, ``embed.*``); a slot no rule matches uses the policy
defaults.  The legacy flat ``ArchConfig`` knobs (``compression``,
``hash_mode``, ...) lower into a single ``*`` rule via :func:`from_flat`,
so pre-policy configs resolve to byte-identical ``HashedSpec``\\ s.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
from typing import Dict, Optional, Sequence, Tuple

from repro.core.hashed import HashedSpec
from repro.policy import budget as budget_mod

MODES = ("element", "block")
EXEC_PATHS = ("auto", "materialize", "scan", "pallas")
QUANT_SCHEMES = ("none", "int8", "fp8")


@dataclasses.dataclass(frozen=True)
class PolicyRule:
    """One per-slot override.  Every field except ``match`` is optional;
    unset fields fall through to the policy defaults.  ``floor``/``cap``
    bound the budget solver's allocation for matched slots (a slot with an
    explicit ``compression`` is pinned and excluded from budget solving)."""

    match: str                                   # glob over slot paths
    hashed: Optional[bool] = None                # False => leave dense
    compression: Optional[float] = None          # pinned ratio
    mode: Optional[str] = None                   # element | block
    panel_cols: Optional[int] = None             # element-mode panels
    block_shape: Optional[Tuple[int, int]] = None
    path: Optional[str] = None                   # execution path
    quant: Optional[str] = None                  # artifact quant override
    floor: Optional[float] = None                # budget lower bound
    cap: Optional[float] = None                  # budget upper bound

    def validate(self) -> None:
        if not self.match:
            raise ValueError("rule needs a non-empty match pattern")
        if self.mode is not None and self.mode not in MODES:
            raise ValueError(f"rule {self.match!r}: mode {self.mode!r} "
                             f"not in {MODES}")
        if self.path is not None and self.path not in EXEC_PATHS:
            raise ValueError(f"rule {self.match!r}: path {self.path!r} "
                             f"not in {EXEC_PATHS}")
        if self.quant is not None and self.quant not in QUANT_SCHEMES:
            raise ValueError(f"rule {self.match!r}: quant {self.quant!r} "
                             f"not in {QUANT_SCHEMES}")
        for name in ("compression", "floor", "cap"):
            v = getattr(self, name)
            if v is not None and not (0.0 < v <= 1.0):
                raise ValueError(f"rule {self.match!r}: {name}={v} "
                                 f"outside (0, 1]")
        if (self.floor is not None and self.cap is not None
                and self.floor > self.cap):
            raise ValueError(f"rule {self.match!r}: floor {self.floor} > "
                             f"cap {self.cap}")
        if self.block_shape is not None:
            bm, bn = self.block_shape
            if bm <= 0 or bn <= 0:
                raise ValueError(f"rule {self.match!r}: bad block_shape "
                                 f"{self.block_shape}")


@dataclasses.dataclass(frozen=True)
class CompressionPolicy:
    """Ordered rules + defaults + optional equal-memory budget.

    ``budget`` is the target ratio of total REAL parameters to total
    virtual (dense) parameters across all hashed slots; when set, slots
    without a pinned per-rule ``compression`` get solver-allocated ratios
    (see :mod:`repro.policy.budget`) so the whole model lands on the
    requested storage — the paper's equal-memory comparison as one knob."""

    rules: Tuple[PolicyRule, ...] = ()
    budget: Optional[float] = None
    # defaults for slots (or fields) no rule decides
    compression: float = 0.125
    mode: str = "element"
    panel_cols: int = 512
    block_shape: Tuple[int, int] = (128, 128)
    path: str = "scan"

    def validate(self) -> None:
        if self.mode not in MODES:
            raise ValueError(f"default mode {self.mode!r} not in {MODES}")
        if self.path not in EXEC_PATHS:
            raise ValueError(f"default path {self.path!r} not in "
                             f"{EXEC_PATHS}")
        if not (0.0 < self.compression <= 1.0):
            raise ValueError(f"default compression {self.compression} "
                             f"outside (0, 1]")
        if self.budget is not None and not (0.0 < self.budget <= 1.0):
            raise ValueError(f"budget {self.budget} outside (0, 1]")
        for r in self.rules:
            r.validate()

    def match(self, slot_path: str) -> Optional[PolicyRule]:
        """First rule whose glob matches ``slot_path`` (None = defaults)."""
        for r in self.rules:
            if fnmatch.fnmatchcase(slot_path, r.match):
                return r
        return None


def from_flat(*, compression: float, mode: str, panel_cols: int,
              block_shape: Tuple[int, int], path: str) -> CompressionPolicy:
    """Lower the legacy flat ArchConfig knobs into a single-rule policy.

    Resolution through this policy must be byte-identical to the pre-policy
    ``_hspec`` formula (same seeds/shapes/bucket counts) — the compat
    contract tested in tests/test_policy.py."""
    return CompressionPolicy(rules=(PolicyRule(
        match="*", compression=compression, mode=mode,
        panel_cols=panel_cols, block_shape=tuple(block_shape), path=path),))


def effective(cfg) -> CompressionPolicy:
    """The policy an ArchConfig actually runs under: its ``hash_policy``
    if set, else the compat lowering of its flat knobs."""
    if getattr(cfg, "hash_policy", None) is not None:
        return cfg.hash_policy
    return from_flat(compression=cfg.compression, mode=cfg.hash_mode,
                     panel_cols=cfg.hash_panel_cols,
                     block_shape=tuple(cfg.hash_block), path=cfg.hash_path)


# ---------------------------------------------------------------------------
# slots + resolution
# ---------------------------------------------------------------------------

def slot_path(path: Tuple) -> str:
    """Param-leaf path tuple -> dotted slot path rules match against.

    The trailing ``w`` leaf is dropped (``("layers","attn","q","w")`` ->
    ``layers.attn.q``); MoE banks and embeddings have no ``w`` leaf and
    keep all components (``layers.moe.in``, ``embed.emb``)."""
    parts = [str(p) for p in path]
    if len(parts) > 1 and parts[-1] == "w":
        parts = parts[:-1]
    return ".".join(parts)


@dataclasses.dataclass(frozen=True)
class Slot:
    """One hashable projection in a model: where it lives in the param
    tree, its dense (virtual) shape, and the seed its hash pattern derives
    from.  ``default_on`` encodes the legacy gating (embeddings/lm_head
    hash only under ``hash_embeddings``) that rules may override."""

    path: Tuple[str, ...]            # param-leaf path in the model pytree
    virtual_shape: Tuple[int, int]
    seed: int
    default_on: bool = True

    @property
    def dotted(self) -> str:
        return slot_path(self.path)

    @property
    def virtual_size(self) -> int:
        return self.virtual_shape[0] * self.virtual_shape[1]


@dataclasses.dataclass(frozen=True)
class SlotAssignment:
    """Resolution output for one slot: the spec (None = left dense), which
    rule decided it, and the artifact quant override if any."""

    slot: Slot
    spec: Optional[HashedSpec]
    rule: Optional[str]              # matched rule's pattern, None=defaults
    quant: Optional[str] = None


def _pick(rule: Optional[PolicyRule], field: str, default):
    if rule is not None and getattr(rule, field) is not None:
        return getattr(rule, field)
    return default


def resolve(policy: CompressionPolicy, slots: Sequence[Slot]
            ) -> Dict[Tuple[str, ...], SlotAssignment]:
    """Match every slot against the policy and build its HashedSpec.

    Slots a rule pins (explicit ``compression``) keep that ratio; with a
    ``budget`` set, the remaining hashed slots get solver-allocated ratios
    so total real params land on ``budget * total_virtual``."""
    policy.validate()
    matched = []
    for slot in slots:
        rule = policy.match(slot.dotted)
        on = slot.default_on if (rule is None or rule.hashed is None) \
            else rule.hashed
        matched.append((slot, rule, on))

    ratios: Dict[Tuple[str, ...], float] = {}
    if policy.budget is not None:
        hashed_on = [(s, r) for s, r, on in matched if on]
        total_virtual = sum(s.virtual_size for s, _ in hashed_on)
        target = policy.budget * total_virtual
        fixed_real = 0.0
        free = []
        for s, r in hashed_on:
            pinned = r.compression if r is not None else None
            if pinned is not None:
                fixed_real += pinned * s.virtual_size
            else:
                lo = _pick(r, "floor", 0.0)
                hi = _pick(r, "cap", 1.0)
                # at least one real parameter per slot
                lo = max(lo, 1.0 / max(s.virtual_size, 1))
                free.append((s.path, s.virtual_size, lo, max(lo, hi)))
        ratios = budget_mod.solve(target, free, fixed_real=fixed_real)

    out: Dict[Tuple[str, ...], SlotAssignment] = {}
    for slot, rule, on in matched:
        pattern = rule.match if rule is not None else None
        if not on:
            out[slot.path] = SlotAssignment(slot, None, pattern)
            continue
        mode = _pick(rule, "mode", policy.mode)
        comp = _pick(rule, "compression",
                     ratios.get(slot.path, policy.compression))
        panel = _pick(rule, "panel_cols", policy.panel_cols)
        spec = HashedSpec(
            virtual_shape=tuple(slot.virtual_shape),
            compression=float(comp),
            mode=mode,
            seed=slot.seed,
            panel_cols=(panel if mode == "element" else 0),
            block_shape=tuple(_pick(rule, "block_shape",
                                    policy.block_shape)),
            exec_path=_pick(rule, "path", policy.path),
        )
        spec.validate()
        out[slot.path] = SlotAssignment(slot, spec, pattern,
                                        quant=_pick(rule, "quant", None))
    return out


# ---------------------------------------------------------------------------
# serialization (policy JSON files, ArchConfig dicts, artifact headers)
# ---------------------------------------------------------------------------

_RULE_FIELDS = {f.name for f in dataclasses.fields(PolicyRule)}
_POLICY_FIELDS = {f.name for f in dataclasses.fields(CompressionPolicy)}


def rule_from_dict(d: dict, *, strict: bool = True) -> PolicyRule:
    """strict=True (user-authored files): unknown keys are typos — raise.
    strict=False (artifact/registry read path): drop unknown keys so
    files written by newer versions stay readable (same forward-compat
    contract as ``format.config_from_dict``)."""
    unknown = set(d) - _RULE_FIELDS
    if unknown and strict:
        raise ValueError(f"unknown rule keys {sorted(unknown)} "
                         f"(known: {sorted(_RULE_FIELDS)})")
    kw = {k: v for k, v in d.items() if k in _RULE_FIELDS}
    if kw.get("block_shape") is not None:
        kw["block_shape"] = tuple(int(x) for x in kw["block_shape"])
    r = PolicyRule(**kw)
    r.validate()
    return r


def policy_from_dict(d: dict, *, strict: bool = True) -> CompressionPolicy:
    """Inverse of :func:`policy_to_dict`; also accepts the user-facing
    file layout where defaults sit under a ``"default"`` sub-object.
    See :func:`rule_from_dict` for ``strict``."""
    kw = dict(d)
    kw.update(kw.pop("default", {}) or {})
    unknown = set(kw) - _POLICY_FIELDS
    if unknown and strict:
        raise ValueError(f"unknown policy keys {sorted(unknown)} "
                         f"(known: {sorted(_POLICY_FIELDS)})")
    kw = {k: v for k, v in kw.items() if k in _POLICY_FIELDS}
    kw["rules"] = tuple(
        r if isinstance(r, PolicyRule)
        else rule_from_dict(r, strict=strict)
        for r in kw.get("rules", ()) or ())
    if kw.get("block_shape") is not None:
        kw["block_shape"] = tuple(int(x) for x in kw["block_shape"])
    p = CompressionPolicy(**kw)
    p.validate()
    return p


def policy_to_dict(policy: CompressionPolicy) -> dict:
    """JSON-safe dict; exact inverse of :func:`policy_from_dict`."""
    d = dataclasses.asdict(policy)
    d["rules"] = [dict(r) for r in d["rules"]]
    for r in d["rules"]:
        if r.get("block_shape") is not None:
            r["block_shape"] = list(r["block_shape"])
    d["block_shape"] = list(d["block_shape"])
    return d


def load(path: str) -> CompressionPolicy:
    """Read a policy JSON file (``launch/train --policy``)."""
    with open(path) as f:
        return policy_from_dict(json.load(f))


def dump(policy: CompressionPolicy, path: str) -> None:
    with open(path, "w") as f:
        json.dump(policy_to_dict(policy), f, indent=1, sort_keys=True)


def parse_ratio(text: str) -> float:
    """CLI budget/compression ratios: ``0.125`` or ``1/8``."""
    if "/" in text:
        num, _, den = text.partition("/")
        return float(num) / float(den)
    return float(text)
