"""Fused batched sampling pipeline for the serving engine.

One jitted dispatch per decode tick covers the WHOLE decode batch, no
matter how many distinct `SamplingParams` are in flight: every knob is
a per-row array (temperature, top-k, top-p, min-p, penalties, seed,
position), so mixed greedy / top-p / penalized rows ride one XLA
program — no per-request Python branching in the hot loop.  The stages,
in order:

1. **Penalties** — repetition (HF-style divide/multiply on tokens seen
   in prompt+output) and presence (subtract on generated tokens), from
   per-row seen/generated vocab masks maintained incrementally by
   `SamplerState`.  At the default (1.0 / 0.0) the maths are exact
   identities (``x/1``, ``x*1``, ``x-0`` are bitwise x), so default
   rows see the raw logits — greedy output stays byte-identical to the
   pre-SamplingParams engine.
2. **Logprob surface** — ``log_softmax`` of the penalized,
   UN-temperature-scaled logits: the chosen token's logprob plus an
   optional top-K report (temperature-independent, for eval).
3. **Temperature → top-k → top-p → min-p** truncation.  Top-k runs the
   radix-select Pallas kernel on TPU (`kernels.topk`), the
   ``jax.lax.top_k`` full-sort fallback elsewhere.
4. **Counter-based PRNG sampling** — the Gumbel-argmax trick with a
   per-row key ``fold_in(fold_in(BASE, seed), position)`` where
   ``position`` is the index of the token being generated.  No stream
   state is consumed: the same (seed, position) always reproduces the
   same draw, so preemption-recompute and prefix-cache replay are
   bitwise token-identical for temperature > 0, and sampling a bound
   row that is later discarded (a mid-prefill row riding the batch)
   perturbs nothing.  Greedy rows (temperature 0) take ``argmax`` of
   the penalized logits instead.

`SamplerState` is the host-side row-state mirror: tiny per-row knob
vectors plus (rows, vocab) boolean seen-masks, rebound on admission
(deterministically reconstructed from prompt+tokens, so preemption
rebinds to the identical state) and advanced per committed token.
`FusedSampler` owns the dispatch surface the engine drives: the state,
the bounded menu of jitted specializations, and the sampler's
observability — dispatch counters (``sampler.dispatches.*``) and a
dispatch-latency histogram (``sampler.dispatch_s``) published into the
engine's metrics registry (`repro.obs.metrics`), with per-dispatch
trace slices on the engine track when tracing is enabled.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.topk import NEG, topk_mask
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import ENGINE_PID, Tracer

# Fixed base key for the per-request counter streams; per-row keys are
# fold_in(fold_in(_BASE, seed), position).  Changing this constant
# changes every sampled (temperature > 0) output.
_BASE_KEY_SEED = 20150406        # HashedNets (ICML 2015)


def apply_penalties(logits, seen, out_seen, rep_pen, pres_pen):
    """Repetition + presence penalties, rows vectorized.

    logits (B, V) fp32; seen/out_seen (B, V) bool; rep_pen/pres_pen
    (B,).  Defaults (1.0, 0.0) are exact no-ops bit-for-bit.
    """
    r = rep_pen[:, None]
    pen = jnp.where(logits > 0, logits / r, logits * r)
    x = jnp.where(seen, pen, logits)
    return x - pres_pen[:, None] * out_seen.astype(x.dtype)


def topp_mask(z, p, fill=NEG):
    """Nucleus filtering: keep the smallest descending-probability
    prefix with mass >= p (the prefix-mass rule — a token survives iff
    the mass of strictly-higher-ranked tokens is < p, which always
    keeps the top-1), then admit every token whose probability ties the
    cutoff.  ``p >= 1`` disables the row.  Same semantics as
    `kernels.ref.topp_mask_ref` (the numpy walk oracle)."""
    probs = jax.nn.softmax(z.astype(jnp.float32), axis=-1)
    srt = jnp.sort(probs, axis=-1)[:, ::-1]              # descending
    # exclusive cumsum: mass of strictly-higher-ranked tokens
    excl = jnp.concatenate(
        [jnp.zeros_like(srt[:, :1]), jnp.cumsum(srt, axis=-1)[:, :-1]],
        axis=-1)
    keep_srt = excl < p[:, None]
    cutoff = jnp.min(jnp.where(keep_srt, srt, 2.0), axis=-1)
    keep = (probs >= cutoff[:, None]) | (p >= 1.0)[:, None]
    return jnp.where(keep, z, jnp.asarray(fill, z.dtype))


def minp_mask(z, min_p, fill=NEG):
    """Drop tokens with probability < ``min_p * max_prob`` (0 disables
    the row)."""
    probs = jax.nn.softmax(z.astype(jnp.float32), axis=-1)
    mx = jnp.max(probs, axis=-1, keepdims=True)
    keep = (probs >= min_p[:, None] * mx) | (min_p <= 0.0)[:, None]
    return jnp.where(keep, z, jnp.asarray(fill, z.dtype))


def _row_key(seed, pos):
    base = jax.random.PRNGKey(_BASE_KEY_SEED)
    return jax.random.fold_in(jax.random.fold_in(base, seed), pos)


def sample_tokens(logits, state, *, logprob_k: int = 0,
                  with_sampling: bool = True,
                  with_truncation: bool = True,
                  use_pallas_topk=None, interpret=None):
    """One fused sampling dispatch for a whole batch of rows.

    logits: (B, V); state: dict of per-row arrays —
      temperature/top_p/min_p/rep_pen/pres_pen (B,) f32,
      top_k/seed/pos (B,) i32, and optionally seen/out_seen (B, V)
      bool (the penalty masks; omitting them — statically, by key —
      skips the penalty stage AND the per-tick host->device mask
      transfer, exact for batches whose rows all sit at the default
      penalties, since those are bitwise no-ops anyway).
    ``with_sampling=False`` (static) skips the truncation + Gumbel
    stages entirely — the all-greedy-batch specialization; greedy rows
    take the identical argmax in either variant, so switching variants
    between ticks never changes a token.  ``with_truncation=False``
    (static) skips just the top-k/top-p/min-p masking for
    temperature-only batches — exact, since disabled knobs (k=0, p=1,
    min_p=0) filter nothing.
    Returns {"token" (B,) i32, "logprob" (B,) f32} plus, when
    ``logprob_k > 0``, {"topk_ids" (B, K) i32, "topk_logprobs" (B, K)}.

    Pure: the counter-based keys make repeated calls with the same
    inputs bitwise identical — discarded results (inactive rows sampled
    for batching convenience) never desync anything.
    """
    x = logits.astype(jnp.float32)
    if "seen" in state:          # static: engine omits the (B, V) masks
        pen = apply_penalties(x, state["seen"], state["out_seen"],
                              state["rep_pen"], state["pres_pen"])
    else:                        # when no bound row uses penalties
        pen = x
    lp = jax.nn.log_softmax(pen, axis=-1)
    greedy_tok = jnp.argmax(pen, axis=-1)

    t = state["temperature"]
    if with_sampling:
        z = pen / jnp.maximum(t, 1e-6)[:, None]
        if with_truncation:
            z = topk_mask(z, state["top_k"], fill=NEG,
                          use_pallas=use_pallas_topk, interpret=interpret)
            z = topp_mask(z, state["top_p"])
            z = minp_mask(z, state["min_p"])

        keys = jax.vmap(_row_key)(state["seed"], state["pos"])
        g = jax.vmap(lambda k: jax.random.gumbel(
            k, (x.shape[-1],), jnp.float32))(keys)
        sampled_tok = jnp.argmax(z + g, axis=-1)
        tok = jnp.where(t <= 0.0, greedy_tok, sampled_tok)
    else:
        tok = greedy_tok
    tok = tok.astype(jnp.int32)
    out = {"token": tok,
           "logprob": jnp.take_along_axis(lp, tok[:, None], axis=-1)[:, 0]}
    if logprob_k > 0:
        top_lp, top_ids = jax.lax.top_k(lp, logprob_k)
        out["topk_ids"] = top_ids.astype(jnp.int32)
        out["topk_logprobs"] = top_lp
    return out


class SamplerState:
    """Host-side per-row sampling state for a fixed decode batch.

    One slot per engine row.  ``bind`` reconstructs a slot entirely
    from the request's (sampling params, prompt, tokens-so-far) — a
    pure function of request state, so a preempted request rebinds to
    the exact state it would have had uninterrupted.  ``note`` advances
    the slot one committed token.  ``batch`` materializes the array
    dict `sample_tokens` consumes (sliced for B=1 prefill dispatches).
    """

    def __init__(self, rows: int, vocab: int):
        self.rows, self.vocab = rows, vocab
        self.temperature = np.zeros((rows,), np.float32)
        self.top_k = np.zeros((rows,), np.int32)
        self.top_p = np.ones((rows,), np.float32)
        self.min_p = np.zeros((rows,), np.float32)
        self.rep_pen = np.ones((rows,), np.float32)
        self.pres_pen = np.zeros((rows,), np.float32)
        self.seed = np.zeros((rows,), np.int32)
        self.pos = np.zeros((rows,), np.int32)
        self.seen = np.zeros((rows, vocab), bool)
        self.out_seen = np.zeros((rows, vocab), bool)
        # dispatch-shaping flags (host-side, read by the engine to pick
        # the cheapest fused-sampler specialization)
        self.uses_penalties = np.zeros((rows,), bool)
        self.wants_logprobs = np.zeros((rows,), bool)
        self.is_sampled = np.zeros((rows,), bool)
        self.uses_truncation = np.zeros((rows,), bool)

    def _ids_in_vocab(self, ids) -> np.ndarray:
        ids = np.asarray(ids, np.int64).ravel()
        return ids[(ids >= 0) & (ids < self.vocab)]

    def bind(self, row: int, req) -> None:
        sp = req.sampling
        self.temperature[row] = sp.temperature
        self.top_k[row] = sp.top_k
        self.top_p[row] = sp.top_p
        self.min_p[row] = sp.min_p
        self.rep_pen[row] = sp.repetition_penalty
        self.pres_pen[row] = sp.presence_penalty
        self.seed[row] = np.int32(np.uint32(req.seed_used or 0))
        self.uses_penalties[row] = (sp.repetition_penalty != 1.0
                                    or sp.presence_penalty != 0.0)
        self.wants_logprobs[row] = sp.logprobs is not None
        self.is_sampled[row] = sp.temperature > 0.0
        self.uses_truncation[row] = (sp.top_k > 0 or sp.top_p < 1.0
                                     or sp.min_p > 0.0)
        toks = list(req.tokens or ())
        self.pos[row] = len(toks)
        self.seen[row] = False
        self.out_seen[row] = False
        self.seen[row, self._ids_in_vocab(req.prompt)] = True
        if toks:
            gen = self._ids_in_vocab(toks)
            self.seen[row, gen] = True
            self.out_seen[row, gen] = True

    def clear(self, row: int) -> None:
        self.temperature[row] = 0.0
        self.top_k[row] = 0
        self.top_p[row] = 1.0
        self.min_p[row] = 0.0
        self.rep_pen[row] = 1.0
        self.pres_pen[row] = 0.0
        self.seed[row] = 0
        self.pos[row] = 0
        self.seen[row] = False
        self.out_seen[row] = False
        self.uses_penalties[row] = False
        self.wants_logprobs[row] = False
        self.is_sampled[row] = False
        self.uses_truncation[row] = False

    def note(self, row: int, tok: int) -> None:
        """Advance one committed token: the PRNG counter moves, and the
        penalty masks absorb the new token."""
        self.pos[row] += 1
        if 0 <= tok < self.vocab:
            self.seen[row, tok] = True
            self.out_seen[row, tok] = True

    def batch(self, sl: slice = slice(None), *,
              with_masks: bool = True) -> Dict[str, np.ndarray]:
        out = {"temperature": self.temperature[sl],
               "top_k": self.top_k[sl],
               "top_p": self.top_p[sl],
               "min_p": self.min_p[sl],
               "rep_pen": self.rep_pen[sl],
               "pres_pen": self.pres_pen[sl],
               "seed": self.seed[sl],
               "pos": self.pos[sl]}
        if with_masks:
            out["seen"] = self.seen[sl]
            out["out_seen"] = self.out_seen[sl]
        return out


class FusedSampler:
    """The engine-facing fused-sampler dispatch surface.

    Holds the per-row `SamplerState`, the bounded menu of compiled
    `sample_tokens` specializations keyed by (logprob width,
    any-sampled-row, any-truncated-row), and the sampler's metrics:
    the engine dispatches the k=0 variant (no per-tick top-K) unless
    some bound row asked for logprobs, the ``with_sampling=False``
    variant (argmax only — no Gumbel field) when every bound row is
    greedy, the ``with_truncation=False`` variant (no top-k/top-p/min-p
    sorts) for temperature-only batches, and omits the penalty masks
    from the input dict (statically, by key) when no bound row uses
    penalties — sparing the (rows, vocab) host->device transfer on
    default traffic.  All variants are bitwise token-identical (greedy
    rows take argmax in every variant; disabled knobs are exact
    no-ops).  (trunc only matters when samp; the samp=False entries for
    trunc=True just alias the same compiled program shape.)
    """

    def __init__(self, rows: int, vocab: int, logprob_k: int = 8, *,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        m = metrics if metrics is not None else MetricsRegistry()
        self.state = SamplerState(rows, vocab)
        self.logprob_k = int(min(logprob_k, vocab))
        self.tracer = tracer if tracer is not None else Tracer(enabled=False)
        self._fns = {
            (k, samp, trunc): jax.jit(functools.partial(
                sample_tokens, logprob_k=k,
                with_sampling=samp, with_truncation=trunc))
            for k in {0, self.logprob_k}
            for samp in (False, True) for trunc in (False, True)}
        self.dispatches = m.group("sampler.dispatches",
                                  keys=("prefill", "decode", "verify"))
        self._h_dispatch = m.histogram("sampler.dispatch_s")

    @property
    def time_s(self) -> float:
        """Cumulative seconds spent inside sampler dispatches."""
        return self._h_dispatch.total

    def run(self, logits, sl: slice, kind: str) -> Dict[str, np.ndarray]:
        """One fused dispatch over the row slice ``sl`` of the state
        (full batch for decode ticks, the single admitted row for a
        prefill completion)."""
        # sync the model's (async-dispatched) logits BEFORE the clock
        # starts, so sampler.dispatch_s measures the sampler, not the
        # decode forward pass it would otherwise absorb
        logits = jax.block_until_ready(jnp.asarray(logits, jnp.float32))
        t0 = time.perf_counter()
        tr0 = self.tracer.now()
        st = self.state
        masks = bool(st.uses_penalties[sl].any())
        k = self.logprob_k if st.wants_logprobs[sl].any() else 0
        samp = bool(st.is_sampled[sl].any())
        trunc = samp and bool(st.uses_truncation[sl].any())
        out = self._fns[k, samp, trunc](
            logits, st.batch(sl, with_masks=masks))
        res = {k2: np.asarray(v) for k2, v in out.items()}
        self._h_dispatch.observe(time.perf_counter() - t0)
        self.dispatches[kind] += 1
        if self.tracer.enabled:
            self.tracer.complete(ENGINE_PID, 0, f"sampler:{kind}", tr0)
        return res


    def run_block(self, logits, sl: slice, proposals: np.ndarray,
                  kind: str = "verify") -> Dict[str, np.ndarray]:
        """One fused dispatch over an (R, S, V) logits block — the
        speculative-verify surface.

        Slot ``s`` of row ``r`` is the token the engine WOULD commit at
        generated-index ``pos[r] + s`` assuming ``proposals[r, :s]``
        were the previous ``s`` commits: the per-slot state is exactly
        what `SamplerState` would hold after ``s`` `note` calls — pos
        advanced by ``s``, seen/out_seen OR'd with the proposal one-hots
        — so every slot reproduces the baseline per-tick dispatch
        bit-for-bit (same counter-based (seed, pos) PRNG stream, same
        penalty masks, same specialization flags).  Returns flat
        (R*S,) result arrays (slot ``r*S + s``), matching what
        ``run`` returns for a batch of R*S rows.
        """
        logits = jax.block_until_ready(jnp.asarray(logits, jnp.float32))
        t0 = time.perf_counter()
        tr0 = self.tracer.now()
        st = self.state
        r, s_blk, vocab = logits.shape
        proposals = np.asarray(proposals, np.int32).reshape(r, s_blk - 1)
        masks = bool(st.uses_penalties[sl].any())
        k = self.logprob_k if st.wants_logprobs[sl].any() else 0
        samp = bool(st.is_sampled[sl].any())
        trunc = samp and bool(st.uses_truncation[sl].any())

        exp = {key: np.repeat(v, s_blk, axis=0)
               for key, v in st.batch(sl, with_masks=False).items()}
        exp["pos"] = (st.pos[sl][:, None]
                      + np.arange(s_blk, dtype=np.int32)).reshape(-1)
        if masks:
            seen = np.repeat(st.seen[sl], s_blk, axis=0)
            out_seen = np.repeat(st.out_seen[sl], s_blk, axis=0)
            cum = np.zeros((r, vocab), bool)      # proposals committed < s
            rows = np.arange(r)
            for s in range(1, s_blk):
                t = proposals[:, s - 1]
                ok = (t >= 0) & (t < vocab)
                cum[rows[ok], t[ok]] = True
                seen[s::s_blk] |= cum
                out_seen[s::s_blk] |= cum
            exp["seen"], exp["out_seen"] = seen, out_seen

        out = self._fns[k, samp, trunc](logits.reshape(r * s_blk, vocab),
                                        exp)
        res = {k2: np.asarray(v) for k2, v in out.items()}
        self._h_dispatch.observe(time.perf_counter() - t0)
        self.dispatches[kind] += 1
        if self.tracer.enabled:
            self.tracer.complete(ENGINE_PID, 0, f"sampler:{kind}", tr0)
        return res


def accept_counts(targets: np.ndarray, proposals: np.ndarray,
                  limits: np.ndarray) -> np.ndarray:
    """Commits per row for a verified block.

    targets (R, S): the tokens the base model commits at each slot
    (slot s valid under the hypothesis that proposals[:s] matched);
    proposals (R, S-1): the draft's k proposals; limits (R,): number
    of verify slots actually usable for the row (room/max_tokens).
    A row commits targets[0..c-1] where c = 1 + the length of the
    leading proposal prefix that matches the targets, clamped to the
    row's limit — the deterministic-verify acceptance rule, exact for
    greedy AND seeded sampling because targets ARE the baseline's
    (seed, pos)-keyed draws.
    """
    match = (targets[:, :-1] == proposals).astype(np.int64)
    run = np.cumprod(match, axis=1).sum(axis=1)
    return np.minimum(1 + run, np.asarray(limits, np.int64)).astype(np.int64)


def match_stop(tokens: List[int], stop) -> bool:
    """True when ``tokens`` ends with any of the stop sequences."""
    for seq in stop:
        n = len(seq)
        if n and len(tokens) >= n and tuple(tokens[-n:]) == tuple(seq):
            return True
    return False
