"""Paged KV cache: fixed-size pages, free-list allocator, per-row page
tables.

The device side is a physical page pool per layer-stacked k/v
(``model.init_paged_cache``); this module is the *host-side* bookkeeping
the engine drives every tick:

- ``PageAllocator`` — a free-list over physical page ids.  Page 0 is
  reserved as the **trash page**: idle decode rows point their whole
  table at it so their (masked, discarded) writes land somewhere
  harmless, and no live row ever owns it.
- ``PagedKVCache`` — per-row page lists, the dense ``(rows, MAXP)``
  int32 table the decode step consumes, and per-row lengths.

Invariants (property-tested in tests/test_serving.py):
- a physical page is owned by at most one row at a time,
- alloc is all-or-nothing (no partial grants),
- release returns exactly the pages a row acquired (no leak, no
  double-free).
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

import numpy as np

TRASH_PAGE = 0


class PageAllocator:
    """Free-list allocator over physical pages [1, num_pages)."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (one is the trash page)")
        self.num_pages = num_pages
        self._free = deque(range(1, num_pages))
        self._used: set = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return len(self._used)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Grant n pages, or None (all-or-nothing) if fewer are free."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            assert p not in self._used, f"double-assigned page {p}"
            self._used.add(p)
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if p not in self._used:
                raise ValueError(f"freeing page {p} that is not allocated")
            self._used.remove(p)
            self._free.append(p)


class PagedKVCache:
    """Row-indexed page-table bookkeeping over a PageAllocator.

    ``rows`` is the static decode-batch width; ``max_pages_per_seq`` the
    static table width (ceil(max_len / page_size)).  Device page pools
    are owned by the engine; this class only tracks who owns what.
    """

    def __init__(self, num_pages: int, page_size: int, rows: int,
                 max_pages_per_seq: int):
        self.page_size = page_size
        self.rows = rows
        self.maxp = max_pages_per_seq
        self.alloc = PageAllocator(num_pages)
        self.table = np.zeros((rows, max_pages_per_seq), np.int32)
        self.lengths = np.zeros((rows,), np.int32)
        self.row_pages: Dict[int, List[int]] = {}

    # ------------------------------------------------------------------
    @property
    def usable_pages(self) -> int:
        return self.alloc.num_pages - 1          # minus the trash page

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def fits_ever(self, tokens: int) -> bool:
        """Could a request whose feed ever reaches ``tokens`` cached
        positions hold its working set in an otherwise empty pool?
        (Submit-time guard: prevents un-admittable requests from wedging
        the FIFO head forever — with this bound, an admission that keeps
        failing eventually succeeds once the pool drains.)"""
        return self.pages_for(tokens) <= min(self.usable_pages, self.maxp)

    def can_admit(self, tokens: int) -> bool:
        """Pages available right now to cache ``tokens`` prefilled
        positions AND address the first decode write at position
        ``tokens`` (pages_for(tokens + 1) covers both: one extra page
        exactly when the feed ends on a page boundary)."""
        return self.pages_for(tokens + 1) <= self.alloc.num_free

    # ------------------------------------------------------------------
    def admit_row(self, row: int, tokens: int) -> bool:
        """Bind ``row`` to freshly-allocated pages covering ``tokens``
        cached positions.  False (nothing changed) if pages are short."""
        assert row not in self.row_pages, f"row {row} already bound"
        pages = self.alloc.alloc(self.pages_for(tokens))
        if pages is None:
            return False
        self.row_pages[row] = pages
        self.table[row, :] = TRASH_PAGE
        self.table[row, :len(pages)] = pages
        self.lengths[row] = tokens
        return True

    def ensure_decode_room(self, row: int) -> str:
        """Make position ``lengths[row]`` addressable (the next token's
        k/v write).  Allocates at most one page.  Returns:

        - "ok"   — position addressable,
        - "oom"  — pool exhausted (caller preempts a row and retries),
        - "full" — table width (max_len) hit (caller force-retires).
        """
        need = self.lengths[row] // self.page_size + 1
        pages = self.row_pages[row]
        if len(pages) >= need:
            return "ok"
        if need > self.maxp:
            return "full"
        got = self.alloc.alloc(1)
        if got is None:
            return "oom"
        pages.extend(got)
        self.table[row, len(pages) - 1] = got[0]
        return "ok"

    def advance(self, row: int) -> None:
        self.lengths[row] += 1

    def release_row(self, row: int) -> None:
        pages = self.row_pages.pop(row)
        self.alloc.free(pages)
        self.table[row, :] = TRASH_PAGE
        self.lengths[row] = 0

    def leak_check(self) -> None:
        """Every page is either free or owned by exactly one live row."""
        owned = [p for pages in self.row_pages.values() for p in pages]
        assert len(owned) == len(set(owned)), "page owned by two rows"
        assert TRASH_PAGE not in owned, "trash page was allocated"
        assert len(owned) == self.alloc.num_used, \
            (len(owned), self.alloc.num_used)
        assert self.alloc.num_free + self.alloc.num_used \
            == self.usable_pages
