"""Paged KV cache: fixed-size pages, refcounted free-list allocator,
per-row page tables, and a prefix-sharing radix tree.

The device side is a physical page pool per layer-stacked k/v
(``model.init_paged_cache``); this module is the *host-side* bookkeeping
the engine drives every tick:

- ``PageAllocator`` — a refcounted free-list over physical page ids.
  Page 0 is reserved as the **trash page**: idle decode rows point their
  whole table at it so their (masked, discarded) writes land somewhere
  harmless, and no live row ever owns it.
- ``PrefixIndex`` — a radix tree over page-granular token-id chunks:
  each node indexes the physical page holding the K/V of one *full*
  page of tokens; nodes additionally carry *partial tail* entries for
  the last, partially-filled page of an indexed sequence.  Matching a
  new request's feed against the tree yields pages that can be mapped
  by reference instead of recomputed.
- ``PagedKVCache`` — per-row page lists, the dense ``(rows, MAXP)``
  int32 table the decode step consumes, per-row lengths, and the
  prefix-sharing/COW lifecycle.

Refcount / copy-on-write lifecycle invariants (property-tested in
tests/test_serving.py and tests/test_serving_fuzz.py):

- Every allocated page's refcount equals the number of *holders*: rows
  whose table maps it, plus one if the prefix tree indexes it, plus one
  while it is pinned as a gather source (``RowMeta.tail_page``).
- A page is only ever **written** while its refcount is 1 and its sole
  holder is the writing row.  ``admit_row`` maps shared prefix pages
  read-only; the partially-filled boundary page is never written in
  place when shared — the row gets a private copy (copy-on-write):
  either rebuilt from the gathered prefix during chunked prefill, or,
  when a decode write targets a shared page, via ``ensure_decode_room``
  allocating a replacement and scheduling a device page copy
  (``pending_copies``).
- ``release_row`` and preemption *decrement* refcounts; pages the
  prefix tree still indexes survive the owning request and serve later
  prefix hits.  Tree-held pages with refcount 1 are reclaimed
  least-recently-used when the allocator runs dry.
- ``leak_check`` asserts the full accounting after any sequence of
  operations: refcounts match holders exactly, no page is free and
  referenced at once, and free + used == usable.

**Sharded layout (tensor-parallel serving, ``Engine(mesh=...)``):** the
page pool shards on the KV-HEAD axis over the mesh's "model" axis (see
:func:`pool_pspec`) — each device holds ``num_kv_heads / tp`` heads of
EVERY physical page, so there is still exactly ONE global page id space
and ONE global ``(rows, MAXP)`` page table.  Nothing in this module
changes under sharding: the allocator, refcounts, prefix tree, and COW
queue stay host-global (page ids name whole cross-device pages), and
the device-local gathers happen inside the shard_mapped attention
dispatch (`nn.attention`).
"""
from __future__ import annotations

import dataclasses
from collections import Counter, deque
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.obs.metrics import MetricsRegistry

TRASH_PAGE = 0


def pool_pspec(num_kv_heads: int, num_q_heads: int, tp: int):
    """PartitionSpec for the stacked page pool ``{"k","v"}`` of shape
    ``(num_layers, num_pages, page_size, num_kv_heads, head_dim)``.

    Shards the kv-head axis over "model" when both head counts divide
    ``tp`` (GQA ships each kv head's whole query group to one shard);
    otherwise fully replicated — the engine then runs single-device
    math on every device rather than splitting a softmax contraction
    (head_dim/page sharding would break the bitwise-identity contract).
    """
    from jax.sharding import PartitionSpec as P
    if tp > 1 and num_kv_heads % tp == 0 and num_q_heads % tp == 0:
        return P(None, None, None, "model", None)
    return P(None, None, None, None, None)


class PageAllocator:
    """Refcounted free-list allocator over physical pages [1, num_pages).

    ``alloc`` hands out pages at refcount 1; ``incref`` adds a holder
    (prefix sharing); ``decref``/``free`` drop holders and return the
    page to the free list when the count reaches zero.
    """

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (one is the trash page)")
        self.num_pages = num_pages
        self._free = deque(range(1, num_pages))
        self._ref: Dict[int, int] = {}

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return len(self._ref)

    def refcount(self, page: int) -> int:
        """Current holder count; 0 means the page is on the free list."""
        return self._ref.get(page, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Grant n pages at refcount 1, or None (all-or-nothing) if fewer
        are free."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            assert p not in self._ref, f"double-assigned page {p}"
            self._ref[p] = 1
        return pages

    def incref(self, page: int) -> None:
        if page not in self._ref:
            raise ValueError(f"incref on unallocated page {page}")
        self._ref[page] += 1

    def decref(self, page: int) -> bool:
        """Drop one holder; True if the page was freed by this call."""
        if page not in self._ref:
            raise ValueError(f"freeing page {page} that is not allocated")
        self._ref[page] -= 1
        if self._ref[page] == 0:
            del self._ref[page]
            self._free.append(page)
            return True
        return False

    def free(self, pages: List[int]) -> None:
        """Drop one holder per page (a row releasing its table)."""
        for p in pages:
            self.decref(p)


def _common_prefix(a, b) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


class _TrieNode:
    __slots__ = ("chunk", "page", "children", "partials", "parent", "stamp")

    def __init__(self, chunk: Tuple[int, ...], page: Optional[int],
                 parent: Optional["_TrieNode"]):
        self.chunk = chunk
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], _TrieNode] = {}
        # partial boundary pages: token-tuple -> [page, fill, stamp]
        self.partials: Dict[Tuple[int, ...], list] = {}
        self.stamp = 0


class PrefixIndex:
    """Radix tree over token-id chunks of one page each.

    Depth d indexes the page holding K/V for absolute positions
    [d*ps, (d+1)*ps) of any sequence whose first (d+1)*ps token ids
    spell the path — K/V of a token depends only on the tokens before
    it, so a common prefix means bitwise-identical pages, and positions
    stay aligned because matches always start at position 0.

    The tree holds one allocator reference per indexed page; entries
    whose page has no other holder (refcount 1) are evicted LRU when
    the allocator runs dry.
    """

    def __init__(self, page_size: int, alloc: PageAllocator,
                 metrics: Optional[MetricsRegistry] = None):
        self.ps = page_size
        self.alloc = alloc
        self.root = _TrieNode((), None, None)
        self._clock = 0
        m = metrics if metrics is not None else MetricsRegistry()
        # live view into the registry (prefix.* metrics); short keys
        # preserved for existing readers
        self.stats = m.group("prefix", keys=(
            "hit_tokens", "miss_tokens", "indexed_pages", "evictions"))

    # ------------------------------------------------------------------
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def pages(self) -> Iterator[int]:
        """Every page the tree holds a reference on (leak accounting)."""
        stack = [self.root]
        while stack:
            node = stack.pop()
            if node.page is not None:
                yield node.page
            for ent in node.partials.values():
                yield ent[0]
            stack.extend(node.children.values())

    @property
    def num_pages(self) -> int:
        return sum(1 for _ in self.pages())

    def evictable(self) -> int:
        """Upper bound on pages eviction could free right now.  Rows map
        prefixes contiguously from the root, so a node with a row holder
        implies its parent has one too — refcount-1 subtrees are whole,
        and every refcount-1 page is eventually freeable leaf-first."""
        return sum(1 for p in self.pages() if self.alloc.refcount(p) == 1)

    # ------------------------------------------------------------------
    def match(self, tokens, peek: bool = False
              ) -> Tuple[List[int], Optional[Tuple[int, int]]]:
        """Longest page-granular prefix of ``tokens`` in the tree.

        Returns (full_pages, tail): pages covering whole leading pages,
        plus an optional (page, use) source for the next, partial page —
        the best common-prefix among this node's partial entries and
        full children (a full page whose chunk *starts with* the
        remaining feed is a valid partial source: only its first ``use``
        positions are read).  ``peek`` skips LRU stamping (admissibility
        probes must not perturb eviction order).
        """
        toks = [int(t) for t in tokens]
        stamp = None if peek else self._tick()
        node = self.root
        fulls: List[int] = []
        i = 0
        while i + self.ps <= len(toks):
            child = node.children.get(tuple(toks[i:i + self.ps]))
            if child is None:
                break
            node = child
            fulls.append(child.page)
            i += self.ps
            if stamp is not None:
                node.stamp = stamp
        rest = toks[i:i + self.ps]
        tail: Optional[Tuple[int, int]] = None
        best, winner = 0, None
        for ptoks, ent in node.partials.items():
            use = _common_prefix(ptoks, rest)
            if use > best:
                best, tail, winner = use, (ent[0], use), ent
        for chunk, child in node.children.items():
            use = _common_prefix(chunk, rest)
            if use > best:
                best, tail, winner = use, (child.page, use), child
        if stamp is not None and winner is not None:
            # stamp only the candidate actually returned: refreshing
            # losers would shield never-used pages from LRU eviction
            if isinstance(winner, _TrieNode):
                winner.stamp = stamp
            else:
                winner[2] = stamp
        return fulls, tail

    def insert(self, tokens, pages: List[int], n_tokens: int) -> None:
        """Index ``pages`` as holding K/V for tokens[:n_tokens].

        Full pages become tree nodes; a trailing partial page becomes a
        partial entry at its node.  The tree increfs each page it newly
        claims; chunks already indexed (by this row earlier, or by a
        concurrent row with identical content) are walked, not re-claimed
        — the caller's duplicate page simply stays private to it.
        """
        stamp = self._tick()
        node = self.root
        i, j = 0, 0
        n_tokens = min(n_tokens, len(tokens), len(pages) * self.ps)
        while i + self.ps <= n_tokens:
            chunk = tuple(int(t) for t in tokens[i:i + self.ps])
            child = node.children.get(chunk)
            if child is None:
                page = pages[j]
                self.alloc.incref(page)
                child = _TrieNode(chunk, page, node)
                node.children[chunk] = child
                self.stats["indexed_pages"] += 1
            child.stamp = stamp
            node = child
            i += self.ps
            j += 1
        fill = n_tokens - i
        if fill > 0:
            ptoks = tuple(int(t) for t in tokens[i:n_tokens])
            if ptoks not in node.partials:
                self.alloc.incref(pages[j])
                node.partials[ptoks] = [pages[j], fill, stamp]
                self.stats["indexed_pages"] += 1

    def evict(self, need: int) -> int:
        """Free >= ``need`` pages by dropping LRU entries whose page has
        no other holder.  Nodes go leaf-first (a parent becomes a leaf
        once its subtree is gone); returns how many pages were freed.

        One DFS per tree "level": each pass collects every currently
        evictable candidate and drops them in LRU order (evicting a leaf
        never un-leafs anything, so the batch stays valid); parents
        exposed by a pass are picked up by the next one."""
        freed = 0
        while freed < need:
            cands = []             # (stamp, node, partial_key_or_None)
            stack = [self.root]
            while stack:
                node = stack.pop()
                for ptoks, ent in node.partials.items():
                    if self.alloc.refcount(ent[0]) == 1:
                        cands.append((ent[2], node, ptoks))
                if node.page is not None and not node.children \
                        and not node.partials \
                        and self.alloc.refcount(node.page) == 1:
                    cands.append((node.stamp, node, None))
                stack.extend(node.children.values())
            if not cands:
                break
            cands.sort(key=lambda c: c[0])
            for _, node, pkey in cands:
                if freed >= need:
                    break
                if pkey is not None:
                    page = node.partials.pop(pkey)[0]
                else:
                    page = node.page
                    node.parent.children.pop(node.chunk)
                self.alloc.decref(page)
                self.stats["evictions"] += 1
                freed += 1
        return freed


@dataclasses.dataclass
class RowMeta:
    """Prefix-sharing bookkeeping for one admitted row.

    ``shared`` leading table slots are mapped by reference (read-only);
    ``hit_tokens`` cached positions were served from the prefix tree
    (the engine's prefill starts there instead of position 0).
    ``tail_page`` pins a partial-page gather source — the engine copies
    its first ``tail_use`` positions into the row's private boundary
    page (the COW copy) and then drops the pin (``drop_tail_ref``)."""
    shared: int = 0
    hit_tokens: int = 0
    tail_page: Optional[int] = None
    tail_use: int = 0


class PagedKVCache:
    """Row-indexed page-table bookkeeping over a PageAllocator.

    ``rows`` is the static decode-batch width; ``max_pages_per_seq`` the
    static table width (ceil(max_len / page_size)).  Device page pools
    are owned by the engine; this class only tracks who owns what.
    With ``prefix_cache=True`` a PrefixIndex dedups shared prompt
    prefixes across rows (see the module docstring for the refcount /
    copy-on-write lifecycle).
    """

    def __init__(self, num_pages: int, page_size: int, rows: int,
                 max_pages_per_seq: int, prefix_cache: bool = False,
                 metrics: Optional[MetricsRegistry] = None,
                 alloc: Optional[PageAllocator] = None,
                 page_quota: Optional[int] = None):
        self.page_size = page_size
        self.rows = rows
        self.maxp = max_pages_per_seq
        # `alloc` lets several caches (one per hosted model) share ONE
        # physical pool; `page_quota` caps how many distinct pages THIS
        # cache may hold at once — the per-tenant fairness knob of the
        # multi-model engine (None = bounded only by the pool).
        if alloc is not None and alloc.num_pages != num_pages:
            raise ValueError(f"shared allocator has {alloc.num_pages} "
                             f"pages, cache expects {num_pages}")
        self._shared_alloc = alloc is not None
        self.alloc = alloc if alloc is not None else PageAllocator(num_pages)
        if page_quota is not None and page_quota < 1:
            raise ValueError(f"page_quota must be >= 1: {page_quota}")
        self.page_quota = page_quota
        self.table = np.zeros((rows, max_pages_per_seq), np.int32)
        self.lengths = np.zeros((rows,), np.int32)
        self.row_pages: Dict[int, List[int]] = {}
        self.row_meta: Dict[int, RowMeta] = {}
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.prefix = PrefixIndex(page_size, self.alloc,
                                  metrics=self.metrics) if prefix_cache \
            else None
        # device page copies the engine must perform before the next
        # write to the pool (copy-on-write sources -> private targets)
        self.pending_copies: List[Tuple[int, int]] = []
        # kv.* registry counters behind the legacy short-key dict view
        self.stats = self.metrics.group("kv", keys=(
            "pages_fresh", "pages_shared", "cow_copies"))

    # ------------------------------------------------------------------
    @property
    def usable_pages(self) -> int:
        return self.alloc.num_pages - 1          # minus the trash page

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def pages_held(self) -> int:
        """Distinct physical pages this cache currently references: row
        tables, pinned gather tails, and prefix-tree entries.  This is
        the quantity ``page_quota`` bounds — on a shared allocator it is
        the cache's true pool footprint (holders across *different*
        caches never share a page: sharing happens only through a
        cache-private prefix tree)."""
        held = set()
        for pages in self.row_pages.values():
            held.update(pages)
        for meta in self.row_meta.values():
            if meta.tail_page is not None:
                held.add(meta.tail_page)
        if self.prefix is not None:
            held.update(self.prefix.pages())
        return len(held)

    def fits_ever(self, tokens: int) -> bool:
        """Could a request whose feed ever reaches ``tokens`` cached
        positions hold its working set in an otherwise empty pool?
        (Submit-time guard: prevents un-admittable requests from wedging
        the FIFO head forever — with this bound, an admission that keeps
        failing eventually succeeds once the pool drains.)"""
        cap = min(self.usable_pages, self.maxp)
        if self.page_quota is not None:
            cap = min(cap, self.page_quota)
        return self.pages_for(tokens) <= cap

    def can_admit(self, tokens: int, token_ids=None) -> bool:
        """Pages available right now to cache ``tokens`` prefilled
        positions AND address the first decode write at position
        ``tokens`` (pages_for(tokens + 1) covers both: one extra page
        exactly when the feed ends on a page boundary).  With a prefix
        index this is an *optimistic* gate: shared pages reduce the need
        and tree-held reclaimable pages extend the supply, but the two
        sets may overlap — callers must tolerate ``admit_row`` failing
        and re-queue (liveness holds: on a drained pool the estimate is
        exact, so a ``fits_ever`` request is eventually admitted)."""
        need = self.pages_for(tokens + 1)
        avail = self.alloc.num_free
        evictable = 0
        if self.prefix is not None:
            if token_ids is not None and tokens > 0:
                fulls, _ = self.prefix.match(token_ids, peek=True)
                need -= min(len(fulls), (tokens - 1) // self.page_size)
            evictable = self.prefix.evictable()
            avail += evictable
        if self.page_quota is not None:
            # optimistic quota gate mirroring the pool gate: prefix-hit
            # pages are already in the footprint (the tree holds them),
            # only the fresh `need` grows it, and quota-driven eviction
            # can shrink it by at most `evictable`
            if self.pages_held() - evictable + need > self.page_quota:
                return False
        return need <= avail

    def _alloc_or_evict(self, n: int) -> Optional[List[int]]:
        """Grant ``n`` fresh pages, evicting this cache's own prefix
        entries to satisfy pool pressure or the per-cache quota.  The
        quota gate lives here — the one chokepoint every fresh
        allocation (admission, decode growth, COW) funnels through —
        so shared/prefix mappings never charge against it (they do not
        grow the distinct-page footprint)."""
        if self.page_quota is not None and n > 0:
            over = self.pages_held() + n - self.page_quota
            if over > 0 and self.prefix is not None:
                # shed tree-only pages first: quota pressure should
                # reclaim cache, not refuse live work
                self.prefix.evict(over)
            if self.pages_held() + n > self.page_quota:
                return None
        got = self.alloc.alloc(n)
        if got is None and self.prefix is not None:
            self.prefix.evict(n - self.alloc.num_free)
            got = self.alloc.alloc(n)
        return got

    # ------------------------------------------------------------------
    def admit_row(self, row: int, tokens: int, token_ids=None) -> bool:
        """Bind ``row`` to pages covering ``tokens`` cached positions.
        False (nothing changed) if pages are short.

        With ``token_ids`` and a prefix index, leading pages whose full
        token chunk is already indexed are mapped **by reference**
        (shared, read-only) instead of freshly allocated; a partial-page
        source for the boundary is pinned for the engine to gather from
        (``gather_table``/``drop_tail_ref``).  The usable prefix is
        capped at tokens-1 so at least one position is always computed —
        prefill must produce last-token logits to sample from."""
        assert row not in self.row_pages, f"row {row} already bound"
        meta = RowMeta()
        shared: List[int] = []
        if self.prefix is not None and token_ids is not None and tokens > 1:
            fulls, tail = self.prefix.match(token_ids)
            cap = tokens - 1
            n_full = min(len(fulls), cap // self.page_size)
            shared = fulls[:n_full]
            tail_page, tail_use = None, 0
            if n_full < len(fulls):
                # cap dropped a matched full page: its leading positions
                # still serve as the boundary-page source (unless the
                # cap landed exactly on the page boundary)
                tail_use = cap - n_full * self.page_size
                tail_page = fulls[n_full] if tail_use > 0 else None
            elif tail is not None:
                tail_use = min(tail[1], cap - n_full * self.page_size)
                tail_page = tail[0] if tail_use > 0 else None
            for p in shared:
                self.alloc.incref(p)
            if tail_page is not None:
                self.alloc.incref(tail_page)
            meta = RowMeta(shared=len(shared),
                           hit_tokens=n_full * self.page_size + tail_use,
                           tail_page=tail_page, tail_use=tail_use)
        fresh = self._alloc_or_evict(self.pages_for(tokens) - len(shared))
        if fresh is None and meta.tail_page is not None:
            # the tail pin itself can hold the last reclaimable page
            # hostage (a drained pool whose every page the tree retains
            # for this very prompt): trade the partial-page reuse for
            # admission — unpin, making it evictable, and retry
            self.alloc.decref(meta.tail_page)
            meta = RowMeta(shared=meta.shared,
                           hit_tokens=len(shared) * self.page_size)
            fresh = self._alloc_or_evict(self.pages_for(tokens)
                                         - len(shared))
        if fresh is None:
            for p in shared:
                self.alloc.decref(p)
            return False
        pages = shared + fresh
        self.row_pages[row] = pages
        self.row_meta[row] = meta
        self.table[row, :] = TRASH_PAGE
        self.table[row, :len(pages)] = pages
        self.lengths[row] = tokens
        self.stats["pages_fresh"] += len(fresh)
        self.stats["pages_shared"] += len(shared)
        if self.prefix is not None and token_ids is not None:
            self.prefix.stats["hit_tokens"] += meta.hit_tokens
            self.prefix.stats["miss_tokens"] += tokens - meta.hit_tokens
        return True

    def gather_table(self, row: int) -> np.ndarray:
        """Page ids to gather the row's prefix K/V from: the row's own
        table with the boundary slot redirected to the pinned partial
        source.  Valid until ``drop_tail_ref``."""
        meta = self.row_meta[row]
        pids = self.table[row].copy()
        if meta.tail_page is not None:
            pids[meta.shared] = meta.tail_page
        return pids

    def drop_tail_ref(self, row: int) -> None:
        """Unpin the gather source once the engine dispatched the gather
        (device ordering keeps the read ahead of any later reuse)."""
        meta = self.row_meta[row]
        if meta.tail_page is not None:
            self.alloc.decref(meta.tail_page)
            meta.tail_page = None

    def first_private_page(self, row: int) -> int:
        """First table slot the row may write (everything before is
        mapped by reference)."""
        meta = self.row_meta.get(row)
        return meta.shared if meta is not None else 0

    # ------------------------------------------------------------------
    def ensure_decode_room(self, row: int) -> str:
        """Make position ``lengths[row]`` addressable AND writable (the
        next token's k/v write) — the single-token decode case of
        :meth:`ensure_room`."""
        return self.ensure_room(row, self.lengths[row] + 1)

    def ensure_room(self, row: int, upto: int) -> str:
        """Make positions ``lengths[row] .. upto-1`` addressable AND
        writable (a k/v write block — speculative verify writes k+1
        positions in one dispatch).  Allocates the page shortfall plus,
        when the write-cursor page is shared (refcount > 1), one more
        for a private copy-on-write replacement — the device copy is
        queued on ``pending_copies`` for the engine to drain before the
        write.  Only the cursor page needs the COW check: sharers (the
        prefix tree, sibling rows) only ever reference fully-cached
        pages, all at or before the cursor, and pages past it are fresh
        allocations or truncate-trimmed privates.  (The engine's
        admission discipline keeps shared pages strictly behind the
        write cursor, so the COW branch is its defense-in-depth
        backstop; the stateful refcount tests drive it directly.)
        Returns:

        - "ok"   — every position addressable and privately writable,
        - "oom"  — pool exhausted (caller preempts a row and retries),
        - "full" — table width (max_len) hit (caller force-retires).
        """
        need = self.pages_for(upto)
        pages = self.row_pages[row]
        if len(pages) < need:
            if need > self.maxp:
                return "full"
            got = self._alloc_or_evict(need - len(pages))
            if got is None:
                return "oom"
            self.table[row, len(pages):need] = got
            pages.extend(got)
        j = self.lengths[row] // self.page_size
        if self.alloc.refcount(pages[j]) > 1:
            got = self._alloc_or_evict(1)
            if got is None:
                return "oom"
            old, new = pages[j], got[0]
            self.pending_copies.append((old, new))
            # the remaining holder (tree / other row) keeps `old` alive
            # until the engine performs the queued device copy
            self.alloc.decref(old)
            pages[j] = new
            self.table[row, j] = new
            meta = self.row_meta.get(row)
            if meta is not None and j < meta.shared:
                meta.shared = j
            self.stats["cow_copies"] += 1
        return "ok"

    def advance(self, row: int) -> None:
        self.lengths[row] += 1

    def truncate_row(self, row: int, keep_tokens: int) -> None:
        """Roll the row back to at most ``keep_tokens`` cached
        positions, freeing pages wholly past the new end (speculative
        rollback).  Popped pages are always privately held: rollback
        only ever discards positions past the last committed token, and
        nothing past the commit point is ever published to the prefix
        tree or mapped by another row."""
        keep = self.pages_for(keep_tokens)
        pages = self.row_pages[row]
        while len(pages) > keep:
            p = pages.pop()
            assert self.alloc.refcount(p) == 1, \
                f"truncating shared page {p} of row {row}"
            self.alloc.free([p])
            self.table[row, len(pages)] = TRASH_PAGE
        if self.lengths[row] > keep_tokens:
            self.lengths[row] = keep_tokens

    def release_row(self, row: int) -> None:
        """Drop the row's references.  Shared pages survive while other
        holders (the prefix tree, concurrent rows) remain."""
        pages = self.row_pages.pop(row)
        meta = self.row_meta.pop(row, None)
        if meta is not None and meta.tail_page is not None:
            self.alloc.decref(meta.tail_page)
        self.alloc.free(pages)
        self.table[row, :] = TRASH_PAGE
        self.lengths[row] = 0

    def index_row(self, row: int, token_ids, n_tokens: int) -> None:
        """Publish the row's first ``n_tokens`` cached positions to the
        prefix tree (token_ids spell their content).  No-op without a
        prefix index."""
        if self.prefix is None or row not in self.row_pages or n_tokens <= 0:
            return
        self.prefix.insert(token_ids, self.row_pages[row], n_tokens)

    def prefix_stats(self) -> Dict[str, float]:
        out = dict(self.stats)
        if self.prefix is not None:
            out.update(self.prefix.stats)
            total = out["hit_tokens"] + out["miss_tokens"]
            out["prefix_hit_rate"] = out["hit_tokens"] / total if total \
                else 0.0
            out["trie_pages"] = self.prefix.num_pages
        denom = out["pages_fresh"] + out["pages_shared"]
        out["pages_saved_frac"] = out["pages_shared"] / denom if denom \
            else 0.0
        return out

    def leak_check(self) -> None:
        """Refcounts match holders exactly: every allocated page is held
        by the rows mapping it + the prefix tree + pending gather pins,
        no free page is referenced, and free + used == usable."""
        refs: Counter = Counter()
        for pages in self.row_pages.values():
            assert len(pages) == len(set(pages)), \
                "row maps a page twice"
            refs.update(pages)
        for meta in self.row_meta.values():
            if meta.tail_page is not None:
                refs[meta.tail_page] += 1
        if self.prefix is not None:
            tree_pages = list(self.prefix.pages())
            assert len(tree_pages) == len(set(tree_pages)), \
                "prefix tree claims a page twice"
            refs.update(tree_pages)
        assert TRASH_PAGE not in refs, "trash page was allocated"
        held = {p: self.alloc.refcount(p) for p in refs}
        assert all(c > 0 for c in held.values()), "holder of a free page"
        assert dict(refs) == held, (dict(refs), held)
        if self._shared_alloc:
            # sibling caches hold the rest of num_used; pages are still
            # disjoint across caches (per-page equality above proves no
            # foreign holder on OUR pages)
            assert len(refs) <= self.alloc.num_used, \
                (len(refs), self.alloc.num_used)
        else:
            assert len(refs) == self.alloc.num_used, \
                (len(refs), self.alloc.num_used)
        assert self.alloc.num_free + self.alloc.num_used \
            == self.usable_pages
        if self.page_quota is not None:
            assert len(refs) <= self.page_quota, \
                (len(refs), self.page_quota)
