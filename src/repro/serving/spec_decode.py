"""Self-speculative decoding: draft k tokens with a compressed policy
variant, verify them all in one base-model dispatch.

The draft model (see `serving.draft`) shares the served model's hash
seeds and layout — HashedNets' ladder makes it a free byproduct of the
artifact.  Per scheduler tick, for every decoding row:

1. **Propose** — the draft catches its private paged KV up to the
   base's committed history (amortized 1-2 positions per tick; chunked
   on admission) and autoregressively samples k proposals
   ``d_1..d_k``, all inside ONE jitted dispatch.
2. **Verify** — the base model runs ``[t_last, d_1..d_k]`` as one
   (B, k+1) block through `Model.decode_paged_block` (bitwise equal to
   k+1 sequential decode steps) and the fused sampler's `run_block`
   computes the token the baseline engine WOULD commit at every slot,
   reusing the counter-based (seed, position) PRNG streams.
3. **Commit / rollback** — row commits the verified targets up to the
   first draft mismatch (`sampling.accept_counts`); base and draft
   caches truncate back to the commit point (`truncate_row`).

**Exactness.**  The emitted tokens ARE the base sampler's own draws —
slot s is valid precisely when the draft matched the baseline's first
s tokens, in which case its logits (and penalty masks and PRNG
counter) are bitwise the baseline's.  The draft's output distribution
never enters the acceptance rule, so every `SamplingParams` mix stays
distribution-correct and greedy/seeded decode is bitwise
token-identical to the non-speculative engine, including under
preemption, prefix cache, and chunked prefill.  This is the
deterministic-verify specialization of rejection-sampling speculative
decoding (classic accept/resample needs draft *probabilities*; with a
deterministic per-slot draw the accept test degenerates to equality
against the recomputed target — exact, and simpler).

**Isolation.**  The draft owns a private, fully-provisioned
`PagedKVCache` (its own registry: `MetricsRegistry.group` is
get-or-create, so sharing the engine's would alias the ``kv.*`` /
``prefix.*`` counters) — speculation never contends with the base
page pool and never causes extra preemptions.  When the base pool is
too tight for a row's k+1 verify writes the block just shrinks (down
to 1 == baseline) instead of preempting anyone.

Observability: ``spec.*`` counters (proposed/accepted/dispatches), an
accept-length histogram, and propose/verify/rollback tracer spans.
"""
from __future__ import annotations

import functools
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import ENGINE_PID, REQUEST_PID
from repro.serving import sampling as sampling_lib
from repro.serving.paged_cache import TRASH_PAGE, PagedKVCache

_CATCH_CHUNK = 16     # draft catch-up positions per chunk dispatch
_PROP_CATCH = 2       # catch-up slots fused into the propose dispatch
                      # (steady state needs 1, the all-accepted bonus
                      # token makes it 2; admission pre-chunks down)


class SpecDecoder:
    """Per-engine speculative-decode driver (one per Engine)."""

    # adaptive-k controller: EWMA of the per-tick draft accept rate;
    # raise k when drafts almost always land, back off when they mostly
    # roll back.  Emitted tokens are unaffected (acceptance is equality
    # against the base sampler's own draws — k only sets how far ahead
    # we *try* per tick), so the knob trades dispatch count for
    # rollback waste with zero output risk.
    _EWMA_ALPHA = 0.3
    _K_UP_AT = 0.8        # ewma above this and k < k_max -> k += 1
    _K_DOWN_AT = 0.4      # ewma below this and k > 1     -> k -= 1

    def __init__(self, engine, draft_model, draft_params, k: int = 4, *,
                 attn_impl: str = "ref", adaptive: bool = False):
        if k < 1:
            raise ValueError(f"spec_k must be >= 1: {k}")
        self.eng = engine
        self.k_max = int(k)
        self.k = int(k)
        self.adaptive = bool(adaptive)
        self._accept_ewma: float = 0.0
        self._ewma_primed = False
        self.draft_model = draft_model
        self.draft_params = draft_params
        rows, maxp = engine.n_rows, engine.kv.maxp
        ps = engine.kv.page_size
        # fully provisioned private pool: the draft never contends with
        # the base pages and never triggers preemption
        num_pages = rows * maxp + 1
        self._kv_metrics = MetricsRegistry()
        self.kv = PagedKVCache(num_pages, ps, rows, maxp,
                               prefix_cache=False,
                               metrics=self._kv_metrics)
        self.pages = draft_model.init_paged_cache(num_pages, ps)

        m = engine.metrics
        self.counts = m.group("spec", keys=(
            "ticks", "proposed", "accepted_drafts", "rollback_tokens",
            "draft_dispatches", "verify_dispatches", "baseline_rows"))
        self._h_accept = m.histogram(
            "spec.accept_len",
            edges=tuple(float(i) for i in range(1, self.k_max + 2)))
        self._g_k = m.gauge("spec.k_current")
        self._g_k.set(self.k)
        self._g_ewma = m.gauge("spec.accept_ewma")
        self.tracer = engine.tracer

        blk = draft_model.decode_paged_block
        base_blk = engine.model.decode_paged_block
        if blk is None or base_blk is None:
            raise ValueError("speculative decoding needs "
                             "decode_paged_block (decoder, non-MoE)")
        impl = attn_impl
        # catch-up: pages-only (XLA DCEs the LM head)
        self._catchup = jax.jit(
            lambda dp, t, pg, tb, ln, ct: blk(dp, t, pg, tb, ln, ct,
                                              impl)[1],
            donate_argnums=(2,))
        self._verify = jax.jit(
            lambda p, t, pg, tb, ln, ct: base_blk(p, t, pg, tb, ln, ct,
                                                  impl),
            donate_argnums=(2,))

        def propose_body(dparams, catch_tokens, lengths, counts,
                         step_mask, pages, table, knobs, pmasks, *,
                         masks, samp, trunc, k):
            """Fused draft tick: catch-up block + k sample/decode steps.

            The sampling stages mirror the base sampler exactly — same
            `sample_tokens`, same (seed, pos) counter streams, same
            penalty-mask evolution — so an identical-logits draft (the
            equal-ratio rung) reproduces the baseline's draws bit for
            bit and accepts every slot.  ``step_mask`` zeroes KV writes
            for rows riding the batch without speculating this tick.
            """
            st = dict(knobs)
            if masks:
                st.update(pmasks)
            logits_all, pages = blk(dparams, catch_tokens, pages, table,
                                    lengths, counts, impl)
            pick = jnp.maximum(counts - 1, 0)[:, None, None]
            logits = jnp.take_along_axis(logits_all, pick, axis=1)[:, 0]
            cur = lengths + counts
            ridx = jnp.arange(catch_tokens.shape[0])
            props = []
            for j in range(k):
                r = sampling_lib.sample_tokens(
                    logits, st, logprob_k=0, with_sampling=samp,
                    with_truncation=trunc)
                d = r["token"]
                props.append(d)
                if j + 1 < k:
                    if masks:
                        st["seen"] = st["seen"].at[ridx, d].set(True)
                        st["out_seen"] = st["out_seen"].at[ridx, d] \
                            .set(True)
                    st["pos"] = st["pos"] + 1
                    logits_all, pages = blk(dparams, d[:, None], pages,
                                            table, cur, step_mask, impl)
                    logits = logits_all[:, 0]
                    cur = cur + step_mask
            return jnp.stack(props, 1), pages

        # per-k propose jits, built lazily: fixed-k engines only ever
        # key (k_max, ...); the adaptive controller adds a key per depth
        # it actually visits
        self._propose_body = propose_body
        self._propose_fns: Dict[tuple, object] = {}

    def _propose_fn(self, k: int, masks: bool, samp: bool, trunc: bool):
        key = (k, masks, samp, trunc)
        fn = self._propose_fns.get(key)
        if fn is None:
            fn = jax.jit(functools.partial(
                self._propose_body, masks=masks, samp=samp, trunc=trunc,
                k=k), donate_argnums=(5,))
            self._propose_fns[key] = fn
        return fn

    # ------------------------------------------------------------------
    def _history(self, i: int, upto: int) -> np.ndarray:
        req = self.eng.rows[i]
        ids = np.concatenate([np.asarray(req.prompt, np.int64).ravel(),
                              np.asarray(req.tokens or [], np.int64)])
        return ids[:upto].astype(np.int32)

    def _sampler_flags(self):
        st = self.eng._sampler_state
        masks = bool(st.uses_penalties.any())
        samp = bool(st.is_sampled.any())
        trunc = samp and bool(st.uses_truncation.any())
        return masks, samp, trunc

    # ------------------------------------------------------------------
    def tick(self, active: List[int]) -> int:
        """One propose/verify/commit round over the decode batch.

        Runs in place of the engine's per-tick decode+sample block,
        after the engine's room/COW pass.  Returns committed tokens.
        """
        eng, k = self.eng, self.k
        S = k + 1
        B = eng.n_rows
        kv_b = eng.kv

        # ---- per-row verify limits --------------------------------
        limits = np.zeros((B,), np.int64)
        elig: List[int] = []
        for i in active:
            n = int(kv_b.lengths[i])
            req = eng.rows[i]
            want = min(S, eng.max_len - n,
                       req.sampling.max_tokens - len(req.tokens))
            v = max(want, 1)
            # best-effort room for the k+1-position write block: on
            # pool pressure shrink the block (never preempt for spec)
            while v > 1 and kv_b.ensure_room(i, n + v) != "ok":
                v -= 1
            limits[i] = v
            # extras rows (image tokens) can't be replayed from token
            # ids alone, so the draft skips them: verify-only == one
            # baseline-equivalent token per tick
            if v >= 2 and n + k <= eng.max_len and not req.extras:
                elig.append(i)
            else:
                self.counts["baseline_rows"] += 1
        # a second COW should be impossible here (the engine's
        # ensure-room pass privatized every cursor page) but drain
        # defensively: a queued copy must land before the block write
        eng._drain_cow()
        # dispatch views AFTER ensure_room extended the page tables;
        # mid-prefill rows must neither write real pages nor attend
        table, lengths = kv_b.table, kv_b.lengths
        if eng._prefilling:
            table = table.copy()
            lengths = lengths.copy()
            for i in eng._prefilling:
                table[i, :] = TRASH_PAGE
                lengths[i] = 0

        masks, samp, trunc = self._sampler_flags()
        sst = eng._sampler_state
        proposals = np.zeros((B, k), np.int32)

        # ---- draft catch-up + propose (eligible rows only) --------
        if elig:
            tr0 = self.tracer.now()
            rem = np.zeros((B,), np.int64)
            hist: Dict[int, np.ndarray] = {}
            for i in elig:
                n = int(kv_b.lengths[i])
                if i not in self.kv.row_pages:
                    ok = self.kv.admit_row(i, 0)
                    assert ok, "draft pool is fully provisioned"
                st = self.kv.ensure_room(i, n + k)
                assert st == "ok", f"draft room: {st}"
                hist[i] = self._history(i, n + 1)
                rem[i] = n + 1 - int(self.kv.lengths[i])
                assert rem[i] >= 1
            while any(rem[i] > _PROP_CATCH for i in elig):
                feed = np.zeros((B, _CATCH_CHUNK), np.int32)
                cnts = np.zeros((B,), np.int32)
                for i in elig:
                    if rem[i] > _PROP_CATCH:
                        c = int(min(_CATCH_CHUNK, rem[i] - _PROP_CATCH))
                        dl = int(self.kv.lengths[i])
                        feed[i, :c] = hist[i][dl:dl + c]
                        cnts[i] = c
                self.pages = self._catchup(
                    self.draft_params, jnp.asarray(feed), self.pages,
                    jnp.asarray(self.kv.table),
                    jnp.asarray(self.kv.lengths), jnp.asarray(cnts))
                self.counts["draft_dispatches"] += 1
                for i in elig:
                    if cnts[i]:
                        self.kv.lengths[i] += cnts[i]
                        rem[i] -= cnts[i]
            feed = np.zeros((B, _PROP_CATCH), np.int32)
            cnts = np.zeros((B,), np.int32)
            step_mask = np.zeros((B,), np.int32)
            for i in elig:
                c = int(rem[i])
                dl = int(self.kv.lengths[i])
                feed[i, :c] = hist[i][dl:dl + c]
                cnts[i] = c
                step_mask[i] = 1
            knobs = sst.batch(slice(None), with_masks=False)
            pmasks = {"seen": sst.seen, "out_seen": sst.out_seen} \
                if masks else {}
            props, self.pages = self._propose_fn(k, masks, samp, trunc)(
                self.draft_params, jnp.asarray(feed),
                jnp.asarray(self.kv.lengths), jnp.asarray(cnts),
                jnp.asarray(step_mask), self.pages,
                jnp.asarray(self.kv.table), knobs, pmasks)
            proposals = np.asarray(props)
            self.counts["draft_dispatches"] += 1
            self.counts["proposed"] += k * len(elig)
            for i in elig:
                # catch-up wrote `cnts` positions, steps wrote k-1 more
                self.kv.lengths[i] += int(cnts[i]) + (k - 1)
            if self.tracer.enabled:
                self.tracer.complete(ENGINE_PID, 0, "spec:propose", tr0,
                                     rows=len(elig))

        # ---- verify: one base-model block + one fused sampler -----
        tr1 = self.tracer.now()
        tokens_blk = np.zeros((B, S), np.int32)
        tokens_blk[:, 0] = eng._tokens[:, 0]
        tokens_blk[:, 1:] = proposals
        counts_v = np.zeros((B,), np.int32)
        for i in active:
            counts_v[i] = limits[i]
        logits, eng.pages = self._verify(
            eng.params, jnp.asarray(tokens_blk), eng.pages,
            jnp.asarray(table), jnp.asarray(lengths),
            jnp.asarray(counts_v))
        res = eng._sampler.run_block(logits, slice(None), proposals,
                                     kind="verify")
        self.counts["verify_dispatches"] += 1
        if self.tracer.enabled:
            self.tracer.complete(ENGINE_PID, 0, "spec:verify", tr1,
                                 rows=len(active))

        # ---- accept / commit / rollback ---------------------------
        targets = res["token"].reshape(B, S)
        commits = sampling_lib.accept_counts(targets, proposals, limits)
        total = 0
        tick_accepted = 0
        for i in active:
            req = eng.rows[i]
            done = 0
            for s in range(int(commits[i])):
                kv_b.advance(i)
                eng._commit_token(i, req, res, i * S + s)
                done += 1
                if eng._stop_reason(req) is not None:
                    break
            n_new = int(kv_b.lengths[i])
            kv_b.truncate_row(i, n_new)      # free speculative pages
            rolled = int(limits[i]) - done
            if i in self.kv.row_pages:
                if self.tracer.enabled and rolled:
                    self.tracer.instant(REQUEST_PID, req.uid,
                                        "spec_rollback", tokens=rolled)
                # all accepted: the bonus target is committed but not
                # yet in any KV, so the draft re-feeds it next tick
                self.kv.truncate_row(i, min(n_new,
                                            int(self.kv.lengths[i])))
            self._h_accept.observe(done)
            if i in elig:
                self.counts["accepted_drafts"] += max(done - 1, 0)
                tick_accepted += max(done - 1, 0)
            self.counts["rollback_tokens"] += max(rolled, 0)
            total += done
        if self.adaptive and elig:
            # controller: EWMA the tick's draft accept rate, step k by
            # one within [1, k_max].  Output-safe by construction —
            # k only bounds how many equality-verified proposals each
            # tick attempts, never which tokens commit.
            rate = tick_accepted / (k * len(elig))
            if not self._ewma_primed:
                self._accept_ewma = rate
                self._ewma_primed = True
            else:
                a = self._EWMA_ALPHA
                self._accept_ewma = a * rate + (1 - a) * self._accept_ewma
            if self._accept_ewma > self._K_UP_AT and self.k < self.k_max:
                self.k += 1
            elif self._accept_ewma < self._K_DOWN_AT and self.k > 1:
                self.k -= 1
            self._g_k.set(self.k)
            self._g_ewma.set(round(self._accept_ewma, 6))
        self.counts["ticks"] += 1
        return total

    # ------------------------------------------------------------------
    def release_row(self, row: int) -> None:
        """Drop the row's draft pages (finish/preempt hook)."""
        if row in self.kv.row_pages:
            self.kv.release_row(row)

    def leak_check(self) -> None:
        """Refcount audit over the draft pool (Engine.shutdown)."""
        self.kv.leak_check()

    def stats(self) -> Dict[str, object]:
        proposed = int(self.counts["proposed"])
        accepted = int(self.counts["accepted_drafts"])
        return {
            "k": self.k,
            "k_max": self.k_max,
            "adaptive": self.adaptive,
            "accept_ewma": round(self._accept_ewma, 6),
            "ticks": int(self.counts["ticks"]),
            "proposed": proposed,
            "accepted_drafts": accepted,
            "accept_rate": accepted / proposed if proposed else 0.0,
            "mean_accept_len": self._h_accept.mean,
            "draft_dispatches": int(self.counts["draft_dispatches"]),
            "verify_dispatches": int(self.counts["verify_dispatches"]),
            "baseline_rows": int(self.counts["baseline_rows"]),
            "draft_pages_in_use": self.kv.alloc.num_used,
        }
