"""First-class request surface for the serving engine: sampling
parameters, streamed outputs, and request handles.

This is the stable API callers program against (the engine internals —
paged cache, scheduler, fused sampler — stay free to move underneath):

- `SamplingParams`: a frozen, validated description of HOW to decode
  one request — temperature (0 = greedy, the degenerate case, not a
  separate mode), top-k / top-p / min-p truncation, repetition and
  presence penalties, stop token-sequences, max_tokens, an optional
  seed, and an optional log-probability report width.  Attached to
  `Request`; the legacy ``Request(temperature=..., max_new_tokens=...)``
  shape lowers into an equivalent SamplingParams automatically, so
  pre-existing callers (and the pinned greedy fuzz cases) see identical
  behavior.
- **Seeded determinism**: token sampling uses a counter-based PRNG
  stream keyed on ``(seed, generated-token index)`` — no engine-global
  key is consumed — so preemption-recompute, prefix-cache replay, and
  chunked prefill reproduce the identical token sequence for
  temperature > 0 exactly as they do for greedy.  ``seed=None`` draws a
  per-request seed from the engine's own seeded stream at submit time:
  still reproducible run-to-run for a fixed submit order.
- `RequestOutput`: one *delta* of a streamed generation — the new token
  ids since the previous delta, their logprobs, the cumulative logprob,
  and (on the final delta) a ``finish_reason``.
- `RequestHandle`: returned by ``Engine.submit``.  Truthy iff the
  request was accepted (so ``if eng.submit(r):`` keeps working).
  Iterating the handle yields `RequestOutput` deltas, driving engine
  ticks on demand when none are buffered; ``drain()`` returns whatever
  is available without blocking — the poll-style surface for serving
  many streams off one engine loop.

Finish reasons:

- ``"stop"``     — EOS or one of ``SamplingParams.stop`` matched,
- ``"length"``   — ``max_tokens`` generated, or the row hit the
  engine's ``max_len`` context ceiling (``Request.truncated``),
- ``"deadline"`` — expired in queue before first admission
  (scheduler ``deadline_s``),
- ``"cancelled"`` — still queued when the engine began a graceful
  drain (``Engine.cancel_queued``); never admitted, zero tokens.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

FINISH_STOP = "stop"
FINISH_LENGTH = "length"
FINISH_DEADLINE = "deadline"
# cancelled-while-queued (graceful drain); a terminal state like the
# three above but kept OUT of FINISH_REASONS, which keys the per-reason
# completion counters for requests that ran
FINISH_CANCELLED = "cancelled"
FINISH_REASONS = (FINISH_STOP, FINISH_LENGTH, FINISH_DEADLINE)


def _normalize_stop(stop) -> Tuple[Tuple[int, ...], ...]:
    """Accept one token-id sequence or a collection of them; store a
    tuple of int tuples (hashable — SamplingParams stays frozen)."""
    if stop is None:
        return ()
    stop = tuple(stop)
    if not stop:
        return ()
    if isinstance(stop[0], (int, np.integer)):
        stop = (stop,)
    out = tuple(tuple(int(t) for t in seq) for seq in stop)
    for seq in out:
        if not seq:
            raise ValueError("empty stop sequence")
    return out


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How to decode one request.  Frozen + validated at construction.

    temperature: 0 => greedy (argmax — the degenerate case of the same
        pipeline, not a separate code path); > 0 scales logits before
        truncation and sampling.
    top_k: keep only the k highest logits (0 disables).
    top_p: nucleus — keep the smallest descending-probability prefix
        with mass >= top_p (1.0 disables).  Applied after top_k.
    min_p: drop tokens whose probability is below ``min_p * max_prob``
        (0 disables).  Applied after top_p.
    repetition_penalty: HF-style — logits of tokens already present in
        the prompt or output are divided (if positive) / multiplied (if
        negative) by this (1.0 disables).
    presence_penalty: subtracted from logits of tokens already
        *generated* (0 disables).
    stop: token-id sequences; generation finishes (reason "stop") when
        the output ends with any of them.  The matched tokens stay in
        the output (like EOS).
    max_tokens: generation budget (reason "length" when reached).
    seed: PRNG stream seed; None draws one from the engine's seeded
        stream at submit.  Sampling is keyed on (seed, token index), so
        a given seed reproduces its token sequence bitwise across
        preemption, prefix caching, and chunked prefill.
    logprobs: if not None, report the top-``logprobs`` (id, logprob)
        pairs per generated token alongside the chosen token's logprob
        (0 = chosen token only).  Logprobs come from the penalized,
        UN-temperature-scaled distribution — a temperature-independent
        eval surface.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    min_p: float = 0.0
    repetition_penalty: float = 1.0
    presence_penalty: float = 0.0
    stop: Tuple[Tuple[int, ...], ...] = ()
    max_tokens: int = 32
    seed: Optional[int] = None
    logprobs: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "stop", _normalize_stop(self.stop))
        if self.temperature < 0:
            raise ValueError(f"temperature < 0: {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k < 0: {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p not in (0, 1]: {self.top_p}")
        if not 0.0 <= self.min_p <= 1.0:
            raise ValueError(f"min_p not in [0, 1]: {self.min_p}")
        if self.repetition_penalty <= 0:
            raise ValueError(
                f"repetition_penalty <= 0: {self.repetition_penalty}")
        if self.max_tokens < 1:
            raise ValueError(f"max_tokens < 1: {self.max_tokens}")
        if self.logprobs is not None and self.logprobs < 0:
            raise ValueError(f"logprobs < 0: {self.logprobs}")
        if self.seed is not None and not isinstance(
                self.seed, (int, np.integer)):
            raise ValueError(f"seed must be int or None: {self.seed!r}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


@dataclasses.dataclass
class RequestOutput:
    """One streamed delta of a generation (see module docstring)."""
    uid: int
    new_token_ids: List[int]
    new_logprobs: List[float]
    new_topk: Optional[List[List[Tuple[int, float]]]]  # when logprobs asked
    cumulative_logprob: float
    num_generated: int            # total tokens generated so far
    finish_reason: Optional[str]  # set on the final delta only
    done: bool


class RequestHandle:
    """Streaming view of one submitted request.

    Truthy iff accepted.  Iteration yields `RequestOutput` deltas; when
    none are buffered it drives ``engine.step()`` until new tokens land
    or the request reaches a terminal state.  ``drain()`` is the
    non-blocking variant (returns possibly-empty list) for callers
    multiplexing many handles over their own engine loop.

    Deltas are derived lazily from the request's recorded state (a
    cursor over ``req.tokens``), so preemption is invisible here:
    already-streamed tokens are never re-generated (recompute restores
    the KV, not the tokens), and the stream simply continues.
    """

    _MAX_DRIVE_TICKS = 1_000_000

    def __init__(self, engine, req, accepted: bool):
        self.engine = engine
        self.req = req
        self.accepted = accepted
        self._sent = 0
        self._final = not accepted    # rejected: nothing will ever stream

    def __bool__(self) -> bool:
        return self.accepted

    def __repr__(self):
        return (f"RequestHandle(uid={self.req.uid}, "
                f"accepted={self.accepted}, status={self.req.status!r})")

    # ------------------------------------------------------------------
    def _terminal(self) -> bool:
        return self.req.done or self.req.status in (
            "expired", "rejected", "cancelled")

    def _delta(self) -> Optional[RequestOutput]:
        req = self.req
        n = len(req.tokens or ())
        terminal = self._terminal()
        if n == self._sent and not (terminal and not self._final):
            return None
        lo = self._sent
        self._sent = n
        done = terminal
        if terminal:
            self._final = True
        sp = req.sampling
        topk = None
        if sp is not None and sp.logprobs is not None \
                and req.topk_logprobs is not None:
            topk = [list(t) for t in req.topk_logprobs[lo:n]]
        return RequestOutput(
            uid=req.uid,
            new_token_ids=list(req.tokens[lo:n]),
            new_logprobs=list((req.token_logprobs or [])[lo:n]),
            new_topk=topk,
            cumulative_logprob=req.cumulative_logprob,
            num_generated=n,
            finish_reason=req.finish_reason if terminal else None,
            done=done)

    def drain(self) -> List[RequestOutput]:
        """Currently-available deltas (possibly empty); never steps the
        engine."""
        d = self._delta()
        return [d] if d is not None else []

    def __iter__(self):
        return self

    def __next__(self) -> RequestOutput:
        d = self._delta()
        if d is not None:
            return d
        if self._final or self._terminal():
            raise StopIteration
        for _ in range(self._MAX_DRIVE_TICKS):
            self.engine.step()
            d = self._delta()
            if d is not None:
                return d
            if self._terminal():
                raise StopIteration
        raise RuntimeError(           # pragma: no cover - engine wedge
            f"request {self.req.uid} made no progress in "
            f"{self._MAX_DRIVE_TICKS} ticks")
