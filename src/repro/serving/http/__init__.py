"""Async HTTP serving front-end (stdlib-asyncio, no third-party deps).

- `frontend` — the server: OpenAI-style ``/v1/completions`` (JSON and
  streaming SSE), ``/v1/models``, ``/healthz``, ``/metrics``, bridging
  async connections onto the tick-driven `Engine` / `MultiModelEngine`
  via `RequestHandle` and one background tick-driver task.
- `client`   — minimal asyncio HTTP/SSE client helpers used by the
  tests and the traffic bench (the container has no requests/aiohttp
  guarantee, so both ends are stdlib-only).
"""
from repro.serving.http.frontend import HTTPFrontend, serve

__all__ = ["HTTPFrontend", "serve"]
