"""Asyncio HTTP front-end over the tick-driven serving engine.

Endpoints (HTTP/1.1, ``Connection: close`` per request — the harness
and tests open one connection per request, which keeps the hand-rolled
parser honest and the drain logic trivial):

- ``POST /v1/completions`` — OpenAI-style completion over **token
  ids** (the repo has no tokenizer; ``prompt`` is a list of ints).
  Body: ``{"model": str, "prompt": [int], "max_tokens": int,
  "temperature"/"top_k"/"top_p"/"min_p"/"seed"/"stop"/"logprobs"/
  "priority": optional, "stream": bool}``.
  Non-streaming returns one JSON body; ``"stream": true`` returns
  Server-Sent Events: ``data: {json-delta}\\n\\n`` per engine delta,
  the last delta carrying ``finish_reason``, then ``data: [DONE]``.
- ``GET /v1/models`` — the hosted catalog.
- ``GET /healthz`` — liveness + drain state.
- ``GET /metrics`` — the shared registry rendered as text.

Status mapping (the scheduler's decisions become transport codes):

- 400 — malformed JSON/params, or a request that could NEVER fit the
  page pool (``fits_ever``),
- 404 — unknown model tag,
- 429 — scheduler backpressure (bounded queue refused; Retry-After: 1),
- 503 — server draining (new work refused; queued work cancelled),
- 504 — queue-deadline expiry before first admission.  On the stream
  path the status line is DELAYED until the first delta, so a request
  that dies in queue still gets a real 504 instead of a 200 + error
  frame.

Concurrency model — single event loop, engine single-threaded:

- Connection handlers NEVER touch the engine.  Submissions go through
  a queue the **driver task** drains between ticks; handlers get back
  a `RequestHandle` future.
- The driver is the only engine caller: it submits queued work, then
  runs ``engine.step()`` in the default executor (one tick at a time —
  the loop stays responsive while jitted dispatches run), then swaps
  the tick event to wake every waiting handler.
- Handlers wait on a snapshot of the tick event BEFORE draining the
  handle (snapshot-then-drain: a tick landing between the two just
  means one spurious wakeup, never a missed delta).

Graceful drain (`begin_drain`, wired to SIGINT/SIGTERM by
``launch/serve_http.py``): new requests get 503, still-queued requests
are cancelled (clients receive a terminal ``"cancelled"`` delta /
503), in-flight rows run to completion, then the driver exits and
``wait_drained`` resolves.
"""
from __future__ import annotations

import asyncio
import json
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.serving.api import (FINISH_CANCELLED, FINISH_DEADLINE,
                               RequestHandle, SamplingParams)
from repro.serving.engine import Engine, Request
from repro.serving.multi_model import MultiModelEngine

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            405: "Method Not Allowed", 429: "Too Many Requests",
            500: "Internal Server Error", 503: "Service Unavailable",
            504: "Gateway Timeout"}

_MAX_BODY = 8 << 20


class _BadRequest(Exception):
    pass


def _json_bytes(obj) -> bytes:
    return json.dumps(obj, separators=(",", ":")).encode()


def _error_body(status: int, message: str) -> bytes:
    return _json_bytes({"error": {"code": status, "message": message}})


class HTTPFrontend:
    """One server over one `Engine` or `MultiModelEngine`."""

    def __init__(self, engine, *, host: str = "127.0.0.1", port: int = 0,
                 default_model: str = "default"):
        self.engine = engine
        self.host = host
        self.port = port           # 0 = ephemeral; real port after start()
        self._multi = isinstance(engine, MultiModelEngine)
        self._default_model = default_model
        self.metrics: MetricsRegistry = engine.metrics
        self._c_requests = self.metrics.counter("http.requests")
        self._c_streams = self.metrics.counter("http.streams")
        self._g_conns = self.metrics.gauge("http.connections")
        self._h_req = self.metrics.histogram("http.request_s")
        self._nconns = 0
        self._uid = 0
        self._server: Optional[asyncio.AbstractServer] = None
        self._driver: Optional[asyncio.Task] = None
        self._submit_q: deque = deque()
        self._wake = asyncio.Event()
        self._tick = asyncio.Event()
        self._draining = False
        self._drained = asyncio.Event()

    # ------------------------------------------------------------------
    # engine adaptation (single vs multi)
    def model_names(self) -> List[str]:
        if self._multi:
            return self.engine.models()
        return [self._default_model]

    def _tenant_engine(self, tag: str) -> Engine:
        return self.engine[tag] if self._multi else self.engine

    def _fits_ever(self, tag: str, total_tokens: int) -> bool:
        eng = self._tenant_engine(tag)
        if not getattr(eng, "paged", False):
            return True
        return eng.kv.fits_ever(total_tokens)

    def _do_submit(self, req: Request, tag: str) -> RequestHandle:
        if self._multi:
            return self.engine.submit(req, model=tag)
        return self.engine.submit(req)

    # ------------------------------------------------------------------
    async def start(self) -> None:
        """Bind the socket and start the driver; ``self.port`` is the
        real port afterwards (pass port=0 for an ephemeral one)."""
        if self._multi:
            self.engine._ensure_built()   # catalog + pool before serving
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._driver = asyncio.get_running_loop().create_task(
            self._drive())

    def begin_drain(self) -> None:
        """Stop admitting; cancel queued; let in-flight rows finish."""
        self._draining = True
        self._wake.set()

    async def wait_drained(self) -> None:
        await self._drained.wait()

    async def aclose(self) -> None:
        """Graceful shutdown: drain, stop the driver, close the
        socket."""
        self.begin_drain()
        await self.wait_drained()
        if self._driver is not None:
            await self._driver
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        self.engine.shutdown()

    # ------------------------------------------------------------------
    # driver: the ONLY task that touches the engine
    def _notify_tick(self) -> None:
        ev, self._tick = self._tick, asyncio.Event()
        ev.set()

    async def _drive(self) -> None:
        loop = asyncio.get_running_loop()
        cancelled_sent = False
        while True:
            self._wake.clear()
            while self._submit_q:
                req, tag, fut = self._submit_q.popleft()
                if fut.cancelled():
                    continue
                try:
                    fut.set_result(self._do_submit(req, tag))
                except Exception as e:           # surface as HTTP 500
                    fut.set_exception(e)
            if self._draining and not cancelled_sent:
                cancelled_sent = True
                self.engine.cancel_queued()
                self._notify_tick()              # cancelled -> terminal
            if self.engine.pending():
                # one tick off-loop: jitted dispatches may block for
                # milliseconds; the loop keeps accepting connections
                await loop.run_in_executor(None, self.engine.step)
                self._notify_tick()
            elif self._draining and not self._submit_q:
                self._notify_tick()
                break
            elif not self._submit_q:
                await self._wake.wait()
        self._drained.set()

    async def _submit_async(self, req: Request, tag: str) -> RequestHandle:
        fut = asyncio.get_running_loop().create_future()
        self._submit_q.append((req, tag, fut))
        self._wake.set()
        return await fut

    # ------------------------------------------------------------------
    # HTTP plumbing
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        self._nconns += 1
        self._g_conns.set(self._nconns)
        t0 = asyncio.get_running_loop().time()
        try:
            parsed = await self._read_request(reader)
            if parsed is not None:
                method, path, headers, body = parsed
                self._c_requests.inc()
                await self._route(method, path, body, writer)
        except (asyncio.IncompleteReadError, ConnectionError):
            pass
        finally:
            self._h_req.observe(asyncio.get_running_loop().time() - t0)
            self._nconns -= 1
            self._g_conns.set(self._nconns)
            try:
                if writer.can_write_eof():
                    writer.write_eof()
            except (OSError, RuntimeError):
                pass
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    @staticmethod
    async def _read_request(reader) -> Optional[Tuple[str, str, Dict, bytes]]:
        line = await reader.readline()
        if not line:
            return None
        parts = line.decode("latin1").split()
        if len(parts) < 2:
            return None
        method, path = parts[0].upper(), parts[1]
        headers: Dict[str, str] = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin1").partition(":")
            headers[k.strip().lower()] = v.strip()
        n = int(headers.get("content-length", "0") or 0)
        if n > _MAX_BODY:
            raise _BadRequest(f"body too large: {n}")
        body = await reader.readexactly(n) if n else b""
        return method, path, headers, body

    def _respond(self, writer, status: int, body: bytes,
                 ctype: str = "application/json",
                 extra: Tuple[str, ...] = ()) -> None:
        code_counter = self.metrics.counter(f"http.responses.{status}")
        code_counter.inc()
        head = [f"HTTP/1.1 {status} {_REASONS.get(status, 'Unknown')}",
                f"Content-Type: {ctype}",
                f"Content-Length: {len(body)}",
                "Connection: close", *extra, "", ""]
        writer.write("\r\n".join(head).encode() + body)

    async def _route(self, method: str, path: str, body: bytes,
                     writer) -> None:
        path = path.split("?", 1)[0]
        if path == "/v1/completions":
            if method != "POST":
                self._respond(writer, 405, _error_body(405, "POST only"))
                return
            await self._completions(body, writer)
        elif path == "/v1/models":
            data = [{"id": n, "object": "model",
                     "owned_by": "repro"} for n in self.model_names()]
            self._respond(writer, 200,
                          _json_bytes({"object": "list", "data": data}))
        elif path == "/healthz":
            status = "draining" if self._draining else "ok"
            self._respond(writer, 200 if not self._draining else 503,
                          _json_bytes({"status": status}))
        elif path == "/metrics":
            self._respond(writer, 200, self.metrics.render().encode(),
                          ctype="text/plain; charset=utf-8")
        else:
            self._respond(writer, 404, _error_body(404,
                                                   f"no route {path}"))
        await writer.drain()

    # ------------------------------------------------------------------
    def _parse_completion(self, body: bytes) -> Tuple[Request, str, bool]:
        try:
            p = json.loads(body.decode())
        except (ValueError, UnicodeDecodeError) as e:
            raise _BadRequest(f"bad JSON: {e}")
        if not isinstance(p, dict):
            raise _BadRequest("body must be a JSON object")
        tag = p.get("model", self.model_names()[0])
        prompt = p.get("prompt")
        if not isinstance(prompt, list) or not prompt \
                or not all(isinstance(t, int) for t in prompt):
            raise _BadRequest("prompt must be a non-empty list of "
                              "token ids (ints)")
        try:
            sp = SamplingParams(
                temperature=float(p.get("temperature", 0.0)),
                top_k=int(p.get("top_k", 0)),
                top_p=float(p.get("top_p", 1.0)),
                min_p=float(p.get("min_p", 0.0)),
                stop=tuple(tuple(s) for s in p.get("stop", ()) or ()),
                max_tokens=int(p.get("max_tokens", 32)),
                seed=p.get("seed"),
                logprobs=p.get("logprobs"))
        except (TypeError, ValueError) as e:
            raise _BadRequest(f"bad sampling params: {e}")
        self._uid += 1
        req = Request(uid=self._uid,
                      prompt=np.asarray(prompt, np.int32),
                      priority=int(p.get("priority", 0)), sampling=sp)
        return req, str(tag), bool(p.get("stream", False))

    async def _completions(self, body: bytes, writer) -> None:
        try:
            req, tag, stream = self._parse_completion(body)
        except _BadRequest as e:
            self._respond(writer, 400, _error_body(400, str(e)))
            return
        if self._draining:
            self._respond(writer, 503, _error_body(
                503, "server is draining"))
            return
        if tag not in self.model_names():
            self._respond(writer, 404, _error_body(
                404, f"unknown model {tag!r}"))
            return
        total = len(req.prompt) + req.sampling.max_tokens
        if not self._fits_ever(tag, total):
            self._respond(writer, 400, _error_body(
                400, f"prompt + max_tokens = {total} tokens can never "
                     "fit the page pool"))
            return
        try:
            h = await self._submit_async(req, tag)
        except Exception as e:
            self._respond(writer, 500, _error_body(500, repr(e)))
            return
        if not h:
            self._respond(writer, 429, _error_body(
                429, "queue full, retry later"), extra=("Retry-After: 1",))
            return
        if stream:
            self._c_streams.inc()
            await self._stream_response(h, tag, writer)
        else:
            await self._json_response(h, tag, writer)

    async def _wait_terminal(self, h: RequestHandle) -> None:
        while not h._terminal():
            ev = self._tick          # snapshot BEFORE re-checking
            if h._terminal():
                break
            await ev.wait()

    @staticmethod
    def _failure_status(reason: Optional[str]) -> Optional[int]:
        if reason == FINISH_DEADLINE:
            return 504
        if reason == FINISH_CANCELLED:
            return 503
        return None

    async def _json_response(self, h: RequestHandle, tag: str,
                             writer) -> None:
        await self._wait_terminal(h)
        req = h.req
        fail = self._failure_status(req.finish_reason) \
            if not req.done else None
        if fail is not None:
            self._respond(writer, fail, _error_body(
                fail, f"request {req.finish_reason} before completion"))
            return
        body = _json_bytes({
            "id": f"cmpl-{req.uid}",
            "object": "text_completion",
            "model": tag,
            "choices": [{
                "index": 0,
                "token_ids": list(req.tokens),
                "logprobs": (list(req.token_logprobs)
                             if req.sampling.logprobs is not None
                             else None),
                "finish_reason": req.finish_reason,
            }],
            "usage": {"prompt_tokens": int(len(req.prompt)),
                      "completion_tokens": len(req.tokens),
                      "total_tokens":
                          int(len(req.prompt)) + len(req.tokens)},
        })
        self._respond(writer, 200, body)

    async def _stream_response(self, h: RequestHandle, tag: str,
                               writer) -> None:
        req = h.req
        started = False

        def frame(delta) -> bytes:
            return b"data: " + _json_bytes({
                "id": f"cmpl-{req.uid}",
                "object": "text_completion.chunk",
                "model": tag,
                "choices": [{
                    "index": 0,
                    "token_ids": list(delta.new_token_ids),
                    "finish_reason": delta.finish_reason,
                }],
            }) + b"\n\n"

        while True:
            ev = self._tick          # snapshot BEFORE draining
            deltas = h.drain()
            if deltas:
                if not started:
                    # first delta decides the status line: a request
                    # that died in queue gets a real error status
                    first = deltas[0]
                    if first.done and not first.new_token_ids:
                        fail = self._failure_status(first.finish_reason)
                        if fail is not None:
                            self._respond(writer, fail, _error_body(
                                fail, f"request {first.finish_reason} "
                                      "before first token"))
                            return
                    started = True
                    self.metrics.counter("http.responses.200").inc()
                    writer.write(
                        b"HTTP/1.1 200 OK\r\n"
                        b"Content-Type: text/event-stream\r\n"
                        b"Cache-Control: no-cache\r\n"
                        b"Connection: close\r\n\r\n")
                for d in deltas:
                    writer.write(frame(d))
                await writer.drain()
                if deltas[-1].done:
                    writer.write(b"data: [DONE]\n\n")
                    await writer.drain()
                    return
            elif h._terminal() and h._final:
                return               # everything already streamed
            else:
                await ev.wait()


def serve(engine, *, host: str = "127.0.0.1", port: int = 0,
          default_model: str = "default") -> HTTPFrontend:
    """Construct (but do not start) a frontend — call
    ``await fe.start()`` inside a running loop."""
    return HTTPFrontend(engine, host=host, port=port,
                        default_model=default_model)
