"""Minimal stdlib-asyncio HTTP/SSE client for the serving front-end.

Used by tests and the traffic bench — the container guarantees no
third-party HTTP client, so this speaks just enough HTTP/1.1 for our
own server (``Connection: close``, one request per connection).

``stream_completion`` additionally timestamps every SSE frame with the
loop's monotonic clock, which is how the arrival-process harness
measures client-side TTFT without touching server internals.
"""
from __future__ import annotations

import asyncio
import json
from typing import Any, AsyncIterator, Dict, List, Optional, Tuple


async def request(host: str, port: int, method: str, path: str,
                  payload: Optional[dict] = None,
                  timeout: float = 120.0) -> Tuple[int, Any]:
    """One JSON request; returns (status, parsed-body-or-text)."""
    body = json.dumps(payload).encode() if payload is not None else b""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(
            f"{method} {path} HTTP/1.1\r\nHost: {host}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n".encode() + body)
        await writer.drain()
        status, headers = await _read_head(reader, timeout)
        raw = await asyncio.wait_for(reader.read(), timeout)
        ctype = headers.get("content-type", "")
        out = json.loads(raw.decode()) if raw and "json" in ctype \
            else raw.decode(errors="replace")
        return status, out
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass


async def _read_head(reader, timeout: float) -> Tuple[int, Dict[str, str]]:
    line = await asyncio.wait_for(reader.readline(), timeout)
    parts = line.decode("latin1").split()
    if len(parts) < 2 or not parts[1].isdigit():
        raise ConnectionError(f"bad status line: {line!r}")
    status = int(parts[1])
    headers: Dict[str, str] = {}
    while True:
        h = await asyncio.wait_for(reader.readline(), timeout)
        if h in (b"\r\n", b"\n", b""):
            break
        k, _, v = h.decode("latin1").partition(":")
        headers[k.strip().lower()] = v.strip()
    return status, headers


async def stream_completion(host: str, port: int, payload: dict,
                            timeout: float = 120.0
                            ) -> AsyncIterator[Tuple[float, dict]]:
    """POST a ``"stream": true`` completion; yield
    ``(monotonic_time, delta_dict)`` per SSE frame (the terminal
    ``[DONE]`` sentinel is consumed, not yielded).  A non-200 status
    raises ``HTTPStreamError`` carrying the code and error body."""
    body = json.dumps(dict(payload, stream=True)).encode()
    reader, writer = await asyncio.open_connection(host, port)
    loop = asyncio.get_running_loop()
    try:
        writer.write(
            f"POST /v1/completions HTTP/1.1\r\nHost: {host}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n".encode() + body)
        await writer.drain()
        status, _ = await _read_head(reader, timeout)
        if status != 200:
            raw = await asyncio.wait_for(reader.read(), timeout)
            raise HTTPStreamError(status, raw.decode(errors="replace"))
        while True:
            line = await asyncio.wait_for(reader.readline(), timeout)
            if not line:
                return
            line = line.strip()
            if not line or not line.startswith(b"data: "):
                continue
            data = line[len(b"data: "):]
            if data == b"[DONE]":
                return
            yield loop.time(), json.loads(data.decode())
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass


class HTTPStreamError(Exception):
    def __init__(self, status: int, body: str):
        super().__init__(f"HTTP {status}: {body}")
        self.status = status
        self.body = body


async def collect_stream(host: str, port: int, payload: dict,
                         timeout: float = 120.0) -> Dict[str, Any]:
    """Convenience: run a streamed completion to the end, returning
    ``{"tokens": [...], "finish_reason": str, "ttft_s": float,
    "e2e_s": float}`` (client-side timings)."""
    loop = asyncio.get_running_loop()
    t0 = loop.time()
    tokens: List[int] = []
    ttft: Optional[float] = None
    finish: Optional[str] = None
    async for t, delta in stream_completion(host, port, payload, timeout):
        ch = delta["choices"][0]
        if ch["token_ids"] and ttft is None:
            ttft = t - t0
        tokens.extend(ch["token_ids"])
        if ch["finish_reason"] is not None:
            finish = ch["finish_reason"]
    return {"tokens": tokens, "finish_reason": finish,
            "ttft_s": ttft, "e2e_s": loop.time() - t0}
