"""Admission scheduler for the continuous-batching engine.

Policy surface (the ``--scheduler`` knob):

- ``fifo``     — one class, strict arrival order.
- ``priority`` — FIFO *within* each priority class; classes served in
  ascending ``Request.priority`` (0 = most urgent).  Head-of-line rule:
  only the head of each class is eligible, so service order within a
  class always equals arrival order (the property tests pin this).

Backpressure: the queue is bounded (``max_queue``); ``submit`` refuses
beyond it — callers see the rejection immediately instead of a silently
growing tail.  An optional queue deadline expires requests that waited
longer than ``deadline_s`` before admission (they fail fast rather than
serve a dead client).

Preempted requests re-enter at the *head* of their class: they were
admitted before anything still queued there, so head placement restores
arrival order.

The scheduler also keeps the prefill/decode interleave accounting: the
engine reports every tick (``account``) how many prefill chunk steps ran
and how many rows decoded, and ``snapshot`` exposes the tick split
(prefill-only / decode-only / interleaved) plus queue-event counters —
the observability surface for tuning ``max_prefills_per_tick`` and
``prefill_chunk`` against head-of-line blocking.

All counters publish into a metrics registry (`repro.obs.metrics`)
under ``sched.*`` — ``self.counters`` is a live dict-view over it, so
pre-registry call sites and tests keep their short names.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

from repro.obs.metrics import MetricsRegistry
from repro.serving.api import FINISH_DEADLINE

POLICIES = ("fifo", "priority")

_COUNTERS = ("submitted", "queue_rejected", "requeued", "queue_expired",
             "admitted", "unpopped", "prefill_chunks", "decoded_tokens",
             "prefill_ticks", "decode_ticks", "interleaved_ticks")


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    policy: str = "fifo"
    max_queue: int = 256            # bounded queue: submit rejects beyond
    max_prefills_per_tick: int = 1  # prefill/decode interleaving ratio
    deadline_s: Optional[float] = None  # max queue wait before expiry

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"policy {self.policy!r} not in {POLICIES}")
        if self.max_queue < 1 or self.max_prefills_per_tick < 1:
            raise ValueError((self.max_queue, self.max_prefills_per_tick))


class Scheduler:
    def __init__(self, cfg: SchedulerConfig = SchedulerConfig(),
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        # class key is (priority, model): FIFO within a (class, tenant)
        # lane.  Single-model engines tag every request "" so behavior
        # is unchanged; the multi-model engine's per-tenant lanes mean a
        # hot tenant's backlog can never head-of-line-block another
        # tenant's admission (pop_admissible scans every lane head).
        self._classes: Dict[Tuple[int, str], deque] = {}
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.counters = self.metrics.group("sched", keys=_COUNTERS)
        self._depth = self.metrics.gauge("sched.queue_depth")

    def __len__(self) -> int:
        return sum(len(q) for q in self._classes.values())

    def _class(self, req) -> Tuple[int, str]:
        prio = req.priority if self.cfg.policy == "priority" else 0
        return (prio, getattr(req, "model", None) or "")

    def _tenant(self, req, event: str, n: int = 1) -> None:
        """Per-tenant admission accounting (`sched.tenant.<model>.*`) —
        only for tagged requests, so single-model metrics stay flat."""
        model = getattr(req, "model", None)
        if model:
            self.metrics.counter(f"sched.tenant.{model}.{event}").inc(n)

    # ------------------------------------------------------------------
    def submit(self, req, now: float) -> bool:
        """Enqueue; False = rejected by backpressure (queue full).

        ``now`` is a MONOTONIC-clock reading: it feeds the deadline
        check in ``expire`` and the queue-wait histogram, so a wall
        clock (NTP-steppable) here would corrupt both."""
        if len(self) >= self.cfg.max_queue:
            self.counters["queue_rejected"] += 1
            self._tenant(req, "rejected")
            return False
        req.submit_mono = now
        self._classes.setdefault(self._class(req), deque()).append(req)
        self.counters["submitted"] += 1
        self._tenant(req, "submitted")
        return True

    def requeue(self, req) -> None:
        """Return a preempted request to the head of its class."""
        self.counters["requeued"] += 1
        self._classes.setdefault(self._class(req), deque()).appendleft(req)

    def unpop(self, req) -> None:
        """Put back a popped head that could not actually be admitted
        (the engine's admission gate is optimistic under prefix
        sharing): restores arrival order without recording a
        preemption-style requeue.  Counts ``unpopped`` rather than
        decrementing ``admitted`` — counters stay monotone so
        ``diff_snapshots`` over a window containing an unpop can never
        report negative admissions; ``snapshot`` derives the net."""
        self.counters["unpopped"] += 1
        self._classes.setdefault(self._class(req), deque()).appendleft(req)

    def expire(self, now: float, model: Optional[str] = None) -> List:
        """Remove and return queued requests past the queue deadline.

        The deadline bounds the wait *before first admission* only: a
        preempted request re-enters with its original submit_mono, but
        it already served tokens — expiring it would silently discard
        them, so anything ever admitted is exempt.  Expired requests
        get ``finish_reason = "deadline"`` (the streaming API's
        terminal marker) here, where the expiry decision is made.
        Deadlines compare monotonic marks — a wall-clock step can
        neither spuriously expire nor immortalize a queued request.

        ``model`` filters to one tenant's lanes (None = all) — on a
        shared scheduler each sub-engine expires only its own queue."""
        if self.cfg.deadline_s is None:
            return []
        dead = []
        for key, q in self._classes.items():
            if model is not None and key[1] != model:
                continue
            kept = deque()
            for r in q:
                if getattr(r, "first_admit_mono", None) is None \
                        and now - r.submit_mono > self.cfg.deadline_s:
                    if hasattr(r, "finish_reason"):
                        r.finish_reason = FINISH_DEADLINE
                    dead.append(r)
                else:
                    kept.append(r)
            q.clear()
            q.extend(kept)
        self.counters["queue_expired"] += len(dead)
        for r in dead:
            self._tenant(r, "expired")
        return dead

    def pop_admissible(self, can_admit: Callable,
                       model: Optional[str] = None) -> Optional[object]:
        """Next request to prefill: the head of the most urgent
        non-empty class whose head fits.  Heads only — skipping past a
        blocked head would break FIFO-within-class.  ``model`` restricts
        the scan to one tenant's lanes (a sub-engine admits only its
        own traffic); ties between tenants at equal priority go to the
        lexicographically smaller tag — deterministic, and per-lane
        arrival order is what fairness tests pin, not cross-lane order.
        """
        for key in sorted(self._classes):
            if model is not None and key[1] != model:
                continue
            q = self._classes[key]
            if q and can_admit(q[0]):
                self.counters["admitted"] += 1
                req = q.popleft()
                self._tenant(req, "admitted")
                return req
        return None

    def drain(self, model: Optional[str] = None) -> List:
        """Remove and return every queued (never-admitted this pass)
        request — the graceful-shutdown path: the caller marks them
        cancelled and emits terminal deltas instead of leaving clients
        hanging.  ``model`` drains one tenant's lanes only."""
        out: List = []
        for key, q in self._classes.items():
            if model is not None and key[1] != model:
                continue
            out.extend(q)
            q.clear()
        return out

    def depth_by_class(self) -> Dict[int, int]:
        """Queue depth per priority class (tenant lanes aggregated —
        the pre-multi-model reader surface)."""
        out: Dict[int, int] = {}
        for (prio, _), q in self._classes.items():
            if q:
                out[prio] = out.get(prio, 0) + len(q)
        return out

    def depth_by_model(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for (_, model), q in self._classes.items():
            if q:
                out[model] = out.get(model, 0) + len(q)
        return out

    # ------------------------------------------------------------------
    def account(self, prefill_chunks: int, decoded_rows: int) -> None:
        """Record one engine tick's prefill/decode interleave: how many
        prefill chunk steps ran and how many rows decoded."""
        self.counters["prefill_chunks"] += prefill_chunks
        self.counters["decoded_tokens"] += decoded_rows
        if prefill_chunks and decoded_rows:
            self.counters["interleaved_ticks"] += 1
        elif prefill_chunks:
            self.counters["prefill_ticks"] += 1
        elif decoded_rows:
            self.counters["decode_ticks"] += 1
        self._depth.set(len(self))

    def snapshot(self) -> Dict[str, int]:
        """Counters + current depth, for Engine.stats()."""
        self._depth.set(len(self))
        out = dict(self.counters)
        out["queue_depth"] = len(self)
        # derived, not a counter: admissions that actually stuck
        out["admitted_net"] = out["admitted"] - out["unpopped"]
        return out
