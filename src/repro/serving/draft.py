"""Draft-model derivation for self-speculative decoding.

HashedNets' hash functions are stateless and seeded per *slot*
(``models.transformer._slot_seed`` keys on the slot name only), so the
same served weights can be re-addressed at any compression ratio: a
`CompressionPolicy` rung below the served one is a *free draft model*
sharing the base artifact's seeds, layout, and tokenizer.

The draft bank is derived from the served weights by least-squares
projection onto the draft's weight-sharing pattern: with the draft's
virtual matrix ``V_d[i,j] = xi_d(i,j) * w_d[h_d(i,j)]``, minimizing
``||V_d - V||^2`` over ``w_d`` gives

    w_d[b] = mean_{(i,j): h_d(i,j)=b}  xi_d(i,j) * V[i,j]

i.e. a signed segment-mean of the served virtual matrix over the
draft's buckets.  When a slot's draft spec EQUALS its base spec the
bank is aliased by reference (zero copy, exact) — the degenerate top
rung of the ladder.  Dense slots (norms, biases, routers, untouched
projections) always alias.

Nothing here depends on the engine; `serving.spec_decode` consumes the
(model, params) pair this module builds.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashed as H
from repro.policy import rules as POL

DraftSpec = Union[str, float, POL.CompressionPolicy]


def resolve_draft_policy(spec: DraftSpec, base_cfg) -> POL.CompressionPolicy:
    """Lower a CLI-ish draft spec into a CompressionPolicy.

    Accepts a ready policy, a ratio (``0.0625`` / ``"1/16"``), or a path
    to a policy JSON.  Ratio forms inherit the base config's effective
    mode/panel/path defaults so the draft's bucket geometry lines up
    with the served banks (same panels => same per-panel hash streams).
    """
    if isinstance(spec, POL.CompressionPolicy):
        spec.validate()
        return spec
    if isinstance(spec, str) and (spec.endswith(".json")
                                  or os.path.isfile(spec)):
        return POL.load(spec)
    ratio = POL.parse_ratio(spec) if isinstance(spec, str) else float(spec)
    if not (0.0 < ratio <= 1.0):
        raise ValueError(f"draft compression must be in (0, 1], got {ratio}")
    if base_cfg.hashed:
        base_pol = POL.effective(base_cfg)
        return dataclasses.replace(base_pol, rules=(), budget=None,
                                   compression=ratio)
    return POL.CompressionPolicy(rules=(), compression=ratio)


def _project_bank(v: jnp.ndarray, spec: H.HashedSpec) -> jnp.ndarray:
    """Least-squares bank for one virtual matrix v (rows, cols), f32."""
    if spec.mode == "element":
        i = jnp.arange(spec.rows, dtype=jnp.int32)[:, None]
        j = jnp.arange(spec.cols, dtype=jnp.int32)[None, :]
        idx, sgn = H.element_indices(spec, i, j)
        flat_idx = idx.reshape(-1)
        num = jax.ops.segment_sum((v * sgn.astype(v.dtype)).reshape(-1),
                                  flat_idx, num_segments=spec.num_buckets)
        cnt = jax.ops.segment_sum(jnp.ones_like(flat_idx, v.dtype),
                                  flat_idx, num_segments=spec.num_buckets)
        return num / jnp.maximum(cnt, 1.0)
    idx, sgn = H.block_indices(spec)                       # (gi, gj)
    gi, gj = spec.tile_grid
    bm, bn = spec.block_shape
    vp = jnp.pad(v, ((0, gi * bm - spec.rows), (0, gj * bn - spec.cols)))
    tiles = vp.reshape(gi, bm, gj, bn).transpose(0, 2, 1, 3)
    tiles = tiles.reshape(gi * gj, bm, bn) \
        * sgn.reshape(-1, 1, 1).astype(v.dtype)
    flat_idx = idx.reshape(-1)
    num = jax.ops.segment_sum(tiles, flat_idx,
                              num_segments=spec.bank_tiles)
    cnt = jax.ops.segment_sum(jnp.ones_like(flat_idx, v.dtype), flat_idx,
                              num_segments=spec.bank_tiles)
    return num / jnp.maximum(cnt, 1.0)[:, None, None]


def _transform_leaf(base_leaf, base_spec, draft_spec, vshape, out_sd):
    """base leaf -> draft leaf for one slot (handles layer stacking).

    The per-layer result is either the draft bank or the dense virtual
    matrix; the trailing reshape restores the model's exact leaf layout
    (e.g. MoE expert-major splits of the flattened virtual rows).
    """
    def one(w):
        v = (H.materialize(w, base_spec, dtype=jnp.float32)
             if base_spec is not None
             else w.reshape(vshape).astype(jnp.float32))
        if draft_spec is not None:
            return _project_bank(v, draft_spec)
        return v
    per_layer_ndim = (len(base_spec.real_param_shape())
                      if base_spec is not None else len(vshape))
    if base_leaf.ndim == per_layer_ndim + 1:      # stacked over layers
        out = jax.lax.map(one, base_leaf)         # sequential: bounds memory
    else:
        out = one(base_leaf)
    return out.reshape(out_sd.shape).astype(out_sd.dtype)


def derive_draft_params(base_cfg, draft_cfg, draft_model, params):
    """Build the draft model's param tree from the served weights.

    Per leaf: alias when base/draft agree (dense==dense or identical
    HashedSpec), else materialize the served virtual matrix and
    project it onto the draft's bank (or leave it dense).  Aliased
    leaves share device buffers with the base params — the draft costs
    only its differing banks.
    """
    from repro.models import transformer as T

    base_specs = T.bank_spec_map(base_cfg)
    draft_specs = T.bank_spec_map(draft_cfg)
    slots = {s.path: s for s in T.hash_slots(draft_cfg)}
    shapes = jax.eval_shape(draft_model.init, jax.random.PRNGKey(0))

    def fill(sub_sd, sub_params, path):
        if isinstance(sub_sd, dict):
            return {k: fill(sub_sd[k], sub_params[k], path + (k,))
                    for k in sub_sd}
        bspec, dspec = base_specs.get(path), draft_specs.get(path)
        if bspec == dspec:                        # includes dense==dense
            assert sub_params.shape == sub_sd.shape, path
            return sub_params
        slot = slots[path]
        return _transform_leaf(sub_params, bspec, dspec,
                               slot.virtual_shape, sub_sd)

    return fill(shapes, params, ())


def build_draft(base_cfg, params, draft_policy: DraftSpec,
                ) -> Tuple[object, object, object]:
    """(draft_cfg, draft_model, draft_params) for a served model.

    The draft config is the base config re-pointed at the draft policy;
    seeds are ratio-independent so every draft bank re-addresses the
    same hash streams as the served banks.
    """
    from repro.models import build

    policy = resolve_draft_policy(draft_policy, base_cfg)
    draft_cfg = base_cfg.policy_variant(policy).with_(
        name=f"{base_cfg.name}-draft")
    draft_model = build(draft_cfg)
    draft_params = derive_draft_params(base_cfg, draft_cfg, draft_model,
                                       params)
    return draft_cfg, draft_model, draft_params
