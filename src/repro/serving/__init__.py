from repro.serving import engine  # noqa: F401
from repro.serving.api import (  # noqa: F401
    FINISH_CANCELLED, FINISH_DEADLINE, FINISH_LENGTH, FINISH_REASONS,
    FINISH_STOP, RequestHandle, RequestOutput, SamplingParams)
from repro.serving.engine import Engine, Request, generate_batch  # noqa: F401
from repro.serving.multi_model import MultiModelEngine  # noqa: F401
from repro.serving.paged_cache import (  # noqa: F401
    PageAllocator, PagedKVCache, PrefixIndex, TRASH_PAGE)
from repro.serving.scheduler import Scheduler, SchedulerConfig  # noqa: F401
