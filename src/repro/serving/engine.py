"""Batched serving engine: continuous-batching prefill/decode over a
fixed-slot KV cache.

Design (vLLM-style, adapted to XLA's static-shape world):

- ``slots`` fixed decode batch; each slot holds one active sequence.
- Requests queue up; free slots are filled by *prefill* (one sequence at a
  time, written into the slot's cache region), decode advances ALL slots
  in lockstep with a single ``decode_step`` (B = n_slots, S = 1).
- Finished sequences (EOS or max_len) free their slot immediately
  (continuous batching — no head-of-line blocking on long generations).
- Per-slot cache layout: the model's init_cache(batch=slots) pytree;
  prefill writes through a batch=1 cache then scatters into the slot.

Sampling: greedy or temperature top-k, fp32 logits.

All jitted functions are donate-free and cache-functional (cache in,
cache out) so the same engine code runs under pjit on a mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (P,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0            # 0 => greedy
    # filled by the engine
    tokens: Optional[List[int]] = None
    done: bool = False
    extras: Optional[Dict[str, Any]] = None   # frames / image_embeds


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0


def _slot_update(cache, slot_cache, slot_idx):
    """Scatter a batch=1 cache pytree into slot `slot_idx` of the batched
    cache.  Leaves whose leading dims are (layers, batch, ...) or
    (batch, ...) are handled by matching the batch-dim size."""
    def upd(full, one):
        one = jnp.asarray(one)
        if full.ndim != one.ndim or full.ndim == 0:
            return full            # index-like leaves: engine-managed
        # find the batch axis: first axis where full=N and one=1
        for ax in range(full.ndim):
            if one.shape[ax] == 1 and full.shape[ax] != 1:
                start = [0] * full.ndim
                start[ax] = slot_idx
                return jax.lax.dynamic_update_slice(
                    full, one.astype(full.dtype), tuple(start))
        return full
    return jax.tree.map(upd, cache, slot_cache)


class Engine:
    def __init__(self, model: Model, params, slots: int = 4,
                 max_len: int = 512, eos_id: int = 1, seed: int = 0):
        self.model = model
        self.params = params
        self.slots = [_Slot() for _ in range(slots)]
        self.n_slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = model.init_cache(slots, max_len)
        # per-slot write positions: every slot decodes at its own index
        # (true continuous batching); supported by decoder/zamba/rwkv
        # kinds.  encdec keeps the scalar index (synchronous waves).
        self.per_row = model.cfg.arch_kind in ("decoder", "zamba", "rwkv")
        if self.per_row:
            self.cache["index"] = jnp.zeros((slots,), jnp.int32)
        self._key = jax.random.PRNGKey(seed)
        self._queue: List[Request] = []
        self._done: List[Request] = []
        self._tokens = np.zeros((slots, 1), np.int32)

        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    # ------------------------------------------------------------------
    @classmethod
    def from_artifact(cls, path_or_name: str, *,
                      registry_root: Optional[str] = None,
                      slots: int = 4, max_len: int = 512, eos_id: int = 1,
                      seed: int = 0) -> "Engine":
        """Cold-start an engine from a compressed model artifact.

        path_or_name: a .hnart file path, or (with registry_root) a
        registered model name, optionally ``name@version``.  The artifact
        carries the config, hash seeds, and banks — no checkpoint or live
        training state is involved (repro.artifact).  Quantized banks are
        dequantized at load: the model layers need real arrays (a
        keep-quantized engine path waits on an int8 decompress kernel).
        """
        from repro.artifact import io as artifact_io
        if registry_root is not None:
            from repro.artifact import registry as artifact_registry
            entry = artifact_registry.resolve(registry_root, path_or_name)
            path_or_name = entry["path"]
        _, model, params = artifact_io.load_model(path_or_name)
        return cls(model, params, slots=slots, max_len=max_len,
                   eos_id=eos_id, seed=seed)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.tokens = []
        self._queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.req is None]

    BUCKET = 64

    def _can_bucket(self, req: Request) -> bool:
        """Pad-and-mask bucketing is sound only for pure KV-cache decoders:
        pads after the prompt are causally invisible and the true length
        rides through prefill, so garbage K/V stays masked.  Recurrent
        kinds (rwkv/zamba) would fold pads into their state, and extras
        (encoder frames / image tokens) shift positions — those stay
        exact-length."""
        return self.model.cfg.arch_kind == "decoder" and not req.extras

    def _admit(self) -> None:
        """Prefill queued requests into free slots (continuous batching).

        Prompt lengths are bucketed to multiples of BUCKET with real
        pad-and-mask (batch["length"] carries the true length into the
        model), so prefill compiles once per bucket, not once per distinct
        prompt length."""
        for i in self._free_slots():
            if not self._queue:
                break
            req = self._queue.pop(0)
            p = len(req.prompt)
            if self._can_bucket(req):
                # clamp to the cache: a bucket can't exceed max_len (a
                # prompt longer than max_len is a caller error either way)
                bucket = min(-(-p // self.BUCKET) * self.BUCKET,
                             self.max_len)
                bucket = max(bucket, p)
                prompt = np.pad(req.prompt, (0, bucket - p))
                batch = {"tokens": jnp.asarray(prompt[None, :]),
                         "cache": self.model.init_cache(1, self.max_len),
                         "length": jnp.asarray(p, jnp.int32)}
            else:
                batch = {"tokens": jnp.asarray(req.prompt[None, :]),
                         "cache": self.model.init_cache(1, self.max_len)}
            if req.extras:
                batch.update({k: jnp.asarray(v) for k, v in
                              req.extras.items()})
            logits, c1 = self._prefill(self.params, batch)
            self.cache = _slot_update(self.cache, c1, i)
            pos = int(np.asarray(c1["index"]))
            if self.per_row:
                self.cache["index"] = \
                    self.cache["index"].at[i].set(pos)
            else:
                self.cache["index"] = c1["index"]
            self.slots[i] = _Slot(req, pos)
            tok = self._sample(logits[:, -1], temps=[req.temperature])
            req.tokens.append(int(tok[0]))
            self._tokens[i, 0] = int(tok[0])

    def _sample(self, logits, temps: Optional[List[float]] = None
                ) -> np.ndarray:
        """Sample next tokens.  temps: per-row temperatures; defaults to
        the active slots' temperatures (decode path).  Prefill passes the
        admitted request's temperature explicitly — slot state isn't
        updated yet at that point, so deriving it from self.slots would
        read a stale/unrelated slot."""
        logits = jnp.asarray(logits, jnp.float32)
        if temps is None:
            temps = [s.req.temperature if s.req else 0.0
                     for s in self.slots]
        assert len(temps) >= logits.shape[0], (len(temps), logits.shape)
        self._key, k = jax.random.split(self._key)
        greedy = jnp.argmax(logits, -1)
        t = jnp.asarray([max(t, 1e-6) for t in temps])[:logits.shape[0]]
        sampled = jax.random.categorical(k, logits / t[:, None])
        use_greedy = jnp.asarray([tt <= 0.0 for tt in temps]
                                 )[:logits.shape[0]]
        return np.asarray(jnp.where(use_greedy, greedy, sampled),
                          np.int32)

    def _retire(self) -> None:
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            r = s.req
            if (r.tokens and r.tokens[-1] == self.eos_id) \
                    or len(r.tokens) >= r.max_new_tokens:
                r.done = True
                self._done.append(r)
                self.slots[i] = _Slot()

    def step(self) -> int:
        """One engine tick: admit, decode all active slots, retire."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            return 0
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self._tokens), self.cache)
        toks = self._sample(logits[:, -1])
        for i in active:
            self.slots[i].req.tokens.append(int(toks[i]))
            self._tokens[i, 0] = int(toks[i])
            self.slots[i].pos += 1
        self._retire()
        return len(active)

    def run(self, max_ticks: int = 10000) -> List[Request]:
        ticks = 0
        while (self._queue or any(s.req for s in self.slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self._done


def generate_batch(model: Model, params, prompts: List[np.ndarray],
                   max_new_tokens: int = 32, max_len: int = 512,
                   slots: int = 4, eos_id: int = 1,
                   extras: Optional[List[Dict]] = None) -> List[List[int]]:
    """Convenience wrapper: submit all prompts, run to completion."""
    eng = Engine(model, params, slots=slots, max_len=max_len, eos_id=eos_id)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=np.asarray(p, np.int32),
                           max_new_tokens=max_new_tokens,
                           extras=extras[i] if extras else None))
    done = eng.run()
    return [r.tokens for r in sorted(done, key=lambda r: r.uid)]
