"""Continuous-batching serving engine over a paged KV cache.

Design (vLLM-style, adapted to XLA's static-shape world):

- A fixed decode batch of ``max_concurrency`` *rows*; each row holds one
  active sequence at its own position (per-row positions ride into the
  model, so rows of different lengths share one ``decode_step``).
- Decoder-kind models use the **paged backend**: K/V lives in fixed-size
  pages (`repro.serving.paged_cache`), rows hold page lists instead of a
  ``max_len`` reservation, and decode reads K/V through the page table
  (`kernels/paged_attention`, ref fallback in `kernels/ref`).  When the
  pool is oversubscribed and a row needs a page none are free, the
  youngest active row is preempted — its pages are released and it
  re-enters the queue head to be re-prefilled later (decode is
  reproducible across preemption — greedy trivially, sampled via the
  counter-based per-request PRNG streams below).
- **Prefix caching** (``prefix_cache=True``): a radix tree over token-id
  page chunks dedups shared prompt prefixes — a new request whose feed
  starts with an indexed prefix maps those pages by reference instead of
  recomputing them (refcounted, copy-on-write on the boundary page; see
  paged_cache).  Shared pages are read through the same page table, so
  the decode kernels need no new math, and greedy output is
  token-identical to the non-shared path by construction (prefix K/V is
  bitwise what a fresh prefill would have produced).
- **Chunked prefill** (``prefill_chunk=N``): long prompts prefill N
  tokens per tick, interleaved with decode ticks for the already-running
  rows — no head-of-line blocking on a long prompt.  Chunks write
  straight into the row's (possibly shared) pages; positions covered by
  a prefix hit are gathered from the tree's pages instead of recomputed.
  Chunked prefill is bitwise-identical to monolithic prefill (each query
  attends over the same full-width cache buffer either way; pinned in
  tests/test_kernels.py).
- **Batched ragged prefill** (``batched_prefill=True``, the default on
  paged decoder kinds): all rows mid-prefill advance in ONE ragged
  dispatch per tick (`kernels/flash_prefill`) — per-row start/length
  ride as scalars, shared-prefix pages are read through the page table,
  and fresh K/V lands straight in each row's private pages, no batch=1
  scratch-cache round trip.  Rows group by the same fixed compile-shape
  bucket menu as sequential chunking (the row count pads to a power of
  two); non-chunkable rows (extras, non-decoder kinds) fall back to the
  sequential path.  Emitted tokens are bitwise identical to sequential
  chunked prefill: every sublayer is row-wise for batch >= 2, the
  attention oracle mirrors the dense path op for op, and the LM head
  runs per completing row at the same M=1 dispatch shape
  (``model.logits_head``) — pinned in tests/test_serving_fuzz.py.
- Recurrent / encoder-decoder kinds (rwkv, zamba, encdec) keep the
  dense fixed-row cache (recurrent state is O(1) per row; paging buys
  nothing there).
- Admission/retirement happen *mid-flight*, every tick: a scheduler
  (`repro.serving.scheduler`) with a bounded queue (backpressure:
  ``submit`` returns False when full), FIFO-within-priority-class
  ordering, optional queue deadlines, and a prefill/decode interleaving
  knob decides who prefills next.  Finished rows free immediately — no
  head-of-line blocking on long generations.

Prefill is bucketed pad-and-mask (one compile per bucket) for pure
decoders.  **Sampling** is one fused jitted dispatch per decode tick
(`repro.serving.sampling`): every `SamplingParams` knob rides as a
per-row array — penalties, temperature, top-k (Pallas radix-select
kernel on TPU), top-p, min-p, and a counter-based PRNG keyed on
``(seed, generated-token index)`` — so mixed greedy/sampled batches
never branch per request in the hot loop, and seeded decoding is
bitwise reproducible across preemption-recompute, prefix-cache replay,
and chunked prefill.  ``Engine.submit`` returns a `RequestHandle`
(truthy iff accepted) that streams incremental `RequestOutput` deltas
(`repro.serving.api`).  All jitted functions are cache-functional
(cache in, cache out) so the same engine code runs under pjit on a
mesh.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import ENGINE_PID, REQUEST_PID, Tracer
from repro.serving import sampling as sampling_lib
from repro.serving.api import (FINISH_CANCELLED, FINISH_DEADLINE,
                               FINISH_LENGTH, FINISH_STOP, FINISH_REASONS,
                               RequestHandle, SamplingParams)
from repro.serving.paged_cache import TRASH_PAGE, PagedKVCache
from repro.serving.scheduler import Scheduler, SchedulerConfig

# Clock discipline: every DURATION and DEADLINE (queue wait, TTFT,
# latency, tick/chunk timing, scheduler expiry) comes off the monotonic
# clock — wall time (`time.time`) steps under NTP/manual adjustment,
# which used to skew engine.ttft_s / engine.queue_wait_s (the old
# ``max(.., 0.0)`` clamps silently hid negative deltas) and could
# spuriously expire — or immortalize — deadlined requests.  Wall time
# survives only as the user-facing ``*_time`` timestamps on Request.
# Module-level indirections so tests can monkeypatch a stepping clock.
_now_wall = time.time
_now_mono = time.monotonic


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (P,) int32
    max_new_tokens: int = 32            # legacy mirror of sampling.max_tokens
    temperature: float = 0.0            # legacy mirror of sampling.temperature
    priority: int = 0                   # lower = more urgent
    model: Optional[str] = None         # tenant tag (multi-model engine)
    sampling: Optional[SamplingParams] = None
    # filled by the engine
    tokens: Optional[List[int]] = None
    done: bool = False
    extras: Optional[Dict[str, Any]] = None   # frames / image_embeds
    status: str = "new"       # queued/prefilling/running/preempted/done/...
    # wall-clock timestamps: user-facing only (logs, dashboards) —
    # NEVER subtracted from each other
    submit_time: Optional[float] = None
    first_admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    # monotonic-clock marks: the source for every reported duration
    # (queue wait, TTFT, latency) and for scheduler deadline expiry
    submit_mono: Optional[float] = None
    first_admit_mono: Optional[float] = None
    first_token_mono: Optional[float] = None
    finish_mono: Optional[float] = None
    preemptions: int = 0
    truncated: bool = False             # force-retired at max_len
    finish_reason: Optional[str] = None       # stop / length / deadline
    token_logprobs: Optional[List[float]] = None   # chosen-token logprobs
    cumulative_logprob: float = 0.0
    topk_logprobs: Optional[List[List[Tuple[int, float]]]] = None
    seed_used: Optional[int] = None     # effective PRNG seed (engine-drawn
    #                                     when sampling.seed is None)

    def __post_init__(self):
        # Compat shim: the legacy flat knobs and the SamplingParams
        # surface stay coherent both ways.  A legacy
        # ``Request(temperature=t, max_new_tokens=n)`` lowers into an
        # equivalent SamplingParams; an explicit ``sampling=`` wins and
        # back-fills the mirrors so old readers keep working.
        if self.sampling is None:
            self.sampling = SamplingParams(temperature=self.temperature,
                                           max_tokens=self.max_new_tokens)
        else:
            self.temperature = self.sampling.temperature
            self.max_new_tokens = self.sampling.max_tokens
        if self.token_logprobs is None:
            self.token_logprobs = []
        if self.sampling.logprobs is not None and self.topk_logprobs is None:
            self.topk_logprobs = []


@dataclasses.dataclass
class _Prefill:
    """In-flight chunked prefill for one row."""
    req: Request
    feed: np.ndarray          # prompt + pre-preemption tokens
    target: int               # cached positions when complete (feed+extras)
    pos: int                  # cached positions so far (starts at prefix hit)
    cache: Any                # batch=1 scratch cache (None => zeros cache)
    chunkable: bool           # bucketed decoder without extras


def _slot_update(cache, slot_cache, slot_idx):
    """Scatter a batch=1 cache pytree into slot `slot_idx` of the batched
    cache.  Leaves whose leading dims are (layers, batch, ...) or
    (batch, ...) are handled by matching the batch-dim size."""
    def upd(full, one):
        one = jnp.asarray(one)
        if full.ndim != one.ndim or full.ndim == 0:
            return full            # index-like leaves: engine-managed
        # find the batch axis: first axis where full=N and one=1
        for ax in range(full.ndim):
            if one.shape[ax] == 1 and full.shape[ax] != 1:
                start = [0] * full.ndim
                start[ax] = slot_idx
                return jax.lax.dynamic_update_slice(
                    full, one.astype(full.dtype), tuple(start))
        return full
    return jax.tree.map(upd, cache, slot_cache)


def _copy_pages(pages, ck, cv, pids):
    """Scatter a prefill scratch cache's K/V into pages, one jitted call.

    ck/cv: (nl, 1, max_len, n_kv, hd) from the batch=1 prefill cache;
    pages: {"k","v"} (nl, P, ps, n_kv, hd); pids: (MAXP,) int32 — logical
    page j -> physical page pids[j].  Slots that must NOT be written
    (shared prefix pages, pages outside the chunk being landed, unused
    table slots) carry the trash page, whose contents are never read, so
    the loop writes all MAXP slots unconditionally — one compile covers
    every chunk shape.  The fori_loop carries the pools, so XLA
    bufferizes the updates in place — one pool rewrite per call instead
    of one per page.
    """
    nl, _, _, hkv, hd = ck.shape
    ps = pages["k"].shape[2]

    def body(j, pools):
        pk, pv = pools
        src = jnp.minimum(j * ps, ck.shape[2] - ps)
        chunk_k = jax.lax.dynamic_slice(ck, (0, 0, src, 0, 0),
                                        (nl, 1, ps, hkv, hd))
        chunk_v = jax.lax.dynamic_slice(cv, (0, 0, src, 0, 0),
                                        (nl, 1, ps, hkv, hd))
        pk = jax.lax.dynamic_update_slice(
            pk, chunk_k.astype(pk.dtype), (0, pids[j], 0, 0, 0))
        pv = jax.lax.dynamic_update_slice(
            pv, chunk_v.astype(pv.dtype), (0, pids[j], 0, 0, 0))
        return pk, pv

    pk, pv = jax.lax.fori_loop(0, pids.shape[0], body,
                               (pages["k"], pages["v"]))
    return {"k": pk, "v": pv}


def _gather_prefix(pages, pids, index):
    """Materialize a row's (possibly shared) prefix K/V from pages into
    a fresh batch=1 scratch cache so chunked prefill can resume at
    ``index`` — the read side of prefix sharing.  Positions beyond the
    hit hold stale pool bytes; they are either overwritten by the next
    chunk's cache write or causally invisible, exactly like the zeros
    scratch in the cold path."""
    nl, _, ps, hkv, hd = pages["k"].shape
    maxp = pids.shape[0]
    gk = jnp.take(pages["k"], pids, axis=1).reshape(nl, 1, maxp * ps,
                                                    hkv, hd)
    gv = jnp.take(pages["v"], pids, axis=1).reshape(nl, 1, maxp * ps,
                                                    hkv, hd)
    return {"k": gk, "v": gv, "index": index}


def _copy_page(pages, src, dst):
    """Device copy of one physical page (the COW drain): page ``dst``
    becomes a private replica of ``src`` across every layer."""
    nl, _, ps, hkv, hd = pages["k"].shape

    def one(pool):
        chunk = jax.lax.dynamic_slice(pool, (0, src, 0, 0, 0),
                                      (nl, 1, ps, hkv, hd))
        return jax.lax.dynamic_update_slice(pool, chunk,
                                            (0, dst, 0, 0, 0))

    return {"k": one(pages["k"]), "v": one(pages["v"])}


class Engine:
    BUCKET = 64
    # chunk buckets: small powers of two below BUCKET, then BUCKET
    # multiples (the monolithic ladder) — bounds prefill compiles while
    # chunk offsets roam
    _SUB_BUCKETS = (8, 16, 32)

    def __init__(self, model: Model, params, slots: int = 4,
                 max_len: int = 512, eos_id: int = 1, seed: int = 0, *,
                 max_concurrency: Optional[int] = None,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 attn_impl: str = "ref", paged: Optional[bool] = None,
                 prefix_cache: bool = False,
                 prefill_chunk: Optional[int] = None,
                 batched_prefill: bool = True,
                 max_logprobs: int = 8,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 debug_leak_check: bool = False,
                 draft: Optional[Tuple[Model, Any]] = None,
                 spec_k: int = 4,
                 spec_adaptive: bool = False,
                 mesh: Optional[Any] = None,
                 model_tag: Optional[str] = None,
                 page_allocator: Optional[Any] = None,
                 shared_pages: Optional[Any] = None,
                 page_quota: Optional[int] = None):
        """max_concurrency (alias: slots) fixes the decode batch width.

        Paged knobs (decoder kinds): ``page_size`` tokens per KV page;
        ``num_pages`` sizes the physical pool — default fully provisions
        every row to max_len (no preemption possible); pass less to
        oversubscribe memory and let preemption absorb the overflow.
        ``attn_impl``: "ref" (gather oracle) or "pallas" (paged-gather
        flash-decode kernel; interpret mode off-TPU).
        ``prefix_cache`` dedups shared prompt prefixes across requests
        (radix tree + refcounts + COW); ``prefill_chunk`` prefills long
        prompts N tokens per tick interleaved with decode (None =
        monolithic).  Both require the paged backend.
        ``batched_prefill`` (default on) coalesces every chunkable row's
        prefill step into ONE ragged dispatch per tick over the paged
        pool (`kernels/flash_prefill` via ``model.prefill_paged``) —
        token-bitwise-identical to the sequential path; ignored when the
        model has no ``prefill_paged`` (MoE, non-decoder kinds) or the
        backend isn't paged.

        ``max_logprobs`` caps the per-token top-K logprob report any
        request may ask for (the fused sampler computes top-K once per
        tick at this fixed width); ``seed`` seeds the stream that
        assigns per-request sampling seeds to requests that did not
        pin one — for a fixed submit order the whole run is
        reproducible.

        Observability: ``metrics`` is the registry every component
        (engine, scheduler, paged cache, fused sampler) publishes into
        (default: a fresh private one — ``Engine.stats()`` stays a thin
        compat view over it); ``tracer`` records per-request spans for
        Perfetto export (default: disabled, near-zero overhead).
        ``debug_leak_check`` (or env REPRO_DEBUG_LEAK_CHECK=1) makes
        ``shutdown()`` run the paged cache's refcount audit and export
        anomalies as the ``kv.leak_anomalies`` metric.

        ``draft``: an optional ``(model, params)`` pair (typically from
        `repro.serving.draft.build_draft` — a compressed policy variant
        of the served model) enabling self-speculative decoding: the
        draft proposes ``spec_k`` tokens per tick and the base model
        verifies them in one batched dispatch (`repro.serving.
        spec_decode`).  Emitted tokens are bitwise identical to the
        non-speculative engine for every SamplingParams mix.  Requires
        the paged backend on a decoder kind (MoE excluded: its
        capacity routing is batch-shape dependent, so block-verify
        parity doesn't hold).

        ``mesh``: a ``(data, model)`` jax Mesh (`launch.mesh.
        make_serving_mesh`) enabling tensor-parallel decode/prefill:
        the page pool and hashed banks shard over the "model" axis,
        the paged attention dispatches run shard_mapped per head
        shard (`nn.attention`), and the scheduler/allocator/page
        table stay host-global.  Emitted tokens are BITWISE identical
        to the single-device engine (no cross-shard reduction ever
        runs: attention is per-head, the head shards are all-gathered
        — an exact concat — before the replicated projections).  When
        the head counts don't divide the mesh's model axis the pool
        replicates and each device redundantly computes the
        single-device math.  Requires the paged backend; speculative
        decoding on a mesh is not supported yet (the draft keeps a
        second, unsharded pool).

        ``spec_adaptive``: accept-rate EWMA controller varies the
        proposal depth within [1, spec_k] (spec_k becomes k_max);
        emitted tokens stay bitwise identical (acceptance is equality).

        Multi-tenant hosting (`repro.serving.multi_model`): ``model_tag``
        names this engine's tenant lane on a shared ``scheduler``
        (which may be a live `Scheduler` instance, not just a config);
        ``page_allocator`` / ``shared_pages`` bind it to a shared
        host-side allocator and device page pool; ``page_quota`` caps
        its distinct-page footprint on that pool.
        """
        self.model = model
        self.params = params
        rows = max_concurrency if max_concurrency is not None else slots
        self.n_rows = rows
        self.eos_id = eos_id
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer(enabled=False)
        self.debug_leak_check = bool(
            debug_leak_check or os.environ.get("REPRO_DEBUG_LEAK_CHECK"))
        self.paged = (model.decode_paged is not None) if paged is None \
            else paged
        if self.paged and model.decode_paged is None:
            raise ValueError(
                f"arch kind {model.cfg.arch_kind!r} has no paged decode")
        self.mesh = mesh
        if mesh is not None:
            if not self.paged:
                raise ValueError("mesh= requires the paged backend "
                                 "(decoder kinds)")
            if draft is not None:
                raise ValueError("mesh= with speculative decoding is not "
                                 "supported (the draft keeps a second, "
                                 "unsharded page pool)")
        if not self.paged and (prefix_cache or prefill_chunk is not None):
            raise ValueError("prefix_cache/prefill_chunk require the "
                             "paged backend (decoder kinds)")
        if not self.paged and (page_allocator is not None
                               or shared_pages is not None
                               or page_quota is not None):
            raise ValueError("page_allocator/shared_pages/page_quota "
                             "require the paged backend (decoder kinds)")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1: {prefill_chunk}")
        self.prefix_cache = prefix_cache
        self.prefill_chunk = prefill_chunk
        self.model_tag = model_tag
        if isinstance(scheduler, Scheduler):
            self.sched = scheduler       # shared across hosted models
        else:
            self.sched = Scheduler(scheduler or SchedulerConfig(),
                                   metrics=self.metrics)
        self.rows: List[Optional[Request]] = [None] * rows
        self._row_seq = [0] * rows      # admission order, for preemption
        self._seq = 0
        self._done: List[Request] = []
        self._failed: List[Request] = []
        self._tokens = np.zeros((rows, 1), np.int32)
        self._prefill = jax.jit(model.prefill)
        self._prefilling: Dict[int, _Prefill] = {}
        # fused sampler: per-row SamplingParams state + ONE jitted
        # dispatch per decode tick (a second B=1 specialization serves
        # prefill completions); the specialization menu and its
        # observability live in sampling.FusedSampler
        vocab = model.cfg.vocab_size
        self._sampler = sampling_lib.FusedSampler(
            rows, vocab, max_logprobs, metrics=self.metrics,
            tracer=self.tracer)
        self._sampler_state = self._sampler.state
        self._logprob_k = self._sampler.logprob_k
        self._auto_seeds = np.random.default_rng(seed)
        # engine.* counters (registry-backed; stats() is the compat view)
        self._counts = self.metrics.group("engine", keys=(
            "ticks", "tokens", "done", "failed", "preemptions",
            "cancelled"))
        self._finish_counts = self.metrics.group("engine.finish",
                                                 keys=FINISH_REASONS)
        self._h_ttft = self.metrics.histogram("engine.ttft_s")
        self._h_qwait = self.metrics.histogram("engine.queue_wait_s")
        self._h_tick = self.metrics.histogram("engine.decode_tick_s")
        self._h_chunk = self.metrics.histogram("engine.prefill_chunk_s")
        # batched ragged prefill: dispatches = fused calls, rows/tokens =
        # work coalesced per call, fallback_chunks = rows that took the
        # sequential path (non-chunkable kinds, or batching off)
        self._pb_counts = self.metrics.group("engine.prefill_batch", keys=(
            "dispatches", "rows", "tokens", "fallback_chunks"))
        self._h_pbatch = self.metrics.histogram("engine.prefill_batch_s")
        self._leak_anomalies = self.metrics.counter("kv.leak_anomalies")
        self.last_leak_error: Optional[str] = None
        # engine.shard.* exists only on mesh engines: non-mesh registry
        # snapshots (and the bench deltas diffed off them) stay unchanged
        self._shard_counts = None

        if self.paged:
            # page-aligned max_len keeps every prefill page copy in
            # bounds (dynamic_slice clamping would silently shift rows)
            self.max_len = -(-max_len // page_size) * page_size
            maxp = self.max_len // page_size
            if num_pages is None:
                num_pages = rows * maxp + 1          # +1: trash page
            self.kv = PagedKVCache(num_pages, page_size, rows, maxp,
                                   prefix_cache=prefix_cache,
                                   metrics=self.metrics,
                                   alloc=page_allocator,
                                   page_quota=page_quota)
            self._g_pages_used = self.metrics.gauge("kv.pages_in_use")
            self._g_pages_free = self.metrics.gauge("kv.pages_free")
            self._g_pages_held = self.metrics.gauge("kv.pages_held") \
                if (page_allocator is not None or page_quota is not None) \
                else None
            self.pages = shared_pages if shared_pages is not None \
                else model.init_paged_cache(num_pages, page_size)
            self._prefill_cache = model.init_cache(1, self.max_len)
            # donate the page pools: without donation the functional
            # pages-in/pages-out contract would copy the whole pool per
            # decode tick / prefill (backends that can't donate just
            # warn and copy — no behavior change)
            self._decode_paged = jax.jit(
                lambda p, t, pg, tb, ln: model.decode_paged(
                    p, t, pg, tb, ln, attn_impl),
                donate_argnums=(2,))
            self._page_copy = jax.jit(_copy_pages, donate_argnums=(0,))
            self._gather = jax.jit(_gather_prefix)
            self._cow_copy = jax.jit(_copy_page, donate_argnums=(0,))
            # batched ragged prefill: one fused dispatch advances every
            # chunkable row's current chunk straight into its private
            # pages.  It returns the last-real-slot HIDDEN state; the
            # LM head runs separately per completing row at batch=1 —
            # the same M=1 GEMM dispatch the sequential path uses, so
            # sampled logits are bitwise identical (M=1 GEMV lowering
            # differs from M>=2 rows, which all agree with each other).
            self.batched_prefill = bool(batched_prefill) \
                and model.prefill_paged is not None
            if self.batched_prefill:
                self._prefill_batched = jax.jit(
                    lambda p, t, pg, tb, st, cn, wf: model.prefill_paged(
                        p, t, pg, tb, st, cn, wf, attn_impl),
                    donate_argnums=(2,))
                self._logits_head = jax.jit(model.logits_head)
            self.spec = None
            if draft is not None:
                if model.decode_paged_block is None \
                        or draft[0].decode_paged_block is None:
                    raise ValueError(
                        "speculative decoding needs decode_paged_block "
                        "(decoder kind, non-MoE)")
                from repro.serving.spec_decode import SpecDecoder
                self.spec = SpecDecoder(self, draft[0], draft[1],
                                        k=spec_k, attn_impl=attn_impl,
                                        adaptive=spec_adaptive)
            if mesh is not None:
                self._init_mesh(mesh)
        else:
            if draft is not None:
                raise ValueError("speculative decoding requires the "
                                 "paged backend (decoder kinds)")
            self.spec = None
            self.batched_prefill = False
            self.max_len = max_len
            self.cache = model.init_cache(rows, max_len)
            # per-row write positions: every row decodes at its own index
            # (continuous batching); supported by decoder/zamba/rwkv
            # kinds.  encdec keeps the scalar index (synchronous waves).
            self.per_row = model.cfg.arch_kind in ("decoder", "zamba",
                                                   "rwkv")
            if self.per_row:
                self.cache["index"] = jnp.zeros((rows,), jnp.int32)
            self._decode = jax.jit(model.decode_step)

    # ------------------------------------------------------------------
    @classmethod
    def from_artifact(cls, path_or_name: str, *,
                      registry_root: Optional[str] = None,
                      slots: int = 4, max_len: int = 512, eos_id: int = 1,
                      seed: int = 0, draft_policy=None,
                      **kwargs) -> "Engine":
        """Cold-start an engine from a compressed model artifact.

        path_or_name: a .hnart file path, or (with registry_root) a
        registered model name, optionally ``name@version``.  The artifact
        carries the config, hash seeds, and banks — no checkpoint or live
        training state is involved (repro.artifact).  Quantized banks are
        dequantized at load: the model layers need real arrays (a
        keep-quantized engine path waits on an int8 decompress kernel).
        Extra kwargs (page_size, prefix_cache, prefill_chunk, scheduler,
        max_logprobs, ...) pass through to Engine, so the full sampling
        & streaming surface (SamplingParams requests, RequestHandle
        deltas, seeded reproducibility) works identically on a
        cold-started artifact.

        ``draft_policy`` switches on self-speculative decoding: a
        `CompressionPolicy`, policy-JSON path, or ratio string ("1/16")
        naming the compressed draft variant, derived off the SAME
        loaded params (one mmap: equal-ratio banks alias by reference,
        deeper rungs project through the shared hash seeds — see
        `repro.serving.draft`).  ``spec_k`` (in kwargs) sets the
        proposal depth.
        """
        from repro.artifact import io as artifact_io
        if registry_root is not None:
            from repro.artifact import registry as artifact_registry
            entry = artifact_registry.resolve(registry_root, path_or_name)
            path_or_name = entry["path"]
        _, model, params = artifact_io.load_model(path_or_name)
        if draft_policy is not None:
            from repro.serving import draft as draft_lib
            _, dmodel, dparams = draft_lib.build_draft(
                model.cfg, params, draft_policy)
            kwargs["draft"] = (dmodel, dparams)
        return cls(model, params, slots=slots, max_len=max_len,
                   eos_id=eos_id, seed=seed, **kwargs)

    # ------------------------------------------------------------------
    def _init_mesh(self, mesh) -> None:
        """Tensor-parallel placement: shard the page pool on the kv-head
        axis and the hashed banks on their bucket axis, replicate every
        other param, and wrap each jitted serving dispatch so it traces
        and executes under the serving rule set (``tp_kv -> model``,
        all activation/dense-weight axes replicated —
        `distributed.sharding.serving_rules`).  Inside that context
        `nn.attention` shard_maps its scatter+kernel block per head
        shard and all-gathers the head outputs (an exact concat) before
        the replicated o-projection — no cross-shard reduction ever
        runs, so emitted tokens are bitwise the single-device ones."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed import sharding as shd
        from repro.serving.paged_cache import pool_pspec

        cfg = self.model.cfg
        tp = mesh.shape.get("model", 1)
        self._rules = shd.serving_rules(cfg.num_heads, cfg.num_kv_heads,
                                        mesh)
        self.pages = jax.device_put(
            self.pages, NamedSharding(mesh, pool_pspec(
                cfg.num_kv_heads, cfg.num_heads, tp)))
        self.params = self._place_params(mesh, tp)

        def wrap(fn, replicate_out=False):
            def call(*a):
                with shd.use_mesh(mesh, self._rules):
                    out = fn(*a)
                if replicate_out:
                    # scratch caches feed the replicated sequential
                    # prefill path: re-replicate eagerly so no sharded
                    # operand leaks into an unconstrained dot (which
                    # GSPMD could partition into a psum — inexact)
                    out = jax.device_put(out, NamedSharding(mesh, P()))
                return out
            return call

        self._decode_paged = wrap(self._decode_paged)
        self._page_copy = wrap(self._page_copy)
        self._gather = wrap(self._gather, replicate_out=True)
        self._cow_copy = wrap(self._cow_copy)
        self._prefill = wrap(self._prefill)
        if self.batched_prefill:
            self._prefill_batched = wrap(self._prefill_batched)
            self._logits_head = wrap(self._logits_head)
        self.metrics.gauge("engine.shard.devices").set(mesh.size)
        self.metrics.gauge("engine.shard.tp").set(tp)
        self._shard_counts = self.metrics.group("engine.shard", keys=(
            "decode_dispatches", "prefill_dispatches"))

    def _place_params(self, mesh, tp: int):
        """Hashed banks shard over "model" (the bucket axis is a pure
        gather source — exact under sharding); everything else
        replicates.  Banks are the ONLY pspec leaves with a TUPLE axis
        containing "tp" (`nn.layers.bank_pspec`; layer-stacked banks
        carry it on axis 1 behind the stack axis); dense weights carry
        plain (fsdp, tp) axes and MUST stay replicated — sharding a
        projection's contraction dim would psum its output, breaking
        bitwise token-identity."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed import sharding as shd

        specs = self.model.pspecs()
        rep = NamedSharding(mesh, P())

        def bank_axis(spec):
            for i, ax in enumerate(spec):
                if isinstance(ax, (tuple, list)) and "tp" in ax:
                    return i
            return None

        def place(spec, p):
            ax = bank_axis(spec)
            if ax is not None and p.shape[ax] % tp == 0:
                phys = shd.resolve_spec(spec, shd.SERVING_BANK_RULES)
                return jax.device_put(p, NamedSharding(mesh, phys))
            return jax.device_put(p, rep)

        return jax.tree.map(
            place, specs, self.params,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    # ------------------------------------------------------------------
    def _extra_tokens(self, req: Request) -> int:
        if req.extras and "image_embeds" in req.extras:
            return self.model.cfg.num_image_tokens
        return 0

    def submit(self, req: Request) -> RequestHandle:
        """Enqueue a request.  Returns a `RequestHandle` — truthy iff
        accepted (falsy: backpressure on the bounded queue, or the
        request could never fit the page pool), so ``if eng.submit(r)``
        keeps its legacy meaning.  Iterate the handle (or ``drain()``
        it) for streamed `RequestOutput` deltas."""
        if req.tokens is None:
            req.tokens = []
        if self.model_tag is not None:
            req.model = self.model_tag   # tenant lane on a shared sched
        sp = req.sampling
        if sp.logprobs is not None and sp.logprobs > self._logprob_k:
            raise ValueError(
                f"logprobs={sp.logprobs} exceeds engine "
                f"max_logprobs={self._logprob_k}")
        if self.paged:
            total = len(req.prompt) + self._extra_tokens(req) \
                + sp.max_tokens
            if not self.kv.fits_ever(total):
                req.status = "rejected"
                self._counts["failed"] += 1
                self._failed.append(req)
                return RequestHandle(self, req, accepted=False)
        if not self.sched.submit(req, _now_mono()):
            req.status = "rejected"
            self._counts["failed"] += 1
            self._failed.append(req)
            return RequestHandle(self, req, accepted=False)
        req.submit_time = _now_wall()
        if req.seed_used is None:
            # the effective PRNG stream seed: explicit, or drawn from
            # the engine's seeded stream (deterministic in submit order)
            req.seed_used = int(sp.seed) if sp.seed is not None \
                else int(self._auto_seeds.integers(0, 2 ** 31 - 1))
        req.status = "queued"
        if self.tracer.enabled:
            self.tracer.track(REQUEST_PID, req.uid, f"req {req.uid}")
            self.tracer.begin(REQUEST_PID, req.uid, "request",
                              prompt_len=len(req.prompt))
            self.tracer.begin(REQUEST_PID, req.uid, "queued")
        return RequestHandle(self, req, accepted=True)

    def _free_rows(self) -> List[int]:
        return [i for i, r in enumerate(self.rows) if r is None]

    def _can_bucket(self, req: Request) -> bool:
        """Pad-and-mask bucketing is sound only for pure KV-cache decoders:
        pads after the prompt are causally invisible and the true length
        rides through prefill, so garbage K/V stays masked.  Recurrent
        kinds (rwkv/zamba) would fold pads into their state, and extras
        (encoder frames / image tokens) shift positions — those stay
        exact-length."""
        return self.model.cfg.arch_kind == "decoder" and not req.extras

    def _feed(self, req: Request) -> np.ndarray:
        """Prefill token feed: the prompt plus anything generated before
        a preemption (re-prefilling them recomputes the evicted K/V)."""
        if req.tokens:
            return np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(req.tokens, np.int32)])
        return np.asarray(req.prompt, np.int32)

    def _prefix_ids(self, req: Request) -> Optional[np.ndarray]:
        """Token ids for prefix matching/indexing, or None when the row
        is ineligible: extras (image tokens shift every position, so the
        feed ids don't spell the cached content) and non-bucketable
        kinds stay out of the tree."""
        if self.paged and self.kv.prefix is not None \
                and self._can_bucket(req):
            return self._feed(req)
        return None

    def _can_admit(self, req: Request) -> bool:
        if not self.paged:
            return True
        feed = len(req.prompt) + len(req.tokens or ()) \
            + self._extra_tokens(req)
        return self.kv.can_admit(feed, token_ids=self._prefix_ids(req))

    def _admit(self, now: float) -> int:
        """Advance in-flight chunked prefills, then start new ones
        (continuous batching).  At most ``max_prefills_per_tick`` chunk
        steps run per tick — the prefill/decode interleave budget.
        With ``batched_prefill`` the advancing rows' chunks coalesce
        into one ragged dispatch per compile bucket instead of one
        dispatch each; budget accounting (chunk steps) is identical.
        Returns the number of chunk steps taken."""
        budget = self.sched.cfg.max_prefills_per_tick
        n_inflight = len(self._prefilling)
        advancing = sorted(self._prefilling,
                           key=lambda r: self._row_seq[r])[:budget]
        chunks = self._advance_rows(advancing)
        if n_inflight > budget:
            return chunks        # budget exhausted mid-flight
        admitted: List[int] = []
        while chunks + len(admitted) < budget:
            free = self._free_rows()
            if not free:
                break
            req = self.sched.pop_admissible(self._can_admit,
                                            model=self.model_tag)
            if req is None:
                break
            if not self._begin_prefill(free[0], req, now):
                # can_admit is optimistic under prefix sharing (shared
                # and reclaimable pages may overlap); put the head back
                self.sched.unpop(req)
                break
            admitted.append(free[0])
        # newly admitted rows take their first chunk TOGETHER — the
        # burst-arrival case (N submissions land in one tick) coalesces
        # into one ragged dispatch instead of N single-row ones.  Dense
        # (non-paged) rows finished inside _begin_prefill and are not
        # in _prefilling, so they drop out here but still count.
        self._advance_rows([r for r in admitted if r in self._prefilling])
        return chunks + len(admitted)

    # ------------------------------------------------------------------
    def _begin_prefill(self, row: int, req: Request, now: float) -> bool:
        """Bind a row: allocate/share pages and seed the scratch cache
        from any prefix hit.  The caller batches the first chunk step
        (``_admit`` advances all same-tick admissions as one ragged
        dispatch).  False if the pool came up short (caller
        re-queues)."""
        if not self.paged:
            self._prefill_into_dense(row, req, now)
            return True
        feed = self._feed(req)
        target = len(feed) + self._extra_tokens(req)
        ids = self._prefix_ids(req)
        if not self.kv.admit_row(row, target, token_ids=ids):
            return False
        hit = self.kv.row_meta[row].hit_tokens
        chunkable = self._can_bucket(req)
        cache = None
        if hit > 0 and self.batched_prefill and chunkable:
            # batched path: no scratch cache to seed — the ragged kernel
            # reads the shared prefix through the page table.  Only the
            # partial boundary page needs a private replica: its hit
            # bytes below ``hit`` are read but never recomputed (chunks
            # start at ``hit``; slide-back rewrites are bitwise equal).
            meta = self.kv.row_meta[row]
            if meta.tail_page is not None:
                dst = int(self.kv.table[row, meta.shared])
                self.pages = self._cow_copy(
                    self.pages, jnp.asarray(meta.tail_page, jnp.int32),
                    jnp.asarray(dst, jnp.int32))
                # copy dispatched; device ordering keeps it ahead of any
                # later pool write, so the pin can drop now
                self.kv.drop_tail_ref(row)
        elif hit > 0:
            pids = self.kv.gather_table(row)
            cache = self._gather(self.pages, jnp.asarray(pids),
                                 jnp.asarray(hit, jnp.int32))
            # the gather is dispatched; device ordering keeps it ahead
            # of any later pool write, so the pin can drop now
            self.kv.drop_tail_ref(row)
        self._prefilling[row] = _Prefill(
            req=req, feed=feed, target=target, pos=hit, cache=cache,
            chunkable=chunkable)
        self.rows[row] = req
        # (re)bind the row's sampling state: pure function of the
        # request's (params, prompt, tokens), so a preempted request
        # resumes its PRNG stream at exactly len(tokens)
        self._sampler_state.bind(row, req)
        self._seq += 1
        self._row_seq[row] = self._seq
        req.status = "prefilling"
        self._note_admitted(req, now, hit_tokens=hit)
        return True

    def _note_admitted(self, req: Request, now: float, *,
                       hit_tokens: int = 0) -> None:
        """Admission observability: close the request's ``queued`` span
        and, on FIRST admission, record the queue wait."""
        if self.tracer.enabled:
            self.tracer.end(REQUEST_PID, req.uid, "queued",
                            hit_tokens=hit_tokens)
        if req.first_admit_mono is None:
            req.first_admit_mono = now
            req.first_admit_time = _now_wall()
            self._h_qwait.observe(now - (req.submit_mono or now))

    def _chunk_shape(self, pos: int, c: int):
        """Compile shape for a chunk of c tokens at cached position pos:
        returns (start, bucket, length) with start + bucket <= max_len
        (dynamic_update clamping would silently shift the write) and
        length real tokens fed from ``start``.

        Buckets come from a FIXED menu — small powers of two, 64
        multiples, 8 multiples — so token-granular prefix-hit offsets
        can't mint unbounded compile shapes.  When no menu bucket fits
        between c and the remaining room, the window *slides back*
        (start < pos): up to 7 already-cached positions are recomputed —
        bitwise-identical values (the chunk-parity property), one extra
        sliver of compute instead of a fresh XLA compile per distinct
        hit length.  Monolithic prefill from position 0 keeps the legacy
        64-multiple ladder (one compile per 64-bucket — pinned by the
        artifact tests)."""
        room = self.max_len - pos
        if self.prefill_chunk is None and pos == 0:
            b = max(min(-(-c // self.BUCKET) * self.BUCKET, room), c)
            return 0, b, c
        for b in self._SUB_BUCKETS:
            if c <= b <= room:
                return pos, b, c
        mult = -(-c // self.BUCKET) * self.BUCKET
        if mult <= room:
            return pos, mult, c
        b = min(-(-c // 8) * 8, pos + c)     # slide-back: 8-grid bucket
        start = pos + c - b
        # the docstring's contract, re-checked on THIS branch too: holds
        # because pos + c <= target <= max_len, but an out-of-range
        # write would silently shift (dynamic_update clamping), so fail
        # loudly instead
        assert start + b <= self.max_len, (start, b, self.max_len)
        return start, b, b

    def _advance_prefill(self, row: int) -> None:
        """One chunk step: compute ``c`` more feed positions against the
        scratch cache, land their pages, and on completion sample the
        first token and hand the row to decode."""
        st = self._prefilling[row]
        req = st.req
        t0 = time.perf_counter()
        tr0 = self.tracer.now()
        pos0 = st.pos
        remaining = len(st.feed) - (st.pos if st.chunkable else 0)
        c = remaining if (self.prefill_chunk is None or not st.chunkable) \
            else min(self.prefill_chunk, remaining)
        cache = st.cache if st.cache is not None else self._prefill_cache
        if st.chunkable:
            start, bucket, real = self._chunk_shape(st.pos, c)
            if start != st.pos:
                # slid-back window: rewind the write index; positions
                # [start, pos) recompute to the same bytes
                cache = dict(cache,
                             index=jnp.asarray(start, jnp.int32))
            toks = st.feed[start:start + real]
            prompt = np.pad(toks, (0, bucket - real))
            batch = {"tokens": jnp.asarray(prompt[None, :]),
                     "cache": cache,
                     "length": jnp.asarray(real, jnp.int32)}
        else:
            batch = {"tokens": jnp.asarray(st.feed[None, :]),
                     "cache": cache}
            if req.extras:
                batch.update({k: jnp.asarray(v) for k, v in
                              req.extras.items()})
        logits, c1 = self._prefill(self.params, batch)
        if self._shard_counts is not None:
            self._shard_counts["prefill_dispatches"] += 1
        st.cache = c1
        new_pos = int(np.asarray(c1["index"]))
        # land the freshly computed positions' pages; shared prefix
        # pages (slots below first_private_page) are never rewritten —
        # write targets outside the chunk resolve to the trash page
        lo = max(st.pos // self.kv.page_size,
                 self.kv.first_private_page(row))
        hi = self.kv.pages_for(new_pos)
        wpids = np.full((self.kv.maxp,), TRASH_PAGE, np.int32)
        wpids[lo:hi] = self.kv.table[row, lo:hi]
        self.pages = self._page_copy(self.pages, c1["k"], c1["v"],
                                     jnp.asarray(wpids))
        st.pos = new_pos
        self._h_chunk.observe(time.perf_counter() - t0)
        if self.tracer.enabled:
            self.tracer.complete(REQUEST_PID, req.uid, "prefill_chunk",
                                 tr0, start=pos0, end=st.pos)
        if st.pos < st.target:
            return
        self._complete_prefill(row, logits[:, -1])

    def _complete_prefill(self, row: int, last_logits) -> None:
        """Prefill complete: publish the feed's full pages for reuse
        (the partial boundary page is published at release, once decode
        stops writing it), sample the first token off ``last_logits``
        ((1, V) last-real-position logits), and hand the row to
        decode."""
        st = self._prefilling.pop(row)
        req = st.req
        ids = self._prefix_ids(req)
        if ids is not None:
            full = (st.target // self.kv.page_size) * self.kv.page_size
            self.kv.index_row(row, ids, full)
        req.status = "running"
        res = self._run_sampler(last_logits, slice(row, row + 1),
                                "prefill")
        self._commit_token(row, req, res, 0)
        self._note_first_token(req)

    def _advance_rows(self, rows_: List[int]) -> int:
        """Advance each row's prefill by one chunk step.  Chunkable rows
        coalesce into one ragged dispatch per compile bucket
        (``batched_prefill``); the rest take the sequential scratch-cache
        path.  Completions are processed in admission order either way,
        so sampler dispatch order — and thus every observable — matches
        the sequential engine.  Returns the number of chunk steps."""
        if not (self.batched_prefill and len(rows_) > 0):
            for row in rows_:
                self._advance_prefill(row)
            return len(rows_)
        groups: Dict[int, List[Tuple[int, int, int]]] = {}
        for row in rows_:
            st = self._prefilling[row]
            if not st.chunkable:
                self._pb_counts["fallback_chunks"] += 1
                self._advance_prefill(row)
                continue
            remaining = len(st.feed) - st.pos
            c = remaining if self.prefill_chunk is None \
                else min(self.prefill_chunk, remaining)
            start, bucket, real = self._chunk_shape(st.pos, c)
            groups.setdefault(bucket, []).append((row, start, real))
        done: Dict[int, Any] = {}
        for bucket in sorted(groups):
            self._dispatch_prefill_batch(bucket, groups[bucket], done)
        for row in rows_:            # admission order, like sequential
            if row in done:
                self._complete_prefill(row, done[row])
        return len(rows_)

    def _dispatch_prefill_batch(self, bucket: int,
                                entries: List[Tuple[int, int, int]],
                                done: Dict[int, Any]) -> None:
        """ONE ragged dispatch advancing every (row, start, real) entry
        by its current chunk: queries at positions [start, start+real)
        per row, fresh K/V scattered straight into the row's private
        pages, shared-prefix pages read through the page table.  The row
        count pads to a power of two (row-wise parity holds for any
        batch >= 2, so padding rows are free).  Rows that reach target
        stash their (1, V) last-position logits in ``done`` for ordered
        completion by the caller."""
        t0 = time.perf_counter()
        tr0 = self.tracer.now()
        n = len(entries)
        n_pad = max(2, 1 << (n - 1).bit_length())
        toks = np.zeros((n_pad, bucket), np.int32)
        starts = np.zeros((n_pad,), np.int32)
        counts = np.zeros((n_pad,), np.int32)
        wfrom = np.zeros((n_pad,), np.int32)
        tables = np.full((n_pad, self.kv.maxp), TRASH_PAGE, np.int32)
        for j, (row, start, real) in enumerate(entries):
            st = self._prefilling[row]
            toks[j, :real] = st.feed[start:start + real]
            starts[j] = start
            counts[j] = real
            # write protection: positions below the first private page
            # (shared prefix) — or below this chunk's landing floor —
            # must not be rewritten; slide-back recomputes land bitwise-
            # equal bytes so rewriting them above the floor is safe
            lo = max(st.pos // self.kv.page_size,
                     self.kv.first_private_page(row))
            wfrom[j] = lo * self.kv.page_size
            tables[j] = self.kv.table[row]
        x_last, self.pages = self._prefill_batched(
            self.params, jnp.asarray(toks), self.pages,
            jnp.asarray(tables), jnp.asarray(starts),
            jnp.asarray(counts), jnp.asarray(wfrom))
        dt = time.perf_counter() - t0
        self._pb_counts["dispatches"] += 1
        if self._shard_counts is not None:
            self._shard_counts["prefill_dispatches"] += 1
        self._pb_counts["rows"] += n
        self._pb_counts["tokens"] += int(counts.sum())
        self._h_pbatch.observe(dt)
        if self.tracer.enabled:
            self.tracer.complete(ENGINE_PID, 0, "prefill_batch", tr0,
                                 rows=n, bucket=bucket)
        for j, (row, start, real) in enumerate(entries):
            st = self._prefilling[row]
            pos0 = st.pos
            st.pos = start + real
            # the chunk histogram keeps per-row-step count semantics
            # (one observation per chunk step, like the sequential
            # path); the batch histogram carries the fused wall time
            self._h_chunk.observe(dt)
            if self.tracer.enabled:
                self.tracer.complete(REQUEST_PID, st.req.uid,
                                     "prefill_chunk", tr0,
                                     start=pos0, end=st.pos)
            if st.pos >= st.target:
                # per-row LM head at the sequential path's exact M=1
                # dispatch shape (see __init__: bitwise parity)
                done[row] = self._logits_head(
                    self.params, x_last[j:j + 1])[:, -1]

    def _note_first_token(self, req: Request) -> None:
        if req.first_token_mono is None:
            req.first_token_mono = _now_mono()
            req.first_token_time = _now_wall()
            self._h_ttft.observe(
                req.first_token_mono
                - (req.submit_mono or req.first_token_mono))
            if self.tracer.enabled:
                self.tracer.instant(REQUEST_PID, req.uid, "first_token")

    def _prefill_into_dense(self, row: int, req: Request,
                            now: float) -> None:
        """Non-paged kinds (rwkv/zamba/encdec): monolithic prefill into
        the batched dense cache (the pre-chunking path)."""
        feed = self._feed(req)
        p = len(feed)
        if self._can_bucket(req):
            bucket = min(-(-p // self.BUCKET) * self.BUCKET, self.max_len)
            bucket = max(bucket, p)
            prompt = np.pad(feed, (0, bucket - p))
            batch = {"tokens": jnp.asarray(prompt[None, :]),
                     "cache": self.model.init_cache(1, self.max_len),
                     "length": jnp.asarray(p, jnp.int32)}
        else:
            batch = {"tokens": jnp.asarray(feed[None, :]),
                     "cache": self.model.init_cache(1, self.max_len)}
        if req.extras:
            batch.update({k: jnp.asarray(v) for k, v in
                          req.extras.items()})
        logits, c1 = self._prefill(self.params, batch)
        pos = int(np.asarray(c1["index"]))
        self.cache = _slot_update(self.cache, c1, row)
        if self.per_row:
            self.cache["index"] = self.cache["index"].at[row].set(pos)
        else:
            self.cache["index"] = c1["index"]
        self.rows[row] = req
        self._sampler_state.bind(row, req)
        self._seq += 1
        self._row_seq[row] = self._seq
        req.status = "running"
        self._note_admitted(req, now)
        res = self._run_sampler(logits[:, -1], slice(row, row + 1),
                                "prefill")
        self._commit_token(row, req, res, 0)
        self._note_first_token(req)

    def _run_sampler(self, logits, sl: slice, kind: str
                     ) -> Dict[str, np.ndarray]:
        """One fused sampler dispatch over the row slice ``sl`` of the
        sampler state (full batch for decode ticks, the single admitted
        row for a prefill completion).  Thin delegate to
        `sampling.FusedSampler.run` — kept as a method so tests can
        subclass/spy on the engine's dispatch boundary."""
        return self._sampler.run(logits, sl, kind)

    def _commit_token(self, row: int, req: Request,
                      res: Dict[str, np.ndarray], j: int) -> None:
        """Record row ``row``'s sampled token (index ``j`` in the
        sampler result): request output + logprobs, the sampler's PRNG
        counter / penalty masks, and the next decode feed."""
        tok = int(res["token"][j])
        lp = float(res["logprob"][j])
        self._counts["tokens"] += 1
        req.tokens.append(tok)
        req.token_logprobs.append(lp)
        req.cumulative_logprob += lp
        kk = req.sampling.logprobs
        if kk is not None and "topk_ids" in res:
            req.topk_logprobs.append(list(zip(
                res["topk_ids"][j][:kk].tolist(),
                res["topk_logprobs"][j][:kk].tolist())))
        self._sampler_state.note(row, tok)
        self._tokens[row, 0] = tok

    # ------------------------------------------------------------------
    def _history_ids(self, row: int) -> np.ndarray:
        """Token ids spelling the row's cached positions (prompt plus
        generated tokens, clipped to what has actually been written)."""
        req = self.rows[row]
        ids = np.concatenate([np.asarray(req.prompt, np.int32),
                              np.asarray(req.tokens or [], np.int32)])
        return ids[:int(self.kv.lengths[row])]

    def _publish_row(self, row: int) -> None:
        """Index everything the row cached — full pages AND the partial
        boundary page — before its references drop.  Called when writes
        to the row's pages are provably over (finish/preempt)."""
        req = self.rows[row]
        if req is None or self._prefix_ids(req) is None:
            return
        if row in self._prefilling:
            st = self._prefilling[row]
            self.kv.index_row(row, st.feed[:st.pos], st.pos)
        else:
            ids = self._history_ids(row)
            self.kv.index_row(row, ids, len(ids))

    def _preempt(self, row: int) -> None:
        req = self.rows[row]
        self._publish_row(row)           # landed pages serve the resume
        self._prefilling.pop(row, None)
        self.rows[row] = None
        self.kv.release_row(row)
        if self.spec is not None:
            self.spec.release_row(row)
        self._sampler_state.clear(row)
        req.status = "preempted"
        req.preemptions += 1
        self._counts["preemptions"] += 1
        if self.tracer.enabled:
            self.tracer.instant(REQUEST_PID, req.uid, "preempt",
                                tokens=len(req.tokens or ()))
            self.tracer.begin(REQUEST_PID, req.uid, "queued")
        self.sched.requeue(req)

    def _finish(self, row: int, truncated: bool = False,
                reason: str = FINISH_STOP) -> None:
        req = self.rows[row]
        if self.paged:
            self._publish_row(row)
            self.rows[row] = None
            self.kv.release_row(row)
            if self.spec is not None:
                self.spec.release_row(row)
        else:
            self.rows[row] = None
        self._sampler_state.clear(row)
        req.done = True
        req.truncated = truncated
        req.status = "done"
        req.finish_reason = reason
        self._counts["done"] += 1
        self._finish_counts[reason] += 1
        req.finish_mono = _now_mono()
        req.finish_time = _now_wall()
        if self.tracer.enabled:
            self.tracer.end(REQUEST_PID, req.uid, "request",
                            finish=reason, tokens=len(req.tokens or ()))
        self._done.append(req)

    def _ensure_room(self, active: List[int]) -> List[int]:
        """Paged backend: make every active row's next write position
        addressable and privately writable (COW), preempting
        youngest-first on pool exhaustion."""
        for i in list(active):
            if self.rows[i] is None:        # preempted by an earlier row
                continue
            n0 = len(self.kv.pending_copies)
            while True:
                st = self.kv.ensure_decode_room(i)
                if st == "ok":
                    if self.tracer.enabled:
                        for src, dst in self.kv.pending_copies[n0:]:
                            self.tracer.instant(
                                REQUEST_PID, self.rows[i].uid, "cow_copy",
                                src=src, dst=dst)
                    break
                if st == "full":            # max_len hit: force-retire
                    self._finish(i, truncated=True, reason=FINISH_LENGTH)
                    break
                victims = [j for j in range(self.n_rows)
                           if self.rows[j] is not None]
                victim = max(victims, key=lambda j: self._row_seq[j])
                self._preempt(victim)
                if victim == i:
                    break
        return [i for i in active if self.rows[i] is not None]

    def _drain_cow(self) -> None:
        """Perform queued copy-on-write page copies before anything
        writes the pool (decode's token write must hit the private
        replica, never the shared original)."""
        for src, dst in self.kv.pending_copies:
            self.pages = self._cow_copy(self.pages,
                                        jnp.asarray(src, jnp.int32),
                                        jnp.asarray(dst, jnp.int32))
        self.kv.pending_copies.clear()

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine tick: expire, admit/advance prefills, decode all
        running rows, retire.  Returns the number of rows decoded."""
        self._counts["ticks"] += 1
        tick_tr0 = self.tracer.now()
        decoded = self._step_inner()
        if self.paged:
            self._g_pages_used.set(self.kv.alloc.num_used)
            self._g_pages_free.set(self.kv.alloc.num_free)
            if self._g_pages_held is not None:
                self._g_pages_held.set(self.kv.pages_held())
        if self.tracer.enabled:
            self.tracer.complete(ENGINE_PID, 0, "tick", tick_tr0,
                                 decoded=decoded)
        return decoded

    def _step_inner(self) -> int:
        now = _now_mono()
        for r in self.sched.expire(now, model=self.model_tag):
            r.status = "expired"       # scheduler set finish_reason
            # stamp the finish clocks like _finish does: a streaming
            # client's terminal "deadline" delta and the latency math
            # must see real marks, not None
            r.finish_mono = now
            r.finish_time = _now_wall()
            self._counts["failed"] += 1
            self._finish_counts[FINISH_DEADLINE] += 1
            if self.tracer.enabled:
                self.tracer.end(REQUEST_PID, r.uid, "queued")
                self.tracer.end(REQUEST_PID, r.uid, "request",
                                finish=FINISH_DEADLINE)
            self._failed.append(r)
        chunks = self._admit(now)
        # retire BEFORE decoding: a prefill that already satisfied the
        # request (max_new_tokens == 1, or EOS as the first token) must
        # not decode a surplus token
        self._retire()
        active = [i for i, r in enumerate(self.rows)
                  if r is not None and i not in self._prefilling]
        if not active:
            self.sched.account(chunks, 0)
            return 0
        if self.paged:
            active = self._ensure_room(active)
            # drain queued COW copies in the SAME tick they were queued,
            # even when every row got preempted: a stale copy whose
            # target was released and re-allocated next tick would
            # clobber the new occupant's freshly prefilled K/V
            self._drain_cow()
            if not active:
                self.sched.account(chunks, 0)
                return 0
            t_dec = time.perf_counter()
            dec_tr0 = self.tracer.now()
            if self.spec is not None:
                # speculative tick: draft-propose + block-verify commits
                # 1..spec_k+1 tokens per row, bitwise what the baseline
                # path below would have emitted (spec_decode)
                self.spec.tick(active)
            else:
                table, lengths = self.kv.table, self.kv.lengths
                if self._prefilling:
                    # rows mid-prefill must not write garbage K/V into
                    # their (real) pages, nor attend: point them at the
                    # trash page
                    table = table.copy()
                    lengths = lengths.copy()
                    for i in self._prefilling:
                        table[i, :] = TRASH_PAGE
                        lengths[i] = 0
                logits, self.pages = self._decode_paged(
                    self.params, jnp.asarray(self._tokens), self.pages,
                    jnp.asarray(table), jnp.asarray(lengths))
                if self._shard_counts is not None:
                    self._shard_counts["decode_dispatches"] += 1
                # ONE fused dispatch for the whole decode batch;
                # inactive rows are sampled-and-discarded (the counter-
                # based PRNG makes discarded draws side-effect free)
                res = self._run_sampler(logits[:, -1], slice(None),
                                        "decode")
                for i in active:
                    self.kv.advance(i)
                    self._commit_token(i, self.rows[i], res, i)
        else:
            t_dec = time.perf_counter()
            dec_tr0 = self.tracer.now()
            logits, self.cache = self._decode(
                self.params, jnp.asarray(self._tokens), self.cache)
            res = self._run_sampler(logits[:, -1], slice(None), "decode")
            for i in active:
                self._commit_token(i, self.rows[i], res, i)
        self._h_tick.observe(time.perf_counter() - t_dec)
        if self.tracer.enabled:
            for i in active:
                self.tracer.complete(REQUEST_PID, self.rows[i].uid,
                                     "decode_tick", dec_tr0,
                                     token=int(self._tokens[i, 0]))
        self._retire()
        self.sched.account(chunks, len(active))
        return len(active)

    def _stop_reason(self, r: Request) -> Optional[str]:
        """Terminal check for a decoding row: EOS or a stop sequence
        ("stop"), else the max_tokens budget ("length")."""
        if r.tokens and (r.tokens[-1] == self.eos_id
                         or sampling_lib.match_stop(r.tokens,
                                                    r.sampling.stop)):
            return FINISH_STOP
        if len(r.tokens) >= r.sampling.max_tokens:
            return FINISH_LENGTH
        return None

    def _retire(self) -> None:
        for i, r in enumerate(self.rows):
            if r is None or i in self._prefilling:
                continue
            reason = self._stop_reason(r)
            if reason is not None:
                self._finish(i, reason=reason)

    def pending(self) -> bool:
        """True while the engine has work: queued requests or occupied
        rows.  The public loop condition for callers driving their own
        ``step()`` loop (streamed serving).  On a shared scheduler only
        this engine's tenant lane counts."""
        if self.model_tag is not None:
            depth = self.sched.depth_by_model().get(self.model_tag, 0)
        else:
            depth = len(self.sched)
        return bool(depth or any(r is not None for r in self.rows))

    def cancel_queued(self) -> List[Request]:
        """Graceful-drain entry: remove every still-queued request (this
        engine's tenant lane only, on a shared scheduler) and mark it
        terminal with ``finish_reason="cancelled"`` — streaming clients
        see a terminal delta instead of a hung connection.  In-flight
        rows are untouched; keep ticking until ``pending()`` clears to
        let them finish."""
        now = _now_mono()
        out: List[Request] = []
        for r in self.sched.drain(model=self.model_tag):
            r.status = "cancelled"
            r.finish_reason = FINISH_CANCELLED
            r.finish_mono = now
            r.finish_time = _now_wall()
            self._counts["cancelled"] += 1
            self._counts["failed"] += 1
            if self.tracer.enabled:
                self.tracer.end(REQUEST_PID, r.uid, "queued")
                self.tracer.end(REQUEST_PID, r.uid, "request",
                                finish=FINISH_CANCELLED)
            self._failed.append(r)
            out.append(r)
        return out

    def run(self, max_ticks: int = 10000) -> List[Request]:
        ticks = 0
        while self.pending() and ticks < max_ticks:
            self.step()
            ticks += 1
        return self._done

    # ------------------------------------------------------------------
    @property
    def failed(self) -> List[Request]:
        """Requests refused (backpressure) or expired (deadline)."""
        return list(self._failed)

    @property
    def _n_preempt(self) -> int:
        """Legacy alias for the ``engine.preemptions`` counter."""
        return int(self._counts["preemptions"])

    @property
    def _n_ticks(self) -> int:
        """Legacy alias for the ``engine.ticks`` counter."""
        return int(self._counts["ticks"])

    def shutdown(self) -> None:
        """Final bookkeeping audit.  With ``debug_leak_check`` on a
        paged engine, runs the cache's refcount/leak audit over the
        now-idle pool; anomalies increment ``kv.leak_anomalies`` and
        the message lands in ``last_leak_error`` instead of raising
        (shutdown paths should report, not crash)."""
        if self.paged and self.debug_leak_check:
            try:
                self.kv.leak_check()
                if self.spec is not None:
                    self.spec.leak_check()   # draft pool audits too
            except AssertionError as e:
                self._leak_anomalies.inc()
                self.last_leak_error = str(e)

    def stats(self) -> Dict[str, Any]:
        # durations off the monotonic marks — NTP-step immune (the wall
        # *_time fields are display timestamps, never subtracted)
        lat = [r.finish_mono - r.submit_mono for r in self._done
               if r.finish_mono and r.submit_mono]
        ttft = [r.first_token_mono - r.submit_mono for r in self._done
                if r.first_token_mono and r.submit_mono]
        out = {
            "done": len(self._done),
            "failed": len(self._failed),
            # engine-level counter: per-request sums would miss requests
            # preempted (possibly mid-chunked-prefill) and still queued
            "preemptions": self._n_preempt,
            "tokens": sum(len(r.tokens) for r in self._done),
            "ticks": self._n_ticks,
            # why requests ended, and how often the fused sampler ran
            # (decode: exactly one dispatch per decoding tick, however
            # many distinct SamplingParams share the batch)
            "finish_reasons": dict(self._finish_counts),
            "sampler_dispatches": dict(self._sampler.dispatches),
            "sampler_time_s": round(self._sampler.time_s, 6),
        }
        if lat:
            out["latency_p50_s"] = float(np.percentile(lat, 50))
            out["latency_p99_s"] = float(np.percentile(lat, 99))
        if ttft:
            out["ttft_p50_s"] = float(np.percentile(ttft, 50))
            out["ttft_mean_s"] = float(np.mean(ttft))
        out.update(self.sched.snapshot())
        if self.paged:
            out["pages_in_use"] = self.kv.alloc.num_used
            out["pages_free"] = self.kv.alloc.num_free
            out.update(self.kv.prefix_stats())
            if self.spec is not None:
                out["spec"] = self.spec.stats()
        return out


def generate_batch(model: Model, params, prompts: List[np.ndarray],
                   max_new_tokens: int = 32, max_len: int = 512,
                   slots: int = 4, eos_id: int = 1,
                   extras: Optional[List[Dict]] = None,
                   sampling: Optional[List[SamplingParams]] = None,
                   **kwargs) -> List[List[int]]:
    """Convenience wrapper: submit all prompts, run to completion.

    ``sampling``: optional per-prompt SamplingParams (its max_tokens
    overrides ``max_new_tokens`` for that prompt).  All prompts are
    enqueued up front, so the queue bound is sized to the batch
    (backpressure is for live serving, not batch jobs)."""
    kwargs.setdefault("scheduler",
                      SchedulerConfig(max_queue=max(len(prompts), 1)))
    eng = Engine(model, params, slots=slots, max_len=max_len, eos_id=eos_id,
                 **kwargs)
    for i, p in enumerate(prompts):
        ok = eng.submit(Request(uid=i, prompt=np.asarray(p, np.int32),
                                max_new_tokens=max_new_tokens,
                                sampling=sampling[i] if sampling else None,
                                extras=extras[i] if extras else None))
        assert ok, f"request {i} rejected (queue/pool sizing)"
    done = eng.run()
    return [r.tokens for r in sorted(done, key=lambda r: r.uid)]
