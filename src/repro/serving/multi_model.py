"""Multi-model hosting: several registry models/variants served off ONE
shared page pool, ONE shared scheduler, and one metrics registry.

This is the system-level payoff of the compression ladder (HashedNets,
Chen et al.; Deep Compression end-to-end): many compressed variants fit
where one dense model cannot, so one box serves a catalog of policy
rungs — `MultiModelEngine` is the container that makes that concrete.

Architecture (everything below already existed; this class only wires
it together):

- **One physical page pool + one host allocator.**  Every hosted model
  gets its own `Engine` (own rows, page table, sampler, prefix tree)
  constructed over the SAME `PageAllocator` and the same device pool
  arrays (``Engine(page_allocator=..., shared_pages=...)``).  Page ids
  are globally unique, so sub-engines can never clobber each other;
  per-model ``page_quota`` caps any tenant's distinct-page footprint
  (quota pressure evicts that tenant's own prefix cache first, then
  preempts its own youngest row — never a neighbour's).
- **One shared scheduler.**  Class keys are ``(priority, model_tag)``,
  and each sub-engine admits/expires only its own lane
  (``pop_admissible(model=...)``), so a hot tenant's backlog cannot
  head-of-line-block a quiet one.  Per-tenant admission counters
  publish as ``sched.tenant.<name>.*``.
- **Per-model metric labels.**  Each sub-engine publishes into
  ``metrics.scoped("model.<name>")`` — its ``engine.*`` / ``kv.*``
  series appear as ``model.<name>.engine.*`` in the one shared
  registry; shared series (``sched.*``, the pool gauges maintained
  here) stay unscoped.
- **Pool hand-off per step.**  The decode/prefill dispatches donate the
  pool buffers (pages-in → pages-out), so the live pool object must be
  threaded through sub-engine steps: ``step()`` lends the pool to each
  engine in turn and takes back whatever it rebound.  Single-threaded
  by design — exactly one engine touches the pool at a time.

**Bitwise identity.**  A hosted model's emitted tokens are bitwise
identical to a dedicated single-model `Engine` fed the same requests in
the same order (pinned by tests/test_multi_model.py): K/V never depends
on physical page ids, preemption recovery is recompute-exact, sampling
is counter-based per (seed, token index), and each sub-engine draws
auto-seeds from its own stream.  Cross-tenant interference can change
WHEN a token is emitted (shared-pool preemptions), never WHICH.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.serving.api import RequestHandle
from repro.serving.engine import Engine, Request
from repro.serving.paged_cache import PageAllocator
from repro.serving.scheduler import Scheduler, SchedulerConfig


class _ModelSpec:
    __slots__ = ("name", "model", "params", "kwargs")

    def __init__(self, name, model, params, kwargs):
        self.name = name
        self.model = model
        self.params = params
        self.kwargs = kwargs


class MultiModelEngine:
    """Host several models on one shared page pool and scheduler.

    Usage::

        mm = MultiModelEngine(page_size=16, scheduler=SchedulerConfig())
        mm.add_model("dense", model_a, params_a, slots=4, max_len=256)
        mm.add_model("hashed", model_b, params_b, slots=4, max_len=256,
                     page_quota=48)
        h = mm.submit(Request(...), model="hashed")
        while mm.pending():
            mm.step()

    ``add_model`` only records the spec; the pool, allocator, and
    sub-engines are built lazily on the first ``submit``/``step`` (so
    the pool can be sized to the full roster).  Adding a model after
    that raises.
    """

    def __init__(self, *, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 attn_impl: str = "ref",
                 debug_leak_check: bool = False):
        self.page_size = page_size
        self._num_pages = num_pages
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer(enabled=False)
        self.attn_impl = attn_impl
        self.debug_leak_check = debug_leak_check
        self.sched = Scheduler(scheduler or SchedulerConfig(),
                               metrics=self.metrics)
        self._specs: Dict[str, _ModelSpec] = {}
        self._engines: Dict[str, Engine] = {}
        self._alloc: Optional[PageAllocator] = None
        self._pool = None
        self._built = False
        self._g_pages_used = self.metrics.gauge("kv.pages_in_use")
        self._g_pages_free = self.metrics.gauge("kv.pages_free")

    # ------------------------------------------------------------------
    def add_model(self, name: str, model, params, *, slots: int = 4,
                  max_len: int = 512, eos_id: int = 1, seed: int = 0,
                  page_quota: Optional[int] = None,
                  **engine_kwargs) -> None:
        """Register a model under ``name`` (the tenant tag clients put
        in ``Request.model`` / the HTTP ``model`` field).  Extra kwargs
        (prefix_cache, prefill_chunk, draft/spec_k, ...) pass through to
        the sub-`Engine`."""
        if self._built:
            raise RuntimeError("cannot add_model after the pool is "
                               "built (first submit/step)")
        if name in self._specs:
            raise ValueError(f"model {name!r} already hosted")
        if not name or "." in name:
            # tags become metric-name components (model.<name>.engine.*)
            raise ValueError(f"bad model tag: {name!r}")
        if model.decode_paged is None:
            raise ValueError(f"model {name!r} has no paged decode "
                             "(multi-model hosting is paged-only)")
        kwargs = dict(engine_kwargs, slots=slots, max_len=max_len,
                      eos_id=eos_id, seed=seed, page_quota=page_quota)
        self._specs[name] = _ModelSpec(name, model, params, kwargs)

    def models(self) -> List[str]:
        return list(self._specs)

    def __getitem__(self, name: str) -> Engine:
        self._ensure_built()
        return self._engines[name]

    # ------------------------------------------------------------------
    @classmethod
    def from_registry(cls, registry_root: str, names: List[str], *,
                      quotas: Optional[Dict[str, Optional[int]]] = None,
                      model_kwargs: Optional[Dict[str, Dict]] = None,
                      **kwargs) -> "MultiModelEngine":
        """Build a roster straight from the sha256 artifact registry:
        ``names`` are registered model names (``name@version`` pins a
        version; the tag strips the version).  ``quotas`` maps tag ->
        page quota; ``model_kwargs`` maps tag -> extra add_model kwargs
        (slots, max_len, seed, ...); remaining kwargs go to the
        MultiModelEngine itself."""
        from repro.artifact import io as artifact_io
        from repro.artifact import registry as artifact_registry
        mm = cls(**kwargs)
        for spec in names:
            entry = artifact_registry.resolve(registry_root, spec)
            tag = entry["name"]
            _, model, params = artifact_io.load_model(entry["path"])
            extra = dict((model_kwargs or {}).get(tag, {}))
            extra.setdefault("page_quota", (quotas or {}).get(tag))
            mm.add_model(tag, model, params, **extra)
        return mm

    # ------------------------------------------------------------------
    def _pool_geometry(self, model, num_pages: int):
        """Abstract shape/dtype tree of the model's page pool — hosted
        models must agree exactly (they share the physical buffers)."""
        shapes = jax.eval_shape(
            lambda: model.init_paged_cache(num_pages, self.page_size))
        return jax.tree_util.tree_map(lambda x: (x.shape, str(x.dtype)),
                                      shapes)

    def _ensure_built(self) -> None:
        if self._built:
            return
        if not self._specs:
            raise RuntimeError("no models added")
        self._built = True
        num_pages = self._num_pages
        if num_pages is None:
            # fully provision every tenant's rows to max_len, like the
            # single-model default (+1 shared trash page); pass an
            # explicit num_pages to oversubscribe
            total = 0
            for s in self._specs.values():
                max_len = -(-s.kwargs["max_len"] // self.page_size) \
                    * self.page_size
                total += s.kwargs["slots"] * (max_len // self.page_size)
            num_pages = total + 1
        self.num_pages = num_pages
        self._alloc = PageAllocator(num_pages)
        specs = list(self._specs.values())
        geo = self._pool_geometry(specs[0].model, num_pages)
        for s in specs[1:]:
            other = self._pool_geometry(s.model, num_pages)
            if other != geo:
                raise ValueError(
                    f"page-pool geometry mismatch: {specs[0].name!r} "
                    f"{geo} vs {s.name!r} {other} — hosted models must "
                    "share (layers, page_size, kv_heads, head_dim)")
        self._pool = specs[0].model.init_paged_cache(num_pages,
                                                     self.page_size)
        for s in specs:
            eng = Engine(s.model, s.params,
                         page_size=self.page_size, num_pages=num_pages,
                         scheduler=self.sched, attn_impl=self.attn_impl,
                         metrics=self.metrics.scoped(f"model.{s.name}"),
                         tracer=self.tracer,
                         debug_leak_check=self.debug_leak_check,
                         model_tag=s.name, page_allocator=self._alloc,
                         shared_pages=self._pool, **s.kwargs)
            self._engines[s.name] = eng

    # ------------------------------------------------------------------
    def submit(self, req: Request,
               model: Optional[str] = None) -> RequestHandle:
        """Route a request to its tenant engine.  ``model`` (or a
        pre-set ``req.model``) names the lane; unknown names raise
        KeyError.  The returned handle drives THIS engine's step (pool
        hand-off included), so iterating it is safe."""
        self._ensure_built()
        tag = model if model is not None else req.model
        if tag not in self._engines:
            raise KeyError(f"unknown model {tag!r}; hosted: "
                           f"{list(self._engines)}")
        req.model = tag
        h = self._engines[tag].submit(req)
        # handle-driven ticking must go through the pool hand-off
        h.engine = self
        return h

    def step(self) -> int:
        """One tick of every hosted engine, lending the (donated) pool
        to each in turn.  Returns total rows decoded."""
        self._ensure_built()
        decoded = 0
        for eng in self._engines.values():
            eng.pages = self._pool
            decoded += eng.step()
            self._pool = eng.pages
        self._g_pages_used.set(self._alloc.num_used)
        self._g_pages_free.set(self._alloc.num_free)
        return decoded

    def pending(self) -> bool:
        self._ensure_built()
        return any(e.pending() for e in self._engines.values())

    def run(self, max_ticks: int = 10000) -> List[Request]:
        ticks = 0
        while self.pending() and ticks < max_ticks:
            self.step()
            ticks += 1
        done: List[Request] = []
        for eng in self._engines.values():
            done.extend(eng._done)
        return done

    def cancel_queued(self) -> List[Request]:
        """Graceful drain: cancel every still-queued request across all
        tenants (terminal "cancelled" deltas); in-flight rows keep
        running — tick until ``pending()`` clears."""
        self._ensure_built()
        out: List[Request] = []
        for eng in self._engines.values():
            out.extend(eng.cancel_queued())
        return out

    def shutdown(self) -> None:
        for eng in self._engines.values():
            eng.shutdown()

    def stats(self) -> Dict[str, Any]:
        self._ensure_built()
        out: Dict[str, Any] = {
            "models": {},
            "num_pages": self.num_pages,
            "pages_in_use": self._alloc.num_used,
            "pages_free": self._alloc.num_free,
        }
        for name, eng in self._engines.items():
            s = eng.stats()
            s["pages_held"] = eng.kv.pages_held()
            s["page_quota"] = eng.kv.page_quota
            out["models"][name] = s
        out.update(self.sched.snapshot())
        out["queue_depth_by_model"] = self.sched.depth_by_model()
        return out
