"""Synthetic language-model token stream + host-sharded batching.

The LM examples and integration tests need a *learnable* token stream with
no external corpus.  We generate a deterministic order-2 Markov source over
the vocabulary: transition logits are a pure function of (seed, prev2,
prev1) via the same stateless mixers the paper's technique uses, so the
stream is (a) reproducible across hosts, (b) genuinely predictable — a
model that learns reduces cross-entropy well below log(V).

Host sharding: each JAX process draws disjoint sample indices
(sample_id = global_step * num_hosts + host_id), so the global batch is
i.i.d. across the fleet with zero coordination.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


def _mix(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64)
    x ^= x >> np.uint64(16)
    x *= np.uint64(0x85EBCA6B)
    x &= np.uint64(0xFFFFFFFF)
    x ^= x >> np.uint64(13)
    x *= np.uint64(0xC2B2AE35)
    x &= np.uint64(0xFFFFFFFF)
    x ^= x >> np.uint64(16)
    return x


def markov_sequences(seed: int, n: int, seq_len: int, vocab: int,
                     branch: int = 4) -> np.ndarray:
    """(n, seq_len+1) int32 token sequences from a hashed order-2 chain.

    Each (prev2, prev1) context has `branch` plausible successors chosen by
    hashing; the sampler picks among them with a fixed skewed distribution.
    Entropy ~ log(branch) * H(skew) << log(vocab).
    """
    rng = np.random.default_rng(np.random.SeedSequence([seed, n, seq_len]))
    out = np.empty((n, seq_len + 1), np.int64)
    out[:, 0] = rng.integers(0, vocab, size=n)
    out[:, 1] = rng.integers(0, vocab, size=n)
    # skewed choice over branch successors: p ~ 0.55, 0.25, 0.12, 0.08...
    probs = np.array([0.55, 0.25, 0.12, 0.08][:branch])
    probs = probs / probs.sum()
    for t in range(2, seq_len + 1):
        ctx = (out[:, t - 2] * np.int64(vocab) + out[:, t - 1])
        pick = rng.choice(branch, size=n, p=probs)
        h = _mix(ctx.astype(np.uint64) * np.uint64(2654435761)
                 + np.uint64(seed) + pick.astype(np.uint64)
                 * np.uint64(0x9E3779B9))
        out[:, t] = (h % np.uint64(vocab)).astype(np.int64)
    return out.astype(np.int32)


def batches(seed: int, batch: int, seq_len: int, vocab: int,
            host_id: int = 0, num_hosts: int = 1,
            start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite iterator of {tokens (B,S), targets (B,S)} for this host."""
    step = start_step
    while True:
        sample_seed = seed * 1_000_003 + step * num_hosts + host_id
        seqs = markov_sequences(sample_seed, batch, seq_len, vocab)
        yield {"tokens": seqs[:, :-1], "targets": seqs[:, 1:]}
        step += 1
