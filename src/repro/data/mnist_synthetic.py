"""Deterministic synthetic analogues of the paper's eight datasets.

The paper (Chen et al. 2015, §6) evaluates on MNIST, four Larochelle-2007
variants (ROT, BG-RAND, BG-IMG, BG-IMG-ROT) and two binary shape tasks
(RECT, CONVEX).  None are downloadable in this offline container, so we
generate structurally analogous data:

- ten fixed class *prototypes* (seeded low-frequency random blobs,
  thresholded to stroke-like masks) play the role of digit classes;
- samples = prototype, jittered (shift + small rotation + pixel dropout
  + noise), 28x28 grayscale in [0, 1], flattened to 784 dims;
- ROT applies uniform rotation in [0, 2pi) (harder, as in the paper);
- BG-RAND superimposes uniform noise backgrounds;
- BG-IMG superimposes smooth structured backgrounds ("image patches");
- BG-IMG-ROT composes both;
- RECT: wide-vs-tall rectangle outlines (binary);
- CONVEX: filled convex vs non-convex (union-of-discs) shapes (binary).

Split sizes follow the paper (12k/50k variants, 60k/10k original MNIST)
but are scalable via n_train/n_test for CPU benchmarking.  Everything is a
pure function of (dataset, split, size, seed): two hosts generate
byte-identical data, which the multi-host pipeline relies on.
"""
from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

IMG = 28
DIM = IMG * IMG
DATASETS = ("mnist", "basic", "rot", "bg-rand", "bg-img", "bg-img-rot",
            "rect", "convex")

PAPER_SIZES = {
    "mnist": (60000, 10000),
    "basic": (12000, 50000),
    "rot": (12000, 50000),
    "bg-rand": (12000, 50000),
    "bg-img": (12000, 50000),
    "bg-img-rot": (12000, 50000),
    "rect": (12000, 50000),
    "convex": (12000, 50000),
}


def _rng(*key_parts) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence(list(key_parts)))


def _smooth_field(rng, size=IMG, cutoff=5) -> np.ndarray:
    """Low-frequency random field in [0,1] via truncated Fourier basis."""
    spec = np.zeros((size, size), np.complex128)
    spec[:cutoff, :cutoff] = (rng.standard_normal((cutoff, cutoff))
                              + 1j * rng.standard_normal((cutoff, cutoff)))
    img = np.real(np.fft.ifft2(spec, s=(size, size)))
    lo, hi = img.min(), img.max()
    return (img - lo) / max(hi - lo, 1e-9)


@functools.lru_cache(maxsize=4)
def _prototypes(seed: int = 7, n_classes: int = 10) -> np.ndarray:
    """(10, 28, 28) stroke-like class prototypes."""
    protos = []
    for c in range(n_classes):
        rng = _rng(seed, 101, c)
        field = _smooth_field(rng, cutoff=6)
        # threshold band -> stroke-like mask, distinct per class
        lo = 0.40 + 0.02 * (c % 5)
        mask = ((field > lo) & (field < lo + 0.22)).astype(np.float64)
        protos.append(mask)
    return np.stack(protos)


def _rotate(img: np.ndarray, angle: float) -> np.ndarray:
    """Nearest-neighbour rotation about the image centre."""
    c = (IMG - 1) / 2.0
    ys, xs = np.mgrid[0:IMG, 0:IMG]
    ca, sa = np.cos(angle), np.sin(angle)
    sy = ca * (ys - c) - sa * (xs - c) + c
    sx = sa * (ys - c) + ca * (xs - c) + c
    syi = np.clip(np.rint(sy).astype(int), 0, IMG - 1)
    sxi = np.clip(np.rint(sx).astype(int), 0, IMG - 1)
    out = img[syi, sxi]
    out[(sy < -0.5) | (sy > IMG - 0.5) | (sx < -0.5) | (sx > IMG - 0.5)] = 0
    return out


def _digit_sample(rng, proto: np.ndarray, max_angle: float) -> np.ndarray:
    angle = rng.uniform(-max_angle, max_angle)
    img = _rotate(proto, angle)
    # small translation
    dy, dx = rng.integers(-2, 3, size=2)
    img = np.roll(np.roll(img, dy, axis=0), dx, axis=1)
    # stroke dropout + additive noise
    img = img * (rng.random(img.shape) > 0.08)
    img = img + 0.12 * rng.standard_normal(img.shape)
    return np.clip(img, 0.0, 1.0)


def _digits(dataset: str, split: str, n: int, seed: int
            ) -> Tuple[np.ndarray, np.ndarray]:
    rot = dataset in ("rot", "bg-img-rot")
    bg_rand = dataset == "bg-rand"
    bg_img = dataset in ("bg-img", "bg-img-rot")
    protos = _prototypes()
    rng = _rng(seed, hashs(dataset), hashs(split))
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    xs = np.empty((n, IMG, IMG), np.float32)
    max_angle = np.pi if rot else 0.25
    for i in range(n):
        img = _digit_sample(rng, protos[labels[i]], max_angle)
        if bg_rand:
            bg = rng.random((IMG, IMG))
            img = np.where(img > 0.25, img, 0.8 * bg)
        elif bg_img:
            bg = _smooth_field(rng, cutoff=4)
            img = np.where(img > 0.25, img, 0.85 * bg)
        xs[i] = img
    return xs.reshape(n, DIM), labels


def _rect(split: str, n: int, seed: int) -> Tuple[np.ndarray, np.ndarray]:
    rng = _rng(seed, hashs("rect"), hashs(split))
    xs = np.zeros((n, IMG, IMG), np.float32)
    labels = np.empty((n,), np.int32)
    for i in range(n):
        while True:
            h = rng.integers(4, 25)
            w = rng.integers(4, 25)
            if h != w:
                break
        y0 = rng.integers(0, IMG - h)
        x0 = rng.integers(0, IMG - w)
        img = np.zeros((IMG, IMG), np.float32)
        img[y0:y0 + h, x0] = 1.0
        img[y0:y0 + h, x0 + w - 1] = 1.0
        img[y0, x0:x0 + w] = 1.0
        img[y0 + h - 1, x0:x0 + w] = 1.0
        xs[i] = np.clip(img + 0.05 * rng.standard_normal(img.shape), 0, 1)
        labels[i] = int(h > w)   # 1 = tall, 0 = wide
    return xs.reshape(n, DIM), labels


def _convex(split: str, n: int, seed: int) -> Tuple[np.ndarray, np.ndarray]:
    rng = _rng(seed, hashs("convex"), hashs(split))
    ys, xs_grid = np.mgrid[0:IMG, 0:IMG]
    xs = np.zeros((n, IMG, IMG), np.float32)
    labels = rng.integers(0, 2, size=n).astype(np.int32)
    for i in range(n):
        if labels[i]:  # convex: one filled disc (intersection of halfplanes)
            cy, cx = rng.uniform(8, 20, size=2)
            r = rng.uniform(4, 9)
            img = (((ys - cy) ** 2 + (xs_grid - cx) ** 2) <= r * r)
        else:          # non-convex: union of two separated discs
            while True:
                c1 = rng.uniform(6, 22, size=2)
                c2 = rng.uniform(6, 22, size=2)
                if np.hypot(*(c1 - c2)) > 9:
                    break
            r1, r2 = rng.uniform(3.5, 6.5, size=2)
            img = ((((ys - c1[0]) ** 2 + (xs_grid - c1[1]) ** 2) <= r1 * r1)
                   | (((ys - c2[0]) ** 2 + (xs_grid - c2[1]) ** 2) <= r2 * r2))
        xs[i] = np.clip(img.astype(np.float32)
                        + 0.05 * rng.standard_normal(img.shape), 0, 1)
    return xs.reshape(n, DIM), labels


def hashs(s: str) -> int:
    """Deterministic small string hash (builtin hash is process-salted)."""
    import zlib
    return zlib.crc32(s.encode()) & 0x7FFFFFFF


def num_classes(dataset: str) -> int:
    return 2 if dataset in ("rect", "convex") else 10


def load(dataset: str, split: str = "train", n: int | None = None,
         seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x (n, 784) float32 in [0,1], y (n,) int32), deterministic."""
    if dataset not in DATASETS:
        raise KeyError(f"unknown dataset {dataset!r}; known {DATASETS}")
    if n is None:
        n = PAPER_SIZES[dataset][0 if split == "train" else 1]
    if dataset == "rect":
        return _rect(split, n, seed)
    if dataset == "convex":
        return _convex(split, n, seed)
    return _digits(dataset, split, n, seed)
