from repro.data import mnist_synthetic, lm_stream, pipeline  # noqa: F401
