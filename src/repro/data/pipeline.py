"""Device placement + prefetch for host-local batches.

`shard_batch` places a host-local numpy batch onto the active mesh with the
train-step's input sharding (batch axis over ("pod","data")).  In a real
multi-host fleet each process feeds only its addressable shard
(`jax.make_array_from_process_local_data`); single-process (CI, this
container) degenerates to a device_put.

`Prefetcher` overlaps host-side generation with device compute by one step
(double buffering) — the standard input-pipeline latency hiding.
"""
from __future__ import annotations

import collections
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.nn import layers as L


def batch_pspec(ndim: int) -> P:
    return P(L.BATCH, *([None] * (ndim - 1)))


def shard_batch(batch: Dict[str, np.ndarray], mesh: Optional[Mesh] = None):
    """Host-local numpy batch -> global sharded jax.Arrays."""
    mesh = mesh or shd.active_mesh()
    if mesh is None:
        return jax.tree.map(jax.numpy.asarray, batch)

    def place(x):
        spec = shd.resolve_spec(batch_pspec(x.ndim))
        ns = NamedSharding(mesh, spec)
        if jax.process_count() == 1:
            return jax.device_put(x, ns)
        return jax.make_array_from_process_local_data(ns, x)

    return jax.tree.map(place, batch)


class Prefetcher:
    """One-deep background prefetch of (generate + device_put)."""

    def __init__(self, it: Iterator, place: Callable = shard_batch,
                 depth: int = 2):
        self._it = it
        self._place = place
        self._q: collections.deque = collections.deque()
        self._depth = depth
        self._lock = threading.Lock()
        self._exc: Optional[BaseException] = None
        self._done = False
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._sem = threading.Semaphore(0)
        self._space = threading.Semaphore(depth)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                placed = self._place(item)
                self._space.acquire()
                with self._lock:
                    self._q.append(placed)
                self._sem.release()
            self._done = True
        except BaseException as e:  # noqa: BLE001 - surfaced on next()
            self._exc = e
        self._sem.release()

    def __iter__(self):
        return self

    def __next__(self):
        self._sem.acquire()
        # drain queued items FIRST: the producer may have already hit the
        # end/an error while earlier items are still undelivered (a race
        # that surfaced as item loss under CPU contention)
        with self._lock:
            if self._q:
                item = self._q.popleft()
                self._space.release()
                return item
        if self._exc is not None:
            raise self._exc
        raise StopIteration
