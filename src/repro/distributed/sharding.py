"""Logical-axis sharding: rules mapping logical names -> physical mesh axes.

Model code annotates params/activations with *logical* PartitionSpecs
(names from repro.nn.layers: "batch", "fsdp", "tp", "expert", "seq").  The
launcher activates a rule set for a concrete mesh; `resolve` / `constraint`
translate logical specs to physical ones.  Outside an active context (unit
tests on one device) constraints are no-ops.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

# default rule sets -------------------------------------------------------
SINGLE_POD_RULES: Dict[str, Axis] = {
    "batch": ("data",),
    "cache_batch": ("data",),
    "fsdp": "data",
    "tp": "model",
    "expert": "model",
    "seq": "data",
    "tp_kv": "model",   # launch/specs.rules_for flips tp_kv/tp_hd
    "tp_hd": None,      # by kv-head divisibility per arch
}

MULTI_POD_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "cache_batch": ("pod", "data"),
    "fsdp": "data",
    "tp": "model",
    "expert": "model",
    "seq": "data",
    "tp_kv": "model",
    "tp_hd": None,
}


def serving_rules(num_q_heads: int, num_kv_heads: int,
                  mesh: Mesh) -> Dict[str, Axis]:
    """Rule set for tensor-parallel SERVING (engine decode/prefill).

    Bitwise token-identity is the serving contract, which rules out any
    resolution that introduces a psum over a contraction dim (all-reduce
    reassociates fp addition).  Attention is per-head independent, so
    only the KV-head axis of the page pool (and the matching q/k/v head
    dims inside the shard_mapped kernel dispatch) shards over "model";
    everything else — activations, dense weights, the o/FFN projections
    — stays replicated and the sharded attention outputs are
    all-gathered (an exact concat) before the replicated o-projection.

    ``tp_kv`` resolves to "model" only when both head counts divide the
    model-axis size (GQA shards q-heads g-per-kv-head alongside);
    otherwise the pool is replicated too and sharding degenerates to
    single-device math.  ``tp_hd`` never shards in serving: splitting
    head_dim would split the softmax contraction.
    """
    tp = mesh.shape.get("model", 1)
    divisible = (tp > 1 and num_kv_heads % tp == 0
                 and num_q_heads % tp == 0)
    return {
        "batch": None,
        "cache_batch": None,
        "fsdp": None,
        "tp": None,
        "expert": None,
        "seq": None,
        "tp_kv": "model" if divisible else None,
        "tp_hd": None,
    }


# Placement-time rules for the hashed banks only (see
# ``nn.layers.bank_pspec``): banks materialize via gather — exact under
# sharding — so they MAY shard over "model" even though runtime dense
# weights must not.  Used by the engine when device_put-ing params onto
# a serving mesh, never activated during traced computation.
SERVING_BANK_RULES: Dict[str, Axis] = {
    "batch": None,
    "cache_batch": None,
    "fsdp": None,
    "tp": "model",
    "expert": None,
    "seq": None,
    "tp_kv": None,
    "tp_hd": None,
}


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, Axis]] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[Dict[str, Axis]] = None):
    """Activate (mesh, rules) for logical-spec resolution."""
    if rules is None:
        rules = (MULTI_POD_RULES if "pod" in mesh.axis_names
                 else SINGLE_POD_RULES)
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, dict(rules)
    try:
        with mesh:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def resolve_spec(spec: P, rules: Optional[Dict[str, Axis]] = None) -> P:
    """Translate a logical PartitionSpec into a physical one."""
    rules = rules if rules is not None else (_CTX.rules or {})

    def res(axis):
        if axis is None:
            return None
        if isinstance(axis, (tuple, list)):
            out = []
            for a in axis:
                r = res(a)
                if r is None:
                    continue
                out.extend(r if isinstance(r, (tuple, list)) else [r])
            return tuple(out) or None
        return rules.get(axis, None)

    return P(*[res(a) for a in spec])


def resolve_tree(spec_tree, mesh: Optional[Mesh] = None,
                 rules: Optional[Dict[str, Axis]] = None):
    """Logical spec pytree -> NamedSharding pytree for `mesh`."""
    mesh = mesh or _CTX.mesh
    if mesh is None:
        raise RuntimeError("no active mesh; wrap in sharding.use_mesh(...)")
    rules = rules if rules is not None else _CTX.rules
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_spec(s, rules)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def constraint(x, spec: P):
    """with_sharding_constraint with logical names; no-op without a mesh."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    phys = resolve_spec(spec)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, phys))
