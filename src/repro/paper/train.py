"""Paper-faithful training loop for the MLP experiments.

Chen et al. §6: SGD, minibatch 50, momentum, dropout, ReLU; hyperparameters
tuned with Bayesian optimization.  Offline deviation (DESIGN.md §6): we use
a fixed, hand-tuned recipe (momentum 0.9, cosine-decayed LR) shared across
all methods — fair comparison, no per-method tuning advantage.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.paper import mlp


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    epochs: int = 30
    batch: int = 50                 # paper's minibatch size
    lr: float = 0.05
    momentum: float = 0.9
    weight_decay: float = 0.0
    # dark knowledge
    distill_alpha: float = 0.5
    distill_temp: float = 4.0


def _lr_at(cfg: TrainConfig, step: int, total: int) -> float:
    prog = step / max(total, 1)
    return cfg.lr * (0.5 * (1 + np.cos(np.pi * prog)))


@functools.partial(jax.jit, static_argnums=(0, 1))
def _step(spec: mlp.MLPSpec, use_soft: bool, params, mu, x, y, soft, key,
          lr, alpha, temp, momentum):
    def loss_fn(p):
        logits = mlp.apply(spec, p, x, key=key, train=True)
        if use_soft:
            return mlp.distill_loss(logits, y, soft, alpha, temp)
        return mlp.xent(logits, y)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    mu = jax.tree.map(lambda m, g: momentum * m + g, mu, grads)
    params = jax.tree.map(lambda p, m: p - lr * m, params, mu)
    return params, mu, loss


@functools.partial(jax.jit, static_argnums=(0,))
def _eval_logits(spec: mlp.MLPSpec, params, x):
    return mlp.apply(spec, params, x, train=False)


def evaluate(spec: mlp.MLPSpec, params, x: np.ndarray, y: np.ndarray,
             batch: int = 2000) -> float:
    """Test error rate in [0, 1]."""
    wrong = 0
    for i in range(0, len(x), batch):
        logits = _eval_logits(spec, params, jnp.asarray(x[i:i + batch]))
        wrong += int(np.sum(np.argmax(np.asarray(logits), -1)
                            != y[i:i + batch]))
    return wrong / len(x)


def soft_targets(spec: mlp.MLPSpec, params, x: np.ndarray,
                 temperature: float, batch: int = 2000) -> np.ndarray:
    """Teacher's softened softmax over the training set (DK targets)."""
    outs = []
    for i in range(0, len(x), batch):
        logits = _eval_logits(spec, params, jnp.asarray(x[i:i + batch]))
        outs.append(np.asarray(
            jax.nn.softmax(logits.astype(jnp.float32) / temperature)))
    return np.concatenate(outs)


def fit(spec: mlp.MLPSpec, x: np.ndarray, y: np.ndarray,
        cfg: TrainConfig = TrainConfig(), seed: int = 0,
        soft: Optional[np.ndarray] = None,
        x_test: Optional[np.ndarray] = None,
        y_test: Optional[np.ndarray] = None,
        log_every: int = 0) -> Tuple[list, Dict]:
    """Train; returns (params, history)."""
    key = jax.random.PRNGKey(seed)
    key, kinit = jax.random.split(key)
    params = mlp.init(spec, kinit)
    mu = jax.tree.map(jnp.zeros_like, params)

    n = len(x)
    steps_per_epoch = max(1, n // cfg.batch)
    total = cfg.epochs * steps_per_epoch
    rng = np.random.default_rng(seed)
    use_soft = soft is not None
    if not use_soft:
        soft_all = np.zeros((n, int(y.max()) + 1), np.float32)
    else:
        soft_all = soft

    hist = {"loss": [], "test_err": []}
    step = 0
    for epoch in range(cfg.epochs):
        perm = rng.permutation(n)
        for i in range(steps_per_epoch):
            idx = perm[i * cfg.batch:(i + 1) * cfg.batch]
            key, k = jax.random.split(key)
            lr = _lr_at(cfg, step, total)
            params, mu, loss = _step(
                spec, use_soft, params, mu,
                jnp.asarray(x[idx]), jnp.asarray(y[idx]),
                jnp.asarray(soft_all[idx]), k,
                jnp.float32(lr), jnp.float32(cfg.distill_alpha),
                jnp.float32(cfg.distill_temp), jnp.float32(cfg.momentum))
            step += 1
        hist["loss"].append(float(loss))
        if log_every and (epoch + 1) % log_every == 0 and x_test is not None:
            err = evaluate(spec, params, x_test, y_test)
            hist["test_err"].append(err)
            print(f"  epoch {epoch+1:3d} loss {float(loss):.4f} "
                  f"test_err {err*100:.2f}%", flush=True)
    return params, hist


def run_method(method: str, dims, compression: float,
               x, y, x_test, y_test, cfg: TrainConfig = TrainConfig(),
               seed: int = 0, teacher=None) -> Dict:
    """One (method, compression) cell of the paper's tables.

    method in {hashed, hashed_dk, nn, dk, rer, lrd}; `teacher` is
    (spec, params) of the compression-1 dense net for the *_dk variants.
    """
    base = dict(dropout=0.3, input_dropout=0.1, seed=seed)
    soft = None
    if method in ("nn", "dk"):
        eq_dims = mlp.equivalent_dense_dims(dims, compression)
        spec = mlp.MLPSpec(eq_dims, method="dense", **base)
    elif method in ("hashed", "hashed_dk"):
        spec = mlp.MLPSpec(tuple(dims), method="hashed",
                           compression=compression, **base)
    elif method == "rer":
        spec = mlp.MLPSpec(tuple(dims), method="rer",
                           compression=compression, **base)
    elif method == "lrd":
        spec = mlp.MLPSpec(tuple(dims), method="lrd",
                           compression=compression, **base)
    else:
        raise ValueError(method)

    if method in ("dk", "hashed_dk"):
        assert teacher is not None, "DK needs a compression-1 teacher"
        tspec, tparams = teacher
        soft = soft_targets(tspec, tparams, x, cfg.distill_temp)

    params, hist = fit(spec, x, y, cfg=cfg, seed=seed, soft=soft)
    err = evaluate(spec, params, x_test, y_test)
    return {"method": method, "compression": compression,
            "test_err": err, "free_params": spec.free_params(),
            "dims": spec.dims}
