from repro.paper import mlp, train  # noqa: F401
