"""Paper-faithful MLPs (Chen et al. 2015 §6): HashNet + all baselines.

Methods, at a common storage budget K^l per layer (counted strictly in
*free parameters*, biases included, exactly as the paper counts):

- ``hashed`` — HashedNets: V[i,j] = xi(i,j) * w[h(i,j)], dedicated hash
  functions per layer (paper Eq. 7), ReLU, dropout on hidden activations.
- ``dense`` — standard fully-connected net (used for the compression-1
  teacher and, with shrunk hidden widths, the Equivalent-Size NN baseline).
- ``rer`` — Random Edge Removal (Ciresan et al. 2011): a fixed random
  connectivity mask at density = compression; the mask is *recomputed from
  the hash*, so only surviving weights count toward storage.
- ``lrd`` — Low-Rank Decomposition (Denil et al. 2013): V = U @ G with G
  fixed Gaussian (std 1/sqrt(n_in), regenerated from seed, storage-free per
  the paper's accounting) and U learned, rank chosen to meet the budget.

Dark Knowledge (Hinton et al. 2014 / Ba & Caruana 2014) is a *training
mode* (soft targets from a compression-1 teacher), implemented in
``repro.paper.train.distill_targets`` and usable with any method, matching
the paper's HashNet_DK and DK rows.

Parameters are pytrees of f32 jnp arrays; all forward passes are pure.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashed as H
from repro.core.hashing import derive_seed


@dataclasses.dataclass(frozen=True)
class MLPSpec:
    dims: Tuple[int, ...]           # e.g. (784, 1000, 10) for "3 layers"
    method: str = "dense"           # dense | hashed | rer | lrd
    compression: float = 1.0        # storage budget fraction per layer
    dropout: float = 0.3            # hidden-layer dropout (paper trains with)
    input_dropout: float = 0.1
    seed: int = 0

    @property
    def n_layers(self) -> int:
        return len(self.dims) - 1

    def layer_budget(self, l: int) -> int:
        """K^l: free weights for layer l under the compression budget."""
        full = self.dims[l] * self.dims[l + 1]
        return max(1, int(round(self.compression * full)))

    def hashed_spec(self, l: int) -> H.HashedSpec:
        return H.HashedSpec(
            virtual_shape=(self.dims[l], self.dims[l + 1]),
            compression=self.layer_budget(l) / (self.dims[l] * self.dims[l + 1]),
            mode="element",
            seed=derive_seed(self.seed, 0xAB, l),   # dedicated h^l per layer
            panel_cols=0,                            # paper: global buckets
        )

    def lrd_rank(self, l: int) -> int:
        """budget = rank * min(n_in, n_out): the *learned* factor sits on
        the smaller side (maximizes rank; otherwise a wide->narrow layer
        degenerates to rank 1 with a fixed output direction)."""
        return max(1, self.layer_budget(l) // min(self.dims[l],
                                                  self.dims[l + 1]))

    def lrd_learn_left(self, l: int) -> bool:
        """True: learn U (n_in, r), fix G (r, n_out); False: the reverse."""
        return self.dims[l] <= self.dims[l + 1]

    def free_params(self) -> int:
        """Stored parameter count (the paper's x-axis)."""
        total = 0
        for l in range(self.n_layers):
            if self.method == "dense":
                total += self.dims[l] * self.dims[l + 1]
            elif self.method == "hashed":
                total += self.hashed_spec(l).num_buckets
            elif self.method == "rer":
                total += self.layer_budget(l)
            elif self.method == "lrd":
                total += self.lrd_rank(l) * min(self.dims[l],
                                                self.dims[l + 1])
            total += self.dims[l + 1]  # bias
        return total


def equivalent_dense_dims(dims: Sequence[int], compression: float
                          ) -> Tuple[int, ...]:
    """The paper's Equivalent-Size NN: shrink every hidden layer by a common
    factor until stored params match the budget (weights + biases)."""
    dims = tuple(dims)
    if len(dims) == 2:
        return dims

    def params_at(h: float) -> float:
        ds = [dims[0]] + [max(1.0, h)] * (len(dims) - 2) + [dims[-1]]
        return sum(ds[i] * ds[i + 1] + ds[i + 1] for i in range(len(ds) - 1))

    target = compression * params_at(dims[1])
    lo, hi = 1.0, float(dims[1])
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if params_at(mid) > target:
            hi = mid
        else:
            lo = mid
    h = max(1, int(round(lo)))
    return (dims[0],) + (h,) * (len(dims) - 2) + (dims[-1],)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _rer_mask(spec: MLPSpec, l: int) -> jnp.ndarray:
    """Fixed random connectivity mask at density=compression; derived from
    the stateless hash so it is never stored (same trick as the paper)."""
    from repro.core import hashing
    i = jnp.arange(spec.dims[l], dtype=jnp.int32)[:, None]
    j = jnp.arange(spec.dims[l + 1], dtype=jnp.int32)[None, :]
    h = hashing.hash_key(i, j, derive_seed(spec.seed, 0xE, l))
    # keep edge iff h < compression * 2^32
    thresh = np.uint32(min(0xFFFFFFFF, int(spec.compression * 2.0 ** 32)))
    return (h < thresh).astype(jnp.float32)


def _lrd_fixed(spec: MLPSpec, l: int) -> jnp.ndarray:
    """Fixed Gaussian factor, std 1/sqrt(n_in) (paper §6): shape (r, n_out)
    when the left factor is learned, (n_in, r) when the right is."""
    r = spec.lrd_rank(l)
    key = jax.random.PRNGKey(derive_seed(spec.seed, 0x1d, l))
    shape = ((r, spec.dims[l + 1]) if spec.lrd_learn_left(l)
             else (spec.dims[l], r))
    return (jax.random.normal(key, shape, jnp.float32)
            / math.sqrt(spec.dims[l]))


def init(spec: MLPSpec, key) -> List[dict]:
    params = []
    for l in range(spec.n_layers):
        key, k = jax.random.split(key)
        n_in, n_out = spec.dims[l], spec.dims[l + 1]
        scale = 1.0 / math.sqrt(n_in)
        b = jnp.zeros((n_out,), jnp.float32)
        if spec.method == "hashed":
            hs = spec.hashed_spec(l)
            params.append({"w": H.init(k, hs, scale=scale), "b": b})
        elif spec.method == "rer":
            w = jax.random.normal(k, (n_in, n_out), jnp.float32) * scale
            params.append({"w": w, "b": b})
        elif spec.method == "lrd":
            # Var(V) = r * Var(learned) * Var(fixed) with Var(fixed)=1/n_in;
            # learned ~ N(0, 1/r) keeps the virtual init at the dense scale.
            r = spec.lrd_rank(l)
            shape = (n_in, r) if spec.lrd_learn_left(l) else (r, n_out)
            u = (jax.random.normal(k, shape, jnp.float32)
                 / math.sqrt(max(r, 1)))
            params.append({"u": u, "b": b})
        else:
            w = jax.random.normal(k, (n_in, n_out), jnp.float32) * scale
            params.append({"w": w, "b": b})
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer(spec: MLPSpec, l: int, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if spec.method == "hashed":
        y = H.matmul(x, p["w"], spec.hashed_spec(l), path="materialize")
    elif spec.method == "rer":
        y = x @ (p["w"] * _rer_mask(spec, l))
    elif spec.method == "lrd":
        if spec.lrd_learn_left(l):
            y = (x @ p["u"]) @ _lrd_fixed(spec, l)
        else:
            y = (x @ _lrd_fixed(spec, l)) @ p["u"]
    else:
        y = x @ p["w"]
    return y + p["b"]


def apply(spec: MLPSpec, params: List[dict], x: jnp.ndarray,
          key=None, train: bool = False) -> jnp.ndarray:
    """x (B, 784) -> logits (B, C).  ReLU + dropout as in the paper."""
    drop = train and key is not None
    if drop and spec.input_dropout > 0:
        key, k = jax.random.split(key)
        keep = 1.0 - spec.input_dropout
        x = x * jax.random.bernoulli(k, keep, x.shape) / keep
    for l in range(spec.n_layers):
        x = _layer(spec, l, params[l], x)
        if l < spec.n_layers - 1:
            x = jax.nn.relu(x)
            if drop and spec.dropout > 0:
                key, k = jax.random.split(key)
                keep = 1.0 - spec.dropout
                x = x * jax.random.bernoulli(k, keep, x.shape) / keep
    return x


def xent(logits, labels) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))


def distill_loss(logits, labels, soft_targets, alpha: float = 0.5,
                 temperature: float = 4.0) -> jnp.ndarray:
    """Dark-Knowledge combined objective (paper §6: weighted combination of
    original labels and softened teacher softmax)."""
    hard = xent(logits, labels)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32) / temperature)
    soft = -jnp.mean(jnp.sum(soft_targets * logp, axis=-1)) * temperature ** 2
    return alpha * hard + (1.0 - alpha) * soft
