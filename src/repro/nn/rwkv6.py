"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Recurrence per head (key dim N, value dim N):
    o_t = r_t @ (S_{t-1} + outer(u * k_t, v_t))
    S_t = diag(w_t) @ S_{t-1} + outer(k_t, v_t)
with w_t = exp(-exp(wraw_t)) in (0,1) *data-dependent* per key channel
(the RWKV-6 contribution vs RWKV-5), produced by a LoRA on the token-shifted
input.  Training uses lax.scan over time (compile-size friendly); decode is
the O(1) single-step update.  Projections are hashed-capable; the tiny
data-dependent mixers (LoRA) stay dense (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import hashed as H
from repro.nn import layers as L

_MIX = ("r", "k", "v", "w", "g")


@dataclasses.dataclass(frozen=True)
class RWKV6Plan:
    d_model: int
    head_dim: int = 64
    lora_dim: int = 32
    decay_lora_dim: int = 64
    dtype: Any = jnp.bfloat16
    hash_r: Optional[H.HashedSpec] = None
    hash_k: Optional[H.HashedSpec] = None
    hash_v: Optional[H.HashedSpec] = None
    hash_g: Optional[H.HashedSpec] = None
    hash_o: Optional[H.HashedSpec] = None
    hash_path: str = "auto"

    @property
    def num_heads(self) -> int:
        return self.d_model // self.head_dim


def init(plan: RWKV6Plan, key):
    d = plan.d_model
    ks = iter(jax.random.split(key, 24))
    params, specs = {}, {}

    def lin(name, hspec, in_d=d, out_d=d, ps=(L.FSDP, L.TP)):
        p, s = L.linear_init(
            L.LinearPlan(in_d, out_d, hashed=hspec, pspec=ps,
                         dtype=plan.dtype, hash_path=plan.hash_path),
            next(ks))
        params[name], specs[name] = p, s

    lin("r", plan.hash_r)
    lin("k", plan.hash_k)
    lin("v", plan.hash_v)
    lin("g", plan.hash_g)
    lin("o", plan.hash_o, ps=(L.TP, L.FSDP))

    # token-shift ddlerp mixers: base mus + low-rank data dependence
    params["mu_x"] = jnp.zeros((d,), jnp.float32)
    specs["mu_x"] = P(None)
    params["mu"] = jnp.zeros((len(_MIX), d), jnp.float32)
    specs["mu"] = P(None, None)
    params["mix_w1"] = (jax.random.normal(next(ks), (d, len(_MIX), plan.lora_dim),
                                          jnp.float32) * 0.01).astype(jnp.float32)
    specs["mix_w1"] = P(L.FSDP, None, None)
    params["mix_w2"] = (jax.random.normal(next(ks), (len(_MIX), plan.lora_dim, d),
                                          jnp.float32) * 0.01).astype(jnp.float32)
    specs["mix_w2"] = P(None, None, L.FSDP)

    # data-dependent decay LoRA
    params["w0"] = jnp.full((d,), -6.0, jnp.float32)  # slow decay default
    specs["w0"] = P(None)
    params["decay_w1"] = (jax.random.normal(next(ks), (d, plan.decay_lora_dim),
                                            jnp.float32) * 0.01)
    specs["decay_w1"] = P(L.FSDP, None)
    params["decay_w2"] = (jax.random.normal(next(ks), (plan.decay_lora_dim, d),
                                            jnp.float32) * 0.01)
    specs["decay_w2"] = P(None, L.FSDP)

    params["u"] = (jax.random.normal(next(ks), (d,), jnp.float32) * 0.1)
    specs["u"] = P(None)

    # per-head group norm on the wkv output
    params["ln_x"], specs["ln_x"] = L.layernorm_init(plan.head_dim)
    return params, specs


def _token_shift(x, last):
    """shift right by one: [last, x_0, ..., x_{L-2}]; returns shifted, new_last."""
    shifted = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return shifted, x[:, -1, :]


def _ddlerp(plan, params, x, x_shift):
    """RWKV-6 data-dependent token-shift interpolation -> dict per target."""
    dx = x_shift - x
    xx = x + dx * params["mu_x"].astype(x.dtype)
    lora = jnp.tanh(jnp.einsum("bld,dmr->blmr", xx.astype(jnp.float32),
                               params["mix_w1"]))
    lora = jnp.einsum("blmr,mrd->blmd", lora, params["mix_w2"])
    out = {}
    for m, name in enumerate(_MIX):
        mu = params["mu"][m].astype(jnp.float32) + lora[:, :, m, :]
        out[name] = (x.astype(jnp.float32)
                     + dx.astype(jnp.float32) * mu).astype(x.dtype)
    return out


def _decay(plan, params, xw):
    lora = jnp.tanh(xw.astype(jnp.float32) @ params["decay_w1"]) \
        @ params["decay_w2"]
    wraw = params["w0"].astype(jnp.float32) + lora
    return jnp.exp(-jnp.exp(wraw))                            # (B,L,D) in (0,1)


def _wkv_scan(plan, r, k, v, w, u, state):
    """r,k,v,w: (B,L,H,N); u: (H,N); state: (B,H,N,N) fp32."""
    def step(s, args):
        rt, kt, vt, wt = args                                 # (B,H,N)
        kv = jnp.einsum("bhn,bhm->bhnm", kt, vt)              # key x value
        out = jnp.einsum("bhn,bhnm->bhm", rt,
                         s + u[None, :, :, None] * kv)
        s_new = wt[..., None] * s + kv
        return s_new, out

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    state, outs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(outs, 0, 1), state                    # (B,L,H,N)


def apply_time_mix(plan: RWKV6Plan, params, x, state):
    """x (B,L,D); state {"shift": (B,D), "wkv": (B,H,N,N)}."""
    b, l, d = x.shape
    h, n = plan.num_heads, plan.head_dim
    x_shift, new_last = _token_shift(x, state["shift"])
    mixed = _ddlerp(plan, params, x, x_shift)

    def proj(name, hspec, xin):
        return L.linear_apply(
            L.LinearPlan(d, d, hashed=hspec, dtype=plan.dtype,
                         hash_path=plan.hash_path), params[name], xin)

    r = proj("r", plan.hash_r, mixed["r"]).reshape(b, l, h, n)
    k = proj("k", plan.hash_k, mixed["k"]).reshape(b, l, h, n)
    v = proj("v", plan.hash_v, mixed["v"]).reshape(b, l, h, n)
    g = proj("g", plan.hash_g, mixed["g"])
    w = _decay(plan, params, mixed["w"]).reshape(b, l, h, n)
    u = params["u"].reshape(h, n)

    out, wkv_state = _wkv_scan(plan, r, k, v, w, u, state["wkv"])
    out = L.layernorm_apply(params["ln_x"], out.astype(plan.dtype))
    out = out.reshape(b, l, d) * jax.nn.silu(g)
    y = L.linear_apply(
        L.LinearPlan(d, d, hashed=plan.hash_o, dtype=plan.dtype,
                     hash_path=plan.hash_path), params["o"], out)
    return y, {"shift": new_last, "wkv": wkv_state}


def time_mix_state(plan: RWKV6Plan, batch: int):
    h, n = plan.num_heads, plan.head_dim
    return {"shift": jnp.zeros((batch, plan.d_model), plan.dtype),
            "wkv": jnp.zeros((batch, h, n, n), jnp.float32)}


def time_mix_state_pspec():
    return {"shift": P(L.BATCH, None), "wkv": P(L.BATCH, L.TP, None, None)}


# ---------------------------------------------------------------------------
# channel mix
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChannelMixPlan:
    d_model: int
    d_ff: int
    dtype: Any = jnp.bfloat16
    hash_k: Optional[H.HashedSpec] = None
    hash_v: Optional[H.HashedSpec] = None
    hash_r: Optional[H.HashedSpec] = None
    hash_path: str = "auto"


def channel_mix_init(plan: ChannelMixPlan, key):
    d, f = plan.d_model, plan.d_ff
    ks = iter(jax.random.split(key, 4))
    params, specs = {}, {}
    for name, i, o, hs, ps in [
        ("k", d, f, plan.hash_k, (L.FSDP, L.TP)),
        ("v", f, d, plan.hash_v, (L.TP, L.FSDP)),
        ("r", d, d, plan.hash_r, (L.FSDP, L.TP)),
    ]:
        p, s = L.linear_init(
            L.LinearPlan(i, o, hashed=hs, pspec=ps, dtype=plan.dtype,
                         hash_path=plan.hash_path), next(ks))
        params[name], specs[name] = p, s
    params["mu_k"] = jnp.full((d,), 0.5, jnp.float32)
    specs["mu_k"] = P(None)
    params["mu_r"] = jnp.full((d,), 0.5, jnp.float32)
    specs["mu_r"] = P(None)
    return params, specs


def channel_mix_apply(plan: ChannelMixPlan, params, x, state):
    """state: {"shift": (B, D)}."""
    d, f = plan.d_model, plan.d_ff
    x_shift, new_last = _token_shift(x, state["shift"])
    dx = (x_shift - x).astype(jnp.float32)
    xk = (x.astype(jnp.float32) + dx * params["mu_k"]).astype(x.dtype)
    xr = (x.astype(jnp.float32) + dx * params["mu_r"]).astype(x.dtype)
    k = L.linear_apply(L.LinearPlan(d, f, hashed=plan.hash_k,
                                    dtype=plan.dtype,
                                    hash_path=plan.hash_path),
                       params["k"], xk)
    k = jnp.square(jax.nn.relu(k))
    v = L.linear_apply(L.LinearPlan(f, d, hashed=plan.hash_v,
                                    dtype=plan.dtype,
                                    hash_path=plan.hash_path),
                       params["v"], k)
    r = L.linear_apply(L.LinearPlan(d, d, hashed=plan.hash_r,
                                    dtype=plan.dtype,
                                    hash_path=plan.hash_path),
                       params["r"], xr)
    return jax.nn.sigmoid(r.astype(jnp.float32)).astype(x.dtype) * v, \
        {"shift": new_last}


def channel_mix_state(plan: ChannelMixPlan, batch: int):
    return {"shift": jnp.zeros((batch, plan.d_model), plan.dtype)}
