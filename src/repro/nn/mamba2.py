"""Mamba-2 (SSD) block — chunked training form + O(1) decode state.

Used by the zamba2 hybrid architecture.  Projections are hashed-capable
(the paper technique applies to in/out projections; the SSM dynamics
parameters A/dt/D/conv are tiny and structurally constrained — left dense,
see DESIGN.md §5).

Shapes: x (B, L, d_model) -> y (B, L, d_model)
state: conv buffer (B, d_conv-1, conv_dim) + SSM state (B, H, P, N).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import hashed as H
from repro.nn import layers as L


@dataclasses.dataclass(frozen=True)
class Mamba2Plan:
    d_model: int
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    n_groups: int = 1
    chunk: int = 128
    dtype: Any = jnp.bfloat16
    hash_in: Optional[H.HashedSpec] = None
    hash_out: Optional[H.HashedSpec] = None
    hash_path: str = "auto"

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def num_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def in_dim(self) -> int:
        # z, x, B, C, dt
        return 2 * self.d_inner + 2 * self.n_groups * self.d_state \
            + self.num_heads


def init(plan: Mamba2Plan, key):
    ks = jax.random.split(key, 4)
    params, specs = {}, {}
    p, s = L.linear_init(
        L.LinearPlan(plan.d_model, plan.in_dim, hashed=plan.hash_in,
                     pspec=(L.FSDP, L.TP), dtype=plan.dtype,
                     hash_path=plan.hash_path), ks[0])
    params["in_proj"], specs["in_proj"] = p, s
    p, s = L.linear_init(
        L.LinearPlan(plan.d_inner, plan.d_model, hashed=plan.hash_out,
                     pspec=(L.TP, L.FSDP), dtype=plan.dtype,
                     hash_path=plan.hash_path), ks[1])
    params["out_proj"], specs["out_proj"] = p, s

    params["conv_w"] = (jax.random.normal(
        ks[2], (plan.d_conv, plan.conv_dim), jnp.float32)
        * (1.0 / math.sqrt(plan.d_conv))).astype(plan.dtype)
    specs["conv_w"] = P(None, L.TP)
    params["conv_b"] = jnp.zeros((plan.conv_dim,), plan.dtype)
    specs["conv_b"] = P(L.TP)

    h = plan.num_heads
    params["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32))
    specs["A_log"] = P(L.TP)
    params["D"] = jnp.ones((h,), jnp.float32)
    specs["D"] = P(L.TP)
    params["dt_bias"] = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(ks[3], (h,), jnp.float32,
                                   math.log(1e-3), math.log(1e-1)))))
    specs["dt_bias"] = P(L.TP)
    p, s = L.rmsnorm_init(plan.d_inner)
    params["norm"], specs["norm"] = p, s
    return params, specs


def _split(plan: Mamba2Plan, zxbcdt):
    di, g, n, h = plan.d_inner, plan.n_groups, plan.d_state, plan.num_heads
    z = zxbcdt[..., :di]
    xin = zxbcdt[..., di:2 * di]
    bb = zxbcdt[..., 2 * di:2 * di + g * n]
    cc = zxbcdt[..., 2 * di + g * n:2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n:]
    assert dt.shape[-1] == h
    return z, xin, bb, cc, dt


def _conv_train(plan, params, xbc):
    """Causal depthwise conv over (B, L, conv_dim)."""
    w = params["conv_w"].astype(jnp.float32)           # (d_conv, C)
    pad = plan.d_conv - 1
    xp = jnp.pad(xbc.astype(jnp.float32), ((0, 0), (pad, 0), (0, 0)))
    y = sum(xp[:, i:i + xbc.shape[1], :] * w[i] for i in range(plan.d_conv))
    return jax.nn.silu(y + params["conv_b"].astype(jnp.float32)
                       ).astype(xbc.dtype)


def _ssd_chunked(plan, xh, bb, cc, dt, a, init_state=None):
    """Chunked SSD scan.

    xh (B,L,H,P); bb/cc (B,L,G,N); dt (B,L,H) post-softplus; a (H,) negative.
    Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    bsz, l, h, p = xh.shape
    g, n = bb.shape[2], bb.shape[3]
    q = plan.chunk
    assert l % q == 0, (l, q)
    nc = l // q
    rep = h // g

    def reshape_c(t):
        return t.reshape(bsz, nc, q, *t.shape[2:])

    xh_c = xh.reshape(bsz, nc, q, h, p).astype(jnp.float32)
    b_c, c_c, dt_c = map(reshape_c, (bb, cc, dt))
    b_c = b_c.astype(jnp.float32)
    c_c = c_c.astype(jnp.float32)
    da = dt_c * a[None, None, None, :]                       # (B,NC,Q,H)
    da_cum = jnp.cumsum(da, axis=2)
    da_total = da_cum[:, :, -1, :]                           # (B,NC,H)

    # intra-chunk (quadratic within chunk):
    # Y[q] = sum_{s<=q} (C_q . B_s) exp(da_cum[q]-da_cum[s]) dt_s x_s
    lmat = jnp.tril(jnp.ones((q, q), bool))
    diff = da_cum[:, :, :, None, :] - da_cum[:, :, None, :, :]  # (B,NC,Q,S,H)
    decay = jnp.where(lmat[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcqgn,bcsgn->bcqsg", c_c, b_c)      # (B,NC,Q,S,G)
    scores = jnp.repeat(scores, rep, axis=-1)                # (B,NC,Q,S,H)
    y_intra = jnp.einsum("bcqsh,bcqsh,bcsh,bcshp->bcqhp",
                         scores, decay, dt_c, xh_c)

    # chunk-local end states: S_c = sum_s exp(da_total-da_cum_s) dt_s B_s x_s^T
    w_end = jnp.exp(da_total[:, :, None, :] - da_cum)        # (B,NC,Q,H)
    b_h = jnp.repeat(b_c, rep, axis=3) if g != h else b_c    # (B,NC,Q,H,N)
    state_loc = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn",
                           w_end * dt_c, b_h, xh_c)

    # inter-chunk recurrence over nc chunks
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(s, args):
        sl, dtot = args                                      # (B,H,P,N),(B,H)
        s_new = jnp.exp(dtot)[:, :, None, None] * s + sl
        return s_new, s                                      # emit entering state

    final_state, s_in = jax.lax.scan(
        step, init_state,
        (state_loc.transpose(1, 0, 2, 3, 4), da_total.transpose(1, 0, 2)))

    s_in = s_in.transpose(1, 0, 2, 3, 4)                     # (B,NC,H,P,N)
    c_h = jnp.repeat(c_c, rep, axis=3) if g != h else c_c
    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp",
                         c_h, jnp.exp(da_cum), s_in)
    y = (y_intra + y_inter).reshape(bsz, l, h, p)
    return y, final_state


def apply_train(plan: Mamba2Plan, params, x):
    """Full-sequence forward (training / prefill). Returns (y, state).

    Ragged prefill: sequences are right-padded to a multiple of the SSD
    chunk; padded positions get dt = 0, which makes them exact no-ops on
    the recurrence (decay exp(a*0) = 1, input term dt*B*x = 0), so the
    returned state equals the unpadded one bit-for-bit."""
    bsz, l0, _ = x.shape
    pad = (-l0) % plan.chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    l = l0 + pad
    zxbcdt = L.linear_apply(
        L.LinearPlan(plan.d_model, plan.in_dim, hashed=plan.hash_in,
                     dtype=plan.dtype, hash_path=plan.hash_path),
        params["in_proj"], x)
    z, xin, bb, cc, dt = _split(plan, zxbcdt)
    xbc_pre = jnp.concatenate([xin, bb, cc], axis=-1)
    xbc = _conv_train(plan, params, xbc_pre)
    xin = xbc[..., :plan.d_inner]
    bb = xbc[..., plan.d_inner:plan.d_inner + plan.n_groups * plan.d_state]
    cc = xbc[..., plan.d_inner + plan.n_groups * plan.d_state:]

    h, p, g, n = plan.num_heads, plan.head_dim, plan.n_groups, plan.d_state
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    if pad:
        valid = (jnp.arange(l) < l0)[None, :, None]
        dt = jnp.where(valid, dt, 0.0)
    a = -jnp.exp(params["A_log"])
    y, state = _ssd_chunked(
        plan,
        xin.reshape(bsz, l, h, p),
        bb.reshape(bsz, l, g, n),
        cc.reshape(bsz, l, g, n),
        dt, a)
    y = y + params["D"][None, None, :, None] \
        * xin.reshape(bsz, l, h, p).astype(jnp.float32)
    y = y.reshape(bsz, l, plan.d_inner).astype(plan.dtype)
    y = L.rmsnorm_apply(params["norm"], y) * jax.nn.silu(z)
    out = L.linear_apply(
        L.LinearPlan(plan.d_inner, plan.d_model, hashed=plan.hash_out,
                     dtype=plan.dtype, hash_path=plan.hash_path),
        params["out_proj"], y)
    if pad:
        out = out[:, :l0]
    # prefill -> decode handoff: conv buffer holds the last d_conv-1 *raw*
    # (pre-activation) conv inputs of the REAL sequence (l0, not padded l)
    tail = plan.d_conv - 1
    conv_state = jax.lax.dynamic_slice_in_dim(
        xbc_pre, l0 - tail, tail, axis=1) if l0 >= tail else jnp.pad(
        xbc_pre[:, :l0], ((0, 0), (tail - l0, 0), (0, 0)))
    return out, {"conv": conv_state.astype(plan.dtype), "ssm": state}


def init_state(plan: Mamba2Plan, batch: int):
    return {
        "conv": jnp.zeros((batch, plan.d_conv - 1, plan.conv_dim),
                          plan.dtype),
        "ssm": jnp.zeros((batch, plan.num_heads, plan.head_dim,
                          plan.d_state), jnp.float32),
    }


def state_pspec():
    return {"conv": P(L.CACHE_BATCH, None, L.TP),
            "ssm": P(L.CACHE_BATCH, L.TP, None, None)}


def apply_decode(plan: Mamba2Plan, params, x, state):
    """Single-token step. x (B, 1, d_model); returns (y, new_state)."""
    bsz = x.shape[0]
    zxbcdt = L.linear_apply(
        L.LinearPlan(plan.d_model, plan.in_dim, hashed=plan.hash_in,
                     dtype=plan.dtype, hash_path=plan.hash_path),
        params["in_proj"], x)
    z, xin, bb, cc, dt = _split(plan, zxbcdt[:, 0, :][:, None, :])
    xbc = jnp.concatenate([xin, bb, cc], axis=-1)[:, 0, :]   # (B, conv_dim)

    # rolling conv buffer
    conv_buf = state["conv"]
    window = jnp.concatenate([conv_buf, xbc[:, None, :]], axis=1)  # (B,dc,C)
    w = params["conv_w"].astype(jnp.float32)
    yc = jnp.einsum("bdc,dc->bc", window.astype(jnp.float32), w)
    xbc_c = jax.nn.silu(yc + params["conv_b"].astype(jnp.float32)
                        ).astype(plan.dtype)
    new_conv = window[:, 1:, :]

    di, g, n = plan.d_inner, plan.n_groups, plan.d_state
    h, p = plan.num_heads, plan.head_dim
    xin_c = xbc_c[:, :di].reshape(bsz, h, p)
    bb_c = xbc_c[:, di:di + g * n].reshape(bsz, g, n)
    cc_c = xbc_c[:, di + g * n:].reshape(bsz, g, n)
    rep = h // g
    bb_h = jnp.repeat(bb_c, rep, axis=1)                     # (B,H,N)
    cc_h = jnp.repeat(cc_c, rep, axis=1)

    dt_c = jax.nn.softplus(dt[:, 0, :].astype(jnp.float32)
                           + params["dt_bias"][None, :])     # (B,H)
    a = -jnp.exp(params["A_log"])                            # (H,)
    decay = jnp.exp(dt_c * a[None, :])                       # (B,H)
    s = state["ssm"]
    s_new = (decay[:, :, None, None] * s
             + jnp.einsum("bh,bhn,bhp->bhpn", dt_c,
                          bb_h.astype(jnp.float32),
                          xin_c.astype(jnp.float32)))
    y = jnp.einsum("bhn,bhpn->bhp", cc_h.astype(jnp.float32), s_new)
    y = y + params["D"][None, :, None] * xin_c.astype(jnp.float32)
    y = y.reshape(bsz, 1, di).astype(plan.dtype)
    y = L.rmsnorm_apply(params["norm"], y) * jax.nn.silu(z)
    out = L.linear_apply(
        L.LinearPlan(plan.d_inner, plan.d_model, hashed=plan.hash_out,
                     dtype=plan.dtype, hash_path=plan.hash_path),
        params["out_proj"], y)
    return out, {"conv": new_conv, "ssm": s_new}
