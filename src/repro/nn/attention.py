"""Grouped-query attention with RoPE, qk-norm, sliding window, KV cache,
and optional cross-attention — every projection optionally hashed.

Shapes: x (B, S, d_model); KV cache (B, T_max, n_kv, head_dim) per k/v.
GQA is computed with grouped einsums (no materialized KV repeat).
Softmax and score accumulation are float32.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import hashed as H
from repro.nn import layers as L


def _serving_tp():
    """(mesh, tp) when the active sharding rules put the KV-head axis on
    a >1 "model" axis (tensor-parallel serving), else (None, 1).

    Attention is per-head independent, so splitting the paged pool and
    the q/k/v head dims across a mesh axis and running the scatter +
    kernel per shard is BITWISE identical to the single-device dispatch
    — no reduction crosses shards.  The engine activates
    ``distributed.sharding.serving_rules`` around its jitted paths;
    without an active mesh (unit tests, single device) this returns
    (None, 1) and the paged paths below compile exactly as before.
    """
    from repro.distributed import sharding as shd
    mesh = shd.active_mesh()
    if mesh is None:
        return None, 1
    axis = shd.resolve_spec(P(L.TP_KV))[0]
    if isinstance(axis, (tuple, list)):
        axis = axis[0] if len(axis) == 1 else None
    if axis != "model":
        return None, 1
    tp = mesh.shape.get("model", 1)
    return (mesh, tp) if tp > 1 else (None, 1)


def _shard_map(fn, mesh, in_specs, out_specs):
    """shard_map with the settings every serving dispatch needs:
    check_rep off (pallas_call inside a shard_map cannot carry the
    replication-checking rule set)."""
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


@dataclasses.dataclass(frozen=True)
class AttentionPlan:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    use_rope: bool = True
    qk_norm: bool = False
    sliding_window: int = 0          # 0 = full attention
    causal: bool = True
    cross: bool = False              # kv from encoder output
    dtype: Any = jnp.bfloat16
    # hashed specs per projection (None = dense)
    hash_q: Optional[H.HashedSpec] = None
    hash_k: Optional[H.HashedSpec] = None
    hash_v: Optional[H.HashedSpec] = None
    hash_o: Optional[H.HashedSpec] = None
    hash_path: str = "auto"

    # memory-bounded attention: queries processed in chunks of q_chunk
    # (scores never materialize beyond (B, chunk, T)); 0 = auto
    # (chunk 512 once S > 2048), -1 = never chunk.
    q_chunk: int = 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim


def _lin(plan: AttentionPlan, in_dim, out_dim, hspec, pspec):
    return L.LinearPlan(in_dim, out_dim, hashed=hspec, pspec=pspec,
                        dtype=plan.dtype, hash_path=plan.hash_path)


def init(plan: AttentionPlan, key):
    ks = jax.random.split(key, 4)
    params, specs = {}, {}
    for name, k, lin in [
        ("q", ks[0], _lin(plan, plan.d_model, plan.q_dim, plan.hash_q,
                          (L.FSDP, L.TP))),
        ("k", ks[1], _lin(plan, plan.d_model, plan.kv_dim, plan.hash_k,
                          (L.FSDP, L.TP))),
        ("v", ks[2], _lin(plan, plan.d_model, plan.kv_dim, plan.hash_v,
                          (L.FSDP, L.TP))),
        ("o", ks[3], _lin(plan, plan.q_dim, plan.d_model, plan.hash_o,
                          (L.TP, L.FSDP))),
    ]:
        p, s = L.linear_init(lin, k)
        params[name], specs[name] = p, s
    if plan.qk_norm:
        params["q_norm"], specs["q_norm"] = L.rmsnorm_init(plan.head_dim)
        params["k_norm"], specs["k_norm"] = L.rmsnorm_init(plan.head_dim)
    return params, specs


def _project(plan, params, name, x, out_heads):
    lin = {
        "q": _lin(plan, plan.d_model, plan.q_dim, plan.hash_q, (L.FSDP, L.TP)),
        "k": _lin(plan, plan.d_model, plan.kv_dim, plan.hash_k, (L.FSDP, L.TP)),
        "v": _lin(plan, plan.d_model, plan.kv_dim, plan.hash_v, (L.FSDP, L.TP)),
    }[name]
    y = L.linear_apply(lin, params[name], x)
    b, s = x.shape[0], x.shape[1]
    return y.reshape(b, s, out_heads, plan.head_dim)


def attend(plan: AttentionPlan, q, k, v, q_pos, kv_pos, kv_valid,
           is_global=None):
    """Core grouped attention, memory-bounded.

    q: (B, S, Hq, D); k/v: (B, T, Hkv, D)
    q_pos: (S,) absolute positions of queries
    kv_pos: (T,) absolute positions of keys
    kv_valid: (T,) bool — whether the cache slot holds a real key
    is_global: optional traced bool — when the plan has a sliding window,
      a True value disables it for this layer (gemma3 5:1 local:global
      pattern under scan-over-layers).

    Long sequences: queries are processed in chunks via lax.scan so the
    live score tensor is (B, Hkv, G, chunk, T), never (.., S, T) — the
    flash-attention memory bound in TPU/XLA idiom (each query row's
    softmax is still computed over the full T at once, so results are
    bit-identical to the unchunked path).
    """
    b, s, hq, d = q.shape
    chunk = plan.q_chunk if plan.q_chunk != 0 else (512 if s > 2048 else -1)
    if 0 < chunk < s:
        pad = (-s) % chunk
        if pad:
            q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
            q_pos = jnp.pad(q_pos, (0, pad), constant_values=q_pos[-1])
        nc = (s + pad) // chunk
        qc = jnp.moveaxis(q.reshape(b, nc, chunk, hq, d), 1, 0)
        qp = q_pos.reshape(nc, chunk)

        def body(carry, xs):
            qi, qpi = xs
            out = _attend_unchunked(plan, qi, k, v, qpi, kv_pos, kv_valid,
                                    is_global)
            return carry, out

        _, outs = jax.lax.scan(body, None, (qc, qp))   # (nc, B, chunk, HD)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s + pad, hq * d)
        return out[:, :s]
    return _attend_unchunked(plan, q, k, v, q_pos, kv_pos, kv_valid,
                             is_global)


def _attend_unchunked(plan: AttentionPlan, q, k, v, q_pos, kv_pos, kv_valid,
                      is_global=None):
    b, s, hq, d = q.shape
    t = k.shape[1]
    n_kv = plan.num_kv_heads
    g = hq // n_kv
    qg = q.reshape(b, s, n_kv, g, d)

    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum(
        "bskgd,btkd->bkgst", qg.astype(jnp.float32) * scale,
        k.astype(jnp.float32), preferred_element_type=jnp.float32)

    # q_pos may be (S,) or (B, S) (per-row decode positions, continuous
    # batching); kv_valid may be (T,) or (B, T).  Everything broadcasts
    # to (B|1, S, T).
    qp = q_pos if q_pos.ndim == 2 else q_pos[None, :]
    kv2 = kv_valid if kv_valid.ndim == 2 else kv_valid[None, :]
    mask = kv2[:, None, :]                          # (B|1, 1, T)
    if plan.causal:
        mask = mask & (kv_pos[None, None, :] <= qp[:, :, None])
        if plan.sliding_window > 0:
            in_window = (qp[:, :, None] - kv_pos[None, None, :]
                         < plan.sliding_window)
            if is_global is not None:
                in_window = in_window | is_global
            mask = mask & in_window
    else:
        mask = jnp.broadcast_to(mask, (mask.shape[0], s, t))
    neg = jnp.asarray(-1e30, jnp.float32)
    scores = jnp.where(mask[:, None, None, :, :], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1)
    # bf16 probs for the value contraction (flash-attention practice):
    # keeps the (B,T,H,D)-sized backward cotangents in bf16.
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, hq * d).astype(plan.dtype)


def apply(plan: AttentionPlan, params, x, *, positions, cache=None,
          cache_index=None, kv_source=None, is_global=None):
    """Returns (out, new_cache).

    - training / encoder: cache=None, attends within x (or kv_source).
    - prefill: cache=(k,v) zero-filled, cache_index=0; writes S entries.
    - decode:  cache=(k,v), cache_index=current length; S is typically 1.
    kv_source: (B, T_enc, d_model) encoder output for cross-attention.
    """
    b, s, _ = x.shape
    q = _project(plan, params, "q", x, plan.num_heads)

    kv_in = kv_source if plan.cross else x
    k = _project(plan, params, "k", kv_in, plan.num_kv_heads)
    v = _project(plan, params, "v", kv_in, plan.num_kv_heads)

    if plan.qk_norm:
        q = L.rmsnorm_apply(params["q_norm"], q)
        k = L.rmsnorm_apply(params["k_norm"], k)

    if plan.use_rope and not plan.cross:
        q = L.rope(q, positions, plan.rope_theta)
        kv_positions = positions
        k = L.rope(k, kv_positions, plan.rope_theta)

    new_cache = None
    if plan.cross:
        # cross-attention: no cache mutation, all encoder positions valid
        t = k.shape[1]
        kv_pos = jnp.arange(t)
        kv_valid = jnp.ones((t,), bool)
        q_pos = positions
        out = attend(plan, q, k, v, q_pos, kv_pos, kv_valid)
    elif cache is None:
        kv_pos = positions
        kv_valid = jnp.ones((s,), bool)
        out = attend(plan, q, k, v, positions, kv_pos, kv_valid,
                     is_global=is_global)
    else:
        ck, cv = cache
        t_max = ck.shape[1]
        idx = jnp.asarray(cache_index, jnp.int32)
        if idx.ndim == 1:
            # per-row write offsets (continuous batching: every slot is at
            # its own position)
            upd = jax.vmap(
                lambda c, x, i: jax.lax.dynamic_update_slice(
                    c, x.astype(c.dtype), (i, 0, 0)))
            ck = upd(ck, k, idx)
            cv = upd(cv, v, idx)
            kv_pos = jnp.arange(t_max)
            kv_valid = kv_pos[None, :] < (idx[:, None] + s)    # (B, T)
        else:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, idx, 0, 0))
            kv_pos = jnp.arange(t_max)
            kv_valid = kv_pos < (idx + s)
        out = attend(plan, q, ck, cv, positions, kv_pos, kv_valid,
                     is_global=is_global)
        new_cache = (ck, cv)

    o_lin = _lin(plan, plan.q_dim, plan.d_model, plan.hash_o,
                 (L.TP, L.FSDP))
    return L.linear_apply(o_lin, params["o"], out), new_cache


def apply_paged(plan: AttentionPlan, params, x, *, pages, page_table,
                lengths, is_global=None, impl: str = "ref"):
    """One decode step (S=1) through a paged KV cache.

    x: (B, 1, d_model); pages: (pk, pv) each (P, ps, Hkv, D);
    page_table: (B, MAXP) int32; lengths: (B,) int32 — tokens already
    cached per row EXCLUDING the current one (so the current token's
    position is ``lengths`` and its k/v lands at page
    ``table[b, lengths // ps]`` offset ``lengths % ps``).

    Returns (out (B, 1, d_model), (new_pk, new_pv)).  impl: "ref"
    (gather-then-attend oracle) or "pallas" (paged-gather flash-decode
    kernel; interpret mode off-TPU).
    """
    from repro.kernels import paged_attention as PA
    from repro.kernels import ref as KREF

    b = x.shape[0]
    q = _project(plan, params, "q", x, plan.num_heads)
    k = _project(plan, params, "k", x, plan.num_kv_heads)
    v = _project(plan, params, "v", x, plan.num_kv_heads)
    if plan.qk_norm:
        q = L.rmsnorm_apply(params["q_norm"], q)
        k = L.rmsnorm_apply(params["k_norm"], k)
    positions = lengths[:, None]                      # (B, 1)
    if plan.use_rope:
        q = L.rope(q, positions, plan.rope_theta)
        k = L.rope(k, positions, plan.rope_theta)

    pk, pv = pages

    if plan.sliding_window > 0:
        window = jnp.asarray(plan.sliding_window, jnp.int32)
        if is_global is not None:
            window = jnp.where(is_global, 0, window)
    else:
        window = jnp.asarray(0, jnp.int32)

    fn = PA.paged_decode_attention if impl == "pallas" \
        else KREF.paged_attention_ref

    def scatter_attend(q1, k1, v1, pk, pv, page_table, lengths, window):
        ps = pk.shape[1]
        pidx = jnp.take_along_axis(page_table, (lengths // ps)[:, None],
                                   axis=1)[:, 0]
        poff = lengths % ps
        # distinct live rows own distinct pages (allocator invariant);
        # idle rows all write the trash page, collisions harmless there
        npk = pk.at[pidx, poff].set(k1.astype(pk.dtype))
        npv = pv.at[pidx, poff].set(v1.astype(pv.dtype))
        return fn(q1, npk, npv, page_table, lengths + 1, window), npk, npv

    mesh, _tp = _serving_tp()
    if mesh is not None:
        # per-head-shard scatter + attend: each shard owns Hkv/tp kv
        # heads of the pool and the matching Hq/tp q heads (GQA groups
        # ride along), table/lengths replicated — no cross-shard math,
        # so the sharded dispatch is bitwise the single-device one
        head = P(None, "model", None)
        pool = P(None, None, "model", None)
        out, pk, pv = _shard_map(
            scatter_attend, mesh,
            in_specs=(head, head, head, pool, pool,
                      P(None, None), P(None), P()),
            out_specs=(head, pool, pool),
        )(q[:, 0], k[:, 0], v[:, 0], pk, pv, page_table, lengths, window)
        # exact all-gather of the head shards (a concat, not a psum)
        # BEFORE the o-projection, which then runs replicated with the
        # single-device reduction order — the bitwise-identity contract
        out = jax.lax.with_sharding_constraint(
            out, jax.sharding.NamedSharding(mesh, P(None, None, None)))
    else:
        out, pk, pv = scatter_attend(q[:, 0], k[:, 0], v[:, 0], pk, pv,
                                     page_table, lengths, window)
    out = out.reshape(b, 1, plan.q_dim).astype(plan.dtype)

    o_lin = _lin(plan, plan.q_dim, plan.d_model, plan.hash_o,
                 (L.TP, L.FSDP))
    return L.linear_apply(o_lin, params["o"], out), (pk, pv)


def apply_paged_block(plan: AttentionPlan, params, x, *, pages, page_table,
                      lengths, counts, is_global=None, impl: str = "ref"):
    """Multi-token decode block (speculative propose/verify) through a
    paged KV cache.

    x: (B, S, d_model); slot ``s`` of row ``b`` holds the token at
    absolute position ``lengths[b] + s`` and is real iff
    ``s < counts[b]``.  Real slots write K/V into the row's pages (slot
    s attends to slots < s written in the same call); padding slots
    write the trash page (0), whose contents are never read back — the
    page-table mask ``kv_pos < lengths`` already excludes every
    table slot that maps to it.  With S == 1 (counts all 1) the
    projections, RoPE positions, KV scatter targets, and attention
    masks are identical to :func:`apply_paged`, so the block path is
    bitwise-equal to the per-token path — the parity the speculative
    engine's token-identity guarantee rests on.

    Returns (out (B, S, d_model), (new_pk, new_pv)).
    """
    from repro.kernels import paged_attention as PA
    from repro.kernels import ref as KREF

    b, s_blk, _ = x.shape
    q = _project(plan, params, "q", x, plan.num_heads)
    k = _project(plan, params, "k", x, plan.num_kv_heads)
    v = _project(plan, params, "v", x, plan.num_kv_heads)
    if plan.qk_norm:
        q = L.rmsnorm_apply(params["q_norm"], q)
        k = L.rmsnorm_apply(params["k_norm"], k)
    offs = jnp.arange(s_blk, dtype=jnp.int32)[None, :]
    positions = lengths[:, None] + offs               # (B, S)
    if plan.use_rope:
        q = L.rope(q, positions, plan.rope_theta)
        k = L.rope(k, positions, plan.rope_theta)

    pk, pv = pages
    ps = pk.shape[1]
    maxp = page_table.shape[1]
    valid = offs < counts[:, None]                    # (B, S)
    # clamp the page slot for padding positions that run past the
    # table; their writes are redirected to the trash page anyway
    pno = jnp.minimum(positions // ps, maxp - 1)
    pidx = jnp.where(valid,
                     jnp.take_along_axis(page_table, pno, axis=1), 0)
    poff = positions % ps
    pk = pk.at[pidx.reshape(-1), poff.reshape(-1)].set(
        k.reshape(b * s_blk, *k.shape[2:]).astype(pk.dtype))
    pv = pv.at[pidx.reshape(-1), poff.reshape(-1)].set(
        v.reshape(b * s_blk, *v.shape[2:]).astype(pv.dtype))

    if plan.sliding_window > 0:
        window = jnp.asarray(plan.sliding_window, jnp.int32)
        if is_global is not None:
            window = jnp.where(is_global, 0, window)
    else:
        window = jnp.asarray(0, jnp.int32)

    fn = PA.paged_decode_attention if impl == "pallas" \
        else KREF.paged_attention_ref
    outs = [fn(q[:, s], pk, pv, page_table,
               jnp.minimum(lengths + s + 1, maxp * ps), window)
            for s in range(s_blk)]
    out = jnp.stack(outs, axis=1).reshape(b, s_blk, plan.q_dim)
    out = out.astype(plan.dtype)

    o_lin = _lin(plan, plan.q_dim, plan.d_model, plan.hash_o,
                 (L.TP, L.FSDP))
    return L.linear_apply(o_lin, params["o"], out), (pk, pv)


def apply_paged_prefill(plan: AttentionPlan, params, x, *, pages,
                        page_table, starts, counts, write_from,
                        is_global=None, impl: str = "ref"):
    """Batched ragged prefill chunk through a paged KV cache.

    x: (B, S, d_model); slot ``s`` of row ``b`` holds the prompt token
    at absolute position ``starts[b] + s`` and is real iff
    ``s < counts[b]`` (rows with ``counts == 0`` are inert padding
    rows).  Real slots at positions >= ``write_from[b]`` write K/V
    straight into the row's pages through the table — ``write_from`` is
    the first position of the row's private whole-page landing zone, so
    shared (refcount > 1) prefix pages are never written even when a
    slid-back chunk recomputes positions that live on them.  All other
    slots write the trash page (0), whose contents are never read back.
    Every real slot then attends its own causal band over the row's
    paged prefix (shared pages read through the table, like decode).

    For a real query this produces bitwise the scores/probs/output of
    the sequential dense-scratch-cache path (``apply`` with a scalar
    cache index) — the parity the engine's batched-vs-sequential
    token-identity guarantee rests on; see kernels.ref.paged_prefill_ref.

    Returns (out (B, S, d_model), (new_pk, new_pv)).  impl: "ref"
    (gather-then-attend oracle, bitwise vs the dense path) or "pallas"
    (ragged flash-prefill kernel; interpret mode off-TPU).
    """
    from repro.kernels import flash_prefill as FP
    from repro.kernels import ref as KREF

    b, s_blk, _ = x.shape
    q = _project(plan, params, "q", x, plan.num_heads)
    k = _project(plan, params, "k", x, plan.num_kv_heads)
    v = _project(plan, params, "v", x, plan.num_kv_heads)
    if plan.qk_norm:
        q = L.rmsnorm_apply(params["q_norm"], q)
        k = L.rmsnorm_apply(params["k_norm"], k)
    offs = jnp.arange(s_blk, dtype=jnp.int32)[None, :]
    positions = starts[:, None] + offs                # (B, S)
    if plan.use_rope:
        q = L.rope(q, positions, plan.rope_theta)
        k = L.rope(k, positions, plan.rope_theta)

    pk, pv = pages

    if plan.sliding_window > 0:
        window = jnp.asarray(plan.sliding_window, jnp.int32)
        if is_global is not None:
            window = jnp.where(is_global, 0, window)
    else:
        window = jnp.asarray(0, jnp.int32)

    fn = FP.paged_prefill_attention if impl == "pallas" \
        else KREF.paged_prefill_ref

    def scatter_attend(q_, k_, v_, pk, pv, page_table, starts, counts,
                       write_from, window):
        ps = pk.shape[1]
        maxp = page_table.shape[1]
        offs_ = jnp.arange(q_.shape[1], dtype=jnp.int32)[None, :]
        positions_ = starts[:, None] + offs_
        wvalid = (offs_ < counts[:, None]) \
            & (positions_ >= write_from[:, None])     # (B, S)
        # clamp the page slot for padding positions that run past the
        # table; their writes are redirected to the trash page anyway
        pno = jnp.minimum(positions_ // ps, maxp - 1)
        pidx = jnp.where(wvalid,
                         jnp.take_along_axis(page_table, pno, axis=1), 0)
        poff = positions_ % ps
        nb, ns = q_.shape[0], q_.shape[1]
        npk = pk.at[pidx.reshape(-1), poff.reshape(-1)].set(
            k_.reshape(nb * ns, *k_.shape[2:]).astype(pk.dtype))
        npv = pv.at[pidx.reshape(-1), poff.reshape(-1)].set(
            v_.reshape(nb * ns, *v_.shape[2:]).astype(pv.dtype))
        return fn(q_, npk, npv, page_table, starts, counts, window), \
            npk, npv

    mesh, _tp = _serving_tp()
    if mesh is not None:
        # see apply_paged: per-head-shard scatter + kernel, replicated
        # ragged metadata, exact head concat before the o-projection
        head = P(None, None, "model", None)
        pool = P(None, None, "model", None)
        rep1 = P(None)
        out, pk, pv = _shard_map(
            scatter_attend, mesh,
            in_specs=(head, head, head, pool, pool,
                      P(None, None), rep1, rep1, rep1, P()),
            out_specs=(head, pool, pool),
        )(q, k, v, pk, pv, page_table, starts, counts, write_from, window)
        out = jax.lax.with_sharding_constraint(
            out, jax.sharding.NamedSharding(mesh, P(None, None, None, None)))
    else:
        out, pk, pv = scatter_attend(q, k, v, pk, pv, page_table, starts,
                                     counts, write_from, window)
    out = out.reshape(b, s_blk, plan.q_dim).astype(plan.dtype)

    o_lin = _lin(plan, plan.q_dim, plan.d_model, plan.hash_o,
                 (L.TP, L.FSDP))
    return L.linear_apply(o_lin, params["o"], out), (pk, pv)


def init_cache(plan: AttentionPlan, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    shape = (batch, max_len, plan.num_kv_heads, plan.head_dim)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def cache_pspec() -> Tuple[P, P]:
    """KV cache logical sharding: batch over (pod,data); exactly one of
    tp_kv (heads) / tp_hd (head_dim) resolves to model, by divisibility
    (launch/specs.rules_for).  For batch=1 long-context cells the rules
    re-map seq over data."""
    return (P(L.BATCH, None, L.TP_KV, L.TP_HD),
            P(L.BATCH, None, L.TP_KV, L.TP_HD))
