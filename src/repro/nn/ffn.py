"""Feed-forward blocks: gated (SwiGLU/GeGLU) and plain MLP, hashed-capable."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import hashed as H
from repro.nn import layers as L


@dataclasses.dataclass(frozen=True)
class FFNPlan:
    d_model: int
    d_ff: int
    activation: str = "swiglu"   # swiglu | geglu | gelu | relu | relu_sq
    dtype: Any = jnp.bfloat16
    hash_in: Optional[H.HashedSpec] = None    # applies to w_in (and w_gate)
    hash_gate: Optional[H.HashedSpec] = None
    hash_out: Optional[H.HashedSpec] = None
    hash_path: str = "auto"

    @property
    def gated(self) -> bool:
        return self.activation in ("swiglu", "geglu")

    @property
    def inner_act(self):
        if self.activation == "swiglu":
            return jax.nn.silu
        if self.activation == "geglu":
            return lambda x: jax.nn.gelu(x, approximate=True)
        return L.activation(self.activation)


def _lin(plan, i, o, h, ps):
    return L.LinearPlan(i, o, hashed=h, pspec=ps, dtype=plan.dtype,
                        hash_path=plan.hash_path)


def init(plan: FFNPlan, key):
    ks = jax.random.split(key, 3)
    params, specs = {}, {}
    p, s = L.linear_init(
        _lin(plan, plan.d_model, plan.d_ff, plan.hash_in, (L.FSDP, L.TP)),
        ks[0])
    params["in"], specs["in"] = p, s
    if plan.gated:
        p, s = L.linear_init(
            _lin(plan, plan.d_model, plan.d_ff, plan.hash_gate,
                 (L.FSDP, L.TP)), ks[1])
        params["gate"], specs["gate"] = p, s
    p, s = L.linear_init(
        _lin(plan, plan.d_ff, plan.d_model, plan.hash_out, (L.TP, L.FSDP)),
        ks[2])
    params["out"], specs["out"] = p, s
    return params, specs


def apply(plan: FFNPlan, params, x):
    h = L.linear_apply(
        _lin(plan, plan.d_model, plan.d_ff, plan.hash_in, (L.FSDP, L.TP)),
        params["in"], x)
    if plan.gated:
        g = L.linear_apply(
            _lin(plan, plan.d_model, plan.d_ff, plan.hash_gate,
                 (L.FSDP, L.TP)), params["gate"], x)
        h = plan.inner_act(g) * h
    else:
        h = plan.inner_act(h)
    return L.linear_apply(
        _lin(plan, plan.d_ff, plan.d_model, plan.hash_out, (L.TP, L.FSDP)),
        params["out"], h)
