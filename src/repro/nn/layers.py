"""Base layers: linear (dense or hashed), norms, embeddings, rotary, acts.

Convention: every ``*_init`` returns ``(params, pspecs)`` — two parallel
pytrees, the second holding ``jax.sharding.PartitionSpec`` leaves with
*logical* axis names (resolved against the physical mesh by
``repro.distributed.sharding``).  All ``*_apply`` are pure functions.

The paper's technique enters here: ``LinearPlan.hashed`` swaps the dense
weight for a HashedNets bank; everything downstream (attention, FFN, MoE,
SSM projections, embeddings) goes through these two entry points, which is
what makes hashing a first-class, arch-wide feature.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import hashed as H

# ---------------------------------------------------------------------------
# logical axis names (resolved in repro.distributed.sharding)
# ---------------------------------------------------------------------------
BATCH = "batch"      # -> (pod, data)
FSDP = "fsdp"        # -> data
TP = "tp"            # -> model
EXPERT = "expert"    # -> model
SEQ = "seq"          # -> data (context parallelism)
# KV-cache tensor-parallel axes: exactly ONE of these resolves to "model",
# chosen per-arch by divisibility (kv_heads % tp == 0 ? tp_kv : tp_hd) —
# GQA archs with 8 kv heads cannot shard heads over a 16-way axis, but can
# shard head_dim (launch/specs.rules_for decides).
TP_KV = "tp_kv"      # kv-heads dim of the cache
TP_HD = "tp_hd"      # head_dim dim of the cache
# cache batch dim: stays data-sharded even when decode ACTIVATIONS are
# replicated over data (weights-stationary decode, launch/specs.rules_for)
CACHE_BATCH = "cache_batch"
NONE = None


def default_dtype():
    return jnp.bfloat16


def accum_einsum(eq: str, a, b):
    """einsum with f32 accumulation, CPU-runtime-safe.

    XLA CPU (this version) cannot EXECUTE batched bf16xbf16->f32 dots
    (DotThunk UNIMPLEMENTED), so tests/examples cast inputs to f32.  The
    dry-run wants the TPU-faithful bf16 HLO (roofline reads its dtypes):
    it compiles but never executes, and sets REPRO_FAITHFUL_DOTS=1.
    """
    import os
    if (jax.default_backend() == "cpu"
            and os.environ.get("REPRO_FAITHFUL_DOTS") != "1"
            and a.dtype == jnp.bfloat16):
        return jnp.einsum(eq, a.astype(jnp.float32), b.astype(jnp.float32))
    return jnp.einsum(eq, a, b, preferred_element_type=jnp.float32)



def _bank_shard_grid() -> int:
    """How many ways a bank's leading dim must divide to shard over both
    mesh axes.  Derived from the ACTIVE mesh (launchers wrap spec
    construction in ``sharding.use_mesh``) so a small mesh — a (1,1) CI
    run, an elastic (8,16) restart — shards banks it can instead of
    replicating them; without an active mesh, fall back to the production
    256-chip grid."""
    from repro.distributed import sharding as shd
    mesh = shd.active_mesh()
    if mesh is None:
        return 256
    axes = shd.resolve_spec(P((FSDP, TP)))[0]
    if axes is None:
        return 1
    if not isinstance(axes, (tuple, list)):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def bank_pspec(spec) -> P:
    """Sharding for a hashed bank: over BOTH mesh axes when the leading
    dim divides the shard grid (see :func:`_bank_shard_grid`), else
    replicated (small banks, paper-scale MLPs).  A bank replicated over
    model made per-device hashed state 2x the DENSE state at 405B scale
    (EXPERIMENTS.md §Perf); decompression all-gathers the (c-times
    smaller) bank — the FSDP wire win of the technique."""
    n0 = spec.real_param_shape()[0]
    sharded = n0 % _bank_shard_grid() == 0
    if spec.mode == "element":
        return P((FSDP, TP)) if sharded else P(None)
    return P((FSDP, TP), None, None) if sharded else P(None, None, None)

# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LinearPlan:
    in_dim: int
    out_dim: int
    hashed: Optional[H.HashedSpec] = None
    pspec: Tuple[Any, Any] = (FSDP, TP)   # logical axes of the dense weight
    dtype: Any = jnp.bfloat16
    hash_path: str = "auto"               # materialize | scan | pallas | auto
    scale: Optional[float] = None         # init stddev; default 1/sqrt(in)


def linear_init(plan: LinearPlan, key):
    scale = plan.scale if plan.scale is not None else 1.0 / math.sqrt(plan.in_dim)
    if plan.hashed is not None:
        spec = plan.hashed
        assert spec.virtual_shape == (plan.in_dim, plan.out_dim), (
            spec.virtual_shape, (plan.in_dim, plan.out_dim))
        w = H.init(key, spec, scale=scale, dtype=plan.dtype)
        return {"w": w}, {"w": bank_pspec(spec)}
    w = (jax.random.normal(key, (plan.in_dim, plan.out_dim), jnp.float32)
         * scale).astype(plan.dtype)
    return {"w": w}, {"w": P(*plan.pspec)}


def linear_apply(plan: LinearPlan, params, x):
    w = params["w"]
    if plan.hashed is not None:
        # policy-resolved specs carry their own per-slot execution path;
        # hand-built specs (exec_path "") fall back to the plan's
        return H.matmul(x, w, plan.hashed,
                        path=plan.hashed.exec_path or plan.hash_path,
                        dtype=x.dtype, vspec=P(*plan.pspec))
    # native-dtype output (bf16): the MXU accumulates f32 internally
    # regardless; emitting f32 + astype(bf16) would make every backward
    # dot carry f32 activation-sized cotangents.  (On the CPU dry-run
    # artifact this measured ~flat — XLA CPU upcasts bf16 dots to f32
    # anyway — but it is the TPU-correct form; EXPERIMENTS.md §Perf A2.)
    return jax.lax.dot_general(
        x, w.astype(x.dtype), (((x.ndim - 1,), (0,)), ((), ())))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((dim,), dtype)}, {"scale": P(None)}


def rmsnorm_apply(params, x, eps: float = 1e-6):
    """RMSNorm with (1 + scale) parameterization (gemma/llama-compatible
    when scale is init at 0)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    return (xf * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def layernorm_init(dim: int, dtype=jnp.float32):
    return ({"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)},
            {"scale": P(None), "bias": P(None)})


def layernorm_apply(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * params["scale"] + params["bias"]).astype(dt)


def make_norm(kind: str, dim: int):
    if kind == "rmsnorm":
        return rmsnorm_init(dim), rmsnorm_apply
    if kind == "layernorm":
        return layernorm_init(dim), layernorm_apply
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# embeddings (dense or hashed virtual table)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EmbeddingPlan:
    vocab: int
    dim: int
    hashed: Optional[H.HashedSpec] = None
    dtype: Any = jnp.bfloat16
    scale_by_sqrt_dim: bool = False   # gemma convention


def embedding_init(plan: EmbeddingPlan, key):
    # std 1/sqrt(dim): keeps TIED logits (x @ emb^T) at unit scale at init
    # (std-1 embeddings make tied logits ~N(0, d) — loss starts ~d/ln-scale
    # instead of ln(V)); scale_by_sqrt_dim archs (gemma) restore unit-RMS
    # inputs via the sqrt(d) input multiplier.
    scale = 1.0 / math.sqrt(plan.dim)
    if plan.hashed is not None:
        assert plan.hashed.virtual_shape == (plan.vocab, plan.dim)
        w = H.init(key, plan.hashed, scale=scale, dtype=plan.dtype)
        return {"emb": w}, {"emb": bank_pspec(plan.hashed)}
    w = (jax.random.normal(key, (plan.vocab, plan.dim), jnp.float32)
         * scale).astype(plan.dtype)
    return {"emb": w}, {"emb": P(TP, FSDP)}


def embedding_lookup(plan: EmbeddingPlan, params, tokens):
    if plan.hashed is not None:
        x = H.materialize_rows(params["emb"], plan.hashed, tokens)
    else:
        x = jnp.take(params["emb"], tokens, axis=0)
    if plan.scale_by_sqrt_dim:
        x = x * jnp.asarray(math.sqrt(plan.dim), x.dtype)
    return x


def embedding_logits(plan: EmbeddingPlan, params, x):
    """Tied LM head: x @ emb^T."""
    if plan.hashed is not None:
        v = H.materialize(params["emb"], plan.hashed, dtype=x.dtype)
        return jax.lax.dot_general(
            x, v.T, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return jax.lax.dot_general(
        x, params["emb"].astype(x.dtype).T,
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int):
    """Whisper-style fixed sinusoidal embeddings (seq, dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = jnp.arange(seq, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation(name: str):
    return {
        "relu": jax.nn.relu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "silu": jax.nn.silu,
        "relu_sq": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


def dropout(key, x, rate: float, deterministic: bool):
    if deterministic or rate <= 0.0:
        return x
    keep = 1.0 - rate
    mask = jax.random.bernoulli(key, keep, x.shape)
    return jnp.where(mask, x / keep, jnp.zeros_like(x))
