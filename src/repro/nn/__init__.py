"""NN substrate: hashed-capable layers and blocks."""
from repro.nn import layers, attention, ffn, moe, mamba2, rwkv6  # noqa: F401
