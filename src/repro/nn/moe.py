"""Top-k mixture-of-experts with sort-based capacity dispatch (EP-shardable).

Dispatch is MegaBlocks-style: flatten (token, choice) pairs, sort by expert,
compute position-in-expert from per-expert offsets, scatter into an
(E, C, d) buffer (overflow tokens dropped), run per-expert FFN, gather back
with router-probability combine.  All shapes static; the (E, C, d) buffer is
the all-to-all surface (sharded E over the model axis, C over batch axes).

Hashed experts ("hashing across experts", DESIGN.md §5): one bank is shared
by *all* experts of a layer — the virtual matrix is (E * d_model, d_ff) and
expert e reads rows [e*d : (e+1)*d).  Collisions then share weights across
experts too, compounding compression with expert parallelism.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import hashed as H
from repro.nn import layers as L
from repro.distributed import sharding as shd


@dataclasses.dataclass(frozen=True)
class MoEPlan:
    d_model: int
    d_ff: int                    # per-expert hidden
    num_experts: int
    top_k: int
    activation: str = "swiglu"
    capacity_factor: float = 1.25
    dtype: Any = jnp.bfloat16
    router_dtype: Any = jnp.float32
    # hashed expert banks (shared across experts)
    hash_in: Optional[H.HashedSpec] = None
    hash_gate: Optional[H.HashedSpec] = None
    hash_out: Optional[H.HashedSpec] = None
    aux_loss_coef: float = 0.01

    @property
    def gated(self) -> bool:
        return self.activation in ("swiglu", "geglu")

    @property
    def inner_act(self):
        if self.activation == "swiglu":
            return jax.nn.silu
        if self.activation == "geglu":
            return lambda x: jax.nn.gelu(x, approximate=True)
        return L.activation(self.activation)


def init(plan: MoEPlan, key):
    e, d, f = plan.num_experts, plan.d_model, plan.d_ff
    ks = jax.random.split(key, 4)
    params = {"router": (jax.random.normal(ks[0], (d, e), jnp.float32)
                         * (1.0 / math.sqrt(d))).astype(jnp.float32)}
    specs = {"router": P(L.FSDP, None)}

    def bank_or_dense(k, name, vshape, hspec, dense_pspec):
        if hspec is not None:
            assert hspec.virtual_shape == vshape, (hspec.virtual_shape, vshape)
            w = H.init(k, hspec, scale=1.0 / math.sqrt(d), dtype=plan.dtype)
            ps = L.bank_pspec(hspec)
        else:
            # dense expert stacks: (E, in, out)
            in_dim = vshape[0] // e
            w = (jax.random.normal(k, (e, in_dim, vshape[1]), jnp.float32)
                 * (1.0 / math.sqrt(in_dim))).astype(plan.dtype)
            ps = dense_pspec
        params[name], specs[name] = w, ps

    bank_or_dense(ks[1], "in", (e * d, f), plan.hash_in,
                  P(L.EXPERT, L.FSDP, None))
    if plan.gated:
        bank_or_dense(ks[2], "gate", (e * d, f), plan.hash_gate,
                      P(L.EXPERT, L.FSDP, None))
    bank_or_dense(ks[3], "out", (e * f, d), plan.hash_out,
                  P(L.EXPERT, None, L.FSDP))
    return params, specs


def _expert_matmul(plan: MoEPlan, w, hspec: Optional[H.HashedSpec], xe,
                   in_dim: int):
    """xe: (B, E, C, in_dim) -> (B, E, C, out_dim); dense expert stack or
    one shared hashed bank (paper technique compounding across experts)."""
    if hspec is None:
        # native-dtype expert dots (see layers.linear_apply rationale)
        return jnp.einsum("becd,edf->becf", xe, w.astype(xe.dtype))

    def one(carry, args):
        e, xb = args                      # xb: (B, C, in_dim)

        def inner(w_, xb_):
            rows = e * in_dim + jnp.arange(in_dim, dtype=jnp.int32)
            ve = H.materialize_rows(w_, hspec, rows, dtype=xb_.dtype)
            return jax.lax.dot_general(
                xb_, ve, (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32).astype(xb_.dtype)

        return carry, jax.checkpoint(inner)(w, xb)

    es = jnp.arange(plan.num_experts, dtype=jnp.int32)
    _, ys = jax.lax.scan(one, None, (es, jnp.swapaxes(xe, 0, 1)))
    return jnp.swapaxes(ys, 0, 1)


def apply(plan: MoEPlan, params, x):
    """x: (B, S, d) -> (y, aux_loss).

    Dispatch is sort-based but STRICTLY batch-row-local (vmapped over B):
    with batch sharded over the data axis, routing/sort/scatter never cross
    shards, so the only inter-device traffic is the (B, E, C, d) expert
    buffer re-sharding batch->expert (GSPMD all-to-all over the model
    axis) — the GShard dispatch pattern.  A global sort here would make
    XLA gather every token to every device (measured: ~34 GB of
    all-reduce per layer at granite train_4k scale — see EXPERIMENTS.md
    §Perf).  Capacity is per batch row: C = ceil(S*K/E * cf).
    """
    b, s, d = x.shape
    e, k = plan.num_experts, plan.top_k
    cap = int(math.ceil(s * k / e * plan.capacity_factor))

    logits = jnp.einsum("bsd,de->bse", x.astype(plan.router_dtype),
                        params["router"].astype(plan.router_dtype))
    probs = jax.nn.softmax(logits, axis=-1)                    # (B, S, E)
    top_p, top_e = jax.lax.top_k(probs, k)                     # (B, S, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- load-balancing aux loss (Switch-style) ----
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = plan.aux_loss_coef * e * jnp.sum(frac_tokens * frac_probs)

    def dispatch_row(xt, te, tp):
        """xt (S,d), te/tp (S,K) -> (E,C,d) buffer + combine metadata.

        The only scatter is over int32 SLOT IDS (4 B/slot); token VECTORS
        then move via gather.  Scattering (S*K, d) f32 payloads directly
        makes GSPMD emit masked partial-scatter all-reduces of the full
        (B, S*K, d) tensor over the expert/model axis (measured ~0.4 TB
        of wire per granite train step — §Perf it.2); id-scatter + gather
        partitions cleanly."""
        flat_e = te.reshape(-1)                                 # (S*K,)
        flat_p = tp.reshape(-1).astype(plan.dtype)
        flat_tok = jnp.repeat(jnp.arange(s), k)
        order = jnp.argsort(flat_e)                             # stable
        se, stok = flat_e[order], flat_tok[order]
        counts = jax.ops.segment_sum(jnp.ones_like(se), se, num_segments=e)
        starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                  jnp.cumsum(counts)[:-1]])
        pos_in_e = jnp.arange(s * k) - starts[se]
        keep = pos_in_e < cap
        slot = jnp.where(keep, se * cap + pos_in_e, e * cap)    # drop->trash
        slot_src = jnp.full((e * cap + 1,), -1, jnp.int32)
        slot_src = slot_src.at[slot].set(stok.astype(jnp.int32),
                                         mode="drop")[:-1]      # (E*C,)
        valid = slot_src >= 0
        rows = xt[jnp.clip(slot_src, 0, s - 1)].astype(plan.dtype)
        buf = jnp.where(valid[:, None], rows, 0)
        return buf.reshape(e, cap, d), slot, order, keep, flat_p

    xe, slot, order, keep, flat_p = jax.vmap(dispatch_row)(x, top_e, top_p)
    xe = shd.constraint(xe, P(L.BATCH, L.EXPERT, None, None))

    # ---- expert FFN (E sharded over the model axis: EP) ----
    h = _expert_matmul(plan, params["in"], plan.hash_in, xe, d)
    if plan.gated:
        g = _expert_matmul(plan, params["gate"], plan.hash_gate, xe, d)
        h = plan.inner_act(g) * h
    else:
        h = plan.inner_act(h)
    ye = _expert_matmul(plan, params["out"], plan.hash_out, h, plan.d_ff)
    ye = shd.constraint(ye, P(L.BATCH, L.EXPERT, None, None))
    # combine reads token-ordered rows from expert-sharded ye; left to
    # GSPMD that becomes a masked f32 all-reduce of the (B, S*K, d)
    # gather (~4 GB/layer measured).  One explicit bf16 all-gather of ye
    # (~1.3 GB/layer) then a local gather is 3x cheaper (§Perf it.2b).
    ye = shd.constraint(ye, P(L.BATCH, None, None, None))

    def combine_row(ye_r, slot_r, order_r, keep_r, flat_p_r):
        flat_y = ye_r.reshape(e * cap, d)
        gathered = jnp.where(
            keep_r[:, None], flat_y[jnp.clip(slot_r, 0, e * cap - 1)],
            jnp.zeros((1, d), plan.dtype))
        unsort = jnp.argsort(order_r)
        contrib = gathered[unsort] * flat_p_r[:, None]
        return jnp.sum(contrib.reshape(s, k, d), axis=1)

    y = jax.vmap(combine_row)(ye, slot, order, keep, flat_p)
    return y.astype(x.dtype), aux
