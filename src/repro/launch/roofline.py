"""Roofline terms from a compiled dry-run artifact (no real hardware).

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = wire_bytes_per_device / ICI_BW

FLOPs/bytes come from ``compiled.cost_analysis()`` (XLA's per-partition
estimate — the module is already SPMD-partitioned, so these are per-device
numbers).  Collective bytes are NOT in cost_analysis: we parse the
optimized HLO text and sum operand bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, scaled by the standard
ring factors:

    all-gather      (g-1)/g * out_bytes     (bytes received per device)
    reduce-scatter  (g-1)/g * in_bytes      (bytes sent per device)
    all-reduce      2 (g-1)/g * in_bytes    (RS + AG)
    all-to-all      (g-1)/g * in_bytes
    collective-permute  in_bytes

where g = replica-group size parsed per op.  MODEL_FLOPS (6ND train /
2ND-per-token decode) gives the useful-compute ratio.
"""
from __future__ import annotations

import json
import math
import re
from typing import Any, Dict, List, Optional

import numpy as np

from repro.launch import mesh as M

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\))|"
                     r"(?:[\w]+\[[\d,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\(")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    gm = _GROUPS_RE.search(line)
    if gm:
        first = gm.group(1).split("}", 1)[0].lstrip("{")
        ids = [t for t in first.split(",") if t.strip() != ""]
        return max(1, len(ids))
    gi = _GROUPS_IOTA_RE.search(line)
    if gi:
        return max(1, int(gi.group(2)))
    return 2


def parse_collectives(hlo_text: str) -> List[Dict[str, Any]]:
    """Every collective in optimized HLO: kind, in/out bytes, group size.

    Optimized HLO prints operands by *name only*, so we first build a
    name -> result-shape map from all definitions, then resolve each
    collective's operand names against it.
    """
    shapes: Dict[str, str] = {}
    coll_lines: List[str] = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_str, opcode = m.groups()
        shapes[name] = shape_str
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base in _COLLECTIVES:
            coll_lines.append(line)

    out = []
    for line in coll_lines:
        m = _DEF_RE.match(line)
        name, out_shape, opcode = m.groups()
        kind = opcode[:-6] if opcode.endswith("-start") else opcode
        # operand names inside the first paren group
        args_str = line.split(opcode + "(", 1)[1].split(")", 1)[0]
        in_bytes = 0
        for arg in args_str.split(","):
            arg = arg.strip().lstrip("%")
            if arg in shapes:
                in_bytes += _shape_bytes(shapes[arg])
            else:
                in_bytes += _shape_bytes(arg)  # literal shape (rare)
        out.append({"kind": kind, "in_bytes": in_bytes,
                    "out_bytes": _shape_bytes(out_shape),
                    "group": _group_size(line)})
    return out


def collective_wire_bytes(ops: List[Dict[str, Any]]) -> Dict[str, float]:
    """Per-device wire bytes by kind, ring-scaled."""
    per_kind: Dict[str, float] = {}
    for op in ops:
        g = max(op["group"], 1)
        ring = (g - 1) / g
        if op["kind"] == "all-gather":
            b = ring * op["out_bytes"]
        elif op["kind"] == "all-reduce":
            b = 2 * ring * op["in_bytes"]
        elif op["kind"] == "reduce-scatter":
            b = ring * op["in_bytes"]
        elif op["kind"] == "all-to-all":
            b = ring * op["in_bytes"]
        else:  # collective-permute
            b = op["in_bytes"]
        per_kind[op["kind"]] = per_kind.get(op["kind"], 0.0) + b
    return per_kind


def model_flops(cfg, cell, chips: int) -> float:
    """Useful FLOPs per step per device: 6 N D (train), 2 N B (decode),
    2 N B S (prefill); MoE uses active params."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        total = 6.0 * n * cell.batch * cell.seq
    elif cell.kind == "prefill":
        total = 2.0 * n * cell.batch * cell.seq
    else:
        total = 2.0 * n * cell.batch            # one token per sequence
    return total / chips


def analyze(compiled, cfg, cell, chips: int,
            hlo_text: Optional[str] = None) -> Dict[str, Any]:
    """Roofline terms from the compiled artifact.

    Primary numbers come from the hierarchical HLO walk (hlo_analysis),
    which scales while-loop bodies by trip count; XLA's flat
    cost_analysis() is kept as a cross-check (it counts loop bodies once).
    """
    from repro.launch import hlo_analysis

    ca = compiled.cost_analysis() or {}
    # jax API drift: cost_analysis() returns [dict] on older releases
    # (one entry per executable) and a flat dict on newer ones.
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    hlo_text = hlo_text if hlo_text is not None else compiled.as_text()
    h = hlo_analysis.analyze_text(hlo_text)
    flops = h["flops"]
    bytes_acc = h["hbm_bytes"]
    wire_total = h["wire_bytes"]

    compute_s = flops / M.PEAK_FLOPS_BF16
    memory_s = bytes_acc / M.HBM_BW
    collective_s = wire_total / M.ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, cell, chips)
    bound = max(terms.values())
    result = {
        "arch": cfg.name, "shape": cell.name, "chips": chips,
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": bytes_acc,
        "collective_bytes_per_dev": wire_total,
        "collective_by_kind": h["wire_by_kind"],
        "n_collectives": h["n_collectives"],
        "xla_flops_flat": float(ca.get("flops", 0.0)),
        "xla_bytes_flat": float(ca.get("bytes accessed", 0.0)),
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "useful_flops_ratio": (mf / flops) if flops else 0.0,
        "roofline_fraction": (mf / M.PEAK_FLOPS_BF16) / bound
        if bound > 0 else 0.0,
        "step_time_bound_s": bound,
        "top_dots": h["top_dots"],
        "top_collectives": h["top_collectives"],
        "top_memory_ops": h["top_memory_ops"],
    }
    return result


def memory_analysis_dict(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "temp_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    if out:
        # arguments are aliased into outputs for donated state; peak live =
        # args + temps (upper bound; XLA CPU reports totals across devices)
        out["total_bytes"] = (out.get("argument_size_in_bytes", 0)
                              + out.get("temp_size_in_bytes", 0)
                              + out.get("output_size_in_bytes", 0)
                              - out.get("alias_size_in_bytes", 0))
    return out


def fmt_seconds(s: float) -> str:
    if s < 1e-3:
        return f"{s*1e6:.1f}us"
    if s < 1.0:
        return f"{s*1e3:.2f}ms"
    return f"{s:.2f}s"


def report(result: Dict[str, Any]) -> str:
    lines = [
        f"[{result['arch']} x {result['shape']}] chips={result['chips']}",
        f"  HLO flops/dev      {result['hlo_flops_per_dev']:.3e}"
        f"   (useful ratio {result['useful_flops_ratio']:.2f})",
        f"  HLO bytes/dev      {result['hlo_bytes_per_dev']:.3e}",
        f"  wire bytes/dev     {result['collective_bytes_per_dev']:.3e}"
        f"   ({result['n_collectives']} collectives)",
        f"  compute term       {fmt_seconds(result['compute_s'])}",
        f"  memory term        {fmt_seconds(result['memory_s'])}",
        f"  collective term    {fmt_seconds(result['collective_s'])}",
        f"  dominant           {result['dominant']}"
        f"   roofline fraction {result['roofline_fraction']:.3f}",
    ]
    return "\n".join(lines)
