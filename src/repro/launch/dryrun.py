"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the exact production step (train_step with
optimizer state / prefill / decode_step), resolves logical shardings onto
the requested mesh, then::

    lowered  = jax.jit(fn, in_shardings=..., out_shardings=...).lower(*abstract)
    compiled = lowered.compile()
    print(compiled.memory_analysis())   # proves the footprint
    print(compiled.cost_analysis())     # FLOPs/bytes for the roofline

and records memory/cost/collective analysis as JSON for EXPERIMENTS.md.
Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the framework — the CI gate for "would run at scale".

Usage::

    python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
    python -m repro.launch.dryrun --all --multi-pod --out runs/dryrun
    python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k --hashed
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks device count on first init.
os.environ["REPRO_FAITHFUL_DOTS"] = "1"   # compile-only: keep bf16 dots

import argparse  # noqa: E402
import dataclasses
import json
import time
import traceback

import jax

from repro.launch import mesh as mesh_lib
from repro.launch import roofline, specs


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             hashed: bool = False, num_microbatches: int = 1,
             rules=None, verbose: bool = True):
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    bundle = specs.make_step(arch, shape, mesh, hashed=hashed,
                             num_microbatches=num_microbatches, rules=rules)
    t0 = time.time()
    jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings)
    lowered = jitted.lower(*bundle.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = roofline.memory_analysis_dict(compiled)
    result = roofline.analyze(compiled, bundle.cfg, bundle.cell, chips)
    result.update({
        "multi_pod": multi_pod, "hashed": hashed,
        "mesh": {"axes": list(mesh.axis_names),
                 "shape": [int(s) for s in mesh.devices.shape]},
        "memory": mem,
        "lower_s": t_lower, "compile_s": t_compile,
        "num_microbatches": num_microbatches,
    })
    if verbose:
        print(compiled.memory_analysis())
        ca = compiled.cost_analysis()
        print({k: ca[k] for k in sorted(ca)
               if k in ("flops", "bytes accessed")} if ca else ca)
        print(roofline.report(result))
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
    return result


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None,
                   choices=list(specs.SHAPES) + [None])
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--hashed", action="store_true")
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--out", default=None, help="JSON output directory")
    args = p.parse_args()

    if args.all:
        todo = [(a, s) for a, s, skip in specs.cells()]
    else:
        assert args.arch and args.shape, "--arch + --shape, or --all"
        todo = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch, shape in todo:
        for mp in meshes:
            tag = (f"{arch}|{shape}|{'multi' if mp else 'single'}"
                   f"{'|hashed' if args.hashed else ''}")
            print(f"=== {tag} ===", flush=True)
            try:
                result = run_cell(arch, shape, multi_pod=mp,
                                  hashed=args.hashed,
                                  num_microbatches=args.microbatches)
            except Exception as e:  # noqa: BLE001 - report-and-continue CLI
                traceback.print_exc()
                failures.append((tag, repr(e)))
                continue
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                fname = tag.replace("|", "_").replace(".", "_") + ".json"
                with open(os.path.join(args.out, fname), "w") as f:
                    json.dump(result, f, indent=1)
    # skips are part of the record
    for arch, shape, skip in specs.cells(include_skipped=True):
        if skip:
            print(f"SKIP {arch}|{shape}: {skip}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err}")
        return 1
    print(f"\nall {len(todo) * len(meshes)} cells compiled OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
