"""Registry CLI: list / inspect / verify the sha256 artifact registry —
the catalog the multi-model engine and the HTTP front-end serve from.

    python -m repro.launch.registry_cli --registry runs/registry list
    python -m repro.launch.registry_cli --registry runs/registry \
        inspect qwen3-hashed@2
    python -m repro.launch.registry_cli --registry runs/registry verify
    python -m repro.launch.registry_cli --registry runs/registry \
        verify qwen3-hashed

- ``list``    — every model, its versions, sizes, and latest pointer.
- ``inspect`` — one entry in full: index record + the artifact file's
  own header (config name, sections, dtypes) via `artifact.format`.
- ``verify``  — re-hash artifact files against the recorded sha256
  (all models, or the named ones).  Exit code 1 if anything fails —
  usable as a pre-serving health gate in CI/cron.

``--json`` switches every command to machine-readable output.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

from repro.artifact import registry as reg


def _fmt_bytes(n: int) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{n}B"
        n /= 1024
    return f"{n}B"               # pragma: no cover


def cmd_list(root: str, as_json: bool) -> int:
    models = reg.list_models(root)
    if as_json:
        print(json.dumps(models, indent=1, sort_keys=True))
        return 0
    if not models:
        print(f"registry {root}: empty")
        return 0
    for name in sorted(models):
        m = models[name]
        print(f"{name}  (latest: v{m['latest']})")
        for v in sorted(m["versions"], key=int):
            e = m["versions"][v]
            meta = f"  {e['metadata']}" if e.get("metadata") else ""
            print(f"  v{v}: {e['file']}  {_fmt_bytes(e['bytes'])}  "
                  f"sha256={e['sha256'][:12]}…{meta}")
    return 0


def cmd_inspect(root: str, spec: str, as_json: bool) -> int:
    from repro.artifact import format as afmt
    entry = reg.resolve(root, spec, verify=False)
    header = afmt.read_header(entry["path"])
    out = {"name": entry["name"], "version": entry["version"],
           "path": entry["path"], "index_entry": {
               k: v for k, v in entry.items()
               if k not in ("name", "version", "path")},
           "header": header}
    if as_json:
        print(json.dumps(out, indent=1, sort_keys=True, default=str))
        return 0
    print(f"{entry['name']}@{entry['version']}  -> {entry['path']}")
    print(f"  bytes={_fmt_bytes(entry['bytes'])}  "
          f"sha256={entry['sha256']}")
    if entry.get("metadata"):
        print(f"  metadata: {entry['metadata']}")
    cfg = header.get("config") or {}
    if cfg:
        print(f"  config: {cfg.get('name', '?')}  "
              f"family={cfg.get('family', '?')}  "
              f"layers={cfg.get('num_layers', '?')}  "
              f"d_model={cfg.get('d_model', '?')}")
    tensors = header.get("tensors") or header.get("sections") or []
    print(f"  header keys: {sorted(header)}  ({len(tensors)} tensor "
          f"records)" if tensors else f"  header keys: {sorted(header)}")
    return 0


def cmd_verify(root: str, specs: List[str], as_json: bool) -> int:
    targets: List[str] = []
    if specs:
        targets = specs
    else:
        for name, m in sorted(reg.list_models(root).items()):
            targets.extend(f"{name}@{v}" for v in sorted(m["versions"],
                                                         key=int))
    results = []
    failed = 0
    for spec in targets:
        try:
            entry = reg.resolve(root, spec, verify=False)
            actual = reg.sha256_file(entry["path"]) \
                if os.path.exists(entry["path"]) else None
            ok = actual == entry["sha256"]
        except (KeyError, FileNotFoundError) as e:
            results.append({"spec": spec, "ok": False, "error": str(e)})
            failed += 1
            continue
        results.append({"spec": f"{entry['name']}@{entry['version']}",
                        "ok": ok,
                        "expected": entry["sha256"],
                        "actual": actual})
        failed += 0 if ok else 1
    if as_json:
        print(json.dumps({"verified": len(results), "failed": failed,
                          "results": results}, indent=1))
    else:
        for r in results:
            mark = "ok " if r["ok"] else "FAIL"
            detail = r.get("error") or \
                (f"sha256 mismatch (file {str(r.get('actual'))[:12]}…)"
                 if not r["ok"] else f"sha256={r['expected'][:12]}…")
            print(f"[{mark}] {r['spec']}  {detail}")
        print(f"{len(results)} verified, {failed} failed")
    return 1 if failed else 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="list/inspect/verify the model artifact registry")
    p.add_argument("--registry", required=True,
                   help="registry root directory")
    p.add_argument("--json", action="store_true",
                   help="machine-readable output")
    sub = p.add_subparsers(dest="cmd", required=True)
    sub.add_parser("list", help="all models and versions")
    pi = sub.add_parser("inspect", help="one entry + artifact header")
    pi.add_argument("spec", help="name[@version]")
    pv = sub.add_parser("verify",
                        help="re-hash artifacts against recorded sha256")
    pv.add_argument("specs", nargs="*",
                    help="name[@version]... (default: everything)")
    args = p.parse_args(argv)
    if not os.path.isdir(args.registry):
        print(f"no registry at {args.registry}", file=sys.stderr)
        return 2
    if args.cmd == "list":
        return cmd_list(args.registry, args.json)
    if args.cmd == "inspect":
        return cmd_inspect(args.registry, args.spec, args.json)
    return cmd_verify(args.registry, args.specs, args.json)


if __name__ == "__main__":
    raise SystemExit(main())
