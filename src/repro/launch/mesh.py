"""Production mesh construction.

Function (not module constant) on purpose: importing this module must not
touch jax device state — the dry-run sets XLA_FLAGS before first jax init,
smoke tests see one device.

Single pod  : (data=16, model=16)              = 256 chips (v5e pod)
Multi-pod   : (pod=2, data=16, model=16)       = 512 chips
The "pod" axis is the slow-ICI/DCN dimension: pure data parallelism,
gradient all-reduce only (optionally compressed, train/grad_compress.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh for elastic-rescale restarts (e.g. (8,16) after
    losing half a pod): checkpoints restore onto any mesh (train/checkpoint
    elastic-remesh path)."""
    return jax.make_mesh(shape, axes)


def single_device_mesh():
    """1-chip mesh with the standard axis names (CI / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_serving_mesh(tp: int, *, data: int = 1):
    """(data, model=tp) mesh over the FIRST data*tp local devices.

    Built with a raw ``Mesh`` over a device subset (``jax.make_mesh``
    wants to place over all devices) so a tp=2 engine works on a
    host-simulated 8-device CPU (XLA_FLAGS=--xla_force_host_platform_
    device_count=8) and on a partial slice.
    """
    import numpy as np
    from jax.sharding import Mesh

    n = data * tp
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(
            f"mesh (data={data}, model={tp}) needs {n} devices, "
            f"have {len(devs)}")
    return Mesh(np.array(devs[:n]).reshape(data, tp), ("data", "model"))


# TPU v5e hardware model for the roofline (assignment constants)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~per axis direction)
HBM_BYTES = 16 * 2 ** 30      # 16 GiB per chip
