"""Production training runner: mesh + sharded state + data pipeline +
checkpoint/restart + fault tolerance, end to end.

This is the program a pod job runs.  On this CPU container it runs the
same code path on a (1,1) mesh (or --mesh data,model sizes) with reduced
configs — integration tests and examples drive it that way, which is the
point: one code path from laptop to 512 chips.

    python -m repro.launch.train --arch qwen3-1.7b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Fault-tolerance wiring:
- PreemptionGuard: SIGTERM -> emergency checkpoint -> clean exit (restart
  resumes from it; exercised in tests/test_integration.py).
- Heartbeat file per step (watchdog input).
- StepTimer straggler detection (logged; a fleet supervisor consumes it).
- run_with_restarts: in-process restart controller for crash recovery.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as C
from repro import policy
from repro.configs.reduced import reduced as reduce_cfg
from repro.data import lm_stream, pipeline
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_lib
from repro.models import build
from repro.train import checkpoint as ckpt_lib
from repro.train import fault_tolerance as ft
from repro.train import grad_compress
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib


def build_runner(cfg, mesh, *, optimizer_name="adamw", lr=3e-4,
                 num_microbatches=1, clip_norm=1.0, total_steps=10000,
                 grad_compressor=None, compress_ratio=0.125):
    model = build(cfg)
    warmup = max(10, min(100, total_steps // 10))
    optimizer = opt_lib.make(optimizer_name,
                             opt_lib.warmup_cosine_lr(lr, warmup,
                                                      total_steps))
    with_residual = grad_compressor is not None
    train_step = step_lib.make_train_step(
        model, optimizer, num_microbatches=num_microbatches,
        clip_norm=clip_norm, grad_compressor=grad_compressor,
        compress_ratio=compress_ratio)
    rules = (shd.MULTI_POD_RULES if "pod" in mesh.axis_names
             else shd.SINGLE_POD_RULES)

    # spec construction under the active mesh: bank_pspec derives its
    # shard grid from the mesh axes (a (1,1) CI mesh shards nothing it
    # can't; an elastic (8,16) restart shards what it can)
    with shd.use_mesh(mesh, rules):
        state_specs = step_lib.state_pspecs(model, optimizer,
                                            with_residual=with_residual)

    def resolve(tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, shd.resolve_spec(s, rules)),
            tree, is_leaf=lambda x: isinstance(x, P))

    state_sh = resolve(state_specs)

    def stepped(state, batch):
        with shd.use_mesh(mesh, rules):
            return train_step(state, batch)

    jitted = jax.jit(stepped, in_shardings=(state_sh, None),
                     out_shardings=(state_sh, None), donate_argnums=(0,))

    def init_state():
        with shd.use_mesh(mesh, rules):
            init = jax.jit(
                lambda k: step_lib.init_state(
                    model, optimizer, k, with_residual=with_residual),
                out_shardings=state_sh)
            return init(jax.random.PRNGKey(0))

    return model, jitted, init_state, state_specs, state_sh


def run(cfg, mesh, *, steps: int, batch: int, seq: int,
        ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
        num_microbatches: int = 1, log_every: int = 10,
        heartbeat_path: Optional[str] = None,
        lr: float = 3e-4, grad_compressor: Optional[str] = None,
        artifact_dir: Optional[str] = None,
        registry_root: Optional[str] = None
        ) -> Dict[str, Any]:
    model, train_step, init_state, state_specs, state_sh = build_runner(
        cfg, mesh, num_microbatches=num_microbatches, lr=lr,
        total_steps=steps, grad_compressor=grad_compressor)

    # the guard covers init/restore too: a preemption signal during the
    # (potentially minutes-long) first compile must not hard-kill the job
    guard_cm = ft.PreemptionGuard()
    guard = guard_cm.__enter__()
    start_step = 0
    state = None
    if ckpt_dir and ckpt_lib.latest_step(ckpt_dir) is not None:
        target = jax.eval_shape(init_state)
        with shd.use_mesh(mesh):
            state = ckpt_lib.restore(
                ckpt_dir, target, mesh=mesh,
                pspecs=jax.tree.map(
                    lambda s: shd.resolve_spec(s), state_specs,
                    is_leaf=lambda x: isinstance(x, P)))
        start_step = int(np.asarray(state["step"]))
        print(f"restored checkpoint @ step {start_step}", flush=True)
    if state is None:
        state = init_state()

    export_hook = None
    if artifact_dir:
        export_hook = ckpt_lib.artifact_exporter(
            cfg, artifact_dir, registry_root=registry_root)

    hb = ft.Heartbeat(heartbeat_path or
                      os.path.join(ckpt_dir or "/tmp", "heartbeat.json"),
                      host_id=jax.process_index())
    timer = ft.StepTimer()
    it = lm_stream.batches(seed=17, batch=batch, seq_len=seq,
                           vocab=cfg.vocab_size,
                           host_id=jax.process_index(),
                           num_hosts=jax.process_count(),
                           start_step=start_step)
    try:
        with shd.use_mesh(mesh):
            data = pipeline.Prefetcher(it, place=lambda b:
                                       pipeline.shard_batch(b, mesh))
            losses = []
            for step_i in range(start_step, steps):
                timer.start()
                batch_arrays = next(data)
                state, metrics = train_step(state, batch_arrays)
                loss = float(np.asarray(metrics["loss"]))
                losses.append(loss)
                t = timer.stop()
                hb.beat(step_i, loss=loss)
                if log_every and (step_i % log_every == 0):
                    print(f"step {step_i:5d} loss {loss:.4f} "
                          f"({t['step_time']:.2f}s"
                          f"{' STRAGGLER' if t['straggler'] else ''})",
                          flush=True)
                want_ckpt = ckpt_dir and (
                    (step_i + 1) % ckpt_every == 0 or guard.should_stop
                    or step_i + 1 == steps)
                if want_ckpt:
                    ckpt_lib.save(state, ckpt_dir, step_i + 1,
                                  on_save=export_hook)
                if guard.should_stop:
                    print("preemption: emergency checkpoint saved, "
                          "exiting cleanly", flush=True)
                    break
    finally:
        guard_cm.__exit__(None, None, None)
    return {"final_step": int(np.asarray(state["step"])),
            "losses": losses,
            "straggler_count": timer.stragglers}


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True, choices=C.names())
    p.add_argument("--reduced", action="store_true",
                   help="CPU-scale variant of the arch (same family)")
    p.add_argument("--hashed", action="store_true",
                   help="enable the paper's hashed weight sharing")
    p.add_argument("--compression", type=float, default=None,
                   help="uniform hashed compression ratio (default 0.125)")
    p.add_argument("--policy", default=None,
                   help="compression policy JSON (per-slot rules; implies "
                        "hashing — see repro.policy)")
    p.add_argument("--budget", default=None,
                   help="equal-memory target: total real params as a "
                        "ratio of dense ('0.125' or '1/8'); solver "
                        "allocates per-slot ratios (implies hashing)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--mesh", default="1,1",
                   help="data,model (or pod,data,model) sizes")
    p.add_argument("--grad-compress", default=None,
                   choices=[None, "hashed_space", "int8"],
                   help="cross-pod gradient compression (error feedback)")
    p.add_argument("--artifact-dir", default=None,
                   help="export a compressed model artifact alongside "
                        "every committed checkpoint")
    p.add_argument("--artifact-quant", default="none",
                   choices=["none", "int8", "fp8"],
                   help="bank quantization for exported artifacts")
    p.add_argument("--registry", default=None,
                   help="model registry root; exported artifacts are "
                        "registered under the config name")
    args = p.parse_args()
    if (args.artifact_quant != "none" or args.registry) \
            and not args.artifact_dir:
        p.error("--artifact-quant/--registry require --artifact-dir")
    if args.artifact_dir and not args.ckpt_dir:
        p.error("--artifact-dir requires --ckpt-dir (artifacts are "
                "exported at checkpoint commits)")

    cfg = C.get(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    if args.policy or args.budget:
        if args.hashed or args.compression is not None:
            p.error("--policy/--budget replace --hashed/--compression "
                    "(pin ratios with a policy rule instead)")
        pol = (policy.load(args.policy) if args.policy
               else policy.CompressionPolicy())
        if args.budget:
            pol = dataclasses.replace(
                pol, budget=policy.parse_ratio(args.budget))
        cfg = cfg.policy_variant(pol)
    elif args.hashed:
        cfg = cfg.hashed_variant(args.compression
                                 if args.compression is not None else 0.125)
    if args.artifact_quant != "none":
        cfg = cfg.with_(artifact_quant=args.artifact_quant)

    sizes = tuple(int(x) for x in args.mesh.split(","))
    axes = ("pod", "data", "model")[-len(sizes):]
    mesh = mesh_lib.make_mesh(sizes, axes)

    out = run(cfg, mesh, steps=args.steps, batch=args.batch, seq=args.seq,
              ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
              num_microbatches=args.microbatches, lr=args.lr,
              grad_compressor=args.grad_compress,
              artifact_dir=args.artifact_dir, registry_root=args.registry)
    print(json.dumps({k: v for k, v in out.items() if k != "losses"}))
    print(f"loss: first={out['losses'][0]:.4f} last={out['losses'][-1]:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
