"""Hierarchical cost analysis over optimized (SPMD-partitioned) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
but every model here scans over layers (and q-chunks, and microbatches), so
XLA's flat numbers understate FLOPs/bytes/collectives by the trip count
(verified empirically: scan-of-8-matmuls reports 1/8 the flops of the
unrolled version).  We therefore parse the optimized HLO ourselves:

1. split the module into computations, each a list of instructions with a
   local name -> shape map;
2. derive each ``while`` loop's trip count from its condition computation
   (counted loops from lax.scan compare the induction variable against a
   constant: trip = that constant);
3. propagate call multipliers from ENTRY through calls/bodies
   (``fusion``/``call`` keep the parent multiplier; ``while`` bodies
   multiply by trip count; fusion bodies contribute FLOPs but no HBM bytes
   — they are single kernels);
4. count per instruction:
   - FLOPs: ``dot`` = 2 * out_elems * contracted_elems (batch dims fall out
     of out_elems); elementwise/reduce flops are negligible at LLM scale
     and ignored (documented under-count < 2%);
   - HBM bytes: operand bytes + output bytes for every materializing
     instruction (post-fusion HLO = one kernel per instruction, so this is
     the fusion-aware traffic proxy);
   - collective wire bytes: ring-scaled per kind (see roofline.py).

The result also keeps the top-k heaviest dots/collectives/memory ops with
shapes — the profile the perf loop (EXPERIMENTS.md §Perf) reads.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# shape group is lazy-greedy: tuple shapes may contain layout braces and
# /*index=N*/ comments; the opcode is the first bare `word(` after it.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\(")
# greedy .*: computation params may be tuple types with nested parens
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{$")
_CALL_ATTR_RE = re.compile(r"(calls|body|condition|to_apply)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
# ops that never materialize a new buffer / are control-only
_NO_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "while", "call", "conditional", "after-all",
             "custom-call", "partition-id", "replica-id", "iota"}


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        b = _DTYPE_BYTES.get(dtype)
        if b is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * b
    return total


def shape_dims(shape_str: str) -> List[int]:
    """Dims of the FIRST array shape in the string."""
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if cur is None:
            m = _COMP_HDR_RE.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(1), [], {})
                if stripped.startswith("ENTRY"):
                    entry = m.group(1)
                continue
        else:
            if stripped == "}" or stripped.startswith("}"):
                comps[cur.name] = cur
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, shape, opcode = m.groups()
            args = line.split(opcode + "(", 1)
            operands: List[str] = []
            if len(args) > 1:
                depth = 0
                buf = ""
                for ch in args[1]:
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        if depth == 0:
                            break
                        depth -= 1
                    buf += ch
                operands = [a.strip().lstrip("%") for a in buf.split(",")
                            if a.strip()]
            cur.instrs.append(Instr(name, shape, opcode, operands, line))
            cur.shapes[name] = shape
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Counted-loop trip count: the constant in the condition's compare.
    lax.scan loops run [0, N) step 1; the compare constant is N."""
    consts = []
    for ins in cond.instrs:
        m = _CONST_RE.search(ins.line)
        if m:
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def _callees(ins: Instr) -> List[Tuple[str, str]]:
    return [(kind, name) for kind, name in _CALL_ATTR_RE.findall(ins.line)]


def call_multipliers(comps: Dict[str, Computation], entry: str
                     ) -> Dict[str, Tuple[float, float]]:
    """name -> (flops_mult, bytes_mult) accumulated over all call sites."""
    mult: Dict[str, Tuple[float, float]] = {entry: (1.0, 1.0)}
    order = [entry]
    seen = {entry}
    # BFS; the call graph is a DAG in HLO
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        fm, bm = mult[cname]
        comp = comps.get(cname)
        if comp is None:
            continue
        for ins in comp.instrs:
            for kind, callee in _callees(ins):
                if callee not in comps:
                    continue
                if kind == "body":
                    cond_name = dict(_callees(ins)).get("condition")
                    trips = _trip_count(comps[cond_name]) \
                        if cond_name and cond_name in comps else 1
                    dfm, dbm = fm * trips, bm * trips
                elif kind == "condition":
                    trips = _trip_count(comps[callee])
                    dfm, dbm = fm * trips, bm * trips
                elif kind == "calls":   # fusion: flops yes, bytes no
                    dfm, dbm = fm, 0.0
                elif kind == "to_apply":
                    if ins.opcode == "call":
                        # XLA CPU wraps loop bodies: call(...), to_apply=%wide...
                        dfm, dbm = fm, bm
                    else:   # reduce/scatter/sort combiner: negligible
                        continue
                else:
                    dfm, dbm = fm, bm
                pf, pb = mult.get(callee, (0.0, 0.0))
                mult[callee] = (pf + dfm, pb + dbm)
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
    return mult


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = 1
    for d in shape_dims(ins.shape):
        out_elems *= d
    lhs_shape = comp.shapes.get(ins.operands[0], "") if ins.operands else ""
    lhs_dims = shape_dims(lhs_shape)
    m = _CONTRACT_RE.search(ins.line)
    contracted = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx:
                contracted *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contracted


def _sliced_operand_bytes(comp: Computation, param_idx: int,
                          full_bytes: int) -> int:
    """Bytes actually read from a fusion operand: if every use of the
    parameter inside the fused computation is a dynamic-slice / gather /
    slice, only the slice outputs move from HBM — not the full operand.
    (Without this, scan-over-stacked-layer-params charges the FULL stacked
    parameter array once per layer: a 126x overcount at llama3 scale.)"""
    pname = None
    for ins in comp.instrs:
        if ins.opcode == "parameter" and f"parameter({param_idx})" in ins.line:
            pname = ins.name
            break
    if pname is None:
        return full_bytes
    sliced = 0
    for ins in comp.instrs:
        if pname not in ins.operands:
            continue
        if ins.opcode in ("dynamic-slice", "gather", "slice"):
            # reads only the slice it produces
            if ins.operands and ins.operands[0] == pname:
                sliced += shape_bytes(ins.shape)
            else:       # param used as an index operand: negligible
                sliced += 0
        elif ins.opcode == "dynamic-update-slice":
            if ins.operands and ins.operands[0] == pname:
                # in-place update: writes the update region only
                upd = ins.operands[1] if len(ins.operands) > 1 else ""
                sliced += shape_bytes(comp.shapes.get(upd, ""))
            else:
                sliced += 0
        elif ins.opcode in ("bitcast", "tuple", "get-tuple-element"):
            sliced += 0   # aliasing only
        else:
            return full_bytes   # some use touches the whole operand
    return min(sliced, full_bytes)


def _root_effective_out_bytes(comp: Computation, full_bytes: int) -> int:
    """Effective bytes WRITTEN by a fusion: a root dynamic-update-slice
    writes only its update region (the buffer is updated in place)."""
    root = comp.instrs[-1] if comp.instrs else None
    if root is None:
        return full_bytes
    if root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
        upd_bytes = shape_bytes(comp.shapes.get(root.operands[1], ""))
        return min(upd_bytes, full_bytes)
    return full_bytes


def instr_hbm_bytes(ins: Instr, comp: Computation,
                    comps: Dict[str, Computation]) -> float:
    """HBM traffic of one (post-fusion) instruction."""
    base = ins.opcode
    out_b = shape_bytes(ins.shape)
    if base == "fusion":
        called = None
        for kind, cal in _CALL_ATTR_RE.findall(ins.line):
            if kind == "calls":
                called = comps.get(cal)
        in_b = 0
        for idx, op in enumerate(ins.operands):
            fb = shape_bytes(comp.shapes.get(op, ""))
            if called is not None:
                fb = _sliced_operand_bytes(called, idx, fb)
            in_b += fb
        if called is not None:
            out_b = _root_effective_out_bytes(called, out_b)
        return float(in_b + out_b)
    if base in ("dynamic-slice", "slice"):
        return float(2 * out_b)
    if base == "gather":
        idx_b = shape_bytes(comp.shapes.get(ins.operands[1], "")) \
            if len(ins.operands) > 1 else 0
        return float(2 * out_b + idx_b)
    if base == "dynamic-update-slice":
        upd = shape_bytes(comp.shapes.get(ins.operands[1], "")) \
            if len(ins.operands) > 1 else 0
        return float(2 * upd)
    if base == "scatter":
        upd = shape_bytes(comp.shapes.get(ins.operands[-1], "")) \
            if ins.operands else 0
        return float(3 * upd + out_b * 0)   # read+modify+write updates
    in_b = sum(shape_bytes(comp.shapes.get(op, "")) for op in ins.operands)
    return float(in_b + out_b)


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(1, len([t for t in m.group(1).split(",") if t.strip()]))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return max(1, int(m.group(2)))
    return 2


def _collective_wire(ins: Instr, comp: Computation) -> float:
    in_bytes = sum(shape_bytes(comp.shapes.get(op, op))
                   for op in ins.operands)
    out_bytes = shape_bytes(ins.shape)
    g = _group_size(ins.line)
    ring = (g - 1) / g
    base = ins.opcode[:-6] if ins.opcode.endswith("-start") else ins.opcode
    if base == "all-gather":
        return ring * out_bytes
    if base == "all-reduce":
        return 2 * ring * in_bytes
    if base == "reduce-scatter":
        return ring * in_bytes
    if base == "all-to-all":
        return ring * in_bytes
    return float(in_bytes)  # collective-permute


def analyze_text(text: str, top_k: int = 12) -> Dict[str, Any]:
    comps, entry = parse_module(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    mult = call_multipliers(comps, entry)

    flops = 0.0
    hbm_bytes = 0.0
    wire = 0.0
    wire_by_kind: Dict[str, float] = {}
    n_coll = 0
    top_dots: List[Tuple[float, str]] = []
    top_colls: List[Tuple[float, str]] = []
    top_mem: List[Tuple[float, str]] = []

    for cname, comp in comps.items():
        fm, bm = mult.get(cname, (0.0, 0.0))
        if fm == 0.0 and bm == 0.0:
            continue
        for ins in comp.instrs:
            base = ins.opcode[:-6] if ins.opcode.endswith("-start") \
                else ins.opcode
            if base in ("dot", "convolution") and fm > 0:
                f = _dot_flops(ins, comp) * fm
                flops += f
                top_dots.append((f, f"{fm:g}x {ins.line.strip()[:160]}"))
            if base in COLLECTIVES and fm > 0:
                w = _collective_wire(ins, comp) * fm
                wire += w
                wire_by_kind[base] = wire_by_kind.get(base, 0.0) + w
                n_coll += int(fm)
                top_colls.append((w, f"{fm:g}x {ins.line.strip()[:160]}"))
            if bm > 0 and base not in _NO_BYTES \
                    and not base.endswith("-done"):
                b = instr_hbm_bytes(ins, comp, comps) * bm
                hbm_bytes += b
                top_mem.append((b, f"{bm:g}x {ins.opcode} "
                                   f"{ins.shape[:80]}"))

    def top(lst):
        return [f"{v:.3e}  {s}" for v, s in
                sorted(lst, key=lambda t: -t[0])[:top_k]]

    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "wire_bytes": wire,
        "wire_by_kind": wire_by_kind,
        "n_collectives": n_coll,
        "top_dots": top(top_dots),
        "top_collectives": top(top_colls),
        "top_memory_ops": top(top_mem),
    }
