"""HTTP serving launcher: the asyncio front-end over one engine or a
multi-model roster.

    # one model from config flags
    python -m repro.launch.serve_http --arch qwen3-1.7b --reduced \
        --hashed --port 8080

    # a catalog out of the sha256 registry (repeat --model-name)
    python -m repro.launch.serve_http --registry runs/registry \
        --model-name qwen3-dense --model-name qwen3-hashed@2 \
        --quota qwen3-hashed=128 --port 8080

    curl -N -X POST localhost:8080/v1/completions -d \
        '{"model":"qwen3-hashed","prompt":[12,7,99],"max_tokens":8,
          "stream":true}'

SIGINT/SIGTERM drain gracefully: stop admitting (503), cancel queued
(terminal "cancelled" deltas), finish in-flight rows, print the final
metrics table, exit.  A second signal force-quits.

``--self-test`` (the CI smoke mode) starts the server on an ephemeral
port, runs one streaming and one non-streaming completion against it,
and asserts both are token-identical to driving an identically-seeded
`Engine` directly — then exits 0/1.
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import signal
import sys

import jax
import numpy as np

import repro.configs as C
from repro import policy
from repro.configs.reduced import reduced as reduce_cfg
from repro.models import build
from repro.serving.api import SamplingParams
from repro.serving.engine import Engine, Request
from repro.serving.http import HTTPFrontend
from repro.serving.http import client as http_client
from repro.serving.multi_model import MultiModelEngine
from repro.serving.scheduler import SchedulerConfig


def _parse_args(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, choices=C.names())
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--hashed", action="store_true")
    p.add_argument("--compression", type=float, default=None)
    p.add_argument("--policy", default=None,
                   help="compression policy JSON (implies hashing)")
    p.add_argument("--budget", default=None,
                   help="equal-memory ratio ('1/8'; implies hashing)")
    p.add_argument("--registry", default=None,
                   help="model registry root (with --model-name)")
    p.add_argument("--model-name", action="append", default=None,
                   metavar="NAME[@VER]",
                   help="registered model to host (repeatable; two or "
                        "more build a multi-model engine over one "
                        "shared page pool)")
    p.add_argument("--quota", action="append", default=None,
                   metavar="NAME=PAGES",
                   help="per-model page quota on the shared pool "
                        "(repeatable)")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8080,
                   help="0 picks an ephemeral port")
    p.add_argument("--max-concurrency", type=int, default=4)
    p.add_argument("--max-len", type=int, default=256)
    p.add_argument("--page-size", type=int, default=16)
    p.add_argument("--num-pages", type=int, default=None)
    p.add_argument("--prefix-cache", action="store_true")
    p.add_argument("--scheduler", default="fifo",
                   choices=("fifo", "priority"))
    p.add_argument("--queue-limit", type=int, default=256)
    p.add_argument("--deadline", type=float, default=None,
                   help="queue deadline in seconds (maps to HTTP 504)")
    p.add_argument("--seed", type=int, default=0,
                   help="engine auto-seed stream")
    p.add_argument("--self-test", action="store_true",
                   help="CI smoke: serve on an ephemeral port, run one "
                        "streaming + one JSON completion, assert "
                        "token-identity vs a direct Engine, exit")
    return p.parse_args(argv)


def _build_from_flags(args):
    cfg = C.get(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    if args.policy or args.budget:
        pol = (policy.load(args.policy) if args.policy
               else policy.CompressionPolicy())
        if args.budget:
            pol = dataclasses.replace(
                pol, budget=policy.parse_ratio(args.budget))
        cfg = cfg.policy_variant(pol)
    elif args.hashed:
        cfg = cfg.hashed_variant(args.compression
                                 if args.compression is not None
                                 else 0.125)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _sched_cfg(args) -> SchedulerConfig:
    return SchedulerConfig(policy=args.scheduler,
                           max_queue=args.queue_limit,
                           deadline_s=args.deadline)


def _quotas(args):
    out = {}
    for spec in args.quota or ():
        name, _, pages = spec.partition("=")
        if not pages.isdigit():
            raise SystemExit(f"bad --quota {spec!r} (want NAME=PAGES)")
        out[name] = int(pages)
    return out


def _make_engine(args):
    """Returns (engine, default_model_tag)."""
    if args.model_name:
        if not args.registry:
            raise SystemExit("--model-name requires --registry")
        mm = MultiModelEngine.from_registry(
            args.registry, args.model_name,
            quotas=_quotas(args),
            model_kwargs={
                tag.split("@", 1)[0]: dict(
                    slots=args.max_concurrency, max_len=args.max_len,
                    seed=args.seed, prefix_cache=args.prefix_cache)
                for tag in args.model_name},
            page_size=args.page_size, num_pages=args.num_pages,
            scheduler=_sched_cfg(args))
        return mm, mm.models()[0]
    if not args.arch:
        raise SystemExit("--arch or --registry/--model-name required")
    cfg, model, params = _build_from_flags(args)
    eng = Engine(model, params, slots=args.max_concurrency,
                 max_len=args.max_len, eos_id=-1, seed=args.seed,
                 page_size=args.page_size, num_pages=args.num_pages,
                 prefix_cache=args.prefix_cache,
                 scheduler=_sched_cfg(args))
    return eng, cfg.name


async def _serve(args) -> int:
    eng, default_tag = _make_engine(args)
    fe = HTTPFrontend(eng, host=args.host, port=args.port,
                      default_model=default_tag)
    await fe.start()
    print(f"serving on http://{fe.host}:{fe.port}  "
          f"models={fe.model_names()}", flush=True)
    loop = asyncio.get_running_loop()
    sig_count = {"n": 0}

    def _on_signal():
        sig_count["n"] += 1
        if sig_count["n"] > 1:
            sys.exit(130)
        print("\ndraining: no new work, finishing in-flight rows "
              "(signal again to force-quit)", flush=True)
        fe.begin_drain()

    for s in (signal.SIGINT, signal.SIGTERM):
        loop.add_signal_handler(s, _on_signal)
    await fe.wait_drained()
    await fe.aclose()
    print("--- metrics ---")
    print(fe.metrics.render())
    return 0


async def _self_test(args) -> int:
    """Start, hit the server both ways, pin identity vs direct Engine."""
    if not args.arch:
        raise SystemExit("--self-test needs --arch")
    cfg, model, params = _build_from_flags(args)
    mk = dict(slots=args.max_concurrency, max_len=args.max_len,
              eos_id=-1, seed=args.seed, page_size=args.page_size,
              prefix_cache=args.prefix_cache, scheduler=_sched_cfg(args))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size,
                            int(rng.integers(4, 16))).astype(np.int32)
               for _ in range(2)]
    sp = [SamplingParams(temperature=0.8, seed=11 + i, max_tokens=8)
          for i in range(2)]

    ref = Engine(model, params, **mk)
    for i, pr in enumerate(prompts):
        ref.submit(Request(uid=i, prompt=pr, sampling=sp[i]))
    ref.run()
    want = {r.uid: list(r.tokens) for r in ref._done}

    fe = HTTPFrontend(Engine(model, params, **mk), host=args.host,
                      port=0, default_model=cfg.name)
    await fe.start()
    host, port = fe.host, fe.port
    payloads = [dict(model=cfg.name, prompt=[int(t) for t in prompts[i]],
                     max_tokens=8, temperature=0.8, seed=11 + i)
                for i in range(2)]

    status, models = await http_client.request(host, port, "GET",
                                               "/v1/models")
    assert status == 200 and models["data"][0]["id"] == cfg.name, models
    status, body = await http_client.request(
        host, port, "POST", "/v1/completions", payloads[0])
    assert status == 200, (status, body)
    got_json = body["choices"][0]["token_ids"]
    streamed = await http_client.collect_stream(host, port, payloads[1])
    await fe.aclose()

    ok = got_json == want[0] and streamed["tokens"] == want[1]
    print(json.dumps({
        "self_test": "pass" if ok else "FAIL",
        "json_tokens": got_json, "stream_tokens": streamed["tokens"],
        "expected": {str(k): v for k, v in want.items()},
        "stream_ttft_s": streamed["ttft_s"]}))
    return 0 if ok else 1


def main(argv=None) -> int:
    args = _parse_args(argv)
    if args.self_test:
        return asyncio.run(_self_test(args))
    return asyncio.run(_serve(args))


if __name__ == "__main__":
    raise SystemExit(main())
