"""Serving runner: batched prefill/decode with the continuous-batching
engine (repro.serving.engine).

    python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --requests 8 --max-new 16 \
        --temperature 0.8 --top-p 0.9 --seed 0 --stream

Sampling flags (--temperature/--top-k/--top-p/--min-p/--seed/--stop/
--logprobs) build one SamplingParams per request; --stream prints
RequestOutput deltas as tokens land.  With --seed, a rerun reproduces
every token (counter-based per-request PRNG streams).

Three cold-start sources, in priority order:

- --artifact <file.hnart>: compressed model artifact (config + hash
  seeds + banks in one mmap-able file; repro.artifact) — the production
  path: no checkpoint, no live config flags needed.
- --model-name <name[@version]> --registry <root>: resolve the artifact
  through the versioned registry (sha256-verified).
- --arch [--ckpt-dir]: build from config; load the generic training
  checkpoint if present, else random init.

Prints per-request generations + aggregate throughput.

Observability: --metrics-out dumps the full metrics-registry snapshot
as JSON (and prints a human-readable table on exit); --trace-out
records per-request spans (queued / prefill chunks / decode ticks /
preemptions / COW copies) as Chrome trace-event JSON — open the file
at https://ui.perfetto.dev.  --debug-leak-check audits the paged KV
cache's refcounts at shutdown.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

import repro.configs as C
from repro import policy
from repro.configs.reduced import reduced as reduce_cfg
from repro.models import build
from repro.serving.api import SamplingParams
from repro.serving.engine import Engine, Request
from repro.train import checkpoint as ckpt_lib


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, choices=C.names())
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--hashed", action="store_true")
    p.add_argument("--compression", type=float, default=None,
                   help="hashed compression ratio (default 0.125)")
    p.add_argument("--policy", default=None,
                   help="compression policy JSON (per-slot rules; "
                        "implies hashing)")
    p.add_argument("--budget", default=None,
                   help="equal-memory real-param target ratio "
                        "('0.125' or '1/8'; implies hashing)")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=None,
                   help="deprecated alias for --max-concurrency")
    p.add_argument("--max-concurrency", type=int, default=None,
                   help="decode batch width (rows admitted mid-flight)")
    p.add_argument("--page-size", type=int, default=16,
                   help="KV cache page size in tokens (paged decoders)")
    p.add_argument("--num-pages", type=int, default=None,
                   help="physical KV page pool size; default fully "
                        "provisions every row, less oversubscribes "
                        "(preemption absorbs overflow)")
    p.add_argument("--scheduler", default="fifo",
                   choices=("fifo", "priority"),
                   help="admission policy (FIFO within priority class)")
    p.add_argument("--queue-limit", type=int, default=256,
                   help="bounded queue depth; submits beyond are refused")
    p.add_argument("--deadline", type=float, default=None,
                   help="max queue wait in seconds before a request "
                        "expires unserved")
    p.add_argument("--attn-impl", default="ref",
                   choices=("ref", "pallas"),
                   help="paged decode attention: gather oracle or the "
                        "paged-gather Pallas kernel")
    p.add_argument("--prefix-cache", action="store_true",
                   help="dedup shared prompt prefixes across requests "
                        "(radix tree over KV pages, refcounts + "
                        "copy-on-write; paged decoders only)")
    p.add_argument("--prefill-chunk", type=int, default=None,
                   help="prefill long prompts N tokens per tick, "
                        "interleaved with decode (default: monolithic "
                        "prefill; paged decoders only)")
    p.add_argument("--spec-draft", default=None, metavar="POLICY",
                   help="enable self-speculative decoding: a policy "
                        "JSON path or compression ratio ('1/8') naming "
                        "the draft variant derived off the served "
                        "weights (same hash seeds; equal-ratio aliases "
                        "by reference).  Output stays bitwise identical "
                        "to non-speculative decode")
    p.add_argument("--spec-k", type=int, default=4,
                   help="draft proposal depth per tick (with "
                        "--spec-draft); with --spec-adaptive this is "
                        "k_max")
    p.add_argument("--spec-adaptive", action="store_true",
                   help="adapt the proposal depth within [1, --spec-k] "
                        "from an accept-rate EWMA; emitted tokens stay "
                        "bitwise identical (acceptance is equality)")
    p.add_argument("--mesh", default=None, metavar="TP|DxM",
                   help="tensor-parallel serving mesh: a model-axis "
                        "size ('2'), or 'DxM' for (data, model).  The "
                        "page pool and hashed banks shard over the "
                        "model axis; tokens stay bitwise identical to "
                        "single-device.  On CPU, host-simulate devices "
                        "with XLA_FLAGS=--xla_force_host_platform_"
                        "device_count=8")
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--max-len", type=int, default=256)
    p.add_argument("--temperature", type=float, default=0.0,
                   help="0 = greedy; > 0 samples through the fused "
                        "top-k/top-p/min-p pipeline")
    p.add_argument("--top-k", type=int, default=0,
                   help="keep only the k highest logits (0 disables)")
    p.add_argument("--top-p", type=float, default=1.0,
                   help="nucleus sampling mass (1.0 disables)")
    p.add_argument("--min-p", type=float, default=0.0,
                   help="drop tokens below min-p * max-prob (0 disables)")
    p.add_argument("--seed", type=int, default=None,
                   help="base sampling seed; request uid offsets it, so "
                        "a rerun reproduces every token (counter-based "
                        "PRNG: also bitwise across preemption and "
                        "prefix caching)")
    p.add_argument("--stop", action="append", default=None,
                   metavar="IDS",
                   help="comma-separated token ids forming a stop "
                        "sequence (repeatable); generation finishes "
                        "with reason 'stop' when the output ends with "
                        "any of them")
    p.add_argument("--logprobs", type=int, default=None,
                   help="report top-K (id, logprob) pairs per generated "
                        "token (0 = chosen token's logprob only)")
    p.add_argument("--stream", action="store_true",
                   help="print RequestOutput deltas as tokens land "
                        "instead of whole generations at the end")
    p.add_argument("--metrics-out", default=None, metavar="FILE",
                   help="write the metrics-registry snapshot (counters, "
                        "gauges, latency histograms) as JSON on exit")
    p.add_argument("--trace-out", default=None, metavar="FILE",
                   help="record per-request spans and write Chrome "
                        "trace-event JSON on exit (open in Perfetto)")
    p.add_argument("--debug-leak-check", action="store_true",
                   help="audit paged KV refcounts at shutdown; anomalies "
                        "export as the kv.leak_anomalies metric")
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--artifact", default=None,
                   help="serve from a compressed model artifact file")
    p.add_argument("--model-name", default=None,
                   help="registered model name[@version] (with --registry)")
    p.add_argument("--registry", default=None,
                   help="model registry root for --model-name")
    args = p.parse_args()

    if args.slots is not None and args.max_concurrency is not None:
        p.error("--slots is a deprecated alias for --max-concurrency; "
                "pass one, not both")
    concurrency = args.max_concurrency if args.max_concurrency is not None \
        else (args.slots if args.slots is not None else 4)
    from repro.obs import Tracer
    from repro.serving.scheduler import SchedulerConfig
    tracer = Tracer(enabled=bool(args.trace_out))
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serving_mesh
        if "x" in args.mesh:
            d, m = (int(v) for v in args.mesh.lower().split("x"))
        else:
            d, m = 1, int(args.mesh)
        mesh = make_serving_mesh(m, data=d)
        print(f"serving mesh: (data={d}, model={m}) over "
              f"{mesh.size} devices")
    engine_kwargs = dict(
        mesh=mesh, spec_adaptive=args.spec_adaptive,
        slots=concurrency, max_len=args.max_len, eos_id=-1,
        tracer=tracer, debug_leak_check=args.debug_leak_check,
        page_size=args.page_size, num_pages=args.num_pages,
        attn_impl=args.attn_impl, prefix_cache=args.prefix_cache,
        prefill_chunk=args.prefill_chunk,
        # raise the engine's logprob cap when the CLI asks for more
        # than the default report width
        max_logprobs=max(8, args.logprobs or 0),
        scheduler=SchedulerConfig(policy=args.scheduler,
                                  max_queue=args.queue_limit,
                                  deadline_s=args.deadline))

    if args.artifact and args.model_name:
        p.error("--artifact and --model-name are mutually exclusive")
    if args.artifact and args.registry:
        p.error("--registry goes with --model-name; a direct --artifact "
                "path bypasses registry integrity checks")
    if args.model_name and not args.registry:
        p.error("--model-name requires --registry")
    if args.artifact or args.model_name:
        # the artifact IS the model: config flags / checkpoints would be
        # silently ignored, so reject the incoherent combination
        ignored = [flag for flag, on in [
            ("--arch", args.arch), ("--ckpt-dir", args.ckpt_dir),
            ("--hashed", args.hashed), ("--reduced", args.reduced),
            ("--compression", args.compression is not None),
            ("--policy", args.policy), ("--budget", args.budget)] if on]
        if ignored:
            p.error(f"{'/'.join(ignored)} cannot be combined with an "
                    f"artifact source (the artifact carries its own "
                    f"config and weights)")
        t_load = time.perf_counter()   # duration: never the wall clock
        eng = Engine.from_artifact(
            args.artifact or args.model_name,
            registry_root=args.registry if args.model_name else None,
            draft_policy=args.spec_draft, spec_k=args.spec_k,
            **engine_kwargs)
        cfg = eng.model.cfg
        print(f"cold start from artifact: {cfg.name} "
              f"({time.perf_counter() - t_load:.2f}s to "
              f"params-on-device)")
    else:
        if not args.arch:
            p.error("--arch is required without --artifact/--model-name")
        cfg = C.get(args.arch)
        if args.reduced:
            cfg = reduce_cfg(cfg)
        if args.policy or args.budget:
            if args.hashed or args.compression is not None:
                p.error("--policy/--budget replace --hashed/--compression "
                        "(pin ratios with a policy rule instead)")
            pol = (policy.load(args.policy) if args.policy
                   else policy.CompressionPolicy())
            if args.budget:
                pol = dataclasses.replace(
                    pol, budget=policy.parse_ratio(args.budget))
            cfg = cfg.policy_variant(pol)
        elif args.hashed:
            cfg = cfg.hashed_variant(args.compression
                                     if args.compression is not None
                                     else 0.125)
        model = build(cfg)

        params = model.init(jax.random.PRNGKey(0))
        if args.ckpt_dir and ckpt_lib.latest_step(args.ckpt_dir) is not None:
            state = ckpt_lib.restore(
                args.ckpt_dir, {"params": params, "opt": None, "step": 0})
            params = state["params"]
            print(f"loaded params from {args.ckpt_dir}")
        draft = None
        if args.spec_draft:
            from repro.serving.draft import build_draft
            _, dmodel, dparams = build_draft(cfg, params, args.spec_draft)
            draft = (dmodel, dparams)
        eng = Engine(model, params, draft=draft, spec_k=args.spec_k,
                     **engine_kwargs)

    stop = tuple(tuple(int(t) for t in s.split(","))
                 for s in (args.stop or ()))

    def params_for(uid: int) -> SamplingParams:
        return SamplingParams(
            temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p, min_p=args.min_p, stop=stop,
            max_tokens=args.max_new,
            seed=None if args.seed is None else args.seed + uid,
            logprobs=args.logprobs)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()   # duration: never the wall clock
    handles = []
    for uid in range(args.requests):
        plen = int(rng.integers(4, 24))
        prompt = rng.integers(2, cfg.vocab_size, size=plen).astype(np.int32)
        extras = None
        if cfg.arch_kind == "encdec":
            extras = {"frames": rng.standard_normal(
                (1, cfg.encoder_seq, cfg.d_model)).astype(np.float32)}
        if cfg.num_image_tokens:
            extras = {"image_embeds": rng.standard_normal(
                (1, cfg.num_image_tokens, cfg.d_model)).astype(np.float32)}
        h = eng.submit(Request(uid=uid, prompt=prompt, extras=extras,
                               sampling=params_for(uid)))
        if not h:
            print(f"req {uid}: REFUSED (queue full or request can never "
                  f"fit the page pool — see --queue-limit/--num-pages)")
        else:
            handles.append(h)
    # graceful drain: first SIGINT/SIGTERM stops admitting (queued
    # requests get terminal "cancelled" deltas, in-flight rows finish,
    # the final metrics table still prints); a second one force-quits
    import signal
    drain = {"requested": False}

    def _on_signal(signum, frame):
        if drain["requested"]:
            raise SystemExit(130)
        drain["requested"] = True
        print(f"\n[signal {signum}] draining: finishing in-flight rows, "
              "cancelling queued (signal again to force-quit)")

    old_handlers = {s: signal.signal(s, _on_signal)
                    for s in (signal.SIGINT, signal.SIGTERM)}
    drained_queue = False
    try:
        # poll-style multiplexing: one engine loop, drain every handle's
        # available deltas per tick (terminal deltas too — including
        # "cancelled" ones emitted by a drain)
        while eng.pending():
            if drain["requested"] and not drained_queue:
                drained_queue = True
                n = len(eng.cancel_queued())
                if n:
                    print(f"cancelled {n} queued request(s)")
            eng.step()
            if args.stream:
                for h in handles:
                    for d in h.drain():
                        lp = "" if not d.new_logprobs else \
                            f"  lp={['%.3f' % v for v in d.new_logprobs]}"
                        fin = f"  [{d.finish_reason}]" if d.done else ""
                        print(f"req {d.uid} += {d.new_token_ids}{lp}{fin}")
        if args.stream and drain["requested"]:
            # emit any terminal deltas landed after the last tick
            for h in handles:
                for d in h.drain():
                    fin = f"  [{d.finish_reason}]" if d.done else ""
                    print(f"req {d.uid} += {d.new_token_ids}{fin}")
        done = [h.req for h in handles if h.req.done]
    finally:
        for s, old in old_handlers.items():
            signal.signal(s, old)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.tokens) for r in done)
    for r in sorted(done, key=lambda r: r.uid):
        print(f"req {r.uid}: {r.tokens}  "
              f"(finish={r.finish_reason}, seed={r.seed_used}, "
              f"logprob={r.cumulative_logprob:.3f})")
    stats = eng.stats()
    print(f"finish reasons: {stats['finish_reasons']}  "
          f"sampler dispatches: {stats['sampler_dispatches']} "
          f"({stats['sampler_time_s']:.3f}s in sampler over "
          f"{stats['ticks']} ticks)")
    if "spec" in stats:
        sp = stats["spec"]
        print(f"spec decode: accept_rate={sp['accept_rate']:.3f} "
              f"mean_accept_len={sp['mean_accept_len']:.2f} "
              f"(k={sp['k']}, {sp['draft_dispatches']} draft / "
              f"{sp['verify_dispatches']} verify dispatches)")
    summary = {"requests": len(done), "tokens": total_tokens,
               "wall_s": round(dt, 2),
               "tok_per_s": round(total_tokens / dt, 1)}
    summary.update(stats)
    print(json.dumps(summary))
    eng.shutdown()
    if eng.last_leak_error:
        print(f"LEAK CHECK FAILED:\n{eng.last_leak_error}")
    if args.metrics_out or drain["requested"]:
        # a drained run always prints the final table — the operator
        # asked the server to stop, not to discard its telemetry
        print("--- metrics ---")
        print(eng.metrics.render())
    if args.metrics_out:
        eng.metrics.export(args.metrics_out)
        print(f"metrics snapshot -> {args.metrics_out}")
    if args.trace_out:
        tracer.export(args.trace_out)
        print(f"trace -> {args.trace_out} "
              f"(open at https://ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
