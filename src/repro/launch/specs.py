"""(architecture x input-shape) cell definitions + abstract input specs.

Every assigned arch is paired with four shapes (train_4k / prefill_32k /
decode_32k / long_500k).  ``long_500k`` requires sub-quadratic attention
and is skipped (with the reason recorded) for pure full-attention archs —
DESIGN.md §5.  ``input_specs`` returns ShapeDtypeStruct stand-ins only:
weak-type-correct, shardable, zero device allocation.

``make_step`` assembles the exact jittable callable the production job
runs (train_step with optimizer / prefill / decode_step) together with its
abstract inputs and logical->physical resolved shardings for a given mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import repro.configs as C
from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd
from repro.models import build
from repro.nn import layers as L
from repro.train import optimizer as opt_lib
from repro.train import step as step_lib


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def skip_reason(cfg: ArchConfig, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and not cfg.subquadratic:
        return ("pure full-attention arch: 500k-token KV decode assigned "
                "only to SSM/hybrid/local-attention archs (DESIGN.md §5)")
    return None


def cells(include_skipped: bool = False):
    """[(arch_name, shape_name, skip_reason|None)] — 40 nominal cells."""
    out = []
    for arch in C.ASSIGNED:
        cfg = C.get(arch)
        for shape_name in SHAPES:
            reason = skip_reason(cfg, shape_name)
            if reason is None or include_skipped:
                out.append((arch, shape_name, reason))
    return out


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _model_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def cache_len(cfg: ArchConfig, cell: ShapeCell) -> int:
    return cell.seq + cfg.num_image_tokens


def train_batch_struct(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, Any]:
    b, s = cell.batch, cell.seq
    batch = {"tokens": _sds((b, s), jnp.int32),
             "targets": _sds((b, s), jnp.int32)}
    if cfg.arch_kind == "encdec":
        batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model),
                               _model_dtype(cfg))
    if cfg.num_image_tokens:
        batch["image_embeds"] = _sds((b, cfg.num_image_tokens, cfg.d_model),
                                     _model_dtype(cfg))
    return batch


def train_batch_pspecs(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, Any]:
    batch = {"tokens": P(L.BATCH, None), "targets": P(L.BATCH, None)}
    if cfg.arch_kind == "encdec":
        batch["frames"] = P(L.BATCH, None, None)
    if cfg.num_image_tokens:
        batch["image_embeds"] = P(L.BATCH, None, None)
    return batch


def input_specs(arch: str, shape_name: str, hashed: bool = False):
    """Abstract inputs for the cell's step fn (the dry-run entry point)."""
    cfg = C.get(arch)
    if hashed:
        cfg = cfg.hashed_variant()
    cell = SHAPES[shape_name]
    model = build(cfg)
    if cell.kind == "train":
        return train_batch_struct(cfg, cell)
    mlen = cache_len(cfg, cell)
    cache = jax.eval_shape(lambda: model.init_cache(cell.batch, mlen))
    if cell.kind == "prefill":
        batch = train_batch_struct(cfg, cell)
        del batch["targets"]
        batch["cache"] = cache
        return batch
    # decode: one new token against a full cache
    return {"tokens": _sds((cell.batch, 1), jnp.int32), "cache": cache}


# ---------------------------------------------------------------------------
# rules per cell (long-context cells use sequence/context parallelism)
# ---------------------------------------------------------------------------

def rules_for(mesh: Mesh, cell: ShapeCell,
              cfg: Optional[ArchConfig] = None) -> Dict[str, Any]:
    multi = "pod" in mesh.axis_names
    rules = dict(shd.MULTI_POD_RULES if multi else shd.SINGLE_POD_RULES)
    if cell.kind == "decode":
        # flash-decoding style: KV cache seq dim sharded over the model
        # axis (batch already covers data).  Attention reductions over the
        # sharded T decompose into tiny partial-softmax all-reduces —
        # measured 126x cheaper than all-gathering the cache (§Perf it.1).
        # (Tried for prefill too and REFUTED: the 32k-token cache WRITE
        # then thrashes reshardings, +84% collective — prefill keeps the
        # kv-head/head-dim TP sharding.)
        rules["seq"] = "model"
    else:
        rules["seq"] = None
    if cell.kind == "decode" and cell.batch > 1:
        # weights-stationary decode: replicate the (tiny) single-token
        # activations over data instead of all-gathering FSDP weight
        # shards per layer — partial dots reduce over data with MB-scale
        # all-reduces while weights and the KV cache stay fully sharded
        # (cache keeps its own batch axis).  §Perf it.5.
        rules["batch"] = None
    if cell.batch == 1:
        # context parallelism: batch unshardable; KV/seq over everything
        rules["batch"] = None
        rules["cache_batch"] = None
        rules["seq"] = (("pod", "data", "model") if multi
                        else ("data", "model"))
    seq_uses_model = rules.get("seq") is not None and \
        "model" in (rules["seq"] if isinstance(rules["seq"], tuple)
                    else (rules["seq"],))
    if seq_uses_model:
        # the model axis is spent on the cache seq dim — heads/head_dim
        # must not claim it too (one mesh axis per PartitionSpec)
        rules["tp_kv"], rules["tp_hd"] = None, None
    elif cfg is not None:
        # KV-cache TP axis by divisibility: heads if possible, else
        # head_dim (GQA archs have fewer kv heads than the 16-way axis).
        tp = mesh.shape["model"]
        kvh = cfg.num_kv_heads if cfg.arch_kind != "rwkv" \
            else cfg.d_model // cfg.head_dim
        if kvh % tp == 0:
            rules["tp_kv"], rules["tp_hd"] = "model", None
        elif cfg.head_dim % tp == 0:
            rules["tp_kv"], rules["tp_hd"] = None, "model"
        else:
            rules["tp_kv"], rules["tp_hd"] = None, None
    return rules


# ---------------------------------------------------------------------------
# step assembly
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepBundle:
    fn: Callable                 # jittable
    args: Tuple[Any, ...]        # abstract args (ShapeDtypeStructs)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    cfg: ArchConfig
    cell: ShapeCell
    meta: Dict[str, Any]


def make_step(arch: str, shape_name: str, mesh: Mesh, *,
              hashed: bool = False,
              num_microbatches: int = 1,
              rules: Optional[Dict[str, Any]] = None,
              optimizer_name: str = "adamw") -> StepBundle:
    cfg = C.get(arch)
    if hashed:
        cfg = cfg.hashed_variant()
    cell = SHAPES[shape_name]
    model = build(cfg)
    rules = rules or rules_for(mesh, cell, cfg)

    def resolve(spec_tree):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, shd.resolve_spec(s, rules)),
            spec_tree, is_leaf=lambda x: isinstance(x, P))

    meta = {"arch": cfg.name, "shape": shape_name, "kind": cell.kind,
            "seq": cell.seq, "batch": cell.batch, "hashed": hashed}

    if cell.kind == "train":
        optimizer = opt_lib.make(optimizer_name)
        train_step = step_lib.make_train_step(
            model, optimizer, num_microbatches=num_microbatches)
        state = jax.eval_shape(
            lambda: step_lib.init_state(model, optimizer,
                                        jax.random.PRNGKey(0)))
        # under the mesh: bank_pspec derives its shard grid from it
        with shd.use_mesh(mesh, rules):
            state_specs = step_lib.state_pspecs(model, optimizer)
        batch = train_batch_struct(cfg, cell)
        batch_specs = train_batch_pspecs(cfg, cell)
        in_sh = (resolve(state_specs), resolve(batch_specs))
        out_sh = (resolve(state_specs), None)

        def fn(state, batch):
            with shd.use_mesh(mesh, rules):
                return train_step(state, batch)

        return StepBundle(fn, (state, batch), in_sh, out_sh, cfg, cell, meta)

    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    with shd.use_mesh(mesh, rules):
        pspecs = model.pspecs()
    mlen = cache_len(cfg, cell)
    cache = jax.eval_shape(lambda: model.init_cache(cell.batch, mlen))
    cache_specs = model.cache_pspecs(cell.batch, mlen)

    if cell.kind == "prefill":
        batch = train_batch_struct(cfg, cell)
        del batch["targets"]
        batch["cache"] = cache
        batch_specs = train_batch_pspecs(cfg, cell)
        del batch_specs["targets"]
        batch_specs["cache"] = cache_specs
        in_sh = (resolve(pspecs), resolve(batch_specs))
        out_sh = (None, resolve(cache_specs))

        def fn(params, batch):
            with shd.use_mesh(mesh, rules):
                return model.prefill(params, batch)

        return StepBundle(fn, (params, batch), in_sh, out_sh, cfg, cell,
                          meta)

    # decode
    tokens = _sds((cell.batch, 1), jnp.int32)
    tok_spec = P(L.BATCH, None)
    in_sh = (resolve(pspecs), resolve(tok_spec), resolve(cache_specs))
    out_sh = (None, resolve(cache_specs))

    def fn(params, tokens, cache):
        with shd.use_mesh(mesh, rules):
            return model.decode_step(params, tokens, cache)

    return StepBundle(fn, (params, tokens, cache), in_sh, out_sh, cfg, cell,
                      meta)
