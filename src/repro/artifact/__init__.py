"""Compressed model artifacts (the paper's storage claim, deployable).

A HashedNet is fully described by its real parameter banks plus hash
seeds; the virtual weights are recomputed at load with no additional
memory overhead (Chen et al., 2015).  This package turns that into a
serving-grade pipeline:

- :mod:`repro.artifact.format`   — single-file mmap-able container
- :mod:`repro.artifact.quant`    — int8/fp8 bank quantization (per-group)
- :mod:`repro.artifact.io`       — zero-copy cold-start loading
- :mod:`repro.artifact.report`   — paper-style compression tables
- :mod:`repro.artifact.registry` — versioned name -> artifact resolution

Typical flow::

    from repro import artifact
    header = artifact.export_model("m.hnart", cfg, params, quant="int8")
    print(artifact.report.report("m.hnart"))
    cfg, model, params = artifact.load_model("m.hnart")
"""
from __future__ import annotations

from typing import Optional

from repro.artifact import format, io, quant, registry, report  # noqa: F401
from repro.artifact.io import load, load_model, open_artifact  # noqa: F401


def export_model(path: str, cfg, params, *, quant: Optional[str] = None,
                 group: Optional[int] = None, quant_min_size: int = 4096,
                 meta: Optional[dict] = None) -> dict:
    """Serialize a built model's params into a compressed artifact.

    quant/group default to the config's artifact knobs
    (cfg.artifact_quant / cfg.artifact_group).  Returns the header.
    """
    from repro.artifact import format as F
    from repro.models.transformer import bank_spec_map, slot_assignments

    scheme = getattr(cfg, "artifact_quant", "none") if quant is None \
        else quant
    grp = getattr(cfg, "artifact_group", 64) if group is None else group
    # per-slot quant from the compression policy overrides the global
    # scheme for that bank leaf
    overrides = {path: a.quant for path, a in slot_assignments(cfg).items()
                 if a.quant is not None}
    return F.write(path, params, config=F.config_to_dict(cfg),
                   bank_specs=bank_spec_map(cfg), quant=scheme,
                   quant_group=grp, quant_min_size=quant_min_size,
                   quant_overrides=overrides, meta=meta)


def export_tree(path: str, params, *, bank_specs=None, quant: str = "none",
                group: int = 64, meta: Optional[dict] = None) -> dict:
    """Serialize an arbitrary pytree (e.g. a paper-MLP parameter list)
    without an ArchConfig; pass bank_specs for hashed-bank accounting."""
    from repro.artifact import format as F
    return F.write(path, params, config=None, bank_specs=bank_specs,
                   quant=quant, quant_group=group, meta=meta)
