"""Tiny versioned model registry for cold-start serving.

Layout (one directory, fully self-contained and rsync-able)::

    <root>/registry.json                  # atomic index
    <root>/<name>/v0001.hnart ...         # immutable artifact files

``registry.json``::

    {"models": {"<name>": {"latest": 2, "versions": {
        "1": {"file": "<name>/v0001.hnart", "sha256": ..., "bytes": ...,
              "created": ..., "metadata": {...}}, ...}}}}

Properties:
- **Immutable versions**: registering always mints a new version; files
  are copied in under the registry root then the index is atomically
  replaced (tmp + os.replace), so readers never see a half-registered
  model — same commit discipline as the checkpointer.
- **Integrity**: sha256 recorded at register time; ``resolve`` re-hashes
  by default and refuses a corrupt artifact (serving cold-start safety).
- **No daemon**: it's a directory; the engine resolves name[@version] to
  a file path and mmaps it.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from typing import Any, Dict, Optional

INDEX = "registry.json"


def _index_path(root: str) -> str:
    return os.path.join(root, INDEX)


def _load_index(root: str) -> dict:
    p = _index_path(root)
    if not os.path.exists(p):
        return {"models": {}}
    with open(p) as f:
        return json.load(f)


def _store_index(root: str, index: dict) -> None:
    tmp = _index_path(root) + ".tmp"
    with open(tmp, "w") as f:
        json.dump(index, f, indent=1, sort_keys=True)
    os.replace(tmp, _index_path(root))


def sha256_file(path: str, chunk: int = 1 << 20) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            b = f.read(chunk)
            if not b:
                break
            h.update(b)
    return h.hexdigest()


class _Lock:
    """Advisory cross-process lock: mkdir is atomic on POSIX, so the
    directory doubles as the mutex.  Registration is a read-modify-write
    of the index plus a version-numbered copy — two concurrent trainers
    registering the same name would otherwise both claim version N+1 and
    overwrite each other's artifact after its sha256 was recorded."""

    def __init__(self, root: str, timeout_s: float = 30.0):
        self.path = os.path.join(root, ".registry.lock")
        self.timeout_s = timeout_s

    def __enter__(self):
        # monotonic, not wall: an NTP step during acquisition must neither
        # spuriously raise TimeoutError nor extend the wait unboundedly
        deadline = time.monotonic() + self.timeout_s
        while True:
            try:
                os.mkdir(self.path)
                return self
            except FileExistsError:
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"registry lock {self.path} held for "
                        f">{self.timeout_s}s; remove it if its owner died")
                time.sleep(0.05)

    def __exit__(self, *exc):
        os.rmdir(self.path)


def register(root: str, name: str, artifact_path: str, *,
             metadata: Optional[dict] = None) -> int:
    """Copy an artifact into the registry as the next version of ``name``;
    returns the new version number.  Safe under concurrent registrations:
    the byte copy and sha256 run OUTSIDE the lock (they can take minutes
    for multi-GB artifacts on network storage); the lock only covers the
    version claim + a rename + the index update, so it is held for
    milliseconds and a healthy concurrent registrant never times out."""
    os.makedirs(os.path.join(root, name), exist_ok=True)
    staging = os.path.join(root, name,
                           f".staging.{os.getpid()}.{time.time_ns()}")
    shutil.copyfile(artifact_path, staging)
    digest = sha256_file(staging)
    nbytes = os.path.getsize(staging)
    try:
        with _Lock(root):
            index = _load_index(root)
            model = index["models"].setdefault(
                name, {"latest": 0, "versions": {}})
            version = int(model["latest"]) + 1
            rel = os.path.join(name, f"v{version:04d}.hnart")
            os.replace(staging, os.path.join(root, rel))
            model["versions"][str(version)] = {
                "file": rel,
                "sha256": digest,
                "bytes": nbytes,
                "created": time.time(),
                "metadata": metadata or {},
            }
            model["latest"] = version
            _store_index(root, index)
    finally:
        if os.path.exists(staging):
            os.remove(staging)
    return version


def resolve(root: str, name: str, version: Optional[int] = None, *,
            verify: bool = True) -> Dict[str, Any]:
    """name[@version] -> entry dict with an absolute ``path`` added.

    verify: re-hash the file and raise on mismatch (default on — a corrupt
    artifact must fail the cold start, not serve garbage logits)."""
    if "@" in name and version is None:
        name, _, v = name.partition("@")
        version = int(v)
    index = _load_index(root)
    if name not in index["models"]:
        known = sorted(index["models"])
        raise KeyError(f"model {name!r} not in registry {root} "
                       f"(known: {known})")
    model = index["models"][name]
    # explicit None check: version 0 must fail like any missing version,
    # not fall through to latest
    version = int(model["latest"]) if version is None else int(version)
    entry = model["versions"].get(str(version))
    if entry is None:
        raise KeyError(f"{name}@{version} not found "
                       f"(latest: {model['latest']})")
    out = dict(entry)
    out["name"], out["version"] = name, version
    out["path"] = os.path.join(root, entry["file"])
    if verify:
        got = sha256_file(out["path"])
        if got != entry["sha256"]:
            raise ValueError(
                f"{name}@{version}: integrity check failed "
                f"(sha256 {got[:12]}.. != recorded "
                f"{entry['sha256'][:12]}..)")
    return out


def list_models(root: str) -> Dict[str, Any]:
    return _load_index(root)["models"]
