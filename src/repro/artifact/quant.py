"""Bank quantization for compressed artifacts: int8 / fp8, per-group scales.

Applied at export to the real parameter banks (and any large dense leaf);
symmetric, per-group of ``group`` consecutive elements in flattened order:

    q = round(x / s),   s = absmax(group) / Q     (int8: Q = 127)
    q = fp8(x / s),     s = absmax(group) / 448   (fp8: e4m3 max normal)

Scales are float32, one per group — at group=64 the scale overhead is
1/16 of an fp32 bank (int8 total: 0.25 + 0.0625 = ~3.2x smaller than
fp32).  Stacking quantization on top of hashing is the Deep Compression
recipe (Han et al., 2016) transplanted onto HashedNets banks: the hash
already removed redundancy *across* virtual weights, the quantizer then
shrinks each surviving bucket value.

Error bound (int8, documented for the round-trip tests): per element
``|x - dq| <= 0.5 * s = absmax(group) / 254`` — relative to the group's
absmax, 0.4%.  fp8 e4m3 carries 3 mantissa bits: relative error
``<= 2^-4`` of each element's own magnitude after scaling.

All functions are host-side numpy: quantization happens once at export,
dequantization once at cold start (or never, if a consumer wants the raw
int8 bank for a quantized kernel path).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

SCHEMES = ("none", "int8", "fp8")
FP8_MAX = 448.0        # float8_e4m3fn largest normal
INT8_MAX = 127.0


def _fp8_dtype():
    import ml_dtypes  # ships with jax; container-safe
    return np.dtype(ml_dtypes.float8_e4m3fn)


def np_dtype(name: str) -> np.dtype:
    """np.dtype that understands bfloat16/fp8 names via ml_dtypes."""
    import ml_dtypes  # noqa: F401  (registers the extended dtypes)
    return np.dtype(name)


@dataclasses.dataclass(frozen=True)
class Quantized:
    """A quantized leaf: stored codes + per-group scales + restore info."""
    q: np.ndarray            # (n_groups, group) int8 or fp8
    scales: np.ndarray       # (n_groups,) float32
    scheme: str
    group: int
    pad: int                 # zeros appended to fill the last group
    orig_shape: Tuple[int, ...]
    orig_dtype: str

    def dequantize(self) -> np.ndarray:
        return dequantize(self)


def max_abs_error(scheme: str, scales: np.ndarray) -> float:
    """Worst-case elementwise reconstruction error for a quantized leaf."""
    s = float(np.max(scales)) if np.size(scales) else 0.0
    if scheme == "int8":
        return 0.5 * s
    if scheme == "fp8":
        return s * FP8_MAX * 2.0 ** -4
    return 0.0


def quantize(arr: np.ndarray, scheme: str, group: int = 64) -> Quantized:
    if scheme not in ("int8", "fp8"):
        raise ValueError(f"unknown quant scheme {scheme!r}")
    if group <= 0:
        raise ValueError("group must be positive")
    x = np.asarray(arr)
    orig_shape = tuple(int(s) for s in x.shape)
    orig_dtype = str(x.dtype)
    flat = np.asarray(x, np.float32).reshape(-1)
    pad = (-flat.size) % group
    if pad:
        flat = np.pad(flat, (0, pad))
    xg = flat.reshape(-1, group)
    absmax = np.abs(xg).max(axis=1)
    qmax = INT8_MAX if scheme == "int8" else FP8_MAX
    scales = np.where(absmax > 0.0, absmax / qmax, 1.0).astype(np.float32)
    scaled = xg / scales[:, None]
    if scheme == "int8":
        q = np.clip(np.rint(scaled), -INT8_MAX, INT8_MAX).astype(np.int8)
    else:
        q = scaled.astype(_fp8_dtype())
    return Quantized(q=q, scales=scales, scheme=scheme, group=group,
                     pad=pad, orig_shape=orig_shape, orig_dtype=orig_dtype)


def dequantize(z: Quantized) -> np.ndarray:
    xg = np.asarray(z.q, np.float32) * z.scales[:, None]
    flat = xg.reshape(-1)
    if z.pad:
        flat = flat[:flat.size - z.pad]
    return flat.reshape(z.orig_shape).astype(np_dtype(z.orig_dtype))


def stored_dtype(scheme: str) -> np.dtype:
    return np.dtype(np.int8) if scheme == "int8" else _fp8_dtype()


def is_float_dtype(dtype) -> bool:
    """Float check that also covers the ml_dtypes extended types (their
    numpy kind is 'V', so np.issubdtype misses them)."""
    return (np.dtype(dtype).kind == "f"
            or str(dtype) in ("bfloat16", "float8_e4m3fn", "float8_e5m2"))


def should_quantize(path: Tuple, arr: np.ndarray, is_bank: bool,
                    min_size: int = 4096) -> bool:
    """Export policy: quantize every hashed bank, plus any large float
    matrix (embeddings, dense projections).  Norm scales / biases /
    scalars stay exact — they are O(d) bytes and numerically sensitive."""
    arr = np.asarray(arr)
    if not is_float_dtype(arr.dtype):
        return False
    if is_bank:
        return True
    return arr.ndim >= 2 and arr.size >= min_size
