"""Single-file compressed model artifact: JSON header + aligned sections.

Layout (little-endian)::

    bytes 0..8    magic  b"HNETART1"
    bytes 8..16   u64 header length H
    bytes 16..16+H JSON header (utf-8)
    pad to 64-byte boundary
    section data  (each section 64-byte aligned)

The header carries everything needed to rebuild the model with *no other
inputs*: the ArchConfig dict, and per leaf its tree path, stored dtype,
shape, section offset, the serialized :class:`~repro.core.hashed.HashedSpec`
for hashed banks (paper: bank + hash seeds fully determine the virtual
matrix), and quantization metadata (scheme / group / scales section) when
the leaf was quantized at export.

This is what the paper's storage claim looks like as a deployable file:
dense leaves are stored as-is, hashed layers store only the ``c x`` smaller
bank — the virtual weights are *recomputed* from the hash at load, never
stored.  Alignment makes every section directly mmap-able into a typed
numpy view (zero-copy cold start, repro.artifact.io).

Tree paths are JSON lists whose entries are dict keys (strings) or list
indices (integers) — enough to reconstruct the nested dict/list pytrees
used by both the transformer stacks and the paper MLPs without needing a
treedef from a live model.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.artifact import quant as Q
from repro.core import hashed as H

MAGIC = b"HNETART1"
ALIGN = 64
FORMAT_VERSION = 1


# ---------------------------------------------------------------------------
# pytree <-> (path, leaf) lists
# ---------------------------------------------------------------------------

def _path_parts(key_path) -> Tuple:
    parts: List[Any] = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(int(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:  # pragma: no cover - future jax key kinds
            parts.append(str(k))
    return tuple(parts)


def flatten_with_paths(tree) -> List[Tuple[Tuple, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_path_parts(kp), leaf) for kp, leaf in flat]


def unflatten_from_paths(entries: List[Tuple[Tuple, Any]]):
    """Rebuild a nested dict/list pytree from (path, value) pairs."""
    if not entries:
        return {}
    if len(entries) == 1 and entries[0][0] == ():
        return entries[0][1]
    root: Dict = {}
    for path, value in entries:
        node = root
        for part in path[:-1]:
            node = node.setdefault(part, {})
        node[path[-1]] = value

    def finalize(node):
        if not isinstance(node, dict):
            return node
        out = {k: finalize(v) for k, v in node.items()}
        if out and all(isinstance(k, int) for k in out):
            idxs = sorted(out)
            if idxs == list(range(len(idxs))):
                return [out[i] for i in idxs]
        return out

    return finalize(root)


# ---------------------------------------------------------------------------
# config serialization
# ---------------------------------------------------------------------------

def config_to_dict(cfg) -> dict:
    from repro import policy as POL
    d = dataclasses.asdict(cfg)
    d["hash_block"] = list(d["hash_block"])
    if cfg.hash_policy is not None:
        d["hash_policy"] = POL.policy_to_dict(cfg.hash_policy)
    return d


def config_from_dict(d: dict):
    from repro import policy as POL
    from repro.configs.base import ArchConfig
    kw = dict(d)
    kw["hash_block"] = tuple(kw.get("hash_block", (128, 128)))
    if kw.get("hash_policy"):
        # non-strict: artifacts from newer writers may carry policy keys
        # this reader doesn't know; drop them like unknown config keys
        kw["hash_policy"] = POL.policy_from_dict(kw["hash_policy"],
                                                 strict=False)
    fields = {f.name for f in dataclasses.fields(ArchConfig)}
    # forward-compat: ignore unknown keys from newer writers
    kw = {k: v for k, v in kw.items() if k in fields}
    return ArchConfig(**kw)


# ---------------------------------------------------------------------------
# write
# ---------------------------------------------------------------------------

def _aligned(n: int) -> int:
    return (n + ALIGN - 1) // ALIGN * ALIGN


def write(path: str, params, *, config: Optional[dict] = None,
          bank_specs: Optional[Dict[Tuple, H.HashedSpec]] = None,
          quant: str = "none", quant_group: int = 64,
          quant_min_size: int = 4096,
          quant_overrides: Optional[Dict[Tuple, str]] = None,
          meta: Optional[dict] = None) -> dict:
    """Serialize ``params`` into one artifact file; returns the header.

    bank_specs: leaf path tuple -> HashedSpec for hashed banks (layer
    stacking may add leading array axes; the leaf then holds ``stack``
    independent banks and its element count is a multiple of
    ``spec.real_param_count()``).

    quant_overrides: leaf path tuple -> scheme, overriding the global
    ``quant`` for that leaf (compression-policy per-slot quantization);
    ``"none"`` exempts a leaf from a global scheme.  Readers need no new
    logic: every leaf already carries its own quant metadata.
    """
    if quant not in Q.SCHEMES:
        raise ValueError(f"quant must be one of {Q.SCHEMES}")
    quant_overrides = quant_overrides or {}
    for p, scheme in quant_overrides.items():
        if scheme not in Q.SCHEMES:
            raise ValueError(f"quant override for {p}: {scheme!r} "
                             f"not in {Q.SCHEMES}")
    bank_specs = bank_specs or {}
    entries = flatten_with_paths(params)

    leaves = []
    blobs: List[bytes] = []
    offset = 0

    def add_section(data: bytes) -> Tuple[int, int]:
        nonlocal offset
        start = offset
        blobs.append(data)
        offset = _aligned(start + len(data))
        blobs.append(b"\x00" * (offset - start - len(data)))
        return start, len(data)

    for p, leaf in entries:
        arr = np.asarray(jax.device_get(leaf))
        spec = bank_specs.get(p)
        kind = "bank" if spec is not None else "dense"
        entry: Dict[str, Any] = {
            "path": list(p), "kind": kind,
            "shape": [int(s) for s in arr.shape],
            "dtype": str(arr.dtype),
            "spec": spec.to_dict() if spec is not None else None,
        }
        if spec is not None:
            rp = spec.real_param_count()
            if arr.size % rp:
                raise ValueError(
                    f"leaf {p}: size {arr.size} is not a multiple of the "
                    f"spec's real_param_count {rp} — bank_specs mismatch")
            entry["stack"] = int(arr.size // rp)
        scheme = quant_overrides.get(p, quant)
        if scheme != "none" and Q.should_quantize(p, arr, spec is not None,
                                                  min_size=quant_min_size):
            z = Q.quantize(arr, scheme, quant_group)
            qoff, qn = add_section(z.q.tobytes())
            soff, sn = add_section(z.scales.tobytes())
            entry.update({
                "offset": qoff, "nbytes": qn,
                "stored_dtype": str(Q.stored_dtype(scheme)),
                "quant": {"scheme": z.scheme, "group": z.group,
                          "pad": z.pad, "num_groups": int(z.scales.size),
                          "scales_offset": soff, "scales_nbytes": sn},
            })
        else:
            doff, dn = add_section(arr.tobytes())
            entry.update({"offset": doff, "nbytes": dn,
                          "stored_dtype": str(arr.dtype), "quant": None})
        leaves.append(entry)

    header = {
        "format": "hashednet-artifact",
        "version": FORMAT_VERSION,
        "alignment": ALIGN,
        "config": config,
        "quant": quant,
        "leaves": leaves,
        "meta": meta or {},
    }
    hjson = json.dumps(header, separators=(",", ":")).encode("utf-8")
    preamble = MAGIC + struct.pack("<Q", len(hjson)) + hjson
    data_start = _aligned(len(preamble))
    header["data_start"] = data_start
    # re-encode with data_start included (length may grow; re-align)
    for _ in range(3):
        hjson = json.dumps(header, separators=(",", ":")).encode("utf-8")
        new_start = _aligned(len(MAGIC) + 8 + len(hjson))
        if new_start == header["data_start"]:
            break
        header["data_start"] = new_start
    preamble = MAGIC + struct.pack("<Q", len(hjson)) + hjson
    pad = header["data_start"] - len(preamble)

    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(preamble)
        f.write(b"\x00" * pad)
        for b in blobs:
            f.write(b)
    os.replace(tmp, path)          # atomic visibility, same as checkpoints
    return header


# ---------------------------------------------------------------------------
# read
# ---------------------------------------------------------------------------

def read_header(path: str) -> dict:
    with open(path, "rb") as f:
        magic = f.read(len(MAGIC))
        if magic != MAGIC:
            raise ValueError(f"{path}: not a hashednet artifact "
                             f"(magic {magic!r})")
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen).decode("utf-8"))
    if header.get("version", 0) > FORMAT_VERSION:
        raise ValueError(f"{path}: artifact version {header['version']} "
                         f"is newer than this reader ({FORMAT_VERSION})")
    return header
