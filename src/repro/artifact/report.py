"""Per-layer compression accounting: virtual vs real vs on-disk bytes.

Reproduces the paper's compression-ratio tables for our configs, extended
with the two things the paper didn't have to account for: per-group
quantization scales and the artifact header.  Three sizes per leaf:

- virtual: the dense matrix the layer *behaves* as (rows x cols x stack,
  at the restore dtype) — what a non-hashed checkpoint would store.
- real:   the bank actually parameterizing it (spec.real_param_count).
- disk:   bytes in the artifact (codes + scales after quantization).

Rows are aggregated per top-level component ("layers/attn/q", ...), which
matches the per-layer budgeting view of Structured Multi-Hashing (Eban et
al., 2019): each component's ratio is independently visible, so a config
sweep can trade compression between, say, attention and FFN banks.

When the artifact's config carries a compression policy, a second view
(:func:`rows_by_rule`) groups leaves by the policy rule that decided them
— the accounting that tells you whether each rule's slice of the budget
landed where the solver put it.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

from repro.artifact import format as F
from repro.artifact import quant as Q
from repro.core import hashed as H


def _dtype_size(name: str) -> int:
    return Q.np_dtype(name).itemsize


def _group_name(path) -> str:
    parts = [str(p) for p in path if not isinstance(p, int)]
    return "/".join(parts[:-1] if len(parts) > 1 else parts)


def artifact_rows(header: dict) -> List[Dict[str, Any]]:
    """One accounting row per leaf group, from the header alone."""
    groups: Dict[str, Dict[str, Any]] = {}
    for e in header["leaves"]:
        name = _group_name(e["path"])
        g = groups.setdefault(name, {
            "name": name, "kind": e["kind"], "virtual_params": 0,
            "real_params": 0, "virtual_bytes": 0, "real_bytes": 0,
            "disk_bytes": 0})
        n_elems = int(np.prod(e["shape"])) if e["shape"] else 1
        esize = _dtype_size(e["dtype"])
        if e["kind"] == "bank":
            spec = H.spec_from_dict(e["spec"])
            stack = int(e.get("stack", 1))
            virtual = spec.virtual_size * stack
        else:
            virtual = n_elems
        g["virtual_params"] += virtual
        g["real_params"] += n_elems
        g["virtual_bytes"] += virtual * esize
        g["real_bytes"] += n_elems * esize
        disk = e["nbytes"]
        if e.get("quant"):
            disk += e["quant"]["scales_nbytes"]
        g["disk_bytes"] += disk
        if e["kind"] == "bank":
            g["kind"] = "bank"
    rows = sorted(groups.values(), key=lambda r: -r["virtual_bytes"])
    for r in rows:
        r["param_ratio"] = r["real_params"] / max(r["virtual_params"], 1)
        r["disk_ratio"] = r["disk_bytes"] / max(r["virtual_bytes"], 1)
    return rows


def totals(rows: List[Dict[str, Any]], header: Optional[dict] = None
           ) -> Dict[str, Any]:
    t = {"name": "TOTAL", "virtual_params": 0, "real_params": 0,
         "virtual_bytes": 0, "real_bytes": 0, "disk_bytes": 0}
    for r in rows:
        for k in ("virtual_params", "real_params", "virtual_bytes",
                  "real_bytes", "disk_bytes"):
            t[k] += r[k]
    if header is not None:
        t["header_bytes"] = header["data_start"]
        t["disk_bytes_with_header"] = t["disk_bytes"] + header["data_start"]
    t["param_ratio"] = t["real_params"] / max(t["virtual_params"], 1)
    t["disk_ratio"] = t["disk_bytes"] / max(t["virtual_bytes"], 1)
    return t


def format_table(rows: List[Dict[str, Any]],
                 total: Optional[Dict[str, Any]] = None) -> str:
    """The paper's table, per component: virtual / real / disk / ratios."""
    hdr = (f"{'component':<28} {'kind':<6} {'virtual':>12} {'real':>12} "
           f"{'disk(B)':>12} {'c':>7} {'disk/dense':>10}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows + ([total] if total else []):
        lines.append(
            f"{r['name']:<28} {r.get('kind', ''):<6} "
            f"{r['virtual_params']:>12,} {r['real_params']:>12,} "
            f"{r['disk_bytes']:>12,} {r['param_ratio']:>7.3f} "
            f"{r['disk_ratio']:>10.4f}")
    return "\n".join(lines)


def rows_by_rule(header: dict) -> Optional[List[Dict[str, Any]]]:
    """Accounting rows grouped by the policy rule that matched each leaf.

    Returns None when the artifact has no hashed config to derive a
    policy from.  Bank leaves group under their matched rule's pattern
    (``(defaults)`` when no rule matched); non-bank leaves group under
    ``(dense)``.
    """
    from repro import policy as POL
    cfg_dict = header.get("config")
    if not cfg_dict or not cfg_dict.get("hashed"):
        return None
    cfg = F.config_from_dict(cfg_dict)
    pol = POL.effective(cfg)
    groups: Dict[str, Dict[str, Any]] = {}
    for e in header["leaves"]:
        if e["kind"] == "bank":
            rule = pol.match(POL.slot_path(tuple(e["path"])))
            name = rule.match if rule is not None else "(defaults)"
        else:
            name = "(dense)"
        g = groups.setdefault(name, {
            "name": name, "kind": e["kind"], "virtual_params": 0,
            "real_params": 0, "virtual_bytes": 0, "real_bytes": 0,
            "disk_bytes": 0})
        n_elems = int(np.prod(e["shape"])) if e["shape"] else 1
        esize = _dtype_size(e["dtype"])
        if e["kind"] == "bank":
            spec = H.spec_from_dict(e["spec"])
            virtual = spec.virtual_size * int(e.get("stack", 1))
        else:
            virtual = n_elems
        g["virtual_params"] += virtual
        g["real_params"] += n_elems
        g["virtual_bytes"] += virtual * esize
        g["real_bytes"] += n_elems * esize
        disk = e["nbytes"]
        if e.get("quant"):
            disk += e["quant"]["scales_nbytes"]
        g["disk_bytes"] += disk
    rows = sorted(groups.values(), key=lambda r: -r["virtual_bytes"])
    for r in rows:
        r["param_ratio"] = r["real_params"] / max(r["virtual_params"], 1)
        r["disk_ratio"] = r["disk_bytes"] / max(r["virtual_bytes"], 1)
    return rows


def report(path_or_header) -> str:
    """Convenience: artifact path (or header) -> printable table(s)."""
    header = (path_or_header if isinstance(path_or_header, dict)
              else F.read_header(path_or_header))
    rows = artifact_rows(header)
    out = format_table(rows, totals(rows, header))
    by_rule = rows_by_rule(header)
    if by_rule is not None:
        out += "\n\nby policy rule:\n"
        out += format_table(by_rule, totals(by_rule))
    return out
