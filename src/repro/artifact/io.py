"""Cold-start loading: mmap the artifact, view sections, device_put.

The artifact's sections are 64-byte aligned typed blobs, so loading is::

    mm   = np.memmap(path, np.uint8, "r")           # no read, just map
    leaf = mm[off:off+n].view(dtype).reshape(shape) # zero-copy view
    jax.device_put(leaf)                            # one H2D copy

No model ``init`` runs, no treedef is needed from a live model (paths in
the header rebuild the pytree), and nothing is ever materialized for the
virtual matrices — the HashedSpecs ride along in the header and the model
decompresses on the fly, which is exactly the paper's "no additional
memory overhead" load story.

Quantized leaves are dequantized on the host by default (one pass, then a
single H2D copy of the restored dtype).  ``dequant=False`` instead returns
:class:`repro.artifact.quant.Quantized` leaves so a quantized-kernel
consumer (e.g. a future int8 Pallas decompress-GEMM) can ship the codes to
the device untouched.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import numpy as np

from repro.artifact import format as F
from repro.artifact import quant as Q


def open_artifact(path: str) -> Tuple[dict, np.memmap]:
    header = F.read_header(path)
    mm = np.memmap(path, dtype=np.uint8, mode="r")
    return header, mm


def _view(mm: np.memmap, data_start: int, offset: int, nbytes: int,
          dtype: str, shape) -> np.ndarray:
    start = data_start + offset
    raw = mm[start:start + nbytes]
    return raw.view(Q.np_dtype(dtype)).reshape(shape)


def read_leaf(header: dict, mm: np.memmap, entry: dict,
              dequant: bool = True):
    ds = header["data_start"]
    z = entry.get("quant")
    if z is None:
        return _view(mm, ds, entry["offset"], entry["nbytes"],
                     entry["stored_dtype"], entry["shape"])
    q = _view(mm, ds, entry["offset"], entry["nbytes"],
              entry["stored_dtype"], (z["num_groups"], z["group"]))
    scales = _view(mm, ds, z["scales_offset"], z["scales_nbytes"],
                   "float32", (z["num_groups"],))
    zq = Q.Quantized(q=q, scales=scales, scheme=z["scheme"],
                     group=z["group"], pad=z["pad"],
                     orig_shape=tuple(entry["shape"]),
                     orig_dtype=entry["dtype"])
    return zq.dequantize() if dequant else zq


def load(path: str, *, dequant: bool = True, as_jax: bool = True,
         device: Optional[Any] = None) -> Tuple[dict, Any]:
    """Load an artifact -> (header, params pytree).

    as_jax: device_put every array leaf (the cold-start path).  With
    as_jax=False leaves stay numpy views into the mmap — near-free, used
    for inspection/reporting.
    """
    import jax

    header, mm = open_artifact(path)
    entries = []
    for e in header["leaves"]:
        leaf = read_leaf(header, mm, e, dequant=dequant)
        if as_jax and not isinstance(leaf, Q.Quantized):
            leaf = jax.device_put(leaf, device)
        entries.append((tuple(e["path"]), leaf))
    return header, F.unflatten_from_paths(entries)


def load_model(path: str, *, dequant: bool = True,
               device: Optional[Any] = None):
    """Artifact -> (cfg, model, params): the one-call cold start.

    The model is rebuilt from the stored ArchConfig; params land directly
    on the device.  First prefill/decode compile happens lazily in the
    engine, as with a live-trained model.
    """
    from repro.models import build

    header, params = load(path, dequant=dequant, device=device)
    if not header.get("config"):
        raise ValueError(f"{path}: artifact has no model config; "
                         f"use artifact.io.load for raw param trees")
    cfg = F.config_from_dict(header["config"])
    return cfg, build(cfg), params
