"""jit'd public entry points for the hashed decompress-GEMM kernels.

``hashed_matmul(x, w, spec)`` accepts arbitrary leading batch dims, pads the
flattened row count to the kernel's block multiple, dispatches element/block
kernels, and wires a custom VJP whose backward pass is *also* kernelized
(dx = transpose-forward kernel, dw = scatter-reduce kernel; paper Eq. 12).

On non-TPU backends the kernels run in interpret mode (pure-Python grid
walk) — numerically identical, used for CI on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import hashed
from repro.kernels import hashed_matmul as hk


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:  # pragma: no cover
        return False


def _pad_rows(x2, bm):
    m = x2.shape[0]
    pad = (-m) % bm
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    return x2, m


def _pick_bm(m: int, target: int = 128) -> int:
    """Largest power-of-two block <= target that keeps padding waste small."""
    bm = target
    while bm > 8 and m % bm and m < bm:
        bm //= 2
    return bm


def _fwd_impl(x, w, spec: hashed.HashedSpec, dtype, interpret, block):
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    bm = _pick_bm(x2.shape[0], block[0])
    x2, m = _pad_rows(x2, bm)
    if spec.mode == "element":
        y = hk.element_matmul(x2, w, spec, block=(bm, block[1], block[2]),
                              interpret=interpret, out_dtype=dtype)
    else:
        y = hk.block_matmul(x2, w, spec, bm=bm, interpret=interpret,
                            out_dtype=dtype)
    return y[:m].reshape(lead + (spec.cols,))


def _bwd_dx_impl(g, w, spec: hashed.HashedSpec, dtype, interpret, block):
    lead = g.shape[:-1]
    g2 = g.reshape(-1, g.shape[-1])
    bm = _pick_bm(g2.shape[0], block[0])
    g2, m = _pad_rows(g2, bm)
    if spec.mode == "element":
        dx = hk.element_matmul(g2, w, spec, block=(bm, block[1], block[2]),
                               transpose=True, interpret=interpret,
                               out_dtype=dtype)
    else:
        dx = hk.block_matmul(g2, w, spec, bm=bm, transpose=True,
                             interpret=interpret, out_dtype=dtype)
    return dx[:m].reshape(lead + (spec.rows,))


def _bwd_dw_impl(x, g, spec: hashed.HashedSpec, interpret, block):
    x2 = x.reshape(-1, x.shape[-1])
    g2 = g.reshape(-1, g.shape[-1])
    bm = _pick_bm(x2.shape[0], block[0])
    x2, _ = _pad_rows(x2, bm)
    g2, _ = _pad_rows(g2, bm)
    if spec.mode == "element":
        return hk.element_dw(x2, g2, spec, block=(bm, block[1], block[2]),
                             interpret=interpret)
    return hk.block_dw(x2, g2, spec, bm=bm, interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _hashed_matmul(x, w, spec, dtype, interpret, block):
    return _fwd_impl(x, w, spec, dtype, interpret, block)


def _vjp_fwd(x, w, spec, dtype, interpret, block):
    return _fwd_impl(x, w, spec, dtype, interpret, block), (x, w)


def _vjp_bwd(spec, dtype, interpret, block, res, g):
    x, w = res
    dx = _bwd_dx_impl(g, w, spec, x.dtype, interpret, block)
    dw = _bwd_dw_impl(x, g, spec, interpret, block).astype(w.dtype)
    return dx, dw


_hashed_matmul.defvjp(_vjp_fwd, _vjp_bwd)


def hashed_matmul(x, w, spec: hashed.HashedSpec, dtype=None,
                  interpret=None, block=(128, 128, 128)):
    """y = x @ decompress(w, spec), fused Pallas kernel, differentiable."""
    spec.validate()
    if spec.mode == "block":
        bm_, bn_ = spec.block_shape
        if spec.rows % bm_ or spec.cols % bn_:
            raise ValueError(
                f"pallas block path needs block_shape {spec.block_shape} to "
                f"divide virtual_shape {spec.virtual_shape}; use the scan or "
                f"materialize path for ragged grids")
    dtype = dtype or x.dtype
    if interpret is None:
        interpret = not _on_tpu()
    return _hashed_matmul(x, w, spec, dtype, bool(interpret), tuple(block))
