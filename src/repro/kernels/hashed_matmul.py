"""Fused decompress-GEMM Pallas TPU kernels for HashedNets.

The performance-critical op of the paper at deployment time is
``y = x @ V`` where ``V`` never exists in memory — only the hashed bank
``w`` does.  These kernels keep ``w`` compressed in HBM and expand one
MXU-aligned tile of ``V`` at a time into VMEM:

- element mode: the virtual tile's bucket indices + signs are *recomputed
  in-kernel* from the murmur-mix hash over a 2-D iota (zero index storage,
  exactly the paper's point), then gathered from the panel's bucket slice
  (which the BlockSpec pipeline has staged into VMEM).
- block mode: the bank tile for virtual tile (ti, tj) is selected with a
  scalar-prefetch indexed BlockSpec — a *dense contiguous* HBM->VMEM DMA.
  This is the TPU answer to the paper's §7 "non-coalesced access" problem.

Grids iterate (m, n, k) with k innermost; partial products accumulate in a
float32 VMEM scratch and are flushed to the output on the last k step.

The backward kernels realize paper Eq. 12:
- dx = g @ V^T reuses the forward structure with virtual coordinates
  swapped (``transpose=True``).
- dw scatter-reduces sign-weighted outer-product tiles into the bank.  The
  block-mode dw kernel orders the virtual-tile walk by bank index (a static
  permutation — the hash is static given the spec) so that all writes to a
  bank tile are consecutive grid steps, which makes output-block revisiting
  with accumulate-in-place legal under TPU's sequential grid semantics.

TPU-lowering notes (validated with interpret=True on CPU, per the
assignment): the element-mode in-VMEM gather (``jnp.take``) and the
element-mode dw segment-sum depend on Mosaic gather/scatter support; the
block-mode kernels use only dense dots + scalar-prefetch DMAs and are the
deployment path for very large layers (see DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hashed, hashing

# pallas renamed TPUCompilerParams -> CompilerParams across jax releases;
# accept either so the kernels run on the pinned container jax.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

# ---------------------------------------------------------------------------
# element-mode forward / transpose-forward
# ---------------------------------------------------------------------------


def _element_tile(spec: hashed.HashedSpec, wvec, r0, c0, bk, bn, transpose,
                  dtype):
    """Decompress one (bk, bn) tile of V (or V^T if transpose) into VMEM.

    r0/c0 are the tile's top-left coordinates in the *operand being
    multiplied* (i.e. in V^T coordinates when transpose=True).  wvec is the
    bucket slice staged for this tile's panel (local indices).
    """
    di = jax.lax.broadcasted_iota(jnp.int32, (bk, bn), 0)
    dj = jax.lax.broadcasted_iota(jnp.int32, (bk, bn), 1)
    if transpose:
        i = c0 + dj  # virtual row of V
        j = r0 + di  # virtual col of V
    else:
        i = r0 + di
        j = c0 + dj
    kp = spec.buckets_per_panel
    h = hashing.bucket_hash(i, j, kp, spec.seed)
    tile = jnp.take(wvec, h, axis=0)
    if spec.use_sign:
        tile = tile * hashing.sign_hash(i, j, spec.seed).astype(wvec.dtype)
    return tile.astype(dtype)


def _element_fwd_kernel(x_ref, w_ref, o_ref, acc_ref, *, spec, bm, bk, bn,
                        nk, transpose):
    ci = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    vtile = _element_tile(
        spec, w_ref[...], ki * bk, ci * bn, bk, bn, transpose, x_ref.dtype
    )
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], vtile, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def element_matmul(x, w, spec: hashed.HashedSpec, *, block=(128, 128, 128),
                   transpose: bool = False, interpret: bool = True,
                   out_dtype=None):
    """x @ V (transpose=False) or x @ V^T (transpose=True), element mode.

    x: (M, R) where R = spec.rows (or spec.cols when transpose).
    """
    assert spec.mode == "element"
    out_dtype = out_dtype or x.dtype
    bm, bk, bn = block
    m, r = x.shape
    c = spec.cols if not transpose else spec.rows
    assert r == (spec.rows if not transpose else spec.cols), (x.shape, spec)
    assert m % bm == 0 and r % bk == 0 and c % bn == 0, (x.shape, c, block)

    kp = spec.buckets_per_panel
    panel_cols = spec.panel_cols if spec.panel_cols > 0 else spec.cols
    # a kernel tile must sit inside a single bucket panel
    pdim = bk if transpose else bn  # tile extent along virtual columns
    assert panel_cols % pdim == 0, (panel_cols, pdim)

    nk = r // bk

    if transpose:
        # panel determined by the contraction index (virtual column)
        def w_index(mi, ci, ki):
            return ((ki * bk) // panel_cols,)
    else:
        def w_index(mi, ci, ki):
            return ((ci * bn) // panel_cols,)

    kernel = functools.partial(
        _element_fwd_kernel, spec=spec, bm=bm, bk=bk, bn=bn, nk=nk,
        transpose=transpose,
    )
    return pl.pallas_call(
        kernel,
        grid=(m // bm, c // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ci, ki: (mi, ki)),
            pl.BlockSpec((kp,), w_index),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ci, ki: (mi, ci)),
        out_shape=jax.ShapeDtypeStruct((m, c), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)


# ---------------------------------------------------------------------------
# element-mode dw (paper Eq. 12)
# ---------------------------------------------------------------------------


def _element_dw_kernel(x_ref, g_ref, o_ref, acc_ref, *, spec, bk, bn, nm,
                       panel_cols):
    ci = pl.program_id(0)
    ki = pl.program_id(1)
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # accumulate the (bk, bn) slab of x^T g over the batch dimension
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], g_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(mi == nm - 1)
    def _scatter():
        i = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, bn), 0)
        j = ci * bn + jax.lax.broadcasted_iota(jnp.int32, (bk, bn), 1)
        kp = spec.buckets_per_panel
        h = hashing.bucket_hash(i, j, kp, spec.seed)
        val = acc_ref[...]
        if spec.use_sign:
            val = val * hashing.sign_hash(i, j, spec.seed).astype(val.dtype)
        seg = jax.ops.segment_sum(val.ravel(), h.ravel(), num_segments=kp)
        first_of_panel = (ci * bn) % panel_cols == 0

        @pl.when(jnp.logical_and(first_of_panel, ki == 0))
        def _store():
            o_ref[...] = seg

        @pl.when(jnp.logical_not(jnp.logical_and(first_of_panel, ki == 0)))
        def _accum():
            o_ref[...] += seg


def element_dw(x, g, spec: hashed.HashedSpec, *, block=(128, 128, 128),
               interpret: bool = True):
    """dw (num_buckets,) from upstream grad g of y = x @ V."""
    assert spec.mode == "element"
    bm, bk, bn = block
    m, r = x.shape
    mg, c = g.shape
    assert m == mg and r == spec.rows and c == spec.cols
    assert m % bm == 0 and r % bk == 0 and c % bn == 0
    kp = spec.buckets_per_panel
    panel_cols = spec.panel_cols if spec.panel_cols > 0 else spec.cols
    assert panel_cols % bn == 0
    nm = m // bm

    kernel = functools.partial(
        _element_dw_kernel, spec=spec, bk=bk, bn=bn, nm=nm,
        panel_cols=panel_cols,
    )
    return pl.pallas_call(
        kernel,
        grid=(c // bn, r // bk, nm),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda ci, ki, mi: (mi, ki)),
            pl.BlockSpec((bm, bn), lambda ci, ki, mi: (mi, ci)),
        ],
        out_specs=pl.BlockSpec((kp,), lambda ci, ki, mi: ((ci * bn) // panel_cols,)),
        out_shape=jax.ShapeDtypeStruct((spec.num_buckets,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x, g)


# ---------------------------------------------------------------------------
# block-mode forward / transpose-forward (scalar-prefetch tile gather)
# ---------------------------------------------------------------------------


def _block_fwd_kernel(idx_ref, sgn_ref, x_ref, bank_ref, o_ref, acc_ref, *,
                      nk, transpose):
    del idx_ref  # consumed by the index_map
    ci = pl.program_id(1)
    ki = pl.program_id(2)
    ncols = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    tile = bank_ref[0]
    if transpose:
        tile = tile.T
        sgn = sgn_ref[ci * nk + ki]  # sgn indexed by (virtual ti=ci?, tj)
    else:
        sgn = sgn_ref[ki * ncols + ci]
    tile = tile * sgn.astype(tile.dtype)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], tile.astype(x_ref.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def block_matmul(x, w, spec: hashed.HashedSpec, *, bm: int = 128,
                 transpose: bool = False, interpret: bool = True,
                 out_dtype=None):
    """x @ V (or x @ V^T), block mode.  Tile shape = spec.block_shape."""
    assert spec.mode == "block"
    out_dtype = out_dtype or x.dtype
    brow, bcol = spec.block_shape
    gi, gj = spec.tile_grid
    m, r = x.shape
    if transpose:
        nk, nc, bk, bn = gj, gi, bcol, brow
    else:
        nk, nc, bk, bn = gi, gj, brow, bcol
    assert r == nk * bk, (x.shape, spec.virtual_shape)
    assert m % bm == 0

    # (gi, gj) arrays, kept row-major: the index_map linearizes (ti, tj) as
    # ti * gj + tj in both orientations (transpose only swaps which of
    # ci/ki plays ti vs tj).
    idx, sgn = hashed.block_indices(spec)
    idx_flat = idx.reshape(-1)
    sgn_flat = sgn.reshape(-1)

    def bank_index(mi, ci, ki, idx_ref, sgn_ref):
        del mi, sgn_ref
        # virtual tile walk order matches idx_flat layout: (k-major, c-minor)
        if transpose:
            return (idx_ref[ci * nk + ki], 0, 0)
        return (idx_ref[ki * nc + ci], 0, 0)

    kernel = functools.partial(_block_fwd_kernel, nk=nk, transpose=transpose)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m // bm, nc, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ci, ki, idx_ref, sgn_ref: (mi, ki)),
            pl.BlockSpec((1, brow, bcol), bank_index),
        ],
        out_specs=pl.BlockSpec(
            (bm, bn), lambda mi, ci, ki, idx_ref, sgn_ref: (mi, ci)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, nc * bn), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(idx_flat, sgn_flat, x, w)


# ---------------------------------------------------------------------------
# block-mode dw: bank-ordered virtual-tile walk with output revisiting
# ---------------------------------------------------------------------------


def _block_dw_kernel(bank_ref, ti_ref, tj_ref, sgn_ref, first_ref, x_ref,
                     g_ref, o_ref, acc_ref, *, nm):
    del bank_ref  # consumed by the output index_map
    t = pl.program_id(0)
    mi = pl.program_id(1)

    @pl.when(mi == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], g_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(mi == nm - 1)
    def _flush():
        contrib = acc_ref[...] * sgn_ref[t].astype(jnp.float32)

        @pl.when(first_ref[t] == 1)
        def _store():
            o_ref[0] = contrib

        @pl.when(first_ref[t] == 0)
        def _accum():
            o_ref[0] += contrib


def block_dw(x, g, spec: hashed.HashedSpec, *, bm: int = 128,
             interpret: bool = True):
    """dbank (bank_tiles, brow, bcol) from upstream grad of y = x @ V."""
    assert spec.mode == "block"
    brow, bcol = spec.block_shape
    gi, gj = spec.tile_grid
    m, r = x.shape
    mg, c = g.shape
    assert m == mg and r == spec.rows and c == spec.cols and m % bm == 0
    nm = m // bm

    idx, sgn = hashed.block_indices(spec)
    idx_np = np.asarray(idx).reshape(-1)
    # static permutation: walk virtual tiles grouped by bank index so writes
    # to a bank tile are consecutive grid steps
    order = np.argsort(idx_np, kind="stable").astype(np.int32)
    sorted_bank = idx_np[order]
    first = np.ones_like(sorted_bank)
    first[1:] = (sorted_bank[1:] != sorted_bank[:-1]).astype(np.int32)
    ti = (order // gj).astype(np.int32)
    tj = (order % gj).astype(np.int32)
    sgn_sorted = np.asarray(sgn).reshape(-1)[order].astype(np.int32)

    def out_index(t, mi, bank_ref, ti_ref, tj_ref, sgn_ref, first_ref):
        del ti_ref, tj_ref, sgn_ref, first_ref, mi
        return (bank_ref[t], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(gi * gj, nm),
        in_specs=[
            pl.BlockSpec(
                (bm, brow),
                lambda t, mi, b_ref, ti_ref, tj_ref, s_ref, f_ref:
                    (mi, ti_ref[t])),
            pl.BlockSpec(
                (bm, bcol),
                lambda t, mi, b_ref, ti_ref, tj_ref, s_ref, f_ref:
                    (mi, tj_ref[t])),
        ],
        out_specs=pl.BlockSpec((1, brow, bcol), out_index),
        scratch_shapes=[pltpu.VMEM((brow, bcol), jnp.float32)],
    )
    kernel = functools.partial(_block_dw_kernel, nm=nm)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((spec.bank_tiles, brow, bcol),
                                       jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(sorted_bank, jnp.int32), jnp.asarray(ti), jnp.asarray(tj),
      jnp.asarray(sgn_sorted), jnp.asarray(first), x, g)
