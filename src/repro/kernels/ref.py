"""Pure-jnp oracles for the hashed decompress-GEMM kernels.

Each function materializes the virtual matrix explicitly and uses plain
jnp dots — the ground truth every Pallas kernel is swept against.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import hashed


def hashed_matmul_ref(x, w, spec: hashed.HashedSpec, dtype=None):
    """y = x @ V,  V = decompress(w, spec)."""
    dtype = dtype or x.dtype
    v = hashed.materialize(w, spec, dtype=jnp.float32)
    y = jnp.dot(x.astype(jnp.float32), v)
    return y.astype(dtype)


def hashed_matmul_t_ref(g, w, spec: hashed.HashedSpec, dtype=None):
    """dx = g @ V^T (the input-gradient product)."""
    dtype = dtype or g.dtype
    v = hashed.materialize(w, spec, dtype=jnp.float32)
    y = jnp.dot(g.astype(jnp.float32), v.T)
    return y.astype(dtype)


def hashed_dw_ref(x, g, spec: hashed.HashedSpec, dtype=jnp.float32):
    """dw given upstream grad g of y = x @ V — paper Eq. 12.

    element: dw[k] = sum_{(i,j): h(i,j)=k} xi(i,j) * (x^T g)[i, j]
    block:   dbank[b] = sum_{(ti,tj): h=b} sigma(ti,tj) * (x^T g)[tile ti,tj]
    """
    gv = jnp.einsum(
        "...r,...c->rc",
        x.reshape(-1, x.shape[-1]).astype(jnp.float32),
        g.reshape(-1, g.shape[-1]).astype(jnp.float32),
    )
    if spec.mode == "element":
        i = jnp.arange(spec.rows, dtype=jnp.int32)[:, None]
        j = jnp.arange(spec.cols, dtype=jnp.int32)[None, :]
        idx, sgn = hashed.element_indices(spec, i, j)
        contrib = (gv * sgn.astype(jnp.float32)).ravel()
        out = jnp.zeros((spec.num_buckets,), jnp.float32).at[idx.ravel()].add(contrib)
        return out.astype(dtype)
    bm, bn = spec.block_shape
    gi, gj = spec.tile_grid
    idx, sgn = hashed.block_indices(spec)
    rpad, cpad = gi * bm - spec.rows, gj * bn - spec.cols
    if rpad or cpad:
        # ragged tile grid: cotangent is zero over the padded region
        gv = jnp.pad(gv, ((0, rpad), (0, cpad)))
    tiles = gv.reshape(gi, bm, gj, bn).transpose(0, 2, 1, 3)  # (gi,gj,bm,bn)
    tiles = tiles * sgn[..., None, None].astype(jnp.float32)
    out = jnp.zeros((spec.bank_tiles, bm, bn), jnp.float32)
    out = out.at[idx.reshape(-1)].add(tiles.reshape(-1, bm, bn))
    return out.astype(dtype)
