"""Pure-jnp/numpy oracles for the hashed decompress-GEMM,
paged-attention, and sampling-filter kernels.

Each function materializes the implicit operand explicitly (the virtual
matrix for hashed GEMMs, the gathered K/V for paged attention, the full
sort for the radix top-k select) and uses plain jnp/np ops — the ground
truth every Pallas kernel is swept against.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashed


def hashed_matmul_ref(x, w, spec: hashed.HashedSpec, dtype=None):
    """y = x @ V,  V = decompress(w, spec)."""
    dtype = dtype or x.dtype
    v = hashed.materialize(w, spec, dtype=jnp.float32)
    y = jnp.dot(x.astype(jnp.float32), v)
    return y.astype(dtype)


def hashed_matmul_t_ref(g, w, spec: hashed.HashedSpec, dtype=None):
    """dx = g @ V^T (the input-gradient product)."""
    dtype = dtype or g.dtype
    v = hashed.materialize(w, spec, dtype=jnp.float32)
    y = jnp.dot(g.astype(jnp.float32), v.T)
    return y.astype(dtype)


def hashed_dw_ref(x, g, spec: hashed.HashedSpec, dtype=jnp.float32):
    """dw given upstream grad g of y = x @ V — paper Eq. 12.

    element: dw[k] = sum_{(i,j): h(i,j)=k} xi(i,j) * (x^T g)[i, j]
    block:   dbank[b] = sum_{(ti,tj): h=b} sigma(ti,tj) * (x^T g)[tile ti,tj]
    """
    gv = jnp.einsum(
        "...r,...c->rc",
        x.reshape(-1, x.shape[-1]).astype(jnp.float32),
        g.reshape(-1, g.shape[-1]).astype(jnp.float32),
    )
    if spec.mode == "element":
        i = jnp.arange(spec.rows, dtype=jnp.int32)[:, None]
        j = jnp.arange(spec.cols, dtype=jnp.int32)[None, :]
        idx, sgn = hashed.element_indices(spec, i, j)
        contrib = (gv * sgn.astype(jnp.float32)).ravel()
        out = jnp.zeros((spec.num_buckets,), jnp.float32).at[idx.ravel()].add(contrib)
        return out.astype(dtype)
    bm, bn = spec.block_shape
    gi, gj = spec.tile_grid
    idx, sgn = hashed.block_indices(spec)
    rpad, cpad = gi * bm - spec.rows, gj * bn - spec.cols
    if rpad or cpad:
        # ragged tile grid: cotangent is zero over the padded region
        gv = jnp.pad(gv, ((0, rpad), (0, cpad)))
    tiles = gv.reshape(gi, bm, gj, bn).transpose(0, 2, 1, 3)  # (gi,gj,bm,bn)
    tiles = tiles * sgn[..., None, None].astype(jnp.float32)
    out = jnp.zeros((spec.bank_tiles, bm, bn), jnp.float32)
    out = out.at[idx.reshape(-1)].add(tiles.reshape(-1, bm, bn))
    return out.astype(dtype)


def paged_attention_ref(q, pages_k, pages_v, page_table, lengths, window=0):
    """Decode attention through a paged KV cache, gather-then-attend.

    Same contract as kernels.paged_attention.paged_decode_attention:
    q (B, Hq, D) rotated, scaled by 1/sqrt(D) here; pages_k/v (P, ps, Hkv, D);
    page_table (B, MAXP) int32 (unused slots -> trash page 0); lengths
    (B,) counts INCLUDING the current token; window 0 disables.

    Materializes the per-row K/V by gathering the table — (B, MAXP*ps,
    Hkv, D) lives in memory, which is exactly what the Pallas kernel's
    online-softmax page walk avoids.
    """
    b, hq, d = q.shape
    _, ps, n_kv, _ = pages_k.shape
    g = hq // n_kv
    k = jnp.take(pages_k, page_table, axis=0)       # (B, MAXP, ps, Hkv, D)
    v = jnp.take(pages_v, page_table, axis=0)
    t = page_table.shape[1] * ps
    k = k.reshape(b, t, n_kv, d).astype(jnp.float32)
    v = v.reshape(b, t, n_kv, d).astype(jnp.float32)
    qg = q.reshape(b, n_kv, g, d).astype(jnp.float32) / (d ** 0.5)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k,
                        preferred_element_type=jnp.float32)
    kv_pos = jnp.arange(t)[None, :]
    valid = kv_pos < lengths[:, None]
    window = jnp.asarray(window)
    q_pos = (lengths - 1)[:, None]
    valid = valid & jnp.where(window > 0, q_pos - kv_pos < window, True)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # a fully-masked row (idle decode slot, length 0) softmaxes to a
    # uniform distribution over garbage; zero it instead
    probs = jnp.where(valid[:, None, None, :], probs, 0.0)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v)
    return out.reshape(b, hq, d).astype(q.dtype)


def paged_prefill_ref(q, pages_k, pages_v, page_table, starts, counts,
                      window=0):
    """Ragged batched prefill attention through a paged KV cache,
    gather-then-attend.

    Same contract as kernels.flash_prefill.paged_prefill_attention:
    q (B, S, Hq, D) rotated, scaled by 1/sqrt(D) here; pages_k/v
    (P, ps, Hkv, D) already containing the chunk's freshly scattered
    K/V; page_table (B, MAXP) int32 (unused slots -> trash page 0);
    starts (B,) first query position of each row's chunk; counts (B,)
    real (un-padded) query rows, 0 disables the row; window 0 disables.

    Query slot s sits at position ``starts[b] + s`` and attends the
    causal band ``kv_pos <= q_pos`` intersected with the row's live
    prefix ``kv_pos < starts[b] + counts[b]``.  Slots at or past
    ``counts[b]`` are pad: fully masked, output zero.

    Arithmetic deliberately mirrors ``nn.attention._attend_unchunked``
    op for op (fp32 scaled-score einsum, -1e30 masked fill, softmax,
    value contraction with probs cast to the pool dtype): for a real
    query the masked score row here is elementwise identical to the
    sequential dense-cache path's, which is what makes the engine's
    batched prefill bitwise-equal to its sequential chunked prefill.
    """
    b, s, hq, d = q.shape
    _, ps, n_kv, _ = pages_k.shape
    g = hq // n_kv
    k = jnp.take(pages_k, page_table, axis=0)       # (B, MAXP, ps, Hkv, D)
    v = jnp.take(pages_v, page_table, axis=0)
    t = page_table.shape[1] * ps
    k = k.reshape(b, t, n_kv, d)
    v = v.reshape(b, t, n_kv, d)
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, s, n_kv, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    kv_pos = jnp.arange(t)[None, None, :]            # (1, 1, T)
    q_pos = (starts[:, None] + jnp.arange(s)[None, :])[:, :, None]
    end = (starts + counts)[:, None, None]
    valid = (kv_pos <= q_pos) & (kv_pos < end)
    valid = valid & (jnp.arange(s)[None, :, None] < counts[:, None, None])
    window = jnp.asarray(window)
    valid = valid & jnp.where(window > 0, q_pos - kv_pos < window, True)
    neg = jnp.asarray(-1e30, jnp.float32)
    scores = jnp.where(valid[:, None, None, :, :], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1)
    # pad query slots (and count-0 rows) are fully masked: zero them
    # instead of the uniform-over-garbage softmax.  Real-query rows are
    # untouched: their masked entries already underflowed to exactly 0.
    probs = jnp.where(valid[:, None, None, :, :], probs, 0.0)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, hq, d).astype(q.dtype)


def paged_attention_shared_ref(q, pages_k, pages_v, page_table, lengths,
                               window=0):
    """Shared-page-aware oracle: rows may ALIAS physical pages (prefix
    sharing maps one stored page into many rows' tables — the HashedNets
    dedup idea applied to the KV pool).

    Materializes every row's K/V into a fresh PRIVATE pool first —
    breaking all aliasing — and runs the plain oracle per row over an
    identity page table.  Ground truth for the copy-on-write invariant:
    sharing may only change *where* a row's K/V is read from, never
    *what* it reads, so any kernel must produce bitwise the same output
    whether the table aliases pages across rows or each row owns
    private copies.
    """
    b = q.shape[0]
    maxp = page_table.shape[1]
    ident = jnp.arange(maxp, dtype=jnp.int32)[None, :]
    outs = []
    for i in range(b):
        priv_k = jnp.take(pages_k, page_table[i], axis=0)   # private copy
        priv_v = jnp.take(pages_v, page_table[i], axis=0)
        outs.append(paged_attention_ref(
            q[i:i + 1], priv_k, priv_v, ident, lengths[i:i + 1], window))
    return jnp.concatenate(outs, axis=0)


def topk_mask_ref(logits, k, fill=-1e30):
    """Oracle for kernels.topk.topk_mask: per-row k-th-largest threshold
    by an explicit numpy sort (independent of both the radix kernel and
    the lax.top_k fallback).  ``k[b] <= 0`` or ``>= V`` disables the
    row; boundary ties all survive (value-threshold semantics).
    Threshold comparisons happen on the fp32 view; survivors pass
    through in the input dtype."""
    x32 = np.asarray(jnp.asarray(logits).astype(jnp.float32))
    out = np.array(np.asarray(jnp.asarray(logits)), copy=True)
    k = np.asarray(k, np.int64)
    b, v = x32.shape
    fill = np.asarray(jnp.asarray(fill, jnp.asarray(logits).dtype))
    for i in range(b):
        kk = int(k[i])
        if kk <= 0 or kk >= v:
            continue
        thr = np.sort(x32[i])[v - kk]          # k-th largest
        out[i] = np.where(x32[i] >= thr, out[i], fill)
    return jnp.asarray(out)


def topp_mask_ref(z, p, fill=-1e30):
    """Oracle for serving.sampling.topp_mask (nucleus filtering): numpy
    per-row descending walk.  A token survives iff its probability is
    >= that of the least-probable member of the smallest prefix of the
    descending-prob order whose mass reaches p (the prefix-mass rule
    ``cum - prob < p``, which always keeps the top-1 token); ``p >= 1``
    disables the row."""
    z32 = np.asarray(jnp.asarray(z).astype(jnp.float32))
    p = np.asarray(p, np.float64)
    out = np.array(z32, copy=True)
    for i in range(z32.shape[0]):
        if p[i] >= 1.0:
            continue
        row = z32[i]
        e = np.exp((row - row.max()).astype(np.float32))
        probs = (e / e.sum(dtype=np.float32)).astype(np.float32)
        order = np.argsort(-probs, kind="stable")
        cum = np.float32(0.0)
        cutoff = probs[order[0]]
        for j in order:
            if cum < np.float32(p[i]):         # prefix mass so far < p: keep
                cutoff = probs[j]
                cum = np.float32(cum + probs[j])
            else:
                break
        out[i] = np.where(probs >= cutoff, row, np.float32(fill))
    return jnp.asarray(out, jnp.asarray(z).dtype)


def minp_mask_ref(z, min_p, fill=-1e30):
    """Oracle for serving.sampling.minp_mask: tokens whose probability
    falls below ``min_p * max_prob`` are filtered; ``min_p <= 0``
    disables the row."""
    z32 = np.asarray(jnp.asarray(z).astype(jnp.float32))
    min_p = np.asarray(min_p, np.float32)
    out = np.array(z32, copy=True)
    for i in range(z32.shape[0]):
        if min_p[i] <= 0.0:
            continue
        row = z32[i]
        e = np.exp((row - row.max()).astype(np.float32))
        probs = (e / e.sum(dtype=np.float32)).astype(np.float32)
        keep = probs >= min_p[i] * probs.max()
        out[i] = np.where(keep, row, np.float32(fill))
    return jnp.asarray(out, jnp.asarray(z).dtype)
