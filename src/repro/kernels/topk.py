"""Top-k logit filtering: radix-select threshold kernel for the sampler.

The serving sampler's hot loop filters every decode row's logits to its
top-k before sampling (`repro.serving.sampling`).  Per-row k varies
freely across the batch (mixed `SamplingParams`), so the static-k
`jax.lax.top_k` can't be dispatched once for the whole batch — the
sorted fallback pays a full (B, V) sort per tick.

The Pallas kernel selects the k-th largest value WITHOUT sorting: an
MSB-first radix walk over the fp32 bit patterns.  IEEE-754 floats map
monotonically onto int32 by flipping the low 31 bits of negatives
(``s = i ^ ((i >> 31) & 0x7fffffff)``); XOR-ing the top bit then turns
unsigned radix order into native signed compares.  32 fixed iterations
build the threshold's bit pattern top-down — each step keeps the
candidate bit iff at least k lane values still sit at-or-above it — so
the selected threshold is EXACTLY the k-th largest element (bitwise: it
is one of the inputs), and the emitted mask ``x >= threshold`` matches
the `jax.lax.top_k`-derived oracle tie-for-tie (ties at the boundary
all survive, same as the oracle's value-threshold semantics).

One program per row (grid ``(B,)``), the k vector rides in scalar
prefetch, and the whole row stays in VMEM: 32 compare+reduce passes
over (1, Vp) replace sort's O(V log V) shuffles — no data movement at
all beyond the initial row DMA.  Validated with interpret=True on CPU
like the other kernels; off-TPU callers get the `jax.lax.top_k`
(full-sort) fallback instead.

k <= 0 or k >= V disables filtering for that row (the "no top-k" case
in SamplingParams), matching the oracle.
"""
from __future__ import annotations

import functools

import jax
import numpy as np
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG = -1e30
# numpy scalars (not jnp arrays): they inline as literals inside the
# Pallas kernel instead of being captured as device constants
_INT_MIN = np.int32(-(2 ** 31))
_LOW31 = np.int32(0x7FFFFFFF)


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:  # pragma: no cover
        return False


def _sortable(x_f32):
    """Monotone fp32 -> int32 map: a >= b (float) iff s(a) >= s(b) (int).

    -0.0 is canonicalized to +0.0 first: float compares treat them as
    equal, but their bit patterns sort apart — without this a +-0.0
    threshold would mask differently from the float-comparing oracle
    and lax fallback."""
    x_f32 = jnp.where(x_f32 == 0.0, 0.0, x_f32)
    xi = jax.lax.bitcast_convert_type(x_f32, jnp.int32)
    return xi ^ (jnp.right_shift(xi, 31) & _LOW31)


def _topk_kernel(k_ref, x_ref, o_ref, *, v_real, fill):
    b = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                  # (1, Vp)
    s = _sortable(x)
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = col < v_real
    kk = k_ref[b]

    def body(i, p):
        # u-space (unsigned radix order) candidate; compare in s-space
        cand = p | jnp.left_shift(np.int32(1), 31 - i)
        cand_s = cand ^ _INT_MIN
        cnt = jnp.sum(jnp.where(valid & (s >= cand_s), 1, 0))
        return jnp.where(cnt >= kk, cand, p)

    p = jax.lax.fori_loop(0, 32, body, np.int32(0))
    thr = p ^ _INT_MIN                                  # k-th largest, s-space
    disabled = (kk <= 0) | (kk >= v_real)
    keep = disabled | (s >= thr)
    o_ref[...] = jnp.where(keep & valid, x_ref[...],
                           jnp.asarray(fill, o_ref.dtype))


def _topk_mask_lax(logits, k, fill):
    """Off-TPU fallback: jax.lax.top_k with k=V (a full descending sort)
    so one dispatch still covers every per-row k in the batch."""
    b, v = logits.shape
    x = logits.astype(jnp.float32)
    vals = jax.lax.top_k(x, v)[0]                       # (B, V) descending
    idx = jnp.clip(k - 1, 0, v - 1)
    thr = jnp.take_along_axis(vals, idx[:, None], axis=1)
    disabled = (k <= 0) | (k >= v)
    keep = disabled[:, None] | (x >= thr)
    return jnp.where(keep, logits, jnp.asarray(fill, logits.dtype))


def topk_mask(logits, k, fill=NEG, *, use_pallas=None, interpret=None):
    """Mask each row of ``logits`` to its top-``k[row]`` values.

    logits: (B, V) float; k: (B,) int32 per-row k — ``k <= 0`` or
    ``k >= V`` disables filtering for that row.  Values strictly below
    the row's k-th largest become ``fill``; boundary ties all survive
    (value-threshold semantics, identical to `ref.topk_mask_ref`).
    Comparisons happen on the fp32 view of the input; the surviving
    values pass through in the input dtype, bit-untouched.
    """
    b, v = logits.shape
    k = jnp.asarray(k, jnp.int32)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return _topk_mask_lax(logits, k, fill)
    if interpret is None:
        interpret = not _on_tpu()
    vp = -(-v // 128) * 128
    x = logits if vp == v else jnp.pad(
        logits, ((0, 0), (0, vp - v)),
        constant_values=jnp.asarray(fill, logits.dtype))
    kernel = functools.partial(_topk_kernel, v_real=v, fill=fill)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, vp), lambda bi, kr: (bi, 0))],
        out_specs=pl.BlockSpec((1, vp), lambda bi, kr: (bi, 0)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, vp), logits.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(k, x)
    return out[:, :v]
