"""Paged-gather decode attention: flash-decoding over a paged KV cache.

Serving keeps K/V in fixed-size *pages* (``(num_pages, page_size, n_kv,
head_dim)`` per k/v) so requests of different lengths share one decode
batch without reserving ``max_len`` per row — the allocator hands pages
to rows on demand and the per-row *page table* maps logical token
position ``t`` to physical page ``table[b, t // page_size]``.

The kernel is the decode hot path (S=1 per row): grid ``(B, MAXP)``,
one program per (row, logical page).  The page table rides in scalar
prefetch, so the BlockSpec ``index_map`` resolves the *physical* page to
DMA before the body runs — the gather through the table costs nothing
beyond the DMA it would issue anyway (the TPU answer to "non-coalesced
access", same trick as the block-mode hashed GEMM kernels).  Softmax is
online (running max / sum / accumulator in VMEM scratch across the page
walk), so no (B, T) score tensor ever materializes.

Unused table slots point at page 0 — a reserved *trash page* no live row
owns — and are masked out through ``lengths``; rows with ``length == 0``
(idle decode rows) produce zeros.

TPU-lowering notes (validated with interpret=True on CPU, like the
hashed-GEMM kernels): the (n_kv, ps, d) in-kernel transposes and the
small (n_kv, g) accumulator tiles assume Mosaic's relayout support;
pad head_dim/page_size to the (8, 128) fp32 tile for production shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

_NEG = -1e30


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:  # pragma: no cover
        return False


def _decode_kernel(table_ref, len_ref, win_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, ps, n_kv, g, d, maxp, scale):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].reshape(n_kv, g, d).astype(jnp.float32) * scale
    k = k_ref[0].transpose(1, 0, 2).astype(jnp.float32)   # (n_kv, ps, d)
    v = v_ref[0].transpose(1, 0, 2).astype(jnp.float32)

    # (n_kv, g, ps) scores, batched over kv heads (GQA without repeat)
    s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)

    length = len_ref[b]
    kv_pos = p * ps + jax.lax.broadcasted_iota(jnp.int32, (1, 1, ps), 2)
    valid = kv_pos < length
    win = win_ref[0]
    q_pos = length - 1
    valid = valid & jnp.where(win > 0, q_pos - kv_pos < win, True)
    s = jnp.where(valid, s, _NEG)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    # exp() of a fully-masked row is exp(_NEG - _NEG) = 1; re-mask so
    # trash/garbage pages contribute exactly zero weight
    w = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
    m_ref[...] = m_new
    l_ref[...] = l_prev * alpha + w.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jax.lax.dot_general(
        w, v, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)

    @pl.when(p == maxp - 1)
    def _flush():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l > 0, l, 1.0)[..., None]
        o_ref[...] = out.reshape(1, n_kv * g, d).astype(o_ref.dtype)


def paged_decode_attention(q, pages_k, pages_v, page_table, lengths,
                           window=0, *, interpret=None):
    """One decode step of attention through a paged KV cache.

    q:          (B, Hq, D) current-token queries, rotated to position
                ``lengths - 1``; scaled by 1/sqrt(D) in-kernel (fp32).
    pages_k/v:  (P, page_size, Hkv, D) physical page pool (page 0 is the
                reserved trash page).
    page_table: (B, MAXP) int32 — logical page i of row b lives in
                physical page ``page_table[b, i]``; unused slots are 0.
    lengths:    (B,) int32 — valid cached tokens per row INCLUDING the
                current token's k/v (already written to its page).
    window:     scalar int32 — sliding-window size; 0 disables (a traced
                value: the per-layer gemma-style local/global pattern
                feeds it from inside the layer scan).

    Returns (B, Hq, D) in q.dtype.
    """
    b, hq, d = q.shape
    npages, ps, n_kv, dk = pages_k.shape
    assert dk == d and hq % n_kv == 0, (q.shape, pages_k.shape)
    g = hq // n_kv
    maxp = page_table.shape[1]
    if interpret is None:
        interpret = not _on_tpu()

    kernel = functools.partial(_decode_kernel, ps=ps, n_kv=n_kv, g=g, d=d,
                               maxp=maxp, scale=1.0 / (d ** 0.5))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, maxp),
        in_specs=[
            pl.BlockSpec((1, hq, d),
                         lambda bi, p, tbl, ln, wn: (bi, 0, 0)),
            pl.BlockSpec((1, ps, n_kv, d),
                         lambda bi, p, tbl, ln, wn: (tbl[bi, p], 0, 0, 0)),
            pl.BlockSpec((1, ps, n_kv, d),
                         lambda bi, p, tbl, ln, wn: (tbl[bi, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hq, d),
                               lambda bi, p, tbl, ln, wn: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_kv, g), jnp.float32),
            pltpu.VMEM((n_kv, g), jnp.float32),
            pltpu.VMEM((n_kv, g, d), jnp.float32),
        ],
    )
    win = jnp.full((1,), window, jnp.int32) if jnp.ndim(window) == 0 \
        else jnp.asarray(window, jnp.int32).reshape(1)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(page_table, jnp.int32), jnp.asarray(lengths, jnp.int32),
      win, q, pages_k, pages_v)
