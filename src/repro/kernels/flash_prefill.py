"""Batched ragged flash-prefill: multi-request chunked prefill over a
paged KV cache in ONE dispatch.

Chunked prefill used to run one request per dispatch through a batch=1
scratch cache — the TTFT bottleneck at high arrival rates.  This kernel
processes every prefilling row's current chunk together: each row b
brings S query slots (its chunk, padded to the compile bucket) sitting
at positions ``starts[b] + s``, and attends over its OWN paged prefix —
shared-prefix pages read through the page table exactly like decode —
under the causal band.  ``counts[b]`` marks the real (un-padded) slots;
pad slots and ``counts == 0`` rows (the padding rows that round the
batch out to a compile shape) produce zeros.

Layout mirrors ``paged_attention.py``: grid ``(B, MAXP)``, page table +
ragged ``starts``/``counts`` in scalar prefetch so the BlockSpec
``index_map`` resolves physical pages before the body runs, online
softmax (running max / sum / accumulator in VMEM scratch) across the
page walk.  The query block is pre-shaped to ``(n_kv, g*S, d)`` on the
host so the in-kernel score product is one batched ``dot_general`` over
kv heads (GQA without repeat), same as the decode kernel.

The chunk's fresh K/V must already be scattered into each row's private
pages before the call (``nn.attention.apply_paged_prefill`` does the
scatter) — the kernel then reads old prefix and fresh chunk uniformly
through the table, so no per-request scratch cache round-trip exists.

TPU-lowering notes (validated with interpret=True on CPU): the
(n_kv, g*S) accumulator tiles assume Mosaic relayout support; pad
head_dim/page_size/bucket to the (8, 128) fp32 tile for production
shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

_NEG = -1e30


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:  # pragma: no cover
        return False


def _prefill_kernel(table_ref, start_ref, count_ref, win_ref, q_ref,
                    k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                    *, ps, n_kv, g, s_blk, d, maxp):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                      # (n_kv, g*S, d)
    k = k_ref[0].transpose(1, 0, 2).astype(jnp.float32)   # (n_kv, ps, d)
    v = v_ref[0].transpose(1, 0, 2).astype(jnp.float32)

    # (n_kv, g*S, ps) scores, batched over kv heads (GQA without repeat)
    s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)

    start = start_ref[b]
    count = count_ref[b]
    # flat query index j = gi*S + si -> slot si = j % S at position
    # start + si; pad slots (si >= count) are fully masked
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, g * s_blk, 1), 1) % s_blk
    q_pos = start + slot
    kv_pos = p * ps + jax.lax.broadcasted_iota(jnp.int32, (1, 1, ps), 2)
    valid = (kv_pos <= q_pos) & (kv_pos < start + count) & (slot < count)
    win = win_ref[0]
    valid = valid & jnp.where(win > 0, q_pos - kv_pos < win, True)
    s = jnp.where(valid, s, _NEG)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    # exp() of a fully-masked row is exp(_NEG - _NEG) = 1; re-mask so
    # trash/garbage pages and pad slots contribute exactly zero weight
    w = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
    m_ref[...] = m_new
    l_ref[...] = l_prev * alpha + w.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jax.lax.dot_general(
        w, v, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)

    @pl.when(p == maxp - 1)
    def _flush():
        l = l_ref[...]
        out = acc_ref[...] / jnp.where(l > 0, l, 1.0)[..., None]
        o_ref[...] = out[None].astype(o_ref.dtype)


def paged_prefill_attention(q, pages_k, pages_v, page_table, starts,
                            counts, window=0, *, interpret=None):
    """Ragged batched prefill attention through a paged KV cache.

    q:          (B, S, Hq, D) chunk queries, rotated to positions
                ``starts[b] + s``; scaled by 1/sqrt(D) in-kernel (fp32).
    pages_k/v:  (P, page_size, Hkv, D) physical page pool with the
                chunk's K/V already scattered into each row's private
                pages (page 0 is the reserved trash page).
    page_table: (B, MAXP) int32 — logical page i of row b lives in
                physical page ``page_table[b, i]``; unused slots are 0.
    starts:     (B,) int32 — position of each row's first query slot
                (tokens already cached before this chunk).
    counts:     (B,) int32 — real query slots per row; slots >= counts
                are pad, rows with 0 are inert padding rows.
    window:     scalar int32 — sliding-window size; 0 disables (a traced
                value: the per-layer gemma-style local/global pattern
                feeds it from inside the layer scan).

    Returns (B, S, Hq, D) in q.dtype; pad slots are zero.
    """
    b, s_blk, hq, d = q.shape
    npages, ps, n_kv, dk = pages_k.shape
    assert dk == d and hq % n_kv == 0, (q.shape, pages_k.shape)
    g = hq // n_kv
    maxp = page_table.shape[1]
    if interpret is None:
        interpret = not _on_tpu()

    scale = 1.0 / (d ** 0.5)
    # (B, S, n_kv, g, d) -> (B, n_kv, g*S, d): flat j = gi*S + si, so the
    # kernel recovers the slot as j % S
    qk = (q.astype(jnp.float32) * scale).reshape(b, s_blk, n_kv, g, d)
    qk = qk.transpose(0, 2, 3, 1, 4).reshape(b, n_kv, g * s_blk, d)

    kernel = functools.partial(_prefill_kernel, ps=ps, n_kv=n_kv, g=g,
                               s_blk=s_blk, d=d, maxp=maxp)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b, maxp),
        in_specs=[
            pl.BlockSpec((1, n_kv, g * s_blk, d),
                         lambda bi, p, tbl, st, cn, wn: (bi, 0, 0, 0)),
            pl.BlockSpec((1, ps, n_kv, d),
                         lambda bi, p, tbl, st, cn, wn:
                         (tbl[bi, p], 0, 0, 0)),
            pl.BlockSpec((1, ps, n_kv, d),
                         lambda bi, p, tbl, st, cn, wn:
                         (tbl[bi, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_kv, g * s_blk, d),
                               lambda bi, p, tbl, st, cn, wn:
                               (bi, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_kv, g * s_blk), jnp.float32),
            pltpu.VMEM((n_kv, g * s_blk), jnp.float32),
            pltpu.VMEM((n_kv, g * s_blk, d), jnp.float32),
        ],
    )
    win = jnp.full((1,), window, jnp.int32) if jnp.ndim(window) == 0 \
        else jnp.asarray(window, jnp.int32).reshape(1)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g * s_blk, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(page_table, jnp.int32), jnp.asarray(starts, jnp.int32),
      jnp.asarray(counts, jnp.int32), win, qk, pages_k, pages_v)
    # (B, n_kv, g*S, d) -> (B, S, Hq, D)
    out = out.reshape(b, n_kv, g, s_blk, d).transpose(0, 3, 1, 2, 4)
    return out.reshape(b, s_blk, hq, d)
