from repro.models.transformer import Model, build  # noqa: F401
