"""Model builder: ArchConfig -> Model (init / train_loss / prefill / decode).

All stacks scan over layers with layer-stacked parameters (compile-size
friendly at 126 layers).  Four architecture kinds:

- decoder : [attn + ffn|moe] x L                     (llama/gemma/qwen/moe/vlm)
- encdec  : encoder [bidir attn + ffn] x Le, decoder [self + cross + ffn] x Ld
- rwkv    : [time_mix + channel_mix] x L             (attention-free)
- zamba   : 9 groups x [hybrid_group mamba layers + ONE shared attn/ffn block]

Hashing: when cfg.hashed, every projection's weight is a HashedNets bank.
With scan-over-layers the *bucket pattern* is shared across layers while the
bank values differ per layer (paper deviation documented in DESIGN.md §2 —
each layer still has its own w^l; per-layer h^l is kept for the non-scanned
paper MLP experiments).
"""
from __future__ import annotations

import dataclasses
import functools
import math
import zlib
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import policy as POL
from repro.configs.base import ArchConfig
from repro.core import hashed as H
from repro.core.hashing import derive_seed
from repro.distributed import sharding as shd
from repro.nn import attention as ATT
from repro.nn import ffn as FFN
from repro.nn import layers as L
from repro.nn import mamba2 as MB
from repro.nn import moe as MOE
from repro.nn import rwkv6 as RW


class Model(NamedTuple):
    cfg: ArchConfig
    init: Callable          # key -> params
    pspecs: Callable        # () -> logical PartitionSpec tree (matches params)
    train_loss: Callable    # (params, batch) -> (loss, metrics)
    prefill: Callable       # (params, batch) -> (logits_last, cache)
    decode_step: Callable   # (params, tokens(B,1), cache) -> (logits, cache)
    init_cache: Callable    # (batch, max_len) -> cache
    cache_pspecs: Callable  # (batch, max_len) -> spec tree for cache
    # paged-KV serving path (decoder kinds only; None elsewhere):
    # init_paged_cache: (num_pages, page_size) -> {"k","v"} page pools
    # decode_paged: (params, tokens(B,1), pages, page_table(B,MAXP),
    #                lengths(B,), impl) -> (logits, pages)
    init_paged_cache: Optional[Callable] = None
    decode_paged: Optional[Callable] = None
    # decode_paged_block: (params, tokens(B,S), pages, page_table,
    #                      lengths(B,), counts(B,), impl) -> (logits, pages)
    # multi-token decode for speculative propose/verify; None for model
    # kinds where a wider batch is not bitwise row-equivalent (MoE
    # capacity routing mixes rows).
    decode_paged_block: Optional[Callable] = None
    # prefill_paged: (params, tokens(B,S), pages, page_table(B,MAXP),
    #                 starts(B,), counts(B,), write_from(B,), impl)
    #   -> (last_hidden(B,1,d_model), pages)
    # batched ragged prefill straight into paged KV — returns the last
    # REAL slot's hidden state, not logits: the engine runs logits_head
    # on the (1, 1, d) per-row slice so the LM-head GEMM keeps the exact
    # M=1 dispatch shape of the sequential path (M=1 GEMV results are
    # not bitwise row-equal to wider GEMMs).  None for MoE.
    prefill_paged: Optional[Callable] = None
    # logits_head: (params, x(B,S,d_model)) -> logits — final norm + LM
    # head, exactly the tail of prefill/decode.
    logits_head: Optional[Callable] = None


# ---------------------------------------------------------------------------
# spec-capture helper (PartitionSpec can't cross trace boundaries)
# ---------------------------------------------------------------------------

def _is_spec(x):
    return isinstance(x, P)


def stack_init(init_fn, keys):
    """vmap an (params, specs) initializer over layer keys.

    Returns (stacked_params, specs_with_leading_None_axis)."""
    cell = []

    def only_params(k):
        p, s = init_fn(k)
        if not cell:
            cell.append(s)
        return p

    params = jax.vmap(only_params)(keys)
    specs = jax.tree.map(lambda s: P(None, *s), cell[0], is_leaf=_is_spec)
    return params, specs


# ---------------------------------------------------------------------------
# hashed-slot inventory + policy resolution
# ---------------------------------------------------------------------------

def _slot_seed(seed_key: str) -> int:
    # zlib.crc32, NOT builtin hash(): the latter is salted per process
    # (PYTHONHASHSEED) and would give every host a different weight-sharing
    # pattern — fatal for multi-host SPMD and checkpoint restore.
    return derive_seed(0xC0FFEE, zlib.crc32(seed_key.encode()) & 0x7FFFFFFF)


def hash_slots(cfg: ArchConfig) -> Tuple[POL.Slot, ...]:
    """Every hashable projection slot of a model, declaratively.

    One entry per param-leaf path (layer stacking adds a leading array
    axis, never a path component) with its dense virtual shape and hash
    seed.  Seeds keep the pre-policy derivation (``attn.q``, ``ffn.out``,
    ``embed``, ...) so legacy flat-knob configs resolve to byte-identical
    weight-sharing patterns; encoder/decoder FFNs in encdec share seed
    keys (they historically shared one plan).  ``default_on`` encodes the
    legacy embedding gate (``hash_embeddings``), overridable per rule.
    """
    if not cfg.hashed:
        return ()
    d = cfg.d_model
    hq = cfg.num_heads * cfg.head_dim
    hkv = cfg.num_kv_heads * cfg.head_dim
    gated = cfg.activation in ("swiglu", "geglu")
    slots = []

    def add(path, seed_key, vshape, on=True):
        slots.append(POL.Slot(path=tuple(path), virtual_shape=tuple(vshape),
                              seed=_slot_seed(seed_key), default_on=on))

    def add_attn(base, prefix):
        add(base + ("q", "w"), f"{prefix}.q", (d, hq))
        add(base + ("k", "w"), f"{prefix}.k", (d, hkv))
        add(base + ("v", "w"), f"{prefix}.v", (d, hkv))
        add(base + ("o", "w"), f"{prefix}.o", (hq, d))

    def add_ffn(base, prefix):
        add(base + ("in", "w"), f"{prefix}.in", (d, cfg.d_ff))
        if gated:
            add(base + ("gate", "w"), f"{prefix}.gate", (d, cfg.d_ff))
        add(base + ("out", "w"), f"{prefix}.out", (cfg.d_ff, d))

    # every arch kind embeds through _emb_plan; the bank exists whenever
    # the policy turns the slot on (default: the hash_embeddings knob)
    add(("embed", "emb"), "embed", (cfg.padded_vocab, d),
        on=cfg.hash_embeddings)

    if cfg.arch_kind == "decoder":
        add_attn(("layers", "attn"), "attn")
        if cfg.moe:
            # MoE expert banks sit directly under their name (no "w" leaf)
            e, f = cfg.num_experts, cfg.moe_d_ff
            add(("layers", "moe", "in"), "moe.in", (e * d, f))
            if gated:
                add(("layers", "moe", "gate"), "moe.gate", (e * d, f))
            add(("layers", "moe", "out"), "moe.out", (e * f, d))
        else:
            add_ffn(("layers", "ffn"), "ffn")
        if not cfg.tie_embeddings:
            # only the decoder builder hashes its untied lm_head
            add(("lm_head", "w"), "lm_head", (d, cfg.padded_vocab),
                on=cfg.hash_embeddings)
    elif cfg.arch_kind == "rwkv":
        for name in ("r", "k", "v", "g", "o"):
            add(("layers", "tm", name, "w"), f"rwkv.{name}", (d, d))
        add(("layers", "cm", "k", "w"), "cmix.k", (d, cfg.d_ff))
        add(("layers", "cm", "v", "w"), "cmix.v", (cfg.d_ff, d))
        add(("layers", "cm", "r", "w"), "cmix.r", (d, d))
    elif cfg.arch_kind == "zamba":
        mb = _mamba_geometry(cfg)
        add(("mamba_groups", "mamba", "in_proj", "w"), "mamba.in",
            (d, mb.in_dim))
        add(("mamba_groups", "mamba", "out_proj", "w"), "mamba.out",
            (mb.d_inner, d))
        add_attn(("shared", "attn"), "attn")
        add_ffn(("shared", "ffn"), "ffn")
    elif cfg.arch_kind == "encdec":
        add_attn(("encoder", "attn"), "enc")
        add_attn(("decoder", "self"), "dec")
        add_attn(("decoder", "cross"), "xattn")
        add_ffn(("encoder", "ffn"), "ffn")
        add_ffn(("decoder", "ffn"), "ffn")
    return tuple(slots)


@functools.lru_cache(maxsize=128)
def slot_assignments(cfg: ArchConfig) -> Dict[tuple, POL.SlotAssignment]:
    """Policy resolution for a config: param-leaf path -> SlotAssignment.

    This is THE source of truth for which slots are hashed and how —
    plan factories, the artifact subsystem, the compression report, and
    the budget solver all read it.  Cached: resolution walks every rule
    for every slot and may run the budget solver.
    """
    return POL.resolve(POL.effective(cfg), hash_slots(cfg))


def bank_spec_map(cfg: ArchConfig) -> Dict[tuple, H.HashedSpec]:
    """Map param-leaf paths -> HashedSpec for every hashed bank in a model.

    Keys are the nested-dict key tuples of ``model.init`` params.  This is
    the ground truth the artifact subsystem serializes: bank leaves carry
    their spec in the header so the virtual matrix is reconstructible from
    the file alone.
    """
    return {path: a.spec for path, a in slot_assignments(cfg).items()
            if a.spec is not None}


def _slot_spec(cfg: ArchConfig, path: tuple) -> Optional[H.HashedSpec]:
    a = slot_assignments(cfg).get(tuple(path))
    return a.spec if a is not None else None


# ---------------------------------------------------------------------------
# plans from config
# ---------------------------------------------------------------------------

def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _attn_plan(cfg: ArchConfig, base=("layers", "attn"), cross=False,
               causal=True, use_rope=True) -> ATT.AttentionPlan:
    sp = functools.partial(_slot_spec, cfg)
    return ATT.AttentionPlan(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        use_rope=use_rope, qk_norm=cfg.qk_norm,
        sliding_window=cfg.sliding_window, causal=causal, cross=cross,
        dtype=_dtype(cfg),
        hash_q=sp(base + ("q", "w")),
        hash_k=sp(base + ("k", "w")),
        hash_v=sp(base + ("v", "w")),
        hash_o=sp(base + ("o", "w")),
        hash_path=cfg.hash_path,
    )


def _ffn_plan(cfg: ArchConfig, base=("layers", "ffn")) -> FFN.FFNPlan:
    sp = functools.partial(_slot_spec, cfg)
    return FFN.FFNPlan(
        d_model=cfg.d_model, d_ff=cfg.d_ff, activation=cfg.activation,
        dtype=_dtype(cfg),
        hash_in=sp(base + ("in", "w")),
        hash_gate=sp(base + ("gate", "w")),
        hash_out=sp(base + ("out", "w")),
        hash_path=cfg.hash_path,
    )


def _moe_plan(cfg: ArchConfig, base=("layers", "moe")) -> MOE.MoEPlan:
    sp = functools.partial(_slot_spec, cfg)
    return MOE.MoEPlan(
        d_model=cfg.d_model, d_ff=cfg.moe_d_ff,
        num_experts=cfg.num_experts, top_k=cfg.top_k,
        activation=cfg.activation, capacity_factor=cfg.capacity_factor,
        dtype=_dtype(cfg),
        hash_in=sp(base + ("in",)),
        hash_gate=sp(base + ("gate",)),
        hash_out=sp(base + ("out",)),
    )


def _mamba_geometry(cfg: ArchConfig) -> MB.Mamba2Plan:
    """Bare mamba plan (no hash fields): the single source of the
    projection geometry (in_dim/d_inner) for both the slot inventory and
    the full plan."""
    return MB.Mamba2Plan(d_model=cfg.d_model, d_state=cfg.ssm_state,
                         head_dim=cfg.ssm_head_dim, chunk=cfg.ssm_chunk,
                         dtype=_dtype(cfg))


def _mamba_plan(cfg: ArchConfig,
                base=("mamba_groups", "mamba")) -> MB.Mamba2Plan:
    sp = functools.partial(_slot_spec, cfg)
    return dataclasses.replace(
        _mamba_geometry(cfg),
        hash_in=sp(base + ("in_proj", "w")),
        hash_out=sp(base + ("out_proj", "w")),
        hash_path=cfg.hash_path,
    )


def _rwkv_plan(cfg: ArchConfig, base=("layers", "tm")) -> RW.RWKV6Plan:
    d = cfg.d_model
    sp = functools.partial(_slot_spec, cfg)
    return RW.RWKV6Plan(
        d_model=d, head_dim=cfg.head_dim, dtype=_dtype(cfg),
        lora_dim=min(32, max(4, d // 128)),
        decay_lora_dim=min(64, max(4, d // 64)),
        hash_r=sp(base + ("r", "w")),
        hash_k=sp(base + ("k", "w")),
        hash_v=sp(base + ("v", "w")),
        hash_g=sp(base + ("g", "w")),
        hash_o=sp(base + ("o", "w")),
        hash_path=cfg.hash_path,
    )


def _cmix_plan(cfg: ArchConfig, base=("layers", "cm")) -> RW.ChannelMixPlan:
    sp = functools.partial(_slot_spec, cfg)
    return RW.ChannelMixPlan(
        d_model=cfg.d_model, d_ff=cfg.d_ff, dtype=_dtype(cfg),
        hash_k=sp(base + ("k", "w")),
        hash_v=sp(base + ("v", "w")),
        hash_r=sp(base + ("r", "w")),
        hash_path=cfg.hash_path,
    )


def _emb_plan(cfg: ArchConfig) -> L.EmbeddingPlan:
    return L.EmbeddingPlan(cfg.padded_vocab, cfg.d_model,
                           hashed=_slot_spec(cfg, ("embed", "emb")),
                           dtype=_dtype(cfg),
                           scale_by_sqrt_dim=cfg.scale_embeddings)


def _norm_fns(cfg: ArchConfig):
    if cfg.norm == "rmsnorm":
        return lambda: L.rmsnorm_init(cfg.d_model), L.rmsnorm_apply
    return lambda: L.layernorm_init(cfg.d_model), L.layernorm_apply


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def softmax_xent(logits, targets, vocab_size):
    """logits (B,S,Vp) fp32; targets (B,S) int32, -1 = masked."""
    logits = logits.astype(jnp.float32)
    vp = logits.shape[-1]
    if vp > vocab_size:
        # mask padded vocab slots
        pad_mask = jnp.arange(vp) < vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    mask = (targets >= 0)
    tgt = jnp.where(mask, targets, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1)
    loss = nll.sum() / denom
    acc = ((jnp.argmax(logits, -1) == tgt) * mask).sum() / denom
    return loss, {"nll": loss, "accuracy": acc, "tokens": denom}


# ===========================================================================
# decoder-kind model (llama / gemma / qwen / moe / vlm)
# ===========================================================================

def _build_decoder(cfg: ArchConfig) -> Model:
    dt = _dtype(cfg)
    attn_plan = _attn_plan(cfg)
    use_moe = cfg.moe
    ffn_plan = None if use_moe else _ffn_plan(cfg)
    moe_plan = _moe_plan(cfg) if use_moe else None
    emb_plan = _emb_plan(cfg)
    norm_init, norm_apply = _norm_fns(cfg)
    nl = cfg.num_layers

    # per-layer global-attention flags (gemma3 5:1 pattern)
    if cfg.global_every > 0:
        is_global = jnp.array(
            [(i % cfg.global_every) == cfg.global_every - 1
             for i in range(nl)])
    else:
        is_global = jnp.ones((nl,), bool)  # irrelevant when no window

    def layer_init(key):
        ks = jax.random.split(key, 4)
        params, specs = {}, {}
        params["attn"], specs["attn"] = ATT.init(attn_plan, ks[0])
        params["ln1"], specs["ln1"] = norm_init()
        params["ln2"], specs["ln2"] = norm_init()
        if use_moe:
            params["moe"], specs["moe"] = MOE.init(moe_plan, ks[1])
        else:
            params["ffn"], specs["ffn"] = FFN.init(ffn_plan, ks[1])
        return params, specs

    def build_params(key, spec_cell=None):
        kemb, klayers, kout, khead = jax.random.split(key, 4)
        params, specs = {}, {}
        params["embed"], specs["embed"] = L.embedding_init(emb_plan, kemb)
        params["layers"], specs["layers"] = stack_init(
            layer_init, jax.random.split(klayers, nl))
        params["final_norm"], specs["final_norm"] = norm_init()
        if not cfg.tie_embeddings:
            p, s = L.linear_init(
                L.LinearPlan(cfg.d_model, cfg.padded_vocab,
                             hashed=_slot_spec(cfg, ("lm_head", "w")),
                             pspec=(L.FSDP, L.TP), dtype=dt), khead)
            params["lm_head"], specs["lm_head"] = p, s
        if spec_cell is not None:
            spec_cell.append(specs)
        return params

    def layer_body(x, lp, glob, positions, cache_kv=None, cache_index=None):
        h = norm_apply(lp["ln1"], x)
        a, new_kv = ATT.apply(attn_plan, lp["attn"], h, positions=positions,
                              cache=cache_kv, cache_index=cache_index,
                              is_global=glob)
        x = x + a
        h = norm_apply(lp["ln2"], x)
        if use_moe:
            f, aux = MOE.apply(moe_plan, lp["moe"], h)
        else:
            f, aux = FFN.apply(ffn_plan, lp["ffn"], h), 0.0
        x = shd.constraint(x + f, P(L.BATCH, None, None))
        return x, aux, new_kv

    def embed_input(params, batch):
        x = L.embedding_lookup(emb_plan, params["embed"], batch["tokens"])
        if cfg.num_image_tokens > 0 and "image_embeds" in batch:
            x = jnp.concatenate(
                [batch["image_embeds"].astype(x.dtype), x], axis=1)
        return shd.constraint(x, P(L.BATCH, None, None))

    def logits_fn(params, x):
        x = norm_apply(params["final_norm"], x)
        if cfg.tie_embeddings:
            return L.embedding_logits(emb_plan, params["embed"], x)
        return L.linear_apply(
            L.LinearPlan(cfg.d_model, cfg.padded_vocab, dtype=dt,
                         hashed=_slot_spec(cfg, ("lm_head", "w")),
                         hash_path=cfg.hash_path),
            params["lm_head"], x)

    def train_loss(params, batch):
        x = embed_input(params, batch)
        s = x.shape[1]
        positions = jnp.arange(s)

        def body(carry, xs):
            x, aux = carry
            lp, glob = xs

            def inner(x, lp):
                y, a, _ = layer_body(x, lp, glob, positions)
                return y, a

            if cfg.remat:
                inner = jax.checkpoint(inner)
            x, a = inner(x, lp)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, 0.0),
                                   (params["layers"], is_global))
        logits = logits_fn(params, x)
        if cfg.num_image_tokens > 0 and "image_embeds" in batch:
            logits = logits[:, cfg.num_image_tokens:, :]
        loss, metrics = softmax_xent(logits, batch["targets"],
                                     cfg.vocab_size)
        total = loss + aux
        metrics["aux_loss"] = aux
        return total, metrics

    def init_cache(batch, max_len):
        shape = (nl, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
                "index": jnp.zeros((), jnp.int32)}

    def cache_pspecs(batch, max_len):
        # seq axis resolution is per-cell (launch/specs.rules_for):
        # decode cells shard cache seq over the model axis
        # (flash-decoding: partial softmax + tiny all-reduces) -- train
        # cells resolve seq to None.
        kv = P(None, L.CACHE_BATCH, L.SEQ, L.TP_KV, L.TP_HD)
        return {"k": kv, "v": kv, "index": P()}

    def fwd_with_cache(params, x, cache, start, length=None):
        s = x.shape[1]
        start = jnp.asarray(start)
        if start.ndim == 1:     # per-slot decode positions (continuous batching)
            positions = start[:, None] + jnp.arange(s)[None, :]
        else:
            positions = start + jnp.arange(s)

        # Layer caches ride the scan as xs/ys.  (A carried-buffer +
        # dynamic-update-slice variant was tried and REFUTED: XLA CPU
        # inserts two extra full-stack copies for read+write carries —
        # §Perf it.4.  On TPU, ys-stacking dus bufferizes in place.)
        def body(carry, xs):
            x = carry
            lp, glob, ck, cv = xs
            y, _, new_kv = layer_body(x, lp, glob, positions,
                                      cache_kv=(ck, cv), cache_index=start)
            return y, new_kv

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], is_global, cache["k"], cache["v"]))
        if length is not None:
            # Bucketed prefill (pad-and-mask): tokens were right-padded to
            # a static bucket; pads sit AFTER the real prompt so causal
            # attention never lets a real query see one.  Slice the single
            # last real position before the LM head (also skips computing
            # vocab logits for every pad), and advance the write index by
            # the true length — the garbage K/V rows beyond it stay
            # invisible (kv_valid masks >= index) and are overwritten as
            # decode proceeds.
            x = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
            new_cache = {"k": nk, "v": nv, "index": start + length}
        else:
            new_cache = {"k": nk, "v": nv, "index": start + s}
        return logits_fn(params, x), new_cache

    def prefill(params, batch):
        x = embed_input(params, batch)
        cache = batch["cache"]
        logits, cache = fwd_with_cache(params, x, cache, cache["index"],
                                       length=batch.get("length"))
        return logits[:, -1:, :], cache

    def decode_step(params, tokens, cache):
        x = L.embedding_lookup(emb_plan, params["embed"], tokens)
        x = shd.constraint(x, P(L.BATCH, None, None))
        logits, cache = fwd_with_cache(params, x, cache, cache["index"])
        return logits, cache

    def init_paged_cache(num_pages, page_size):
        shape = (nl, num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}

    def decode_paged(params, tokens, pages, page_table, lengths,
                     impl="ref"):
        """One continuous-batching decode step through the paged KV cache.

        tokens (B, 1); pages {"k","v"} (nl, P, ps, n_kv, hd); page_table
        (B, MAXP) int32 (unused slots -> trash page 0); lengths (B,)
        int32 cached-token counts EXCLUDING the current token.  Layer
        page pools ride the scan as xs/ys exactly like the dense cache.
        """
        x = L.embedding_lookup(emb_plan, params["embed"], tokens)
        x = shd.constraint(x, P(L.BATCH, None, None))

        def body(x, xs):
            lp, glob, pk, pv = xs
            h = norm_apply(lp["ln1"], x)
            a, (nk, nv) = ATT.apply_paged(
                attn_plan, lp["attn"], h, pages=(pk, pv),
                page_table=page_table, lengths=lengths, is_global=glob,
                impl=impl)
            # pin the pool's head-shard layout across layers (serving
            # rules resolve tp_kv -> model on a serving mesh, else no-op)
            nk = shd.constraint(nk, P(None, None, L.TP_KV, L.TP_HD))
            nv = shd.constraint(nv, P(None, None, L.TP_KV, L.TP_HD))
            x = x + a
            h = norm_apply(lp["ln2"], x)
            if use_moe:
                f, _ = MOE.apply(moe_plan, lp["moe"], h)
            else:
                f = FFN.apply(ffn_plan, lp["ffn"], h)
            x = shd.constraint(x + f, P(L.BATCH, None, None))
            return x, (nk, nv)

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], is_global, pages["k"], pages["v"]))
        return logits_fn(params, x), {"k": nk, "v": nv}

    def decode_paged_block(params, tokens, pages, page_table, lengths,
                           counts, impl="ref"):
        """Multi-token paged decode for speculative propose/verify.

        tokens (B, S); slot s of row b is the token at position
        ``lengths[b] + s``, real iff ``s < counts[b]`` (padding slots
        write trash-page K/V and produce garbage logits the caller
        ignores).  Per-row compute is bitwise-identical to S successive
        ``decode_paged`` steps — every sublayer is row-wise (GEMMs,
        norms, elementwise) and the attention masks match, the same
        invariance the chunked-prefill parity pin rests on.
        """
        x = L.embedding_lookup(emb_plan, params["embed"], tokens)
        x = shd.constraint(x, P(L.BATCH, None, None))

        def body(x, xs):
            lp, glob, pk, pv = xs
            h = norm_apply(lp["ln1"], x)
            a, (nk, nv) = ATT.apply_paged_block(
                attn_plan, lp["attn"], h, pages=(pk, pv),
                page_table=page_table, lengths=lengths, counts=counts,
                is_global=glob, impl=impl)
            x = x + a
            h = norm_apply(lp["ln2"], x)
            f = FFN.apply(ffn_plan, lp["ffn"], h)
            x = shd.constraint(x + f, P(L.BATCH, None, None))
            return x, (nk, nv)

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], is_global, pages["k"], pages["v"]))
        return logits_fn(params, x), {"k": nk, "v": nv}

    def prefill_paged(params, tokens, pages, page_table, starts, counts,
                      write_from, impl="ref"):
        """Batched ragged prefill chunks straight into the paged cache.

        tokens (B, S); slot s of row b is the prompt token at position
        ``starts[b] + s``, real iff ``s < counts[b]`` (``counts == 0``
        rows are inert padding).  Fresh K/V lands in each row's private
        pages through the table (positions below ``write_from[b]`` —
        shared prefix pages — are write-protected), no per-request
        scratch cache.  Returns the LAST REAL slot's hidden state
        (B, 1, d_model) — run ``logits_head`` on a per-row (1, 1, d)
        slice to finish, preserving the sequential path's M=1 LM-head
        dispatch.  Per-row compute is bitwise-identical to the
        sequential chunked path: every sublayer is row-wise and the
        attention arithmetic mirrors the dense-scratch path op for op
        (kernels.ref.paged_prefill_ref).
        """
        x = L.embedding_lookup(emb_plan, params["embed"], tokens)
        x = shd.constraint(x, P(L.BATCH, None, None))

        def body(x, xs):
            lp, glob, pk, pv = xs
            h = norm_apply(lp["ln1"], x)
            a, (nk, nv) = ATT.apply_paged_prefill(
                attn_plan, lp["attn"], h, pages=(pk, pv),
                page_table=page_table, starts=starts, counts=counts,
                write_from=write_from, is_global=glob, impl=impl)
            # pin the pool's head-shard layout across layers (see
            # decode_paged)
            nk = shd.constraint(nk, P(None, None, L.TP_KV, L.TP_HD))
            nv = shd.constraint(nv, P(None, None, L.TP_KV, L.TP_HD))
            x = x + a
            h = norm_apply(lp["ln2"], x)
            f = FFN.apply(ffn_plan, lp["ffn"], h)
            x = shd.constraint(x + f, P(L.BATCH, None, None))
            return x, (nk, nv)

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], is_global, pages["k"], pages["v"]))
        last = jnp.clip(counts - 1, 0, tokens.shape[1] - 1)
        x = jnp.take_along_axis(x, last[:, None, None], axis=1)
        return x, {"k": nk, "v": nv}

    def pspecs():
        cell = []
        jax.eval_shape(lambda k: build_params(k, cell),
                       jax.random.PRNGKey(0))
        return cell[0]

    return Model(cfg, lambda key: build_params(key), pspecs, train_loss,
                 prefill, decode_step, init_cache, cache_pspecs,
                 init_paged_cache=init_paged_cache,
                 decode_paged=decode_paged,
                 # MoE capacity routing is batch-shape dependent, so a
                 # wider block is not bitwise row-equal there
                 decode_paged_block=None if use_moe else decode_paged_block,
                 prefill_paged=None if use_moe else prefill_paged,
                 logits_head=logits_fn)


# ===========================================================================
# rwkv-kind model (attention-free)
# ===========================================================================

def _build_rwkv(cfg: ArchConfig) -> Model:
    dt = _dtype(cfg)
    tm_plan = _rwkv_plan(cfg)
    cm_plan = _cmix_plan(cfg)
    emb_plan = _emb_plan(cfg)
    norm_init, norm_apply = _norm_fns(cfg)
    nl = cfg.num_layers

    def layer_init(key):
        ks = jax.random.split(key, 2)
        params, specs = {}, {}
        params["tm"], specs["tm"] = RW.init(tm_plan, ks[0])
        params["cm"], specs["cm"] = RW.channel_mix_init(cm_plan, ks[1])
        params["ln1"], specs["ln1"] = norm_init()
        params["ln2"], specs["ln2"] = norm_init()
        return params, specs

    def build_params(key, spec_cell=None):
        kemb, klayers, khead = jax.random.split(key, 3)
        params, specs = {}, {}
        params["embed"], specs["embed"] = L.embedding_init(emb_plan, kemb)
        params["layers"], specs["layers"] = stack_init(
            layer_init, jax.random.split(klayers, nl))
        params["final_norm"], specs["final_norm"] = norm_init()
        p, s = L.linear_init(
            L.LinearPlan(cfg.d_model, cfg.padded_vocab,
                         pspec=(L.FSDP, L.TP), dtype=dt), khead)
        params["lm_head"], specs["lm_head"] = p, s
        if spec_cell is not None:
            spec_cell.append(specs)
        return params

    def layer_body(x, lp, state):
        h = norm_apply(lp["ln1"], x)
        a, tm_state = RW.apply_time_mix(tm_plan, lp["tm"], h, state["tm"])
        x = x + a
        h = norm_apply(lp["ln2"], x)
        f, cm_state = RW.channel_mix_apply(cm_plan, lp["cm"], h, state["cm"])
        x = shd.constraint(x + f, P(L.BATCH, None, None))
        return x, {"tm": tm_state, "cm": cm_state}

    def zero_state(batch):
        return {"tm": RW.time_mix_state(tm_plan, batch),
                "cm": RW.channel_mix_state(cm_plan, batch)}

    def stacked_zero_state(batch):
        one = zero_state(batch)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (nl,) + a.shape), one)

    def run(params, x, state):
        def body(carry, xs):
            x = carry
            lp, st = xs

            def inner(x, lp, st):
                return layer_body(x, lp, st)

            if cfg.remat:
                inner = jax.checkpoint(inner)
            x, new_st = inner(x, lp, st)
            return x, new_st

        x, new_state = jax.lax.scan(body, x, (params["layers"], state))
        x = norm_apply(params["final_norm"], x)
        logits = L.linear_apply(
            L.LinearPlan(cfg.d_model, cfg.padded_vocab, dtype=dt),
            params["lm_head"], x)
        return logits, new_state

    def train_loss(params, batch):
        x = L.embedding_lookup(emb_plan, params["embed"], batch["tokens"])
        x = shd.constraint(x, P(L.BATCH, None, None))
        logits, _ = run(params, x, stacked_zero_state(x.shape[0]))
        loss, metrics = softmax_xent(logits, batch["targets"],
                                     cfg.vocab_size)
        metrics["aux_loss"] = 0.0
        return loss, metrics

    def init_cache(batch, max_len):
        del max_len  # recurrent: O(1) state
        st = stacked_zero_state(batch)
        return {"state": st, "index": jnp.zeros((), jnp.int32)}

    def cache_pspecs(batch, max_len):
        del max_len
        return {"state": {
            "tm": {"shift": P(None, L.CACHE_BATCH, None),
                   "wkv": P(None, L.CACHE_BATCH, L.TP_KV, None, None)},
            "cm": {"shift": P(None, L.CACHE_BATCH, None)},
        }, "index": P()}

    def prefill(params, batch):
        x = L.embedding_lookup(emb_plan, params["embed"], batch["tokens"])
        x = shd.constraint(x, P(L.BATCH, None, None))
        cache = batch["cache"]
        logits, st = run(params, x, cache["state"])
        return logits[:, -1:, :], {"state": st,
                                   "index": cache["index"] + x.shape[1]}

    def decode_step(params, tokens, cache):
        x = L.embedding_lookup(emb_plan, params["embed"], tokens)
        logits, st = run(params, x, cache["state"])
        return logits, {"state": st, "index": cache["index"] + 1}

    def pspecs():
        cell = []
        jax.eval_shape(lambda k: build_params(k, cell),
                       jax.random.PRNGKey(0))
        return cell[0]

    return Model(cfg, lambda key: build_params(key), pspecs, train_loss,
                 prefill, decode_step, init_cache, cache_pspecs)


# ===========================================================================
# zamba-kind model (mamba2 backbone + shared attention block)
# ===========================================================================

def _build_zamba(cfg: ArchConfig) -> Model:
    dt = _dtype(cfg)
    mb_plan = _mamba_plan(cfg)
    attn_plan = _attn_plan(cfg, base=("shared", "attn"))
    ffn_plan = _ffn_plan(cfg, base=("shared", "ffn"))
    emb_plan = _emb_plan(cfg)
    norm_init, norm_apply = _norm_fns(cfg)
    group = cfg.hybrid_group
    n_groups = cfg.num_layers // group
    assert n_groups * group == cfg.num_layers

    def mamba_layer_init(key):
        params, specs = {}, {}
        params["mamba"], specs["mamba"] = MB.init(mb_plan, key)
        params["ln"], specs["ln"] = norm_init()
        return params, specs

    def build_params(key, spec_cell=None):
        kemb, km, ka, kf, kh = jax.random.split(key, 5)
        params, specs = {}, {}
        params["embed"], specs["embed"] = L.embedding_init(emb_plan, kemb)
        # (n_groups, group, ...) stacked mamba layers
        mkeys = jax.random.split(km, cfg.num_layers).reshape(
            n_groups, group, 2)
        cell = []

        def group_init_capture(keys):
            p, s = stack_init(mamba_layer_init, keys)
            if not cell:
                cell.append(s)
            return p

        params["mamba_groups"] = jax.vmap(group_init_capture)(mkeys)
        specs["mamba_groups"] = jax.tree.map(
            lambda s: P(None, *s), cell[0], is_leaf=_is_spec)
        # ONE shared attention + ffn block (zamba's contribution)
        shared_p, shared_s = {}, {}
        shared_p["attn"], shared_s["attn"] = ATT.init(attn_plan, ka)
        shared_p["ffn"], shared_s["ffn"] = FFN.init(ffn_plan, kf)
        shared_p["ln1"], shared_s["ln1"] = norm_init()
        shared_p["ln2"], shared_s["ln2"] = norm_init()
        params["shared"], specs["shared"] = shared_p, shared_s
        params["final_norm"], specs["final_norm"] = norm_init()
        p, s = L.linear_init(
            L.LinearPlan(cfg.d_model, cfg.padded_vocab,
                         pspec=(L.FSDP, L.TP), dtype=dt), kh)
        params["lm_head"], specs["lm_head"] = p, s
        if spec_cell is not None:
            spec_cell.append(specs)
        return params

    def shared_block(params, x, positions, cache_kv=None, cache_index=None):
        sp = params["shared"]
        h = norm_apply(sp["ln1"], x)
        a, new_kv = ATT.apply(attn_plan, sp["attn"], h, positions=positions,
                              cache=cache_kv, cache_index=cache_index)
        x = x + a
        h = norm_apply(sp["ln2"], x)
        x = x + FFN.apply(ffn_plan, sp["ffn"], h)
        return x, new_kv

    def mamba_zero_state(batch):
        one = MB.init_state(mb_plan, batch)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups, group) + a.shape), one)

    def run_train(params, x):
        positions = jnp.arange(x.shape[1])

        def group_body(carry, gp):
            x, aux = carry

            def inner_layer(x, lp):
                h = norm_apply(lp["ln"], x)
                y, _ = MB.apply_train(mb_plan, lp["mamba"], h)
                return x + y, None

            def inner_group(x, gp):
                x, _ = jax.lax.scan(
                    lambda c, lp: inner_layer(c, lp), x, gp)
                x, _ = shared_block(params, x, positions)
                return shd.constraint(x, P(L.BATCH, None, None))

            if cfg.remat:
                inner_group = jax.checkpoint(inner_group)
            x = inner_group(x, gp)
            return (x, aux), None

        (x, _), _ = jax.lax.scan(group_body, (x, 0.0),
                                 params["mamba_groups"])
        return x

    def train_loss(params, batch):
        x = L.embedding_lookup(emb_plan, params["embed"], batch["tokens"])
        x = shd.constraint(x, P(L.BATCH, None, None))
        x = run_train(params, x)
        x = norm_apply(params["final_norm"], x)
        logits = L.linear_apply(
            L.LinearPlan(cfg.d_model, cfg.padded_vocab, dtype=dt),
            params["lm_head"], x)
        loss, metrics = softmax_xent(logits, batch["targets"],
                                     cfg.vocab_size)
        metrics["aux_loss"] = 0.0
        return loss, metrics

    def init_cache(batch, max_len):
        kv_shape = (n_groups, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(kv_shape, dt), "v": jnp.zeros(kv_shape, dt),
                "mamba": mamba_zero_state(batch),
                "index": jnp.zeros((), jnp.int32)}

    def cache_pspecs(batch, max_len):
        # seq axis resolution is per-cell (launch/specs.rules_for):
        # decode cells shard cache seq over the model axis
        # (flash-decoding: partial softmax + tiny all-reduces) -- train
        # cells resolve seq to None.
        kv = P(None, L.CACHE_BATCH, L.SEQ, L.TP_KV, L.TP_HD)
        ms = MB.state_pspec()
        return {"k": kv, "v": kv,
                "mamba": jax.tree.map(
                    lambda s: P(None, None, L.CACHE_BATCH, *s[1:])
                    if len(s) and s[0] == L.BATCH else P(None, None, *s),
                    ms, is_leaf=_is_spec),
                "index": P()}

    def run_cached(params, x, cache, start, decode: bool):
        start = jnp.asarray(start)
        if start.ndim == 1:
            positions = start[:, None] + jnp.arange(x.shape[1])[None, :]
        else:
            positions = start + jnp.arange(x.shape[1])

        def group_body(x, xs):
            gp, ck, cv, mstate = xs

            def inner_layer(x, args):
                lp, st = args
                h = norm_apply(lp["ln"], x)
                if decode:
                    y, new_st = MB.apply_decode(mb_plan, lp["mamba"], h, st)
                else:
                    y, new_st = MB.apply_train(mb_plan, lp["mamba"], h)
                return x + y, new_st

            x, new_mstate = jax.lax.scan(inner_layer, x, (gp, mstate))
            x, new_kv = shared_block(params, x, positions,
                                     cache_kv=(ck, cv), cache_index=start)
            return x, (new_kv[0], new_kv[1], new_mstate)

        x, (nk, nv, nms) = jax.lax.scan(
            group_body, x,
            (params["mamba_groups"], cache["k"], cache["v"],
             cache["mamba"]))
        new_cache = {"k": nk, "v": nv, "mamba": nms,
                     "index": start + x.shape[1]}
        x = norm_apply(params["final_norm"], x)
        logits = L.linear_apply(
            L.LinearPlan(cfg.d_model, cfg.padded_vocab, dtype=dt),
            params["lm_head"], x)
        return logits, new_cache

    def prefill(params, batch):
        x = L.embedding_lookup(emb_plan, params["embed"], batch["tokens"])
        x = shd.constraint(x, P(L.BATCH, None, None))
        cache = batch["cache"]
        logits, cache = run_cached(params, x, cache, cache["index"],
                                   decode=False)
        return logits[:, -1:, :], cache

    def decode_step(params, tokens, cache):
        x = L.embedding_lookup(emb_plan, params["embed"], tokens)
        logits, cache = run_cached(params, x, cache, cache["index"],
                                   decode=True)
        return logits, cache

    def pspecs():
        cell = []
        jax.eval_shape(lambda k: build_params(k, cell),
                       jax.random.PRNGKey(0))
        return cell[0]

    return Model(cfg, lambda key: build_params(key), pspecs, train_loss,
                 prefill, decode_step, init_cache, cache_pspecs)


# ===========================================================================
# enc-dec kind (whisper): stub audio frontend provides frame embeddings
# ===========================================================================

def _build_encdec(cfg: ArchConfig) -> Model:
    dt = _dtype(cfg)
    enc_attn = _attn_plan(cfg, base=("encoder", "attn"), causal=False,
                          use_rope=False)
    self_attn = _attn_plan(cfg, base=("decoder", "self"), causal=True,
                           use_rope=False)
    cross_attn = _attn_plan(cfg, base=("decoder", "cross"), cross=True,
                            causal=False, use_rope=False)
    # encoder/decoder FFNs share seed keys (one historical plan) but are
    # separate slots: a policy may compress them differently
    enc_ffn = _ffn_plan(cfg, base=("encoder", "ffn"))
    dec_ffn = _ffn_plan(cfg, base=("decoder", "ffn"))
    emb_plan = _emb_plan(cfg)
    norm_init, norm_apply = _norm_fns(cfg)
    nl, ne = cfg.num_layers, cfg.encoder_layers

    def enc_layer_init(key):
        ks = jax.random.split(key, 2)
        params, specs = {}, {}
        params["attn"], specs["attn"] = ATT.init(enc_attn, ks[0])
        params["ffn"], specs["ffn"] = FFN.init(enc_ffn, ks[1])
        params["ln1"], specs["ln1"] = norm_init()
        params["ln2"], specs["ln2"] = norm_init()
        return params, specs

    def dec_layer_init(key):
        ks = jax.random.split(key, 3)
        params, specs = {}, {}
        params["self"], specs["self"] = ATT.init(self_attn, ks[0])
        params["cross"], specs["cross"] = ATT.init(cross_attn, ks[1])
        params["ffn"], specs["ffn"] = FFN.init(dec_ffn, ks[2])
        params["ln1"], specs["ln1"] = norm_init()
        params["ln2"], specs["ln2"] = norm_init()
        params["ln3"], specs["ln3"] = norm_init()
        return params, specs

    def build_params(key, spec_cell=None):
        kemb, kenc, kdec, kh = jax.random.split(key, 4)
        params, specs = {}, {}
        params["embed"], specs["embed"] = L.embedding_init(emb_plan, kemb)
        params["encoder"], specs["encoder"] = stack_init(
            enc_layer_init, jax.random.split(kenc, ne))
        params["decoder"], specs["decoder"] = stack_init(
            dec_layer_init, jax.random.split(kdec, nl))
        params["enc_norm"], specs["enc_norm"] = norm_init()
        params["final_norm"], specs["final_norm"] = norm_init()
        p, s = L.linear_init(
            L.LinearPlan(cfg.d_model, cfg.padded_vocab,
                         pspec=(L.FSDP, L.TP), dtype=dt), kh)
        params["lm_head"], specs["lm_head"] = p, s
        if spec_cell is not None:
            spec_cell.append(specs)
        return params

    def encode(params, frames):
        """frames: (B, T_enc, d_model) precomputed stub embeddings."""
        t = frames.shape[1]
        x = frames.astype(dt) + L.sinusoidal_positions(
            t, cfg.d_model).astype(dt)
        x = shd.constraint(x, P(L.BATCH, None, None))
        positions = jnp.arange(t)

        def body(x, lp):
            def inner(x, lp):
                h = norm_apply(lp["ln1"], x)
                a, _ = ATT.apply(enc_attn, lp["attn"], h,
                                 positions=positions)
                x = x + a
                h = norm_apply(lp["ln2"], x)
                return x + FFN.apply(enc_ffn, lp["ffn"], h), None

            if cfg.remat:
                inner = jax.checkpoint(inner)
            return inner(x, lp)

        x, _ = jax.lax.scan(body, x, params["encoder"])
        return norm_apply(params["enc_norm"], x)

    def dec_layer(x, lp, enc_out, positions, cache_kv=None,
                  cache_index=None):
        h = norm_apply(lp["ln1"], x)
        a, new_kv = ATT.apply(self_attn, lp["self"], h, positions=positions,
                              cache=cache_kv, cache_index=cache_index)
        x = x + a
        h = norm_apply(lp["ln2"], x)
        a, _ = ATT.apply(cross_attn, lp["cross"], h, positions=positions,
                         kv_source=enc_out)
        x = x + a
        h = norm_apply(lp["ln3"], x)
        x = shd.constraint(x + FFN.apply(dec_ffn, lp["ffn"], h),
                           P(L.BATCH, None, None))
        return x, new_kv

    def embed_tokens(params, tokens, start, max_pos):
        x = L.embedding_lookup(emb_plan, params["embed"], tokens)
        s = tokens.shape[1]
        table = L.sinusoidal_positions(max_pos, cfg.d_model)
        pe = jax.lax.dynamic_slice_in_dim(table, start, s, axis=0)
        return x + pe.astype(x.dtype)

    def train_loss(params, batch):
        enc_out = encode(params, batch["frames"])
        s = batch["tokens"].shape[1]
        x = embed_tokens(params, batch["tokens"], 0, s)
        x = shd.constraint(x, P(L.BATCH, None, None))
        positions = jnp.arange(x.shape[1])

        def body(x, lp):
            def inner(x, lp):
                y, _ = dec_layer(x, lp, enc_out, positions)
                return y, None

            if cfg.remat:
                inner = jax.checkpoint(inner)
            return inner(x, lp)

        x, _ = jax.lax.scan(body, x, params["decoder"])
        x = norm_apply(params["final_norm"], x)
        logits = L.linear_apply(
            L.LinearPlan(cfg.d_model, cfg.padded_vocab, dtype=dt),
            params["lm_head"], x)
        loss, metrics = softmax_xent(logits, batch["targets"],
                                     cfg.vocab_size)
        metrics["aux_loss"] = 0.0
        return loss, metrics

    def init_cache(batch, max_len):
        kv = (nl, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt),
                "enc": jnp.zeros((batch, cfg.encoder_seq, cfg.d_model), dt),
                "index": jnp.zeros((), jnp.int32)}

    def cache_pspecs(batch, max_len):
        # seq axis resolution is per-cell (launch/specs.rules_for):
        # decode cells shard cache seq over the model axis
        # (flash-decoding: partial softmax + tiny all-reduces) -- train
        # cells resolve seq to None.
        kv = P(None, L.CACHE_BATCH, L.SEQ, L.TP_KV, L.TP_HD)
        return {"k": kv, "v": kv, "enc": P(L.BATCH, None, None),
                "index": P()}

    def run_dec(params, x, enc_out, cache, start):
        positions = start + jnp.arange(x.shape[1])

        def body(x, xs):
            lp, ck, cv = xs
            y, new_kv = dec_layer(x, lp, enc_out, positions,
                                  cache_kv=(ck, cv), cache_index=start)
            return y, new_kv

        x, (nk, nv) = jax.lax.scan(body, x,
                                   (params["decoder"], cache["k"],
                                    cache["v"]))
        x = norm_apply(params["final_norm"], x)
        logits = L.linear_apply(
            L.LinearPlan(cfg.d_model, cfg.padded_vocab, dtype=dt),
            params["lm_head"], x)
        new_cache = {"k": nk, "v": nv, "enc": enc_out,
                     "index": start + x.shape[1]}
        return logits, new_cache

    def prefill(params, batch):
        enc_out = encode(params, batch["frames"])
        cache = batch["cache"]
        max_len = cache["k"].shape[2]
        x = embed_tokens(params, batch["tokens"], cache["index"], max_len)
        x = shd.constraint(x, P(L.BATCH, None, None))
        logits, cache = run_dec(params, x, enc_out, cache, cache["index"])
        return logits[:, -1:, :], cache

    def decode_step(params, tokens, cache):
        max_len = cache["k"].shape[2]
        x = embed_tokens(params, tokens, cache["index"], max_len)
        logits, cache = run_dec(params, x, cache["enc"], cache,
                                cache["index"])
        return logits, cache

    def pspecs():
        cell = []
        jax.eval_shape(lambda k: build_params(k, cell),
                       jax.random.PRNGKey(0))
        return cell[0]

    return Model(cfg, lambda key: build_params(key), pspecs, train_loss,
                 prefill, decode_step, init_cache, cache_pspecs)


# ===========================================================================

def build(cfg: ArchConfig) -> Model:
    if cfg.arch_kind == "decoder":
        return _build_decoder(cfg)
    if cfg.arch_kind == "rwkv":
        return _build_rwkv(cfg)
    if cfg.arch_kind == "zamba":
        return _build_zamba(cfg)
    if cfg.arch_kind == "encdec":
        return _build_encdec(cfg)
    raise ValueError(cfg.arch_kind)
