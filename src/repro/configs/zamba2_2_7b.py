"""Zamba2-2.7B [arXiv:2411.15242]: 54 Mamba-2 layers (d=2560, state=64)
with ONE shared attention+MLP block invoked every 6 layers (9 points),
32H MHA head_dim=80, shared d_ff=10240, vocab=32000.
Simplifications vs HF: single shared block (Zamba2 alternates two) and
no per-invocation LoRA on the shared block (DESIGN.md §5)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-2.7b", family="hybrid", arch_kind="zamba",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    head_dim=80, d_ff=10240, vocab_size=32000,
    rope_theta=10000.0, activation="geglu",
    ssm_state=64, ssm_head_dim=64, hybrid_group=6,
    subquadratic=True,
))
