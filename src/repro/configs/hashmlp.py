"""The paper's own experimental architectures: 3-layer (1 hidden) and
5-layer (3 hidden) fully-connected nets with 1000 hidden units, ReLU,
on 784-dim MNIST-variant inputs (Chen et al. 2015 §6)."""
from repro.configs.base import ArchConfig, register

# These are handled by repro.paper (dedicated MLP implementation); the
# registry entries make them selectable via --arch for the launchers.
MLP_3 = register(ArchConfig(
    name="hashmlp-3layer", family="mlp", arch_kind="decoder",
    num_layers=1, d_model=1000, num_heads=1, num_kv_heads=1, head_dim=64,
    d_ff=1000, vocab_size=10, activation="relu",
))
MLP_5 = register(ArchConfig(
    name="hashmlp-5layer", family="mlp", arch_kind="decoder",
    num_layers=3, d_model=1000, num_heads=1, num_kv_heads=1, head_dim=64,
    d_ff=1000, vocab_size=10, activation="relu",
))
