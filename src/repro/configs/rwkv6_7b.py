"""RWKV-6 "Finch" 7B [arXiv:2404.05892]: 32L, d=4096, attention-free
(data-dependent decay linear recurrence), d_ff=14336, vocab=65536,
head_dim=64."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-7b", family="ssm", arch_kind="rwkv",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    head_dim=64, d_ff=14336, vocab_size=65536,
    norm="layernorm", subquadratic=True,
))
