"""Llama-4 Scout 17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E]:
48L, d=5120, 40H GQA kv=8, MoE 16 experts top-1, expert d_ff=8192,
vocab=202048.  Text backbone only (early-fusion vision frontend is outside
the assigned scope)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-scout-17b-a16e", family="moe", arch_kind="decoder",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=8192, vocab_size=202048,
    rope_theta=500000.0, activation="swiglu",
    moe=True, num_experts=16, top_k=1, moe_d_ff=8192,
))
