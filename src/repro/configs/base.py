"""Architecture config schema + registry.

Every assigned architecture is a frozen ArchConfig; `reduced()` derives the
CPU smoke-test variant.  The paper's technique is a config flag (`hashed`)
applicable to any architecture (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.policy import CompressionPolicy

_REGISTRY: Dict[str, "ArchConfig"] = {}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | audio | hybrid | ssm | vlm
    arch_kind: str                   # decoder | encdec | rwkv | zamba
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention
    rope_theta: float = 500000.0
    qk_norm: bool = False
    sliding_window: int = 0          # 0 = full
    global_every: int = 0            # gemma3: every Nth layer full attention
    activation: str = "swiglu"
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    scale_embeddings: bool = False   # gemma: x *= sqrt(d)
    # moe
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # ssm / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    hybrid_group: int = 0            # zamba: mamba layers per shared-attn point
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed frame embeddings (stub)
    # vlm (llava)
    num_image_tokens: int = 0        # stub patch embeddings prepended
    # paper technique.  The flat knobs below are the legacy uniform
    # configuration; when ``hash_policy`` is set it takes precedence and
    # the flat knobs are ignored (repro.policy.effective) — legacy
    # configs lower into a single-rule policy producing byte-identical
    # HashedSpecs.
    hashed: bool = False
    compression: float = 0.125
    hash_mode: str = "element"       # element | block
    hash_panel_cols: int = 512
    hash_block: Tuple[int, int] = (128, 128)
    hash_embeddings: bool = False
    hash_path: str = "scan"          # execution path for hashed matmuls
    hash_policy: Optional[CompressionPolicy] = None
    # compressed artifact export (repro.artifact)
    artifact_quant: str = "none"     # none | int8 | fp8 bank quantization
    artifact_group: int = 64         # per-group scale granularity
    # numerics / train
    dtype: str = "bfloat16"
    remat: bool = True
    # long-context applicability (DESIGN.md §5 skip list)
    subquadratic: bool = False

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        return ((self.vocab_size + 255) // 256) * 256

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def hashed_variant(self, compression: float = 0.125,
                       mode: str = "element") -> "ArchConfig":
        return self.with_(hashed=True, compression=compression,
                          hash_mode=mode,
                          name=f"{self.name}-{compression_tag(compression)}")

    def policy_variant(self, policy: CompressionPolicy) -> "ArchConfig":
        """Hashed variant driven by a CompressionPolicy (per-slot rules
        and/or an equal-memory budget)."""
        policy.validate()
        tag = (f"budget{policy.budget:g}" if policy.budget is not None
               else "policy")
        return self.with_(hashed=True, hash_policy=policy,
                          name=f"{self.name}-{tag}")

    def param_count_dense(self) -> int:
        """Approximate dense (virtual) parameter count, for roofline N."""
        d, f, L = self.d_model, self.d_ff, self.num_layers
        hq = self.num_heads * self.head_dim
        hkv = self.num_kv_heads * self.head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.arch_kind == "rwkv":
            per = 5 * d * d + 2 * d * f + d * d
            return L * per + emb
        if self.arch_kind == "zamba":
            d_in = 2 * d
            per_mamba = d * (2 * d_in + 2 * self.ssm_state
                             + d_in // self.ssm_head_dim) + d_in * d
            shared = d * (hq + 2 * hkv) + hq * d + 3 * d * f
            return L * per_mamba + (L // max(self.hybrid_group, 1)) * shared + emb
        attn = d * (hq + 2 * hkv) + hq * d
        if self.moe:
            ffn = self.num_experts * 3 * d * self.moe_d_ff + d * self.num_experts
        else:
            gates = 3 if self.activation in ("swiglu", "geglu") else 2
            ffn = gates * d * f
        enc = 0
        if self.arch_kind == "encdec":
            enc_attn = 2 * attn  # self + cross in decoder; encoder self
            enc = self.encoder_layers * (attn + 3 * d * f) \
                + L * (attn + 3 * d * f)  # decoder cross-attn approximated in
            return enc + emb
        return L * (attn + ffn) + emb

    def active_param_count(self) -> int:
        """MoE: params touched per token (for 6·N_active·D)."""
        if not self.moe:
            return self.param_count_dense()
        d, L = self.d_model, self.num_layers
        hq = self.num_heads * self.head_dim
        hkv = self.num_kv_heads * self.head_dim
        attn = d * (hq + 2 * hkv) + hq * d
        ffn_active = self.top_k * 3 * d * self.moe_d_ff + d * self.num_experts
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn_active) + emb


def compression_tag(compression: float) -> str:
    """Exact name tag for a compression ratio: reciprocal rates keep the
    historical ``hashed8`` form; anything else gets an exact ``hashedc``
    tag (0.3 -> ``hashedc0.3``, not the misleading ``hashed3``).
    ``get`` parses both back (variant-name round-trip)."""
    inv = 1.0 / compression
    if abs(inv - round(inv)) < 1e-9:
        return f"hashed{int(round(inv))}"
    return f"hashedc{compression:g}"


def _parse_variant(name: str) -> Optional["ArchConfig"]:
    """Derive ``<base>[-reduced]-hashedN|-hashedcX`` names not in the
    registry, so variant names round-trip through ``get``."""
    base, sep, tag = name.rpartition("-")
    if not sep or not base:
        return None
    if tag.startswith("hashedc"):
        try:
            compression = float(tag[len("hashedc"):])
        except ValueError:
            return None
    elif tag.startswith("hashed") and tag[len("hashed"):].isdigit():
        compression = 1.0 / int(tag[len("hashed"):])
    else:
        return None
    reduce_it = base.endswith("-reduced")
    if reduce_it:
        base = base[: -len("-reduced")]
    if base not in _REGISTRY:
        return None
    cfg = _REGISTRY[base]
    if reduce_it:
        from repro.configs.reduced import reduced
        cfg = reduced(cfg)
    cfg = cfg.hashed_variant(compression)
    # the tag must regenerate exactly, else the name would drift on the
    # next round-trip
    return cfg if cfg.name == name else None


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    import repro.configs  # noqa: F401  (ensure registration side effects)
    if name in _REGISTRY:
        return _REGISTRY[name]
    derived = _parse_variant(name)
    if derived is not None:
        return derived
    raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")


def names():
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
