"""Qwen3-1.7B [hf:Qwen/Qwen3-1.7B]: 28L, d=2048, 16H GQA kv=8,
head_dim=128, d_ff=6144, vocab=151936, qk-norm, tied embeddings."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-1.7b", family="dense", arch_kind="decoder",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=8,
    head_dim=128, d_ff=6144, vocab_size=151936,
    rope_theta=1000000.0, activation="swiglu", qk_norm=True,
    tie_embeddings=True,
))
