"""Gemma-3 4B [hf:google/gemma-3-4b-pt]: 34L, d=2560, 8H GQA kv=4,
head_dim=256, d_ff=10240, vocab=262144, 5:1 local(1024):global attention,
GeGLU, tied + scaled embeddings.  Sub-quadratic-eligible for long_500k
(5/6 of layers are 1024-window)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-4b", family="dense", arch_kind="decoder",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4,
    head_dim=256, d_ff=10240, vocab_size=262144,
    rope_theta=1000000.0, activation="geglu",
    sliding_window=1024, global_every=6,
    tie_embeddings=True, scale_embeddings=True, qk_norm=True,
    subquadratic=True,
))
