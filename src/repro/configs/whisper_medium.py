"""Whisper medium [arXiv:2212.04356]: enc-dec, 24+24L, d=1024, 16H MHA,
d_ff=4096, vocab=51865, GELU, LayerNorm, sinusoidal positions.  The conv
audio frontend is a STUB per the assignment: input_specs() provides
precomputed 1500-frame embeddings (30 s of audio)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-medium", family="audio", arch_kind="encdec",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    head_dim=64, d_ff=4096, vocab_size=51865,
    activation="gelu", norm="layernorm",
    encoder_layers=24, encoder_seq=1500,
))
