"""LLaVA-NeXT (mistral-7b backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf]:
32L, d=4096, 32H GQA kv=8, d_ff=14336, vocab=32000.  The anyres vision
tower is a STUB per the assignment: input_specs() provides precomputed
patch embeddings (up to 2880 tokens) prepended to the text sequence."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llava-next-mistral-7b", family="vlm", arch_kind="decoder",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=32000,
    rope_theta=1000000.0, activation="swiglu",
    num_image_tokens=2880,
))
