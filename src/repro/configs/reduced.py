"""Reduced same-family configs for CPU smoke tests.

Each assigned architecture gets a tiny sibling: same kind (decoder / encdec
/ rwkv / zamba), same structural features (GQA ratio, local:global pattern,
MoE routing, SSM state), but small widths/layers/vocab so one forward +
train step runs on CPU in seconds.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig


def reduced(cfg: ArchConfig) -> ArchConfig:
    kw = dict(
        name=f"{cfg.name}-reduced",
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads,
                                4 * cfg.num_kv_heads // max(cfg.num_heads, 1),
                                4)),
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        remat=False,
    )
    if cfg.arch_kind == "zamba":
        kw.update(num_layers=4, hybrid_group=2, ssm_state=16,
                  ssm_head_dim=16, ssm_chunk=8, num_heads=4, num_kv_heads=4)
    elif cfg.arch_kind == "rwkv":
        kw.update(num_layers=2, num_heads=4, num_kv_heads=4)
    elif cfg.arch_kind == "encdec":
        kw.update(num_layers=2, encoder_layers=2, encoder_seq=24)
    else:
        kw.update(num_layers=2 if cfg.global_every == 0
                  else 2 * cfg.global_every)
    if cfg.moe:
        kw.update(num_experts=4, top_k=min(cfg.top_k, 2), moe_d_ff=32)
    if cfg.sliding_window:
        kw.update(sliding_window=8)
    if cfg.num_image_tokens:
        kw.update(num_image_tokens=8)
    if cfg.hashed:
        kw.update(hash_panel_cols=0)
    return dataclasses.replace(cfg, **kw)
