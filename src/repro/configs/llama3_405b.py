"""Llama-3.1 405B [arXiv:2407.21783]: 126L, d=16384, 128H GQA kv=8,
d_ff=53248, vocab=128256, RoPE theta 500k."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama3-405b", family="dense", arch_kind="decoder",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
    head_dim=128, d_ff=53248, vocab_size=128256,
    rope_theta=500000.0, activation="swiglu",
))
