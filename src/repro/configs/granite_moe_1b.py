"""Granite-3.0 1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base]:
24L, d=1024, 16H GQA kv=8, MoE 32 experts top-8, expert d_ff=512,
vocab=49155 (padded to 49408), tied embeddings."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-1b-a400m", family="moe", arch_kind="decoder",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    head_dim=64, d_ff=512, vocab_size=49155,
    rope_theta=10000.0, activation="swiglu",
    moe=True, num_experts=32, top_k=8, moe_d_ff=512,
    tie_embeddings=True,
))
