"""Assigned architecture configs (public-literature exact settings) +
the paper's own MLP configs.  Importing this package registers everything.
"""
from repro.configs.base import ArchConfig, get, names, register  # noqa: F401
from repro.configs import (  # noqa: F401
    llama3_405b,
    gemma3_4b,
    qwen3_1_7b,
    gemma_7b,
    granite_moe_1b,
    llama4_scout,
    whisper_medium,
    zamba2_2_7b,
    rwkv6_7b,
    llava_next_mistral_7b,
    hashmlp,
)
from repro.configs.reduced import reduced  # noqa: F401

ASSIGNED = [
    "llama3-405b",
    "gemma3-4b",
    "qwen3-1.7b",
    "gemma-7b",
    "granite-moe-1b-a400m",
    "llama4-scout-17b-a16e",
    "whisper-medium",
    "zamba2-2.7b",
    "rwkv6-7b",
    "llava-next-mistral-7b",
]
