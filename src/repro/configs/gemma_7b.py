"""Gemma 7B [arXiv:2403.08295]: 28L, d=3072, 16H kv=16 (MHA),
head_dim=256, d_ff=24576, vocab=256000, GeGLU, tied + scaled embeddings."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma-7b", family="dense", arch_kind="decoder",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
    head_dim=256, d_ff=24576, vocab_size=256000,
    rope_theta=10000.0, activation="geglu",
    tie_embeddings=True, scale_embeddings=True,
))
