"""Sharded, atomic, elastic checkpointing (no tensorstore/orbax offline).

Layout (one directory per step)::

    <dir>/step_000042/
        manifest.json      # treedef, shapes, dtypes, step, mesh snapshot
        arr_00000.npy ...  # one .npy per leaf (host-gathered)
    <dir>/step_000042.done # commit marker -> atomic visibility

Properties required at fleet scale:

- **Atomicity**: writers fill ``step_X.tmp`` then rename + drop a ``.done``
  marker; readers only consider marked steps, so a mid-write preemption
  can never yield a half checkpoint.
- **Elastic remesh restore**: leaves are stored *logically* (full arrays,
  host-gathered); ``restore`` re-shards onto whatever mesh/pspecs the new
  job brings — restarting 2x16x16 -> 16x16 (pod loss) or onto a differently
  shaped mesh is the same code path.
- **keep-k GC** with never-deleting the newest ``.done`` step.
- **Multi-host**: only process 0 writes (arrays are host-gathered via
  ``jax.device_get`` on addressable+replicated data; for truly distributed
  arrays callers pass ``gather=multihost_gather``).  All hosts restore.

The format is intentionally plain .npy: auditable, mmap-able, and free of
version-pinned dependencies — the right trade for an offline reproduction;
swapping in tensorstore is a one-module change.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STEP_RE = re.compile(r"^step_(\d+)$")


def _paths_of(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    keys = ["/".join(str(p) for p in kp) for kp, _ in paths]
    return list(zip(keys, leaves)), treedef


def save(state, directory: str, step: int, keep: int = 3,
         process_index: Optional[int] = None,
         on_save: Optional[Callable[[str, Any, int], None]] = None) -> str:
    """Write one atomic checkpoint; returns its path.

    on_save: optional hook called (on process 0 only, after the commit
    marker lands) with ``(final_path, state, step)`` — the artifact
    exporter rides here so every committed training checkpoint can also
    mint a deployable compressed artifact (repro.artifact) without the
    trainer knowing the artifact format.  Hook errors are surfaced, not
    swallowed: a failed export must fail loudly before the GC can reap
    the checkpoint it shadowed.
    """
    pid = jax.process_index() if process_index is None else process_index
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    final = os.path.join(directory, name)
    tmp = final + ".tmp"
    kv, _ = _paths_of(state)

    if pid == 0:
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": int(step), "time": time.time(), "leaves": []}
        for i, (key, leaf) in enumerate(kv):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"arr_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {"key": key, "file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, final)                      # atomic rename
        with open(final + ".done", "w") as f:      # commit marker
            f.write(str(step))
        if on_save is not None:
            on_save(final, state, int(step))
        _gc(directory, keep)
    return final


def artifact_exporter(cfg, artifact_dir: str,
                      registry_root: Optional[str] = None,
                      model_name: Optional[str] = None,
                      keep: int = 3):
    """Build an ``on_save`` hook that exports ``state["params"]`` as a
    compressed artifact next to each committed checkpoint (and optionally
    registers it in a model registry for serving cold starts).

    keep: same keep-k GC as the checkpointer — only the newest ``keep``
    exports stay in artifact_dir (a 100k-step run would otherwise pile up
    thousands of artifacts).  Registered copies in the registry are
    immutable and exempt: the registry is the long-term store."""
    from repro import artifact as art

    def hook(final_path: str, state, step: int) -> None:
        path = os.path.join(artifact_dir, f"model_{step:08d}.hnart")
        art.export_model(path, cfg, state["params"],
                         meta={"step": step, "checkpoint": final_path})
        if registry_root:
            from repro import policy as pol
            from repro.artifact import registry as reg
            meta = {"step": step}
            if getattr(cfg, "hash_policy", None) is not None:
                # policy rides the registry entry so a deployment can see
                # how the model's storage budget was allocated without
                # opening the artifact
                meta["hash_policy"] = pol.policy_to_dict(cfg.hash_policy)
            reg.register(registry_root, model_name or cfg.name, path,
                         metadata=meta)
        if keep > 0:
            old = sorted(f for f in os.listdir(artifact_dir)
                         if f.startswith("model_")
                         and f.endswith(".hnart"))[:-keep]
            for f in old:
                os.remove(os.path.join(artifact_dir, f))
    return hook


def _gc(directory: str, keep: int) -> None:
    steps = available_steps(directory)
    for s in steps[:-keep] if keep > 0 else []:
        name = os.path.join(directory, f"step_{s:08d}")
        for p in (name + ".done", name):
            if os.path.isdir(p):
                shutil.rmtree(p, ignore_errors=True)
            elif os.path.exists(p):
                os.remove(p)


def available_steps(directory: str) -> List[int]:
    """Committed steps, ascending."""
    if not os.path.isdir(directory):
        return []
    out = []
    for entry in os.listdir(directory):
        m = _STEP_RE.match(entry)
        if m and os.path.exists(os.path.join(directory, entry + ".done")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = available_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, target, step: Optional[int] = None,
            mesh: Optional[Mesh] = None, pspecs=None):
    """Load a checkpoint into the structure of ``target`` (a pytree of
    arrays or ShapeDtypeStructs).  If (mesh, pspecs) given, every leaf is
    placed with its NamedSharding — this is the elastic-remesh path: the
    checkpoint carries no mesh assumptions at all."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    kv, treedef = _paths_of(target)
    if len(kv) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, target has "
            f"{len(kv)} — structure mismatch")
    by_key = {m["key"]: m for m in manifest["leaves"]}

    shardings = None
    if mesh is not None and pspecs is not None:
        shardings = jax.tree_util.tree_flatten(
            pspecs, is_leaf=lambda x: isinstance(x, P))[0]

    leaves = []
    for i, (key, tgt) in enumerate(kv):
        meta = by_key.get(key) or manifest["leaves"][i]
        arr = np.load(os.path.join(path, meta["file"]))
        want_shape = tuple(getattr(tgt, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: ckpt {arr.shape} != target {want_shape}")
        dtype = getattr(tgt, "dtype", arr.dtype)
        arr = arr.astype(dtype)
        if shardings is not None:
            ns = NamedSharding(mesh, shardings[i]) \
                if isinstance(shardings[i], P) else shardings[i]
            leaves.append(jax.device_put(arr, ns))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
