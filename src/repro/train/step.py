"""Train step factory: microbatched gradient accumulation + clipping +
optimizer update, pjit-ready.

TrainState is a plain dict pytree: {"params", "opt", "step"} — shardable,
checkpointable, remeshable.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.train.optimizer import Optimizer, clip_by_global_norm


def init_state(model: Model, optimizer: Optimizer, key,
               with_residual: bool = False):
    params = model.init(key)
    state = {"params": params, "opt": optimizer.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if with_residual:
        # error-feedback residuals for compressed gradient exchange
        state["residual"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def state_pspecs(model: Model, optimizer: Optimizer,
                 with_residual: bool = False):
    """Logical spec tree matching init_state output."""
    from jax.sharding import PartitionSpec as P

    pspecs = model.pspecs()
    # optimizer states mirror param shapes -> same specs per moment slot.
    # NB: probe the STRUCTURE abstractly — optimizer.init on concrete
    # ShapeDtypeStructs would materialize real zeros (terabytes at 405B).
    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    probe = jax.eval_shape(optimizer.init, params_struct)
    opt_specs = {k: pspecs for k in probe.keys()}
    out = {"params": pspecs, "opt": opt_specs, "step": P()}
    if with_residual:
        out["residual"] = pspecs
    return out


def make_train_step(model: Model, optimizer: Optimizer,
                    num_microbatches: int = 1,
                    clip_norm: Optional[float] = 1.0,
                    accum_dtype=jnp.bfloat16,
                    grad_compressor: Optional[str] = None,
                    compress_ratio: float = 0.125):
    """Returns train_step(state, batch) -> (state, metrics).

    batch leaves have leading dim = global_batch; with num_microbatches > 1
    they are reshaped to (MB, B/MB, ...) and grads are accumulated over a
    lax.scan (bounds activation memory; the standard large-model recipe).

    grad_compressor ("hashed_space" | "int8" | None): compress gradients
    before the optimizer with error feedback — what a pod job applies on
    the slow cross-pod link (train/grad_compress.py).  Requires
    init_state(..., with_residual=True).
    """
    from repro.train import grad_compress
    compress = (grad_compress.make_compressor(grad_compressor,
                                              compress_ratio)
                if grad_compressor else None)

    def loss_fn(params, mb):
        loss, metrics = model.train_loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]

        if num_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % num_microbatches == 0, (b, num_microbatches)
                return x.reshape((num_microbatches, b // num_microbatches)
                                 + x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def body(carry, mb):
                acc, loss_acc = carry
                (loss, metrics), grads = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(accum_dtype), acc, grads)
                return (acc, loss_acc + loss), metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (grads, loss_sum), metrics = jax.lax.scan(
                body, (zeros, 0.0), mbs)
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32)
                           / num_microbatches).astype(accum_dtype), grads)
            loss = loss_sum / num_microbatches
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = jnp.asarray(0.0, jnp.float32)

        new_residual = None
        if compress is not None:
            grads, new_residual = compress(grads, state["residual"])

        new_params, new_opt = optimizer.update(
            grads, state["opt"], params, state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if new_residual is not None:
            new_state["residual"] = new_residual
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = gnorm
        return new_state, metrics

    return train_step
