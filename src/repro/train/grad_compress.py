"""Gradient compression for the slow cross-pod link.

Two compressors, both with error feedback (residual carrying), composable
with the train step *before* the optimizer:

1. ``hashed_space`` — the paper's own math turned into a distributed-
   optimization trick.  For a *hashed* parameter the gradient already
   lives in R^K (K = c * N): cross-pod exchange of hashed layers is
   automatically c-times cheaper — nothing to do.  For *dense* parameters
   we feature-hash the gradient into R^K with (h, xi) (paper Eq. 5/6),
   all-reduce the K-vector, and decompress with the same hash:

       g_hat[i] = xi(i) * G[h(i)],   G[k] = sum_{i: h(i)=k} xi(i) g[i]

   E[g_hat] matches g up to collision noise (unbiased, paper Eq. 1 /
   Weinberger et al. 2009); the residual (g - g_hat) is carried to the
   next step (error feedback), which is what makes sketched SGD converge.

2. ``int8`` — per-tensor max-scaled int8 quantization with error feedback:
   4x (vs f32) / 2x (vs bf16) wire reduction, the conservative default.

Both return pytrees that are what actually crosses the pod axis; the
decompression happens after the all-reduce.  At 512 chips the pod
all-reduce is the slowest collective, so wire bytes here trade directly
against step time (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing


# ---------------------------------------------------------------------------
# error-feedback state
# ---------------------------------------------------------------------------

def init_residual(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# int8 with error feedback
# ---------------------------------------------------------------------------

def int8_compress(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def int8_roundtrip(g, residual):
    """(compressed->decompressed grad, new residual). EF: compress g+r."""
    target = g.astype(jnp.float32) + residual
    q, scale = int8_compress(target)
    approx = int8_decompress(q, scale)
    return approx.astype(g.dtype), target - approx


# ---------------------------------------------------------------------------
# hashed-space sketch (paper Eq. 5/6 applied to gradients)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SketchSpec:
    n: int            # dense gradient length (flattened)
    k: int            # sketch buckets
    seed: int = 0


def _idx_sgn(spec: SketchSpec):
    i = jnp.arange(spec.n, dtype=jnp.int32)
    z = jnp.zeros_like(i)
    idx = hashing.bucket_hash(i, z, spec.k, spec.seed)
    sgn = hashing.sign_hash(i, z, spec.seed).astype(jnp.float32)
    return idx, sgn


def sketch_compress(g: jnp.ndarray, spec: SketchSpec) -> jnp.ndarray:
    """g (n,) -> G (k,): G[c] = sum_{i: h(i)=c} xi(i) g[i]."""
    idx, sgn = _idx_sgn(spec)
    flat = g.astype(jnp.float32).ravel() * sgn
    return jnp.zeros((spec.k,), jnp.float32).at[idx].add(flat)


def sketch_decompress(G: jnp.ndarray, spec: SketchSpec, shape,
                      normalize: bool = False) -> jnp.ndarray:
    """G (k,) -> g_hat (n,): g_hat[i] = xi(i) G[h(i)].

    normalize=False: the classic count-sketch estimate — unbiased over
    random hash functions (paper Eq. 1 inheritance), but the FIXED-hash
    roundtrip decompress(compress(.)) has eigenvalue m (bucket collision
    count) on each collision group, which makes iterated error feedback
    diverge.  normalize=True divides by bucket counts: the roundtrip
    becomes the orthogonal projection onto per-bucket sign directions
    (idempotent, non-expansive) — the EF-stable choice used for the
    cross-pod gradient exchange.
    """
    idx, sgn = _idx_sgn(spec)
    if normalize:
        counts = jnp.zeros((spec.k,), jnp.float32).at[idx].add(1.0)
        G = G / jnp.maximum(counts, 1.0)
    return (G[idx] * sgn).reshape(shape)


def sketch_roundtrip(g, residual, compression: float, seed: int):
    """(approx grad, new residual) through the hashed sketch with EF."""
    n = int(np.prod(g.shape))
    k = max(1, int(round(compression * n)))
    spec = SketchSpec(n=n, k=k, seed=seed)
    target = g.astype(jnp.float32) + residual
    G = sketch_compress(target.ravel(), spec)
    approx = sketch_decompress(G, spec, g.shape, normalize=True)
    return approx.astype(g.dtype), target - approx


# ---------------------------------------------------------------------------
# tree-level transform
# ---------------------------------------------------------------------------

def make_compressor(kind: str, compression: float = 0.125,
                    min_size: int = 65536) -> Callable:
    """Returns compress_tree(grads, residuals) -> (grads', residuals').

    Tensors smaller than min_size (norms, biases, hashed banks — already
    compressed by the paper's technique) pass through untouched.
    kind: "none" | "int8" | "hashed_space"
    """
    def passthrough(g, r):
        return g, r

    def compress_tree(grads, residuals):
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_r = jax.tree_util.tree_flatten(residuals)[0]
        out_g, out_r = [], []
        for li, (g, r) in enumerate(zip(flat_g, flat_r)):
            small = int(np.prod(g.shape)) < min_size
            if kind == "none" or small:
                ng, nr = passthrough(g, r)
            elif kind == "int8":
                ng, nr = int8_roundtrip(g, r)
            elif kind == "hashed_space":
                ng, nr = sketch_roundtrip(g, r, compression,
                                          seed=0xFEED ^ li)
            else:
                raise ValueError(kind)
            out_g.append(ng)
            out_r.append(nr)
        return (jax.tree_util.tree_unflatten(treedef, out_g),
                jax.tree_util.tree_unflatten(treedef, out_r))

    return compress_tree


def wire_bytes(grads, kind: str, compression: float = 0.125,
               min_size: int = 65536) -> int:
    """Bytes a cross-pod exchange of `grads` would put on the wire."""
    total = 0
    for g in jax.tree_util.tree_leaves(grads):
        n = int(np.prod(g.shape))
        if kind == "none" or n < min_size:
            total += n * g.dtype.itemsize
        elif kind == "int8":
            total += n + 4
        elif kind == "hashed_space":
            total += max(1, int(round(compression * n))) * 4
    return total
