"""Fault tolerance for 1000+-node fleets: preemption, stragglers, restart.

Mechanisms (each independent, composed by the runner in launch/train.py):

- ``PreemptionGuard``: converts SIGTERM/SIGINT into a checked flag; the
  training loop polls it once per step and performs an *emergency
  checkpoint* + clean exit instead of dying mid-allreduce.  On TPU pods
  this is the maintenance-event path.
- ``Heartbeat``: per-step progress file (step, wallclock).  An external
  supervisor (or the provided ``watchdog``) detects a wedged/lost worker
  by heartbeat age and restarts the job from the last committed
  checkpoint — crash tolerance without in-band consensus.
- ``StepTimer``: per-step duration EMA + straggler detection.  In SPMD
  every host runs the same program, so a straggling host shows up as
  *this* host's step time inflation; the standard mitigation at fleet
  scale (report + restart into a spare) is wired through the supervisor
  hook.  Within-step, input pipeline stalls are hidden by
  data.pipeline.Prefetcher.
- ``run_with_restarts``: in-process supervisor loop — run fn, on crash
  restore from the checkpoint dir and retry (bounded); models the
  cluster-level restart controller so the whole recover path is testable
  in CI.
"""
from __future__ import annotations

import json
import os
import signal
import time
from typing import Callable, Dict, List, Optional


class PreemptionGuard:
    """SIGTERM/SIGINT -> flag; poll with .should_stop once per step."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = False
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False

    def _handler(self, signum, frame):
        self._flag = True

    @property
    def should_stop(self) -> bool:
        return self._flag


class Heartbeat:
    """Append-free single-file heartbeat: {step, time, host}."""

    def __init__(self, path: str, host_id: int = 0):
        self.path = path
        self.host_id = host_id
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def beat(self, step: int, **extra) -> None:
        tmp = f"{self.path}.tmp{self.host_id}"
        with open(tmp, "w") as f:
            json.dump({"step": int(step), "time": time.time(),
                       "host": self.host_id, **extra}, f)
        os.replace(tmp, self.path)

    def age(self) -> Optional[float]:
        try:
            with open(self.path) as f:
                return time.time() - json.load(f)["time"]
        except (OSError, ValueError, KeyError):
            return None


def watchdog(heartbeats: List[Heartbeat], max_age_s: float) -> List[int]:
    """Hosts whose heartbeat is stale (dead or wedged)."""
    stale = []
    for hb in heartbeats:
        age = hb.age()
        if age is None or age > max_age_s:
            stale.append(hb.host_id)
    return stale


class StepTimer:
    """EMA step timing + straggler flagging (step > factor * median-ish)."""

    def __init__(self, ema: float = 0.9, straggler_factor: float = 2.0,
                 warmup: int = 5):
        self.ema = ema
        self.factor = straggler_factor
        self.warmup = warmup
        self.mean: Optional[float] = None
        self.count = 0
        self.stragglers = 0
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> Dict:
        dt = time.perf_counter() - self._t0
        self.count += 1
        is_straggler = False
        if self.mean is None:
            self.mean = dt
        else:
            if self.count > self.warmup and dt > self.factor * self.mean:
                is_straggler = True
                self.stragglers += 1
            self.mean = self.ema * self.mean + (1 - self.ema) * dt
        return {"step_time": dt, "step_time_ema": self.mean,
                "straggler": is_straggler}


def run_with_restarts(make_and_run: Callable[[int], int],
                      max_restarts: int = 3,
                      retriable=(RuntimeError, OSError)) -> int:
    """In-process restart controller.

    ``make_and_run(attempt)`` must restore from its checkpoint directory
    (if any) and return the final step.  Crash -> restart, bounded.
    """
    attempt = 0
    while True:
        try:
            return make_and_run(attempt)
        except retriable:
            attempt += 1
            if attempt > max_restarts:
                raise
