from repro.train import optimizer, step  # noqa: F401
