"""Optimizers (built from scratch — no optax in this environment).

- sgd_momentum: the paper's optimizer (Chen et al. §6 train HashedNets with
  SGD + momentum + dropout).
- adamw: default for the LLM-scale architectures.

States are fp32 regardless of param dtype; updates are computed in fp32 and
cast back (no separate fp32 master copy — documented in DESIGN.md).
Schedules: constant / warmup-cosine.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable   # params -> opt_state
    update: Callable  # (grads, opt_state, params, step) -> (new_params, new_state)


def constant_lr(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine_lr(peak: float, warmup_steps: int, total_steps: int,
                     final_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak * (final_frac + (1 - final_frac)
                      * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gnorm


def sgd_momentum(lr_fn, momentum: float = 0.9,
                 weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)

        def upd(g, mu, p):
            g32 = g.astype(jnp.float32)
            if weight_decay:
                g32 = g32 + weight_decay * p.astype(jnp.float32)
            mu_new = momentum * mu + g32
            p_new = p.astype(jnp.float32) - lr * mu_new
            return p_new.astype(p.dtype), mu_new

        flat = jax.tree.map(upd, grads, state["mu"], params)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu}

    return Optimizer(init, update)


def adamw(lr_fn, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - jnp.power(b1, t)
        c2 = 1.0 - jnp.power(b2, t)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g32
            v_new = b2 * v + (1 - b2) * jnp.square(g32)
            mhat = m_new / c1
            vhat = v_new / c2
            p32 = p.astype(jnp.float32)
            step_ = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p32
            return (p32 - lr * step_).astype(p.dtype), m_new, v_new

        flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
        leaf = lambda x: isinstance(x, tuple)  # noqa: E731
        return (jax.tree.map(lambda t: t[0], flat, is_leaf=leaf),
                {"m": jax.tree.map(lambda t: t[1], flat, is_leaf=leaf),
                 "v": jax.tree.map(lambda t: t[2], flat, is_leaf=leaf)})

    return Optimizer(init, update)


def make(name: str, lr_fn=None, **kw) -> Optimizer:
    lr_fn = lr_fn or constant_lr(1e-3)
    if name == "sgd_momentum":
        return sgd_momentum(lr_fn, **kw)
    if name == "adamw":
        return adamw(lr_fn, **kw)
    raise ValueError(name)
