"""Serving-engine observability: metrics registry and request tracer.

- `repro.obs.metrics` — a lightweight in-process metrics registry
  (counters, gauges, bucketed histograms) that the engine, scheduler,
  paged KV cache, and fused sampler publish into.  ``Engine.stats()``
  remains as a thin compat view over it.
- `repro.obs.trace` — a per-request span tracer (queued -> prefill
  chunks -> decode ticks -> preempt/recompute -> finish, with COW
  copies and sampler dispatches as child events) with near-zero
  overhead when disabled and a Chrome trace-event JSON exporter
  viewable in Perfetto (https://ui.perfetto.dev).
"""
from repro.obs.metrics import (  # noqa: F401
    Counter, Gauge, Histogram, MetricView, MetricsRegistry,
    diff_snapshots)
from repro.obs.trace import ENGINE_PID, REQUEST_PID, Tracer  # noqa: F401
