"""Per-request span tracer with Chrome trace-event JSON export.

Events follow the Chrome trace-event format (the JSON flavor Perfetto
opens directly — https://ui.perfetto.dev): duration spans (``B``/``E``),
complete slices (``X``, with ``dur``), thread-scoped instants (``i``),
and metadata (``M``) naming processes and threads.  The engine maps:

- pid `ENGINE_PID`, tid 0 — the engine track: whole ticks, fused
  sampler dispatches, COW drains.
- pid `REQUEST_PID`, tid = request uid — one track per request:
  a ``request`` span enclosing ``queued`` spans (initial wait and every
  post-preemption re-wait), ``prefill_chunk`` and ``decode_tick``
  slices, and ``preempt`` / ``cow_copy`` / ``first_token`` instants.

Timestamps are microseconds on a ``perf_counter`` clock anchored at
tracer construction.

Overhead discipline: every recording method returns immediately when
``enabled`` is False, and ``now()`` skips the clock read — a disabled
tracer costs one attribute check per call site (the fuzz suite pins
that tracing on vs off never changes emitted tokens).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Tuple

ENGINE_PID = 0
REQUEST_PID = 1


class Tracer:
    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self.events: List[dict] = []
        self._tracks: Dict[Tuple[int, int], str] = {}
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------------
    def now(self) -> float:
        """Microseconds since tracer start (0.0 when disabled — callers
        stash the value and pass it back to ``complete``)."""
        if not self.enabled:
            return 0.0
        return (time.perf_counter() - self._t0) * 1e6

    def track(self, pid: int, tid: int, name: str) -> None:
        """Name a (pid, tid) track; idempotent."""
        if not self.enabled or (pid, tid) in self._tracks:
            return
        self._tracks[(pid, tid)] = name

    def _push(self, ph: str, pid: int, tid: int, name: Optional[str],
              ts: float, args: dict, **extra) -> None:
        ev = {"ph": ph, "pid": pid, "tid": tid, "ts": ts,
              "cat": "serving"}
        if name is not None:
            ev["name"] = name
        if args:
            ev["args"] = args
        ev.update(extra)
        self.events.append(ev)

    def begin(self, pid: int, tid: int, name: str, **args) -> None:
        if not self.enabled:
            return
        self._push("B", pid, tid, name, self.now(), args)

    def end(self, pid: int, tid: int, name: Optional[str] = None,
            **args) -> None:
        if not self.enabled:
            return
        self._push("E", pid, tid, name, self.now(), args)

    def complete(self, pid: int, tid: int, name: str, start_us: float,
                 **args) -> None:
        """A finished slice: ``start_us`` from an earlier ``now()``."""
        if not self.enabled:
            return
        self._push("X", pid, tid, name, start_us, args,
                   dur=max(self.now() - start_us, 0.0))

    def instant(self, pid: int, tid: int, name: str, **args) -> None:
        if not self.enabled:
            return
        self._push("i", pid, tid, name, self.now(), args, s="t")

    # ------------------------------------------------------------------
    def to_json(self) -> dict:
        meta: List[dict] = []
        for pid, pname in ((ENGINE_PID, "engine"),
                           (REQUEST_PID, "requests")):
            meta.append({"ph": "M", "pid": pid, "tid": 0,
                         "name": "process_name",
                         "args": {"name": pname}})
        for (pid, tid), name in sorted(self._tracks.items()):
            meta.append({"ph": "M", "pid": pid, "tid": tid,
                         "name": "thread_name", "args": {"name": name}})
        return {"traceEvents": meta + self.events,
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
