"""Lightweight metrics registry for the serving engine.

Three metric kinds, all plain host-side Python (no device work, no
locks — the engine is single-threaded per tick):

- `Counter` — a monotonic count (``inc``); `set` exists for the
  dict-view compat surface below.
- `Gauge`   — a point-in-time level (``set``): page-pool occupancy,
  queue depth.
- `Histogram` — bucketed distribution over fixed edges.  Bucket ``i``
  counts observations in ``(edges[i-1], edges[i]]`` (values exactly on
  an edge land in the bucket the edge closes — Prometheus ``le``
  semantics); one overflow bucket catches everything past the last
  edge.  Percentiles are estimated as the upper bound of the bucket
  holding the rank, clamped to the observed min/max.

`MetricsRegistry` is the namespace: dotted canonical names
(``engine.ttft_s``, ``sched.submitted``, ``kv.pages_in_use``, ...),
``snapshot()`` for machine-readable export, ``render()`` for the
human-readable on-exit dump.  `MetricView` is a live dict-shaped view
over one name prefix — the compat surface that lets pre-registry call
sites (``stats["cow_copies"] += 1``) and their tests keep working while
the values actually live in the registry.

``diff_snapshots`` subtracts one snapshot from another (counters and
histogram count/sum pairwise) so benchmarks can report workload-only
deltas without hand-rolled per-key lists.
"""
from __future__ import annotations

import json
import math
from bisect import bisect_left
from collections.abc import MutableMapping
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

Number = Union[int, float]

# default histogram edges: latencies in seconds, ~log-spaced 10us..60s
DEFAULT_TIME_EDGES: Tuple[float, ...] = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4,
    1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Number = 0

    def inc(self, n: Number = 1) -> None:
        self.value += n

    def set(self, v: Number) -> None:
        self.value = v


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Number = 0

    def set(self, v: Number) -> None:
        self.value = v


class Histogram:
    __slots__ = ("name", "edges", "counts", "count", "total",
                 "vmin", "vmax")

    def __init__(self, name: str,
                 edges: Sequence[float] = DEFAULT_TIME_EDGES):
        edges = tuple(float(e) for e in edges)
        if not edges or list(edges) != sorted(set(edges)):
            raise ValueError(f"edges must be strictly ascending: {edges}")
        self.name = name
        self.edges = edges
        self.counts = [0] * (len(edges) + 1)    # last = overflow (+Inf)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.edges, v)] += 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def percentile(self, q: float) -> float:
        """Estimated q-th percentile (q in [0, 100]): the upper edge of
        the bucket containing the rank, clamped to observed min/max."""
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(q / 100.0 * self.count))
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= rank:
                hi = self.edges[i] if i < len(self.edges) else self.vmax
                return float(min(max(hi, self.vmin), self.vmax))
        return float(self.vmax)                  # pragma: no cover

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> Dict[str, object]:
        out: Dict[str, object] = {
            "count": self.count,
            "sum": round(self.total, 9),
            "mean": round(self.mean, 9),
        }
        if self.count:
            out.update(min=round(self.vmin, 9), max=round(self.vmax, 9),
                       p50=round(self.percentile(50), 9),
                       p99=round(self.percentile(99), 9))
        # cumulative le-counts, only edges that separate observations
        # (keeps exported JSON small while staying reconstructible)
        cum, acc = [], 0
        for i, c in enumerate(self.counts):
            acc += c
            le = self.edges[i] if i < len(self.edges) else "+Inf"
            if c:
                cum.append([le, acc])
        out["buckets"] = cum
        return out


class MetricView(MutableMapping):
    """Live dict-shaped view over a registry's counters under one
    prefix.  Reads return the counter's current value; writes set it —
    so legacy ``stats["x"] += 1`` call sites publish straight into the
    registry.  Unknown keys are registered on first touch."""

    def __init__(self, registry: "MetricsRegistry", prefix: str,
                 keys: Sequence[str] = ()):
        self._r = registry
        self._p = prefix
        self._keys: List[str] = []
        for k in keys:
            self._touch(k)

    def _full(self, k: str) -> str:
        return f"{self._p}.{k}" if self._p else k

    def _touch(self, k: str) -> Counter:
        c = self._r.counter(self._full(k))
        if k not in self._keys:
            self._keys.append(k)
        return c

    def __getitem__(self, k: str) -> Number:
        return self._touch(k).value

    def __setitem__(self, k: str, v: Number) -> None:
        self._touch(k).set(v)

    def __delitem__(self, k: str) -> None:
        raise TypeError("metrics cannot be deleted")

    def __iter__(self) -> Iterator[str]:
        return iter(list(self._keys))

    def __len__(self) -> int:
        return len(self._keys)

    def __repr__(self) -> str:
        return f"MetricView({dict(self)!r})"


class MetricsRegistry:
    """Get-or-create namespace of metrics, keyed by dotted name."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, *args)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  edges: Optional[Sequence[float]] = None) -> Histogram:
        if name in self._metrics:
            return self._get(name, Histogram)
        return self._get(name, Histogram, edges or DEFAULT_TIME_EDGES)

    def group(self, prefix: str, keys: Sequence[str] = ()) -> MetricView:
        return MetricView(self, prefix, keys)

    def scoped(self, prefix: str) -> "ScopedRegistry":
        """A registry view that prepends ``<prefix>.`` to every metric
        name — the per-model label mechanism for the multi-model engine
        (each hosted model's ``engine.*`` / ``kv.*`` metrics publish as
        ``model.<name>.engine.*`` in the ONE shared parent registry)."""
        return ScopedRegistry(self, prefix)

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """Flat {canonical name: value-or-histogram-summary} dict."""
        out: Dict[str, object] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            out[name] = m.snapshot() if isinstance(m, Histogram) \
                else m.value
        return out

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"metrics": self.snapshot()}, f, indent=1)

    def render(self) -> str:
        """Human-readable snapshot (the on-exit dump)."""
        lines = []
        width = max((len(n) for n in self._metrics), default=0)
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Histogram):
                if m.count:
                    body = (f"count={m.count} mean={m.mean:.6f} "
                            f"p50={m.percentile(50):.6f} "
                            f"p99={m.percentile(99):.6f} "
                            f"max={m.vmax:.6f}")
                else:
                    body = "count=0"
                kind = "hist"
            else:
                kind = "gauge" if isinstance(m, Gauge) else "counter"
                v = m.value
                body = f"{v:.6f}".rstrip("0").rstrip(".") \
                    if isinstance(v, float) else str(v)
            lines.append(f"{kind:7s} {name:<{width}}  {body}")
        return "\n".join(lines)


class ScopedRegistry:
    """A prefix-scoped view over a parent `MetricsRegistry`.

    Every metric created through it lives in the PARENT under
    ``<prefix>.<name>`` — one flat namespace holds every hosted
    model's metrics side by side (``model.a.engine.tokens`` next to
    ``model.b.engine.tokens``), so one ``snapshot()``/``export()`` on
    the parent captures the whole multi-model engine.  The view's own
    ``snapshot()``/``render()`` are filtered to the scope, keeping
    per-engine readers (``Engine.stats()``, bench workload deltas)
    working unchanged on a scoped engine."""

    def __init__(self, parent: "MetricsRegistry", prefix: str):
        if not prefix or prefix.endswith("."):
            raise ValueError(f"bad scope prefix: {prefix!r}")
        # collapse nested scopes so there is exactly one parent level
        if isinstance(parent, ScopedRegistry):
            prefix = f"{parent.prefix}.{prefix}"
            parent = parent.parent
        self.parent = parent
        self.prefix = prefix

    def _full(self, name: str) -> str:
        return f"{self.prefix}.{name}"

    def counter(self, name: str) -> Counter:
        return self.parent.counter(self._full(name))

    def gauge(self, name: str) -> Gauge:
        return self.parent.gauge(self._full(name))

    def histogram(self, name: str,
                  edges: Optional[Sequence[float]] = None) -> Histogram:
        return self.parent.histogram(self._full(name), edges)

    def group(self, prefix: str, keys: Sequence[str] = ()) -> MetricView:
        return self.parent.group(self._full(prefix), keys)

    def scoped(self, prefix: str) -> "ScopedRegistry":
        return ScopedRegistry(self, prefix)

    def snapshot(self) -> Dict[str, object]:
        """The parent snapshot filtered to this scope (full names kept,
        so scoped and parent snapshots diff against each other)."""
        pre = self.prefix + "."
        return {k: v for k, v in self.parent.snapshot().items()
                if k.startswith(pre)}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"metrics": self.snapshot()}, f, indent=1)

    def render(self) -> str:
        pre = self.prefix + "."
        lines = self.parent.render().splitlines()
        return "\n".join(ln for ln in lines if pre in ln)


def diff_snapshots(new: Dict[str, object],
                   base: Dict[str, object]) -> Dict[str, object]:
    """Workload-only delta of two ``MetricsRegistry.snapshot()`` dicts:
    numbers subtract, histogram summaries subtract count/sum (mean is
    recomputed; order statistics are not diffable and are dropped).
    Names absent from ``base`` pass through unchanged."""
    out: Dict[str, object] = {}
    for name, v in new.items():
        b = base.get(name)
        if isinstance(v, dict):                  # histogram summary
            bc = b if isinstance(b, dict) else {}
            dc = v["count"] - bc.get("count", 0)
            ds = round(v["sum"] - bc.get("sum", 0.0), 9)
            out[name] = {"count": dc, "sum": ds,
                         "mean": round(ds / dc, 9) if dc else 0.0}
        elif isinstance(v, (int, float)) and isinstance(b, (int, float)):
            out[name] = round(v - b, 9) if isinstance(v, float) else v - b
        else:
            out[name] = v
    return out
