"""HashedNets parameterization (Chen et al., ICML 2015) + TPU-native block mode.

A *virtual* 2-D weight matrix ``V`` of shape ``(rows, cols)`` is represented
by a small *real* parameter bank:

- ``element`` mode (paper-faithful, Eq. 3/7):
      V[i, j] = xi(i, j) * w[h(i, j)]
  with ``w`` of size ``K ~= compression * rows * cols``.  For TPU locality the
  bucket space is optionally stratified into column *panels*: each panel of
  ``panel_cols`` columns owns ``K / n_panels`` buckets and the hash randomizes
  freely within the panel.  ``panel_cols=0`` gives the paper's single global
  bucket space.

- ``block`` mode (TPU-native adaptation, see DESIGN.md §2):
      tile(ti, tj) = sigma(ti, tj) * bank[h(ti, tj)]
  where tiles are MXU-aligned ``(block_rows, block_cols)`` slabs and ``bank``
  holds ``K_t ~= compression * n_tiles`` real tiles.  Decompression is a dense
  tile gather.

Three numerically-identical execution paths (all differentiable; gradients
realize paper Eq. 12 as the autodiff transpose of the gather):

- :func:`materialize`           — build V explicitly (small layers, oracle)
- :func:`matmul` path="scan"    — lax.scan over column panels; peak live
                                  intermediate is a single panel (used by the
                                  multi-pod dry-run so compiled memory reflects
                                  the compressed footprint)
- path="pallas"                 — fused decompress-GEMM kernel
                                  (repro.kernels.hashed_matmul)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing


@dataclasses.dataclass(frozen=True)
class HashedSpec:
    """Static description of one hashed virtual matrix."""

    virtual_shape: Tuple[int, int]  # (rows, cols); used as x @ V
    compression: float              # c = real params / virtual params
    mode: str = "element"           # "element" | "block"
    seed: int = 0
    panel_cols: int = 0             # element mode: 0 => global bucket space
    block_shape: Tuple[int, int] = (128, 128)
    use_sign: bool = True
    # Execution hint, NOT part of the matrix's identity: which matmul path
    # the policy picked for this slot ("" = caller's default).  Excluded
    # from equality/serialization so policy-resolved specs stay
    # byte-identical to pre-policy ones (see repro.policy).
    exec_path: str = dataclasses.field(default="", compare=False)

    # ---- derived sizes -------------------------------------------------
    @property
    def rows(self) -> int:
        return self.virtual_shape[0]

    @property
    def cols(self) -> int:
        return self.virtual_shape[1]

    @property
    def virtual_size(self) -> int:
        return self.rows * self.cols

    @property
    def n_panels(self) -> int:
        if self.mode != "element":
            raise ValueError("n_panels is element-mode only")
        if self.panel_cols <= 0:
            return 1
        return max(1, math.ceil(self.cols / self.panel_cols))

    @property
    def buckets_per_panel(self) -> int:
        k_total = max(self.n_panels, int(round(self.compression * self.virtual_size)))
        return max(1, k_total // self.n_panels)

    @property
    def num_buckets(self) -> int:
        """Real parameter count, element mode."""
        return self.buckets_per_panel * self.n_panels

    @property
    def tile_grid(self) -> Tuple[int, int]:
        bm, bn = self.block_shape
        return (math.ceil(self.rows / bm), math.ceil(self.cols / bn))

    @property
    def num_tiles(self) -> int:
        gi, gj = self.tile_grid
        return gi * gj

    @property
    def bank_tiles(self) -> int:
        return max(1, int(round(self.compression * self.num_tiles)))

    def real_param_shape(self) -> Tuple[int, ...]:
        if self.mode == "element":
            return (self.num_buckets,)
        bm, bn = self.block_shape
        return (self.bank_tiles, bm, bn)

    def real_param_count(self) -> int:
        return int(np.prod(self.real_param_shape()))

    def validate(self) -> None:
        if self.mode not in ("element", "block"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if not (0.0 < self.compression <= 1.0):
            raise ValueError("compression must be in (0, 1]")
        if self.mode == "block":
            bm, bn = self.block_shape
            if bm <= 0 or bn <= 0:
                raise ValueError(f"bad block_shape {self.block_shape}")
            # Non-divisible virtual shapes are allowed: the tile grid is
            # ceil-sized and every consumer slices back to (rows, cols).
            # Only the fused Pallas kernel requires exact divisibility
            # (checked in repro.kernels.ops at dispatch).

    # ---- serialization (artifact header / registry metadata) -----------
    def to_dict(self) -> dict:
        """JSON-safe description; exact inverse of :func:`spec_from_dict`.

        Everything needed to regenerate the virtual matrix from the bank
        alone — this is what the paper's storage claim rests on: the hash
        is stateless, so an artifact stores only these few scalars + the
        real parameters."""
        return {
            "virtual_shape": [int(x) for x in self.virtual_shape],
            "compression": float(self.compression),
            "mode": self.mode,
            "seed": int(self.seed),
            "panel_cols": int(self.panel_cols),
            "block_shape": [int(x) for x in self.block_shape],
            "use_sign": bool(self.use_sign),
        }


def spec_to_dict(spec: HashedSpec) -> dict:
    return spec.to_dict()


def spec_from_dict(d: dict) -> HashedSpec:
    spec = HashedSpec(
        virtual_shape=tuple(int(x) for x in d["virtual_shape"]),
        compression=float(d["compression"]),
        mode=str(d["mode"]),
        seed=int(d["seed"]),
        panel_cols=int(d.get("panel_cols", 0)),
        block_shape=tuple(int(x) for x in d.get("block_shape", (128, 128))),
        use_sign=bool(d.get("use_sign", True)),
    )
    spec.validate()
    return spec


# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------

def init(key, spec: HashedSpec, scale: Optional[float] = None, dtype=jnp.float32):
    """Initialize the real bank so that the *virtual* matrix has fan-in
    scaled variance.  Because xi decorrelates colliding entries, initializing
    ``w ~ N(0, scale^2)`` gives ``Var(V_ij) = scale^2`` — identical to a dense
    init of V (paper trains with standard init on w)."""
    spec.validate()
    if scale is None:
        scale = 1.0 / math.sqrt(spec.rows)
    return (jax.random.normal(key, spec.real_param_shape(), jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# index computation (shared by all paths + the Pallas kernel)
# ---------------------------------------------------------------------------

def element_indices(spec: HashedSpec, i, j):
    """Bucket index + sign for absolute virtual coordinates (i, j).

    Panel-local stratification: bucket = panel * Kp + h(i,j) % Kp.
    """
    kp = spec.buckets_per_panel
    if spec.panel_cols > 0:
        panel = jnp.asarray(j, jnp.int32) // spec.panel_cols
    else:
        panel = jnp.zeros_like(jnp.asarray(j, jnp.int32))
    h = hashing.bucket_hash(i, j, kp, spec.seed)
    idx = panel * kp + h
    if spec.use_sign:
        sgn = hashing.sign_hash(i, j, spec.seed)
    else:
        sgn = jnp.ones_like(idx)
    return idx, sgn


def block_indices(spec: HashedSpec):
    """Tile->bank index map + per-tile sign for the whole grid (tiny arrays,
    recomputable from the hash at any time — no stored index structure)."""
    gi, gj = spec.tile_grid
    ti = jnp.arange(gi, dtype=jnp.int32)[:, None]
    tj = jnp.arange(gj, dtype=jnp.int32)[None, :]
    idx = hashing.bucket_hash(ti, tj, spec.bank_tiles, spec.seed)
    if spec.use_sign:
        sgn = hashing.sign_hash(ti, tj, spec.seed)
    else:
        sgn = jnp.ones_like(idx)
    return idx, sgn


# ---------------------------------------------------------------------------
# materialization (oracle / small layers)
# ---------------------------------------------------------------------------

def materialize(w, spec: HashedSpec, dtype=None):
    """Build the full virtual matrix V (rows, cols)."""
    spec.validate()
    dtype = dtype or w.dtype
    if spec.mode == "element":
        i = jnp.arange(spec.rows, dtype=jnp.int32)[:, None]
        j = jnp.arange(spec.cols, dtype=jnp.int32)[None, :]
        idx, sgn = element_indices(spec, i, j)
        v = w[idx] * sgn.astype(w.dtype)
        return v.astype(dtype)
    idx, sgn = block_indices(spec)
    gi, gj = spec.tile_grid
    bm, bn = spec.block_shape
    tiles = w[idx] * sgn[..., None, None].astype(w.dtype)  # (gi, gj, bm, bn)
    v = tiles.transpose(0, 2, 1, 3).reshape(gi * bm, gj * bn)
    return v[: spec.rows, : spec.cols].astype(dtype)


def materialize_rows(w, spec: HashedSpec, row_ids, dtype=None):
    """Gather virtual rows V[row_ids, :] without building all of V.

    Used by hashed embedding lookup: row_ids (...,) -> (..., cols).
    """
    spec.validate()
    dtype = dtype or w.dtype
    if spec.mode == "element":
        i = jnp.asarray(row_ids, jnp.int32)[..., None]
        j = jnp.arange(spec.cols, dtype=jnp.int32)
        j = j.reshape((1,) * (i.ndim - 1) + (spec.cols,))
        idx, sgn = element_indices(spec, i, j)
        return (w[idx] * sgn.astype(w.dtype)).astype(dtype)
    # block mode: gather the tile-row each id lives in, then slice.
    bm, bn = spec.block_shape
    gi, gj = spec.tile_grid
    idx, sgn = block_indices(spec)  # (gi, gj)
    rid = jnp.asarray(row_ids, jnp.int32)
    trow = rid // bm
    roff = rid % bm
    row_tiles = w[idx[trow]]                       # (..., gj, bm, bn)
    row_tiles = row_tiles * sgn[trow][..., None, None].astype(w.dtype)
    sliced = jnp.take_along_axis(
        row_tiles, roff[..., None, None, None].astype(jnp.int32), axis=-2
    )                                               # (..., gj, 1, bn)
    out = sliced.squeeze(-2).reshape(rid.shape + (gj * bn,))
    return out[..., : spec.cols].astype(dtype)


# ---------------------------------------------------------------------------
# matmul paths
# ---------------------------------------------------------------------------

def _panel_matmul_element(x, w, spec: HashedSpec, j0, panel_cols, dtype):
    """y_panel = x @ V[:, j0:j0+panel_cols] for element mode."""
    i = jnp.arange(spec.rows, dtype=jnp.int32)[:, None]
    j = j0 + jnp.arange(panel_cols, dtype=jnp.int32)[None, :]
    idx, sgn = element_indices(spec, i, j)
    v = (w[idx] * sgn.astype(w.dtype)).astype(dtype)
    return jax.lax.dot_general(
        x, v, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(dtype)


def matmul_scan(x, w, spec: HashedSpec, panel_cols: int = 0, dtype=None,
                vspec=None):
    """x @ V with bounded peak memory: lax.scan over column panels.

    The per-panel body is rematerialized (jax.checkpoint) so the backward
    pass re-derives each panel from ``w`` instead of storing all panels —
    peak live memory stays ~one panel in fwd and bwd.
    """
    spec.validate()
    dtype = dtype or x.dtype

    def _constrain_panel(v):
        if vspec is None:
            return v
        from repro.distributed import sharding as shd
        return shd.constraint(v, vspec)
    cols = spec.cols
    if panel_cols <= 0:
        panel_cols = spec.panel_cols if spec.panel_cols > 0 else min(cols, 1024)
    if spec.mode == "element" and spec.panel_cols > 0:
        # align scan panels with bucket panels (any multiple works)
        if panel_cols % spec.panel_cols and spec.panel_cols % panel_cols:
            panel_cols = spec.panel_cols
    n_panels = math.ceil(cols / panel_cols)
    pad = n_panels * panel_cols - cols

    lead_shape = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])

    if spec.mode == "element":
        def body(carry, j0):
            def panel(w_, x_):
                i = jnp.arange(spec.rows, dtype=jnp.int32)[:, None]
                j = j0 + jnp.arange(panel_cols, dtype=jnp.int32)[None, :]
                idx, sgn = element_indices(spec, i, j)
                v = _constrain_panel(
                    (w_[idx] * sgn.astype(w_.dtype)).astype(dtype))
                return jax.lax.dot_general(
                    x_, v, (((x_.ndim - 1,), (0,)), ((), ())))

            y = jax.checkpoint(panel)(w, x2)
            return carry, y

        j0s = jnp.arange(n_panels, dtype=jnp.int32) * panel_cols
        _, ys = jax.lax.scan(body, None, j0s)          # (n_panels, B, panel)
        y = jnp.moveaxis(ys, 0, 1).reshape(x2.shape[0], n_panels * panel_cols)
    else:
        bm, bn = spec.block_shape
        gi, gj = spec.tile_grid
        idx, sgn = block_indices(spec)                  # (gi, gj)
        rpad = gi * bm - spec.rows
        if rpad:
            # ragged tile grid: zero-pad the contraction dim (zero rows of
            # x contribute nothing against the padded virtual rows)
            x2 = jnp.pad(x2, ((0, 0), (0, rpad)))
        xt = x2.reshape(x2.shape[0], gi, bm)

        def body(carry, args):
            idx_col, sgn_col = args                     # (gi,)

            def panel(w_, xt_):
                tiles = (w_[idx_col]
                         * sgn_col[:, None, None].astype(w_.dtype))  # (gi,bm,bn)
                vpanel = _constrain_panel(
                    tiles.reshape(gi * bm, bn).astype(dtype))
                return jax.lax.dot_general(
                    xt_.reshape(xt_.shape[0], gi * bm), vpanel,
                    (((1,), (0,)), ((), ())))

            return carry, jax.checkpoint(panel)(w, xt)

        _, ys = jax.lax.scan(body, None, (idx.T, sgn.T))  # (gj, B, bn)
        y = jnp.moveaxis(ys, 0, 1).reshape(x2.shape[0], gj * bn)
        pad = gj * bn - cols

    if pad:
        y = y[:, :cols]
    return y.reshape(lead_shape + (cols,))


def matmul(x, w, spec: HashedSpec, path: str = "auto", dtype=None,
           panel_cols: int = 0, vspec=None):
    """Dispatch x @ V over execution paths.

    path: "materialize" | "scan" | "pallas" | "auto".
    "auto": materialize for small virtual matrices, scan otherwise.
    (The pallas path is dispatched in repro.kernels.ops to avoid a
    circular import; model code calls repro.nn.linear which routes.)

    vspec: logical PartitionSpec for the DECOMPRESSED virtual matrix
    (same spec a dense weight of that shape would carry).  Without it the
    materialized V is unannotated and GSPMD replicates the whole matmul
    on every model shard — measured 16x the flops of the dense baseline
    at llama3-405b scale (EXPERIMENTS.md §Perf).
    """
    spec.validate()
    dtype = dtype or x.dtype
    if path == "auto":
        path = "materialize" if spec.virtual_size <= (4096 * 4096) else "scan"
    if path == "materialize":
        v = materialize(w, spec, dtype=dtype)
        if vspec is not None:
            from repro.distributed import sharding as shd
            v = shd.constraint(v, vspec)
        return jax.lax.dot_general(
            x, v, (((x.ndim - 1,), (0,)), ((), ())))
    if path == "scan":
        return matmul_scan(x, w, spec, panel_cols=panel_cols, dtype=dtype,
                           vspec=vspec)
    if path == "pallas":
        from repro.kernels import ops as kernel_ops
        return kernel_ops.hashed_matmul(x, w, spec, dtype=dtype)
    raise ValueError(f"unknown path {path!r}")
