"""Stateless integer hashing for HashedNets (Chen et al., ICML 2015).

The paper uses xxHash to map a connection key (i, j) to a bucket in
{0..K-1} plus an independent sign hash xi(i,j) in {-1,+1}.  xxHash is not
available offline; the paper only requires an *approximately uniform* hash,
so we use the murmur3 finalizer (a well-studied avalanche mixer) over a
uint32 key derived from (i, j, seed).  Everything here is pure jnp and runs
identically inside Pallas kernel bodies (uint32 arithmetic only).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

# murmur3 / splitmix constants
_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)


def mix32(x):
    """murmur3 finalizer: avalanche a uint32."""
    x = jnp.asarray(x, jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 13)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def hash_key(i, j, seed: int):
    """Combine (i, j, seed) -> well-mixed uint32.

    i, j may be scalars or broadcastable integer arrays (e.g. iota tiles
    inside a kernel).  Two mixing rounds decorrelate rows/columns.
    """
    i = jnp.asarray(i, jnp.uint32)
    j = jnp.asarray(j, jnp.uint32)
    s = np.uint32(seed & 0xFFFFFFFF)
    h = mix32(i * _GOLDEN + s)
    h = mix32(h ^ (j * _M1 + np.uint32(0x165667B1)))
    return h


def bucket_hash(i, j, num_buckets: int, seed: int):
    """h(i,j) in {0..num_buckets-1} (paper Eq. 3)."""
    return (hash_key(i, j, seed) % np.uint32(num_buckets)).astype(jnp.int32)


def sign_hash(i, j, seed: int):
    """xi(i,j) in {-1,+1} (paper Eq. 7), independent of bucket_hash.

    Uses a different derived seed so h and xi are decorrelated.
    """
    h = hash_key(i, j, seed ^ 0x5BF03635)
    # top bit -> {-1, +1}
    return (1 - 2 * (h >> 31).astype(jnp.int32)).astype(jnp.int32)


def bucket_and_sign(i, j, num_buckets: int, seed: int):
    return bucket_hash(i, j, num_buckets, seed), sign_hash(i, j, seed)


def _mix32_py(x: int) -> int:
    """Pure-Python murmur3 finalizer — safe to call inside jit traces
    (static seeds must never touch jnp, or they become tracers)."""
    x &= 0xFFFFFFFF
    x ^= x >> 16
    x = (x * 0x85EBCA6B) & 0xFFFFFFFF
    x ^= x >> 13
    x = (x * 0xC2B2AE35) & 0xFFFFFFFF
    x ^= x >> 16
    return x


def derive_seed(base_seed: int, *path: int) -> int:
    """Derive a per-layer / per-matrix seed from a base seed and a path of
    integers (layer index, matrix slot, ...), mirroring the paper's use of
    dedicated hash functions h^l per layer.  Pure Python on purpose."""
    h = base_seed & 0xFFFFFFFF
    for p in path:
        key = (h ^ ((p & 0xFFFFFFFF) * int(_GOLDEN))) & 0xFFFFFFFF
        h = _mix32_py(key)
    return h
