"""HashedNets core: stateless hashed weight sharing (Chen et al., ICML 2015)."""
from repro.core.hashed import (HashedSpec, init, materialize,
                               materialize_rows, matmul, spec_from_dict,
                               spec_to_dict)
from repro.core import hashing, feature_hash

__all__ = [
    "HashedSpec",
    "init",
    "materialize",
    "materialize_rows",
    "matmul",
    "spec_to_dict",
    "spec_from_dict",
    "hashing",
    "feature_hash",
]
