"""Feature-hashing view of HashedNets (paper §4.3, Eq. 5/6).

For output unit i:   z_i = w^T phi_i(a),  with
    [phi_i(a)]_k = sum_{j : h(i,j) = k} xi(i,j) a_j.

This module exists to *prove* (in tests) the paper's equivalence between the
weight-sharing view (Eq. 4) and the feature-hashing view (Eq. 5), and the
unbiased inner-product property inherited from Weinberger et al. (2009).
It is an oracle, not a production path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hashed import HashedSpec, element_indices


def phi(a, spec: HashedSpec, i: int):
    """Hash the activation vector ``a`` (rows,) into bucket space for output
    unit ``i``: returns (num_buckets,)."""
    assert spec.mode == "element"
    rows = spec.rows
    ii = jnp.full((rows,), i, dtype=jnp.int32)
    jj = jnp.full((rows,), i, dtype=jnp.int32)  # placeholder, replaced below
    del jj
    j = jnp.arange(rows, dtype=jnp.int32)
    # NOTE: in the paper's layer convention z_i = sum_j V_ij a_j with
    # V in R^{n_out x n_in}.  Our storage convention is x @ V with
    # V in R^{rows=n_in, cols=n_out}; so output unit i indexes *columns* and
    # the activation index j runs over *rows*:  V[j, i] pairs (j, i).
    idx, sgn = element_indices(spec, j, ii)
    contrib = a * sgn.astype(a.dtype)
    return jax.ops.segment_sum(contrib, idx, num_segments=spec.num_buckets)


def forward_feature_hash(a, w, spec: HashedSpec):
    """z = [w^T phi_i(a)]_i for all output units — Eq. (5) evaluated naively.

    O(n_out * n_in); test-only oracle.
    """
    assert spec.mode == "element"

    def one(i):
        return jnp.dot(w, phi(a, spec, i))

    return jax.vmap(one)(jnp.arange(spec.cols, dtype=jnp.int32))


def matmul_via_feature_hashing(x, w, spec: HashedSpec):
    """Batched Eq. 5: x (B, rows) -> z (B, cols) via the feature-hash view."""
    return jax.vmap(lambda a: forward_feature_hash(a, w, spec))(x)


def index_map(d: int, k: int, seed: int):
    """1-D hashing-trick map for a d-dim vector into k buckets:
    returns (idx (d,), sgn (d,)) — used by the Eq. 1 unbiasedness test and
    the gradient sketch."""
    from repro.core import hashing
    i = jnp.arange(d, dtype=jnp.int32)
    z = jnp.zeros_like(i)
    return (hashing.bucket_hash(i, z, k, seed),
            hashing.sign_hash(i, z, seed).astype(jnp.float32))
