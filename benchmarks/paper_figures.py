"""Paper Figures 2-4.

Fig 2/3: test error vs compression factor {1, 1/2, 1/4, ... 1/64} on
MNIST + ROT analogues, 3-layer (Fig 2) and 5-layer (Fig 3) nets.
Fig 4: fixed storage, inflated virtual width — expansion factors
{1, 2, 4, 8, 16} with K^l frozen at the 50-hidden-unit dense budget;
the paper's claim: HashNet keeps improving to 8-16x while RER/LRD
saturate or degrade.

ASCII plots + JSON rows (no matplotlib offline).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import numpy as np

from repro.data import mnist_synthetic as D
from repro.paper import mlp, train as T

SWEEP_METHODS = ("hashed", "nn", "rer", "lrd")


def run_compression_sweep(*, datasets=("mnist", "rot"), depths=(3, 5),
                          hidden=500, n_train=2500, n_test=2000,
                          epochs=12, seed=0,
                          compressions=(1.0, 0.5, 0.25, 0.125, 1 / 16,
                                        1 / 32, 1 / 64)) -> List[Dict]:
    cfg = T.TrainConfig(epochs=epochs)
    rows = []
    for ds in datasets:
        x, y = D.load(ds, "train", n=n_train, seed=seed)
        xt, yt = D.load(ds, "test", n=n_test, seed=seed + 1)
        for depth in depths:
            dims = (784,) + (hidden,) * (depth - 2) + (D.num_classes(ds),)
            for c in compressions:
                for m in SWEEP_METHODS:
                    if c == 1.0 and m != "nn":
                        continue       # compression 1: all coincide w/ NN
                    r = T.run_method(m, dims, c, x, y, xt, yt, cfg,
                                     seed=seed)
                    r.update({"dataset": ds, "depth": depth})
                    rows.append(r)
                    print(f"  {ds} {depth}L c=1/{round(1/c):<3d} {m:7s} "
                          f"err {r['test_err']*100:6.2f}%", flush=True)
    return rows


def run_expansion_sweep(*, dataset="rot", depths=(3, 5), base_hidden=50,
                        n_train=2500, n_test=2000, epochs=12, seed=0,
                        factors=(1, 2, 4, 8, 16)) -> List[Dict]:
    cfg = T.TrainConfig(epochs=epochs)
    x, y = D.load(dataset, "train", n=n_train, seed=seed)
    xt, yt = D.load(dataset, "test", n=n_test, seed=seed + 1)
    rows = []
    for depth in depths:
        base_dims = (784,) + (base_hidden,) * (depth - 2) + (10,)
        base_spec = mlp.MLPSpec(base_dims, method="dense", dropout=0.3,
                                input_dropout=0.1, seed=seed)
        bparams, _ = T.fit(base_spec, x, y, cfg=cfg, seed=seed)
        base_err = T.evaluate(base_spec, bparams, xt, yt)
        rows.append({"method": "dense-base", "factor": 1, "depth": depth,
                     "dataset": dataset, "test_err": base_err,
                     "free_params": base_spec.free_params()})
        print(f"  {depth}L dense-50u baseline err {base_err*100:.2f}%",
              flush=True)
        # budget per layer l of the BASE dense net
        for f in factors:
            hidden = base_hidden * f
            dims = (784,) + (hidden,) * (depth - 2) + (10,)
            for m in ("hashed", "rer", "lrd"):
                # per-layer budget = base dense layer size
                spec_kw = dict(dropout=0.3, input_dropout=0.1, seed=seed)
                # compression chosen so layer budget matches the base net:
                # K^l = base_in*base_out  => c = K^l / (in*out)
                # use layer-0 ratio (uniform here by construction)
                c = ((base_dims[0] * base_dims[1])
                     / (dims[0] * dims[1]))
                spec = mlp.MLPSpec(dims, method=m, compression=c, **spec_kw)
                params, _ = T.fit(spec, x, y, cfg=cfg, seed=seed)
                err = T.evaluate(spec, params, xt, yt)
                rows.append({"method": m, "factor": f, "depth": depth,
                             "dataset": dataset, "test_err": err,
                             "free_params": spec.free_params()})
                print(f"  {depth}L x{f:<2d} {m:7s} err {err*100:6.2f}% "
                      f"({spec.free_params():,} params)", flush=True)
    return rows


def ascii_plot(rows: List[Dict], xkey: str, series_key: str = "method",
               width: int = 56, invert_x: bool = False) -> str:
    xs = sorted({r[xkey] for r in rows}, reverse=invert_x)
    out = []
    for m in sorted({r[series_key] for r in rows}):
        pts = {r[xkey]: r["test_err"] for r in rows if r[series_key] == m}
        line = f"{m:11s}|"
        errs = [pts.get(xx) for xx in xs]
        for e in errs:
            line += "  ----" if e is None else f" {e*100:5.1f}"
        out.append(line)
    hdr = f"{'':11s}|" + "".join(
        f" {('1/'+str(round(1/xx)) if xkey=='compression' else 'x'+str(xx)):>5s}"
        for xx in xs)
    return hdr + "\n" + "\n".join(out)


def assert_figure_claims(sweep: List[Dict], expand: List[Dict]) -> List[str]:
    msgs = []
    # F1: at the smallest compression, HashNet has the lowest error
    cmin = min(r["compression"] for r in sweep)
    small = [r for r in sweep if r["compression"] == cmin]

    def mean_err(rows, m):
        v = [r["test_err"] for r in rows if r["method"] == m]
        return float(np.mean(v)) if v else float("nan")

    h = mean_err(small, "hashed")
    others = {m: mean_err(small, m) for m in ("nn", "rer", "lrd")}
    ok = all(h < v for v in others.values())
    msgs.append(f"F1 {'PASS' if ok else 'FAIL'}: at c=1/{round(1/cmin)} "
                f"HashNet {h*100:.1f}% vs " +
                " ".join(f"{m}:{v*100:.1f}%" for m, v in others.items()))
    # F2: expansion helps HashNet (some factor > 1 beats factor 1)
    he = {r["factor"]: r["test_err"] for r in expand
          if r["method"] == "hashed" and r["depth"] == 3}
    best_f = min(he, key=he.get)
    ok2 = best_f > 1
    msgs.append(f"F2 {'PASS' if ok2 else 'FAIL'}: HashNet expansion sweet "
                f"spot x{best_f} (errs: " +
                " ".join(f"x{f}:{e*100:.1f}%" for f, e in sorted(he.items()))
                + ")")
    return msgs


def main(quick=False, out_json=None):
    kw_s, kw_e = {}, {}
    if quick:
        kw_s = dict(datasets=("mnist",), depths=(3,), hidden=200,
                    n_train=1500, n_test=1000, epochs=8,
                    compressions=(1.0, 0.25, 1 / 16, 1 / 64))
        kw_e = dict(depths=(3,), n_train=1500, n_test=1000, epochs=8,
                    factors=(1, 4, 8))
    print("== Figures 2/3 (error vs compression) ==", flush=True)
    sweep = run_compression_sweep(**kw_s)
    for ds in sorted({r["dataset"] for r in sweep}):
        for depth in sorted({r["depth"] for r in sweep}):
            sel = [r for r in sweep if r["dataset"] == ds
                   and r["depth"] == depth]
            if sel:
                print(f"\n[{ds} {depth}-layer] err% vs compression:")
                print(ascii_plot(sel, "compression", invert_x=True))
    print("\n== Figure 4 (fixed storage, inflated width) ==", flush=True)
    expand = run_expansion_sweep(**kw_e)
    for depth in sorted({r["depth"] for r in expand}):
        sel = [r for r in expand if r["depth"] == depth
               and r["method"] != "dense-base"]
        print(f"\n[{depth}-layer] err% vs expansion factor:")
        print(ascii_plot(sel, "factor"))
    print()
    msgs = assert_figure_claims(sweep, expand)
    for m in msgs:
        print(m)
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"sweep": sweep, "expansion": expand,
                       "claims": msgs}, f, indent=1)
    return sweep, expand, msgs


if __name__ == "__main__":
    main()
