"""Serving benchmark: continuous batching under increasing offered load.

For one dense and one hashed config (reduced qwen), drives the paged
continuous-batching engine at several concurrency levels and records

- tokens/s (decode throughput over the whole run),
- p50/p99 request latency (submit -> finish, includes queueing),
- p50 time-to-first-token, preemptions, pages in flight,

then writes ``BENCH_serving.json`` so the serving perf trajectory is
tracked in CI next to the policy and artifact benches.  Requests arrive
open-loop on a deterministic schedule (offered load ~ 2x what one row
sustains, so queueing pressure grows with the request count, and p99
spreads from p50 as concurrency saturates).

A second section benches the **shared-prefix workload** (N users x one
common system prompt — the millions-of-users common case): the same
request set runs with ``prefix_cache`` on vs off (both with chunked
prefill) and reports prefix-hit rate, pages saved, mean/p50 TTFT, and
tok/s, plus a token-identity cross-check between the two arms.

A third section benches the **mixed-sampling workload**: greedy,
seeded top-p, and stop-sequence rows share one decode batch (the fused
sampler's one-dispatch-per-tick contract), reporting tok/s, per-tick
sampler overhead, and the finish-reason split — plus a determinism
cross-check (a rerun with the same seeds must reproduce every token).

A fourth section benches **self-speculative decoding** on the hashed
config: the same workload runs with and without a compression-ladder
draft (`repro.serving.spec_decode`), reporting accept rate, tok/s for
both arms, and a bitwise token-identity cross-check (speculation must
never change what the engine emits).

A **sharded** section benches tensor-parallel serving: the same burst
workload runs on a single device and on a host-simulated mesh
(``Engine(mesh=...)``, page pool + attention heads sharded over
"model"), reporting tok/s for both arms, the sharded dispatch
counters, and a bitwise token-identity cross-check.

An **http_traffic** section drives the full asyncio HTTP front-end
(`repro.serving.http`) over a two-model engine sharing one page pool
(quota on the hashed tenant), replaying seeded Poisson and bursty
arrival processes as real streaming HTTP clients — reporting SLO
attainment, goodput, TTFT/e2e and queue-depth percentiles, plus
deterministic completed/429/504 counts and per-model token totals.

A fifth section measures **observability overhead**: the shared-prefix
workload with the span tracer off vs on, reporting the throughput
delta and a bitwise token-identity cross-check (tracing must never
change what the engine emits).  ``--trace-out`` exports the traced
arm's Perfetto file (the CI artifact).

A sixth section benches **batched ragged prefill** under a high
arrival rate: every request lands at t=0 (the burst that used to
serialize one chunk dispatch per request), and the same shared-prefix
workload runs with ``batched_prefill`` on vs off at a prefill budget
wide enough to coalesce — reporting mean/p50 TTFT for both arms, the
fused-dispatch counters, and a bitwise token-identity cross-check.

All counter numbers are workload-only deltas of the engine's metrics
registry (``repro.obs``) — snapshot after warmup, diff at the end —
instead of hand-rolled per-key subtraction.

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke]
        [--trace-out serving_trace.json]

``benchmarks/check_regression.py`` compares the emitted JSON against
the committed ``BENCH_serving.json`` baseline.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

import repro.configs as C
from repro.configs.reduced import reduced
from repro.models import build
from repro.obs import Tracer, diff_snapshots
from repro.serving.api import FINISH_REASONS, SamplingParams
from repro.serving.engine import Engine, Request
from repro.serving.scheduler import SchedulerConfig

# point-in-time gauges: meaningless as workload deltas, dropped from rows
_GAUGES = ("kv.pages_in_use", "kv.pages_free", "sched.queue_depth")


def _workload_delta(eng, base):
    """Registry delta since ``base``, gauges dropped."""
    d = diff_snapshots(eng.metrics.snapshot(), base)
    return {k: v for k, v in d.items() if k not in _GAUGES}


def _configs():
    base = reduced(C.get("qwen3-1.7b")).with_(dtype="float32")
    return [("qwen3-reduced-dense", base),
            ("qwen3-reduced-hashed", base.hashed_variant(0.125))]


def _requests(n: int, vocab: int, max_new: int, arrival_gap_s: float):
    rng = np.random.default_rng(0)
    reqs = []
    for uid in range(n):
        plen = int(rng.integers(4, 24))
        reqs.append((uid * arrival_gap_s, Request(
            uid=uid,
            prompt=rng.integers(2, vocab, size=plen).astype(np.int32),
            max_new_tokens=max_new)))
    return reqs


def bench_level(model, params, cfg, *, concurrency: int, requests: int,
                max_new: int, max_len: int, page_size: int) -> dict:
    eng = Engine(model, params, max_concurrency=concurrency,
                 max_len=max_len, eos_id=-1, page_size=page_size,
                 scheduler=SchedulerConfig(max_queue=max(requests, 1)))
    # warmup: compile prefill buckets + decode before the clock starts
    eng.submit(Request(uid=-1, prompt=np.arange(5, dtype=np.int32) + 2,
                       max_new_tokens=2))
    eng.run()
    eng._done.clear()
    base = eng.metrics.snapshot()   # counter baseline: report deltas

    # offered load: one request per gap, ~2x one row's sustained rate
    gap = 0.0 if requests <= concurrency else 0.01
    schedule = _requests(requests, cfg.vocab_size, max_new, gap)
    t0 = time.perf_counter()
    pending = list(schedule)
    while pending or eng.pending():
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            eng.submit(pending.pop(0)[1])
        eng.step()
    wall = time.perf_counter() - t0
    stats = eng.stats()
    d = _workload_delta(eng, base)
    total_tokens = d["engine.tokens"]
    out = {"concurrency": concurrency, "requests": requests,
           "tokens": total_tokens,
           "wall_s": round(wall, 3),
           "tok_per_s": round(total_tokens / wall, 2),
           "done": stats["done"]}
    # latency/TTFT percentiles come from the workload's request set
    # (warmup requests were dropped from _done above)
    for k in ("latency_p50_s", "latency_p99_s",
              "ttft_p50_s", "ttft_mean_s"):
        if k in stats:
            out[k] = round(stats[k], 4)
    out["metrics"] = d
    return out


def bench_shared_prefix(model, params, cfg, *, concurrency: int,
                        users: int, sys_len: int, tail_len: int,
                        max_new: int, max_len: int, page_size: int,
                        prefill_chunk: int) -> dict:
    """N users x one system prompt, prefix cache on vs off."""
    rng = np.random.default_rng(1)
    sys_prompt = rng.integers(2, cfg.vocab_size,
                              size=sys_len).astype(np.int32)
    prompts = [np.concatenate([sys_prompt, rng.integers(
        2, cfg.vocab_size, size=tail_len).astype(np.int32)])
        for _ in range(users)]

    def run(prefix: bool):        # -> (stats dict, {uid: tokens})
        eng = Engine(model, params, max_concurrency=concurrency,
                     max_len=max_len, eos_id=-1, page_size=page_size,
                     prefix_cache=prefix, prefill_chunk=prefill_chunk,
                     scheduler=SchedulerConfig(max_queue=users + 2))
        # warmup compiles this arm's whole steady state: the cold
        # chunked prefill AND (prefix arm) the hit path — gather +
        # tail-chunk bucket — via a second request sharing the prefix
        warm_tail = np.asarray([2, 3] * (tail_len // 2 + 1),
                               np.int32)[:tail_len]
        for uid, tail in ((-1, warm_tail), (-2, warm_tail[::-1].copy())):
            eng.submit(Request(
                uid=uid, prompt=np.concatenate([sys_prompt, tail]),
                max_new_tokens=2))
        eng.run()
        eng._done.clear()
        # cumulative engine/tree counters include the warmup admissions;
        # report workload-only registry deltas so the headline hit-rate
        # and pages-saved numbers measure the measured requests alone
        base = eng.metrics.snapshot()

        reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
                for i, p in enumerate(prompts)]
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
        eng.run()
        wall = time.perf_counter() - t0
        eng.kv.leak_check()
        stats = eng.stats()
        d = _workload_delta(eng, base)
        tokens = d["engine.tokens"]

        hit = d.get("prefix.hit_tokens", 0)
        miss = d.get("prefix.miss_tokens", 0)
        shared = d.get("kv.pages_shared", 0)
        fresh = d.get("kv.pages_fresh", 0)
        out = {"tok_per_s": round(tokens / wall, 2),
               "wall_s": round(wall, 3),
               "ttft_mean_s": round(stats.get("ttft_mean_s", 0.0), 4),
               "ttft_p50_s": round(stats.get("ttft_p50_s", 0.0), 4),
               "prefix_hit_rate": round(hit / (hit + miss), 4)
               if hit + miss else 0.0,
               "pages_shared": shared,
               "pages_fresh": fresh,
               "pages_saved_frac": round(shared / (shared + fresh), 4)
               if shared + fresh else 0.0,
               "prefill_chunks": d["sched.prefill_chunks"],
               "preemptions": d["engine.preemptions"]}
        return out, {r.uid: list(r.tokens) for r in reqs}

    off, toks_off = run(False)
    on, toks_on = run(True)
    row = {"concurrency": concurrency, "users": users,
           "sys_prompt_len": sys_len, "tail_len": tail_len,
           "max_new": max_new, "prefill_chunk": prefill_chunk,
           "off": off, "on": on,
           "tokens_match": toks_on == toks_off,
           "pages_saved_frac": on["pages_saved_frac"],
           "ttft_speedup": round(off["ttft_mean_s"]
                                 / max(on["ttft_mean_s"], 1e-9), 3)}
    print(f"shared-prefix @ c={concurrency}: saved "
          f"{100 * row['pages_saved_frac']:.0f}% pages, hit rate "
          f"{on['prefix_hit_rate']:.2f}, ttft {off['ttft_mean_s']:.3f}s "
          f"-> {on['ttft_mean_s']:.3f}s "
          f"({row['ttft_speedup']}x), match={row['tokens_match']}")
    return row


def bench_mixed_sampling(model, params, cfg, *, concurrency: int,
                         requests: int, max_new: int, max_len: int,
                         page_size: int) -> dict:
    """Greedy + seeded top-p + stop-sequence rows in ONE decode batch.

    Measures the fused sampler's overhead (one dispatch per decode tick
    however the batch mixes SamplingParams) and cross-checks seeded
    determinism: a second run with identical seeds must reproduce every
    token.
    """
    rng = np.random.default_rng(2)
    reqs_spec = []
    for uid in range(requests):
        plen = int(rng.integers(4, 20))
        prompt = rng.integers(2, cfg.vocab_size,
                              size=plen).astype(np.int32)
        kind = ("greedy", "top_p", "stop")[uid % 3]
        if kind == "greedy":
            sp = SamplingParams(max_tokens=max_new)
        elif kind == "top_p":
            sp = SamplingParams(temperature=0.8, top_p=0.9, top_k=64,
                                max_tokens=max_new, seed=1000 + uid)
        else:   # sampled with a 1-token stop sequence (may trigger)
            sp = SamplingParams(temperature=1.0, top_p=0.95,
                                max_tokens=max_new, seed=1000 + uid,
                                stop=((int(rng.integers(
                                    2, cfg.vocab_size)),),))
        reqs_spec.append((prompt, sp, kind))

    def run():
        eng = Engine(model, params, max_concurrency=concurrency,
                     max_len=max_len, eos_id=-1, page_size=page_size,
                     scheduler=SchedulerConfig(max_queue=requests + 2))
        # warmup: compile prefill buckets + decode + the sampler
        # variants the workload will hit (all-greedy ticks dispatch the
        # with_sampling=False specialization, mixed ticks the full one)
        eng.submit(Request(uid=-1, prompt=np.arange(6, dtype=np.int32) + 2,
                           sampling=SamplingParams(temperature=0.7,
                                                   top_p=0.9, top_k=64,
                                                   max_tokens=2,
                                                   seed=0)))
        eng.submit(Request(uid=-2, prompt=np.arange(5, dtype=np.int32) + 2,
                           sampling=SamplingParams(max_tokens=2)))
        eng.run()
        eng.submit(Request(uid=-3, prompt=np.arange(7, dtype=np.int32) + 2,
                           sampling=SamplingParams(max_tokens=2)))
        eng.run()                  # an all-greedy batch, alone
        eng._done.clear()
        base = eng.metrics.snapshot()  # counter baseline: report deltas
        t0 = time.perf_counter()
        for uid, (prompt, sp, _) in enumerate(reqs_spec):
            eng.submit(Request(uid=uid, prompt=prompt.copy(),
                               sampling=sp))
        done = eng.run()
        wall = time.perf_counter() - t0
        d = _workload_delta(eng, base)
        ticks = d["engine.ticks"]
        sampler_s = d["sampler.dispatch_s"]["sum"]
        toks = {r.uid: list(r.tokens) for r in done}
        return {"tok_per_s": round(d["engine.tokens"] / wall, 2),
                "wall_s": round(wall, 3),
                "ticks": ticks,
                "sampler_time_s": round(sampler_s, 4),
                "sampler_ms_per_tick": round(1e3 * sampler_s
                                             / max(ticks, 1), 3),
                "sampler_frac": round(sampler_s / wall, 4),
                "sampler_dispatches": {
                    k: d[f"sampler.dispatches.{k}"]
                    for k in ("prefill", "decode")},
                "finish_reasons": {
                    k: d[f"engine.finish.{k}"]
                    for k in FINISH_REASONS}}, toks

    a, toks_a = run()
    _, toks_b = run()
    row = {"concurrency": concurrency, "requests": requests,
           "max_new": max_new,
           "mix": [k for _, _, k in reqs_spec],
           "deterministic_rerun": toks_a == toks_b}
    row.update(a)
    print(f"mixed-sampling @ c={concurrency}: {a['tok_per_s']} tok/s, "
          f"sampler {a['sampler_ms_per_tick']}ms/tick "
          f"({100 * a['sampler_frac']:.1f}% of wall), finish "
          f"{a['finish_reasons']}, rerun-identical="
          f"{row['deterministic_rerun']}")
    return row


def bench_spec_decode(model, params, cfg, *, concurrency: int,
                      requests: int, max_new: int, max_len: int,
                      page_size: int, spec_k: int,
                      draft_policy: str) -> dict:
    """Self-speculative decoding: spec on vs off, same workload.

    The draft is the compression-policy variant named by
    ``draft_policy``, derived off the served params (shared hash seeds;
    at the config's own ratio the banks alias by reference, so the
    draft is the base and every proposal verifies — the deterministic
    upper bound on accept rate).  Reports accept rate, tok/s both arms,
    and a bitwise token-identity cross-check — speculation must never
    change what the engine emits, only how fast it emits it.
    """
    from repro.serving.draft import build_draft
    _, dmodel, dparams = build_draft(cfg, params, draft_policy)

    rng = np.random.default_rng(4)
    reqs_spec = []
    for uid in range(requests):
        plen = int(rng.integers(4, 20))
        prompt = rng.integers(2, cfg.vocab_size,
                              size=plen).astype(np.int32)
        sp = SamplingParams(max_tokens=max_new) if uid % 3 else \
            SamplingParams(temperature=0.8, top_p=0.9, top_k=64,
                           max_tokens=max_new, seed=2000 + uid)
        reqs_spec.append((prompt, sp))

    def run(draft):
        eng = Engine(model, params, max_concurrency=concurrency,
                     max_len=max_len, eos_id=-1, page_size=page_size,
                     draft=draft, spec_k=spec_k,
                     scheduler=SchedulerConfig(max_queue=requests + 2))
        # Steady-state measurement: the first pass over the workload
        # pays every jit specialization its sampler mix and batch widths
        # dispatch — and the spec arm has strictly more shapes to
        # compile (propose/verify variants on top of the sampler
        # blocks).  Warm with the *full* workload, then time a clean
        # second pass, so neither arm is billed for compiles.
        for uid, (prompt, sp) in enumerate(reqs_spec):
            eng.submit(Request(uid=uid, prompt=prompt.copy(),
                               sampling=sp))
        eng.run()
        eng._done.clear()
        base = eng.metrics.snapshot()
        t0 = time.perf_counter()
        for uid, (prompt, sp) in enumerate(reqs_spec):
            eng.submit(Request(uid=uid, prompt=prompt.copy(),
                               sampling=sp))
        done = eng.run()
        wall = time.perf_counter() - t0
        d = _workload_delta(eng, base)
        toks = {r.uid: list(r.tokens) for r in done}
        spec_stats = None
        if eng.spec is not None:
            # per-pass accept stats from the registry delta, not the
            # decoder's lifetime counters (those include the warm pass)
            spec_stats = {
                "accept_rate": d["spec.accepted_drafts"]
                / max(d["spec.proposed"], 1),
                "mean_accept_len": d["spec.accept_len"]["mean"],
                "draft_dispatches": d["spec.draft_dispatches"],
                "verify_dispatches": d["spec.verify_dispatches"]}
        return (round(d["engine.tokens"] / wall, 2), toks, spec_stats, d)

    base_tps, toks_base, _, _ = run(None)
    spec_tps, toks_spec, spec_stats, d = run((dmodel, dparams))
    row = {"concurrency": concurrency, "requests": requests,
           "max_new": max_new, "spec_k": spec_k,
           "draft_policy": draft_policy,
           "tokens_match": toks_base == toks_spec,
           "baseline_tok_s": base_tps,
           "spec_tok_s": spec_tps,
           "speedup": round(spec_tps / base_tps, 3) if base_tps else 0.0,
           "accept_rate": round(spec_stats["accept_rate"], 4),
           "mean_accept_len": round(spec_stats["mean_accept_len"], 3),
           "draft_dispatches": spec_stats["draft_dispatches"],
           "verify_dispatches": spec_stats["verify_dispatches"]}
    print(f"spec-decode @ c={concurrency} k={spec_k} "
          f"draft={draft_policy}: {base_tps} -> {spec_tps} tok/s "
          f"({row['speedup']}x), accept {row['accept_rate']:.2f} "
          f"(mean len {row['mean_accept_len']:.2f}), "
          f"match={row['tokens_match']}")
    return row


def bench_obs_overhead(model, params, cfg, *, concurrency: int,
                       users: int, sys_len: int, tail_len: int,
                       max_new: int, max_len: int, page_size: int,
                       prefill_chunk: int,
                       trace_out: str = None) -> dict:
    """Tracer off vs on over the shared-prefix workload (the busiest
    instrumented path: chunked prefill + prefix hits + COW + decode).

    Acceptance target: < 3% tok/s regression with full tracing.  Also
    cross-checks that tracing is bitwise inert (same tokens) and, with
    ``trace_out``, exports the traced arm for Perfetto (CI artifact).
    """
    rng = np.random.default_rng(3)
    sys_prompt = rng.integers(2, cfg.vocab_size,
                              size=sys_len).astype(np.int32)
    prompts = [np.concatenate([sys_prompt, rng.integers(
        2, cfg.vocab_size, size=tail_len).astype(np.int32)])
        for _ in range(users)]

    def run(trace: bool):
        tracer = Tracer(enabled=trace)
        eng = Engine(model, params, max_concurrency=concurrency,
                     max_len=max_len, eos_id=-1, page_size=page_size,
                     prefix_cache=True, prefill_chunk=prefill_chunk,
                     tracer=tracer,
                     scheduler=SchedulerConfig(max_queue=users + 2))
        warm_tail = np.asarray([2, 3] * (tail_len // 2 + 1),
                               np.int32)[:tail_len]
        for uid, tail in ((-1, warm_tail), (-2, warm_tail[::-1].copy())):
            eng.submit(Request(
                uid=uid, prompt=np.concatenate([sys_prompt, tail]),
                max_new_tokens=2))
        eng.run()
        eng._done.clear()
        reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
                for i, p in enumerate(prompts)]
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
        eng.run()
        wall = time.perf_counter() - t0
        tokens = sum(len(r.tokens) for r in reqs)
        return (round(tokens / wall, 2),
                {r.uid: list(r.tokens) for r in reqs}, tracer)

    tps_off, toks_off, _ = run(False)
    tps_on, toks_on, tracer = run(True)
    if trace_out:
        tracer.export(trace_out)
        print(f"wrote trace -> {os.path.abspath(trace_out)}")
    overhead = round((tps_off - tps_on) / tps_off, 4) if tps_off else 0.0
    row = {"concurrency": concurrency, "users": users,
           "sys_prompt_len": sys_len, "tail_len": tail_len,
           "max_new": max_new, "prefill_chunk": prefill_chunk,
           "tok_per_s_trace_off": tps_off,
           "tok_per_s_trace_on": tps_on,
           "overhead_frac": overhead,
           "trace_events": len(tracer.events),
           "tokens_match": toks_on == toks_off}
    print(f"obs overhead @ c={concurrency}: {tps_off} tok/s untraced -> "
          f"{tps_on} tok/s traced ({100 * overhead:+.1f}%), "
          f"{row['trace_events']} events, match={row['tokens_match']}")
    return row


def bench_prefill_batch(model, params, cfg, *, concurrency: int,
                        users: int, sys_len: int, tail_len: int,
                        max_new: int, max_len: int, page_size: int,
                        prefill_chunk: int) -> dict:
    """High-arrival-rate TTFT: batched ragged prefill on vs off.

    All requests arrive at t=0 (a burst) and the prefill budget
    (``max_prefills_per_tick``) covers the whole batch, so the batched
    arm coalesces every row's chunk into one ragged dispatch per tick
    while the sequential arm pays one dispatch per row per tick — the
    serialization this section exists to measure.  Shared-prefix
    prompts keep the prefix cache in the loop (COW tail resolution on
    the batched path).  Cross-checks bitwise token identity between
    arms and reports the fused-dispatch counters.
    """
    rng = np.random.default_rng(5)
    sys_prompt = rng.integers(2, cfg.vocab_size,
                              size=sys_len).astype(np.int32)
    prompts = [np.concatenate([sys_prompt, rng.integers(
        2, cfg.vocab_size, size=tail_len).astype(np.int32)])
        for _ in range(users)]

    def run(batched: bool):
        eng = Engine(model, params, max_concurrency=concurrency,
                     max_len=max_len, eos_id=-1, page_size=page_size,
                     prefix_cache=True, prefill_chunk=prefill_chunk,
                     batched_prefill=batched,
                     scheduler=SchedulerConfig(
                         max_queue=users + 2,
                         max_prefills_per_tick=concurrency))
        # warmup compiles the arm's steady state: cold chunked prefill,
        # the prefix-hit path, and (batched arm) the ragged dispatch
        # widths the burst will hit
        warm_tail = np.asarray([2, 3] * (tail_len // 2 + 1),
                               np.int32)[:tail_len]
        for uid in range(-concurrency, 0):
            tail = warm_tail if uid % 2 else warm_tail[::-1].copy()
            eng.submit(Request(
                uid=uid, prompt=np.concatenate([sys_prompt, tail]),
                max_new_tokens=2))
        eng.run()
        eng._done.clear()
        base = eng.metrics.snapshot()

        reqs = [Request(uid=i, prompt=p.copy(), max_new_tokens=max_new)
                for i, p in enumerate(prompts)]
        t0 = time.perf_counter()
        for r in reqs:               # the burst: all requests at t=0
            eng.submit(r)
        eng.run()
        wall = time.perf_counter() - t0
        eng.kv.leak_check()
        stats = eng.stats()
        d = _workload_delta(eng, base)
        out = {"tok_per_s": round(d["engine.tokens"] / wall, 2),
               "wall_s": round(wall, 3),
               "ttft_mean_s": round(stats.get("ttft_mean_s", 0.0), 4),
               "ttft_p50_s": round(stats.get("ttft_p50_s", 0.0), 4),
               "prefill_chunks": d["sched.prefill_chunks"],
               "prefill_batch_dispatches":
                   d.get("engine.prefill_batch.dispatches", 0),
               "prefill_batch_rows":
                   d.get("engine.prefill_batch.rows", 0),
               "prefill_batch_tokens":
                   d.get("engine.prefill_batch.tokens", 0),
               "fallback_chunks":
                   d.get("engine.prefill_batch.fallback_chunks", 0)}
        return out, {r.uid: list(r.tokens) for r in reqs}

    off, toks_off = run(False)
    on, toks_on = run(True)
    row = {"concurrency": concurrency, "users": users,
           "sys_prompt_len": sys_len, "tail_len": tail_len,
           "max_new": max_new, "prefill_chunk": prefill_chunk,
           "off": off, "on": on,
           "tokens_match": toks_on == toks_off,
           "prefill_batch_dispatches": on["prefill_batch_dispatches"],
           "prefill_batch_rows": on["prefill_batch_rows"],
           "fallback_chunks": on["fallback_chunks"],
           "ttft_speedup": round(off["ttft_mean_s"]
                                 / max(on["ttft_mean_s"], 1e-9), 3)}
    print(f"prefill-batch @ c={concurrency}: ttft "
          f"{off['ttft_mean_s']:.3f}s -> {on['ttft_mean_s']:.3f}s "
          f"({row['ttft_speedup']}x), "
          f"{on['prefill_batch_dispatches']} fused dispatches / "
          f"{on['prefill_batch_rows']} row-chunks, "
          f"match={row['tokens_match']}")
    return row


def bench_sharded(model, params, cfg, *, concurrency: int, requests: int,
                  max_new: int, max_len: int, page_size: int) -> dict:
    """Tensor-parallel serving: mesh engine vs single device.

    The same burst workload (greedy + seeded top-p rows) runs once
    without a mesh and once with ``Engine(mesh=...)`` sharding the page
    pool and attention heads over the "model" axis; reports tok/s for
    both arms, the sharded dispatch counters, and the section's reason
    to exist: a bitwise token-identity cross-check (head-sharding with
    an exact all-gather must never change what the engine emits).

    CI provides the devices via a host-simulated mesh
    (``XLA_FLAGS=--xla_force_host_platform_device_count=8``); with one
    device the section records the skip instead of failing.
    """
    ndev = jax.device_count()
    tp = next((t for t in (8, 4, 2)
               if t <= ndev and cfg.num_heads % t == 0
               and cfg.num_kv_heads % t == 0), 1)
    if ndev < 2 or tp < 2:
        print(f"sharded: skipped (devices={ndev}, usable tp={tp})")
        return {"skipped": True, "devices": ndev, "tp": tp}
    from repro.launch.mesh import make_serving_mesh
    mesh = make_serving_mesh(tp)

    rng = np.random.default_rng(6)
    reqs_spec = []
    for uid in range(requests):
        plen = int(rng.integers(4, 20))
        prompt = rng.integers(2, cfg.vocab_size,
                              size=plen).astype(np.int32)
        sp = SamplingParams(max_tokens=max_new) if uid % 2 else \
            SamplingParams(temperature=0.8, top_p=0.9, top_k=64,
                           max_tokens=max_new, seed=3000 + uid)
        reqs_spec.append((prompt, sp))

    def run(m):
        eng = Engine(model, params, max_concurrency=concurrency,
                     max_len=max_len, eos_id=-1, page_size=page_size,
                     mesh=m,
                     scheduler=SchedulerConfig(max_queue=requests + 2))
        # warmup compiles the prefill buckets + decode + both sampler
        # specializations (all-greedy and mixed ticks)
        eng.submit(Request(uid=-1, prompt=np.arange(6, dtype=np.int32) + 2,
                           sampling=SamplingParams(
                               temperature=0.7, top_p=0.9, top_k=64,
                               max_tokens=2, seed=0)))
        eng.submit(Request(uid=-2, prompt=np.arange(5, dtype=np.int32) + 2,
                           max_new_tokens=2))
        eng.run()
        eng._done.clear()
        base = eng.metrics.snapshot()
        t0 = time.perf_counter()
        for uid, (prompt, sp) in enumerate(reqs_spec):
            eng.submit(Request(uid=uid, prompt=prompt.copy(),
                               sampling=sp))
        done = eng.run()
        wall = time.perf_counter() - t0
        d = _workload_delta(eng, base)
        return (round(d["engine.tokens"] / wall, 2), round(wall, 3),
                {r.uid: list(r.tokens) for r in done}, d)

    single_tps, single_wall, toks_single, _ = run(None)
    shard_tps, shard_wall, toks_shard, d = run(mesh)
    row = {"devices": mesh.size, "tp": tp,
           "concurrency": concurrency, "requests": requests,
           "max_new": max_new,
           "tokens_match": toks_single == toks_shard,
           "single_tok_s": single_tps, "sharded_tok_s": shard_tps,
           "single_wall_s": single_wall, "sharded_wall_s": shard_wall,
           "shard_decode_dispatches":
               d.get("engine.shard.decode_dispatches", 0),
           "shard_prefill_dispatches":
               d.get("engine.shard.prefill_dispatches", 0)}
    print(f"sharded tp={tp} over {mesh.size} devices: {single_tps} tok/s "
          f"single -> {shard_tps} tok/s sharded, "
          f"{row['shard_decode_dispatches']} decode + "
          f"{row['shard_prefill_dispatches']} prefill dispatches, "
          f"match={row['tokens_match']}")
    return row


def bench_http_traffic(dense_pack, hashed_pack, *, requests: int,
                       max_new: int, max_len: int, page_size: int,
                       quota_pages: int, burst_size: int) -> dict:
    """Arrival-process traffic through the full HTTP stack.

    Hosts the dense + hashed configs on one ``MultiModelEngine``
    (shared page pool, quota on the hashed tenant) behind the asyncio
    front-end, then replays two seeded arrival processes as real
    streaming HTTP clients:

    - **poisson** — exponential inter-arrivals at a fixed offered rate
      (the steady-state mixed-tenant load), and
    - **bursty** — all-at-once bursts of ``burst_size`` (the worst-case
      admission/queueing pattern).

    Per run: SLO attainment (client-side TTFT + e2e against fixed
    SLOs), goodput (tokens from SLO-met requests only), TTFT/e2e
    percentiles, queue-depth percentiles sampled during the run, and
    the deterministic accounting — completed / 429-rejected /
    504-expired counts and per-model token totals (greedy + fixed
    ``max_tokens``: exact counters the regression gate holds TIGHT).
    """
    import asyncio

    from repro.serving.http import HTTPFrontend
    from repro.serving.http import client as http_client
    from repro.serving.multi_model import MultiModelEngine

    model, params, cfg = dense_pack
    hmodel, hparams, hcfg = hashed_pack
    names = ("qwen3-reduced-dense", "qwen3-reduced-hashed")
    slo_ttft_s, slo_e2e_s = 2.0, 20.0

    def _arrivals(kind):
        """Seeded (t_arrive, model, prompt, seq) schedule."""
        rng = np.random.default_rng(7 if kind == "poisson" else 8)
        rate_rps = 40.0
        out, t = [], 0.0
        for i in range(requests):
            if kind == "poisson":
                t += float(rng.exponential(1.0 / rate_rps))
            else:
                t = (i // burst_size) * 0.25
            plen = int(rng.integers(4, 16))
            prompt = [int(x) for x in
                      rng.integers(2, cfg.vocab_size, size=plen)]
            out.append((t, names[int(rng.integers(0, 2))], prompt, i))
        return rate_rps, out

    async def _one_run(kind):
        mm = MultiModelEngine(page_size=page_size,
                              scheduler=SchedulerConfig(
                                  max_queue=requests + 4))
        mm.add_model(names[0], model, params, slots=4, max_len=max_len,
                     eos_id=-1, seed=0)
        mm.add_model(names[1], hmodel, hparams, slots=4,
                     max_len=max_len, eos_id=-1, seed=0,
                     page_quota=quota_pages)
        fe = HTTPFrontend(mm, port=0, default_model=names[0])
        await fe.start()
        # warmup: compile both models' prefill + decode off the clock
        for nm in names:
            await http_client.request(
                fe.host, fe.port, "POST", "/v1/completions",
                dict(model=nm, prompt=[2, 3, 4, 5], max_tokens=2,
                     temperature=0.0))

        rate_rps, sched = _arrivals(kind)
        loop = asyncio.get_running_loop()
        depths, stop = [], asyncio.Event()

        async def _sample_depth():
            while not stop.is_set():
                depths.append(len(mm.sched))
                await asyncio.sleep(0.004)

        async def _client(t_arrive, mdl, prompt, seq, t0):
            await asyncio.sleep(max(0.0, t_arrive - (loop.time() - t0)))
            payload = dict(model=mdl, prompt=prompt,
                           max_tokens=max_new, temperature=0.0)
            try:
                r = await http_client.collect_stream(
                    fe.host, fe.port, payload)
            except http_client.HTTPStreamError as e:
                return {"model": mdl, "status": e.status}
            return {"model": mdl, "status": 200,
                    "tokens": len(r["tokens"]),
                    "ttft_s": r["ttft_s"], "e2e_s": r["e2e_s"]}

        sampler = asyncio.create_task(_sample_depth())
        t0 = loop.time()
        results = await asyncio.gather(
            *(_client(*spec, t0) for spec in sched))
        wall = loop.time() - t0
        stop.set()
        await sampler
        await fe.aclose()

        ok = [r for r in results if r["status"] == 200]
        met = [r for r in ok
               if r["ttft_s"] is not None and r["ttft_s"] <= slo_ttft_s
               and r["e2e_s"] <= slo_e2e_s]
        per_model = {nm: sum(r["tokens"] for r in ok
                             if r["model"] == nm) for nm in names}
        total = sum(per_model.values())
        pct = lambda xs, q: round(  # noqa: E731
            float(np.percentile(xs, q)), 4) if xs else 0.0
        row = {"arrival": kind, "requests": requests,
               "rate_rps": rate_rps if kind == "poisson" else None,
               "bursts": None if kind == "poisson"
               else -(-requests // burst_size),
               "burst_size": None if kind == "poisson" else burst_size,
               "max_new": max_new, "models": list(names),
               "quota_pages": quota_pages,
               "slo_ttft_s": slo_ttft_s, "slo_e2e_s": slo_e2e_s,
               "completed": len(ok),
               "rejected_429": sum(1 for r in results
                                   if r["status"] == 429),
               "expired_504": sum(1 for r in results
                                  if r["status"] == 504),
               "per_model_tokens": per_model,
               "slo_attainment": round(len(met) / max(len(ok), 1), 4),
               "goodput_tok_s": round(
                   sum(r["tokens"] for r in met) / wall, 2),
               "tok_per_s": round(total / wall, 2),
               "wall_s": round(wall, 3),
               "ttft_p50_s": pct([r["ttft_s"] for r in ok], 50),
               "ttft_p99_s": pct([r["ttft_s"] for r in ok], 99),
               "e2e_p50_s": pct([r["e2e_s"] for r in ok], 50),
               "e2e_p99_s": pct([r["e2e_s"] for r in ok], 99),
               "queue_depth_p50": pct(depths, 50),
               "queue_depth_p95": pct(depths, 95)}
        print(f"http_traffic/{kind}: {row['completed']}/{requests} ok, "
              f"{row['tok_per_s']} tok/s, slo {row['slo_attainment']}, "
              f"goodput {row['goodput_tok_s']} tok/s, "
              f"ttft p99 {row['ttft_p99_s']}s, "
              f"qdepth p95 {row['queue_depth_p95']}")
        return row

    return {"runs": [asyncio.run(_one_run("poisson")),
                     asyncio.run(_one_run("bursty"))]}


def main(smoke: bool = False, out_json: str = "BENCH_serving.json",
         trace_out: str = None) -> dict:
    levels = (1, 2, 4) if smoke else (1, 4, 8)
    requests = 6 if smoke else 24
    max_new = 8 if smoke else 24
    results = {"smoke": smoke, "levels": list(levels), "configs": []}
    dense = None                 # (model, params) reused for shared-prefix
    hashed = None                # (model, params) reused for spec-decode
    for tag, cfg in _configs():
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        if dense is None:
            dense = (model, params, cfg)
        if cfg.hashed:
            hashed = (model, params, cfg)
        rows = []
        for c in levels:
            r = bench_level(model, params, cfg, concurrency=c,
                            requests=requests, max_new=max_new,
                            max_len=128, page_size=16)
            print(f"{tag} @ concurrency {c}: {r['tok_per_s']} tok/s, "
                  f"p50 {r.get('latency_p50_s', '-')}s "
                  f"p99 {r.get('latency_p99_s', '-')}s")
            rows.append(r)
        results["configs"].append({"name": tag,
                                   "hashed": bool(cfg.hashed),
                                   "levels": rows})
    # shared-prefix workload on the dense config (the prefix cache is
    # model-agnostic; one arm suffices to track the trajectory)
    model, params, cfg = dense
    results["shared_prefix"] = bench_shared_prefix(
        model, params, cfg, concurrency=8,
        users=8 if smoke else 16,
        sys_len=48 if smoke else 64, tail_len=8,
        max_new=4 if smoke else 16, max_len=128, page_size=16,
        prefill_chunk=32)
    # mixed-sampling workload (fused sampler: greedy + top-p + stop
    # rows share one batch, one dispatch per tick)
    results["mixed_sampling"] = bench_mixed_sampling(
        model, params, cfg, concurrency=4,
        requests=6 if smoke else 18,
        max_new=6 if smoke else 20, max_len=128, page_size=16)
    # self-speculative decoding on the hashed config: the draft is the
    # policy ladder's own rung (equal ratio -> banks alias, proposals
    # verify deterministically — the free-draft upper bound).  Low
    # concurrency + decode-heavy requests is the regime speculation is
    # for: each verified block replaces k+1 per-token dispatches, and
    # at small batch the baseline has no batching to amortize against.
    hmodel, hparams, hcfg = hashed
    results["spec_decode"] = bench_spec_decode(
        hmodel, hparams, hcfg, concurrency=2,
        requests=6 if smoke else 18,
        max_new=24, max_len=128, page_size=16,
        spec_k=4, draft_policy="1/8")
    # batched ragged prefill: burst arrival, batched on vs off
    results["prefill_batch"] = bench_prefill_batch(
        model, params, cfg, concurrency=8,
        users=8 if smoke else 16,
        sys_len=48 if smoke else 64, tail_len=8,
        max_new=4 if smoke else 16, max_len=128, page_size=16,
        prefill_chunk=16)
    # tensor-parallel serving: mesh vs single device, same workload
    # (needs a multi-device host mesh; records the skip otherwise)
    results["sharded"] = bench_sharded(
        model, params, cfg, concurrency=4,
        requests=6 if smoke else 12,
        max_new=6 if smoke else 16, max_len=128, page_size=16)
    # HTTP traffic harness: both configs behind the asyncio front-end
    # on one multi-model engine, seeded Poisson + bursty arrivals
    results["http_traffic"] = bench_http_traffic(
        dense, hashed,
        requests=8 if smoke else 20,
        max_new=4 if smoke else 8, max_len=128, page_size=16,
        quota_pages=40, burst_size=4)
    # observability overhead: tracer off vs on, same workload
    results["obs_overhead"] = bench_obs_overhead(
        model, params, cfg, concurrency=8,
        users=8 if smoke else 16,
        sys_len=48 if smoke else 64, tail_len=8,
        max_new=4 if smoke else 16, max_len=128, page_size=16,
        prefill_chunk=32, trace_out=trace_out)
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {os.path.abspath(out_json)}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="export the traced obs-overhead arm as Chrome "
                         "trace-event JSON (open in Perfetto)")
    a = ap.parse_args()
    main(smoke=a.smoke, out_json=a.out, trace_out=a.trace_out)
