"""Serving benchmark: continuous batching under increasing offered load.

For one dense and one hashed config (reduced qwen), drives the paged
continuous-batching engine at several concurrency levels and records

- tokens/s (decode throughput over the whole run),
- p50/p99 request latency (submit -> finish, includes queueing),
- p50 time-to-first-token, preemptions, pages in flight,

then writes ``BENCH_serving.json`` so the serving perf trajectory is
tracked in CI next to the policy and artifact benches.  Requests arrive
open-loop on a deterministic schedule (offered load ~ 2x what one row
sustains, so queueing pressure grows with the request count, and p99
spreads from p50 as concurrency saturates).

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

import repro.configs as C
from repro.configs.reduced import reduced
from repro.models import build
from repro.serving.engine import Engine, Request
from repro.serving.scheduler import SchedulerConfig


def _configs():
    base = reduced(C.get("qwen3-1.7b")).with_(dtype="float32")
    return [("qwen3-reduced-dense", base),
            ("qwen3-reduced-hashed", base.hashed_variant(0.125))]


def _requests(n: int, vocab: int, max_new: int, arrival_gap_s: float):
    rng = np.random.default_rng(0)
    reqs = []
    for uid in range(n):
        plen = int(rng.integers(4, 24))
        reqs.append((uid * arrival_gap_s, Request(
            uid=uid,
            prompt=rng.integers(2, vocab, size=plen).astype(np.int32),
            max_new_tokens=max_new)))
    return reqs


def bench_level(model, params, cfg, *, concurrency: int, requests: int,
                max_new: int, max_len: int, page_size: int) -> dict:
    eng = Engine(model, params, max_concurrency=concurrency,
                 max_len=max_len, eos_id=-1, page_size=page_size,
                 scheduler=SchedulerConfig(max_queue=max(requests, 1)))
    # warmup: compile prefill buckets + decode before the clock starts
    eng.submit(Request(uid=-1, prompt=np.arange(5, dtype=np.int32) + 2,
                       max_new_tokens=2))
    eng.run()
    eng._done.clear()

    # offered load: one request per gap, ~2x one row's sustained rate
    gap = 0.0 if requests <= concurrency else 0.01
    schedule = _requests(requests, cfg.vocab_size, max_new, gap)
    t0 = time.time()
    pending = list(schedule)
    while pending or len(eng.sched) or any(r is not None for r in eng.rows):
        now = time.time() - t0
        while pending and pending[0][0] <= now:
            eng.submit(pending.pop(0)[1])
        eng.step()
    wall = time.time() - t0
    stats = eng.stats()
    total_tokens = stats.pop("tokens")
    out = {"concurrency": concurrency, "requests": requests,
           "tokens": total_tokens,
           "wall_s": round(wall, 3),
           "tok_per_s": round(total_tokens / wall, 2)}
    out.update({k: round(v, 4) if isinstance(v, float) else v
                for k, v in stats.items()})
    return out


def main(smoke: bool = False, out_json: str = "BENCH_serving.json") -> dict:
    levels = (1, 2, 4) if smoke else (1, 4, 8)
    requests = 6 if smoke else 24
    max_new = 8 if smoke else 24
    results = {"smoke": smoke, "levels": list(levels), "configs": []}
    for tag, cfg in _configs():
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rows = []
        for c in levels:
            r = bench_level(model, params, cfg, concurrency=c,
                            requests=requests, max_new=max_new,
                            max_len=128, page_size=16)
            print(f"{tag} @ concurrency {c}: {r['tok_per_s']} tok/s, "
                  f"p50 {r.get('latency_p50_s', '-')}s "
                  f"p99 {r.get('latency_p99_s', '-')}s")
            rows.append(r)
        results["configs"].append({"name": tag,
                                   "hashed": bool(cfg.hashed),
                                   "levels": rows})
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {os.path.abspath(out_json)}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI")
    ap.add_argument("--out", default="BENCH_serving.json")
    a = ap.parse_args()
    main(smoke=a.smoke, out_json=a.out)
