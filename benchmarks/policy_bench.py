"""Compression-policy benchmark: uniform vs budget-solved policies.

For the paper's own MLP config (``hashmlp``) and one transformer config,
measures under (a) the uniform flat-knob compression and (b) an
equal-memory budget-solved policy (attention pinned, solver reallocating
the remainder):

- real parameter count per policy, and its error vs the requested
  equal-memory target (the budget solver's acceptance metric),
- training-step throughput in tokens/s (jitted loss+grad, the hot path
  both launchers drive),

and writes ``BENCH_policy.json`` so the perf trajectory of the policy
API is tracked in CI.

    PYTHONPATH=src python -m benchmarks.policy_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro import policy as POL
from repro.configs.reduced import reduced
from repro.models import build
from repro.models.transformer import bank_spec_map

BUDGET = 1 / 8


def _budget_policy():
    return POL.CompressionPolicy(
        budget=BUDGET,
        panel_cols=0,   # match the uniform variant's bucket space so the
                        # timing difference is the allocation, not panels
        rules=(
            # pin attention coarse; the solver pushes FFN below 1/8 to
            # keep the TOTAL on the equal-memory target
            POL.PolicyRule(match="*attn*", compression=1 / 4),
        ))


def _configs(smoke: bool):
    mlp = C.get("hashmlp-3layer")
    tfm = reduced(C.get("qwen3-1.7b"))
    if smoke:
        mlp = mlp.with_(d_model=256, d_ff=256, name="hashmlp-3layer-smoke")
    return [("hashmlp", mlp), ("qwen3-reduced", tfm)]


def _real_params(cfg) -> int:
    m = build(cfg)
    shapes = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))


def _bank_totals(cfg):
    specs = bank_spec_map(cfg)
    virtual = sum(s.virtual_size for s in specs.values())
    real = sum(s.real_param_count() for s in specs.values())
    return virtual, real


def _tokens_per_s(cfg, *, batch: int, seq: int, steps: int) -> float:
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch_arrays = {
        "tokens": jnp.asarray(rng.integers(
            0, cfg.vocab_size, (batch, seq)), jnp.int32),
        "targets": jnp.asarray(rng.integers(
            0, cfg.vocab_size, (batch, seq)), jnp.int32),
    }

    @jax.jit
    def step(p, b):
        (loss, _), grads = jax.value_and_grad(
            m.train_loss, has_aux=True)(p, b)
        return loss, grads

    loss, grads = step(params, batch_arrays)        # compile
    jax.block_until_ready(loss)
    t0 = time.perf_counter()
    for _ in range(steps):
        loss, grads = step(params, batch_arrays)
    jax.block_until_ready(loss)
    dt = (time.perf_counter() - t0) / steps
    return batch * seq / dt


def bench_one(tag: str, cfg, *, smoke: bool) -> dict:
    batch, seq = (2, 32) if smoke else (8, 128)
    steps = 2 if smoke else 10
    budgeted_policy = _budget_policy()

    variants = {
        "uniform": cfg.hashed_variant(BUDGET).with_(hash_panel_cols=0),
        "budget": cfg.policy_variant(budgeted_policy).with_(
            hash_panel_cols=0),
    }
    out = {"config": cfg.name, "budget": BUDGET, "variants": {}}
    for name, vcfg in variants.items():
        virtual, bank_real = _bank_totals(vcfg)
        target = BUDGET * virtual
        tps = _tokens_per_s(vcfg, batch=batch, seq=seq, steps=steps)
        out["variants"][name] = {
            "name": vcfg.name,
            "bank_virtual_params": int(virtual),
            "bank_real_params": int(bank_real),
            "budget_target": int(target),
            "budget_error": round(abs(bank_real - target) / target, 5),
            "model_real_params": int(_real_params(vcfg)),
            "train_tokens_per_s": round(tps, 1),
        }
        print(f"[{tag}:{name}] banks {bank_real:,}/{virtual:,} real/virt "
              f"(target {int(target):,}, "
              f"err {out['variants'][name]['budget_error']:.3%}) "
              f"{tps:,.0f} tok/s", flush=True)
    return out


def main(smoke: bool = False, out_json: str = "BENCH_policy.json") -> dict:
    t0 = time.time()
    results = {"budget": BUDGET, "smoke": smoke, "configs": {}}
    for tag, cfg in _configs(smoke):
        results["configs"][tag] = bench_one(tag, cfg, smoke=smoke)
    results["wall_s"] = round(time.time() - t0, 1)
    # acceptance: both policies hold the equal-memory budget within 1%
    worst = max(v["budget_error"]
                for c in results["configs"].values()
                for v in c["variants"].values())
    results["worst_budget_error"] = worst
    ok = worst <= 0.01
    print(f"\nworst equal-memory error: {worst:.3%} "
          f"({'OK (within 1%)' if ok else 'EXCEEDS 1%'})")
    if out_json:
        os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {out_json}")
    return results


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="CI profile: tiny shapes, 2 timed steps")
    p.add_argument("--out", default="BENCH_policy.json")
    args = p.parse_args()
    main(smoke=args.smoke, out_json=args.out)
